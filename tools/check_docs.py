#!/usr/bin/env python3
"""Docs link check: fail CI when docs mention paths that no longer exist.

Scans the markdown files under docs/ (plus README.md and ROADMAP.md) for

  * repo-relative path references (rust/..., python/..., docs/...,
    examples/..., tools/...), optionally suffixed ``:line`` or
    ``:line-line`` — the suffix is stripped before checking;
  * rust module paths (``crate::a::b`` / ``adapmoe::a::b``), resolved
    against rust/src/<a>/<b>.rs, rust/src/<a>/<b>/mod.rs or
    rust/src/<a>.rs (longest-prefix match, so paths that go below module
    granularity, e.g. ``crate::mod::Item``, still resolve).

Also cross-checks the CI workflow: every ``cargo test --test NAME`` step
in .github/workflows/rust.yml must have a matching rust/tests/NAME.rs,
so a renamed or deleted integration suite fails this check instead of
silently passing a step that tests nothing.

Exits non-zero listing every reference that does not resolve, so a
refactor that moves or deletes a module forces the matching docs update
(docs/architecture.md is the main consumer).

Usage: python3 tools/check_docs.py  (from anywhere inside the repo)
"""

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ["README.md", "ROADMAP.md"] + sorted(
    os.path.join("docs", f)
    for f in os.listdir(os.path.join(REPO, "docs"))
    if f.endswith(".md")
)

# path-ish tokens rooted at a known top-level dir
PATH_RE = re.compile(
    r"\b((?:rust|python|docs|examples|tools)/[A-Za-z0-9_./-]+)"
)
# rust module paths
MOD_RE = re.compile(r"\b(?:crate|adapmoe)((?:::[A-Za-z0-9_]+)+)")

# line-number suffix on a path ref: file.rs:123 or file.rs:123-130
LINE_SUFFIX_RE = re.compile(r":\d+(?:-\d+)?$")

WORKFLOW = os.path.join(".github", "workflows", "rust.yml")

# named integration-suite steps in CI: cargo test [...] --test NAME
TEST_STEP_RE = re.compile(r"--test\s+([A-Za-z0-9_-]+)")


def path_exists(rel: str) -> bool:
    return os.path.exists(os.path.join(REPO, rel))


def check_path(tok: str):
    """Return the normalized path if it resolves, else None."""
    tok = tok.rstrip(".,;:)`'\"")
    tok = LINE_SUFFIX_RE.sub("", tok)
    if not tok or "/" not in tok:
        return tok or None
    # globs and placeholders aren't checkable references
    if "*" in tok or "{" in tok or "<" in tok:
        return tok
    return tok if path_exists(tok) else None


def check_module(segs):
    """Resolve crate::a::b::... against rust/src, longest prefix first."""
    for cut in range(len(segs), 0, -1):
        head = segs[:cut]
        candidates = [
            os.path.join("rust", "src", *head) + ".rs",
            os.path.join("rust", "src", *head, "mod.rs"),
        ]
        if any(path_exists(c) for c in candidates):
            return True
        # items/types below module granularity: try shorter prefixes
    return False


def check_workflow_tests():
    """Every --test NAME step in CI must resolve to rust/tests/NAME.rs."""
    missing = []
    full = os.path.join(REPO, WORKFLOW)
    if not os.path.exists(full):
        return missing
    with open(full, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            for m in TEST_STEP_RE.finditer(line):
                rel = os.path.join("rust", "tests", m.group(1) + ".rs")
                if not path_exists(rel):
                    missing.append((WORKFLOW, lineno, rel))
    return missing


def main() -> int:
    missing = check_workflow_tests()
    for doc in DOC_FILES:
        full = os.path.join(REPO, doc)
        if not os.path.exists(full):
            continue
        with open(full, encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, 1):
                for m in PATH_RE.finditer(line):
                    if check_path(m.group(1)) is None:
                        missing.append((doc, lineno, m.group(1)))
                for m in MOD_RE.finditer(line):
                    segs = [s for s in m.group(1).split("::") if s]
                    # skip obvious non-module idioms like crate::prop_assert
                    if len(segs) >= 1 and not check_module(segs):
                        missing.append((doc, lineno, "crate" + m.group(1)))
    if missing:
        print("docs link check FAILED — stale references:")
        for doc, lineno, tok in missing:
            print(f"  {doc}:{lineno}: {tok}")
        return 1
    print(
        f"docs link check OK ({len(DOC_FILES)} files + {WORKFLOW} scanned)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
