#!/usr/bin/env python3
"""Validate a flight-recorder Chrome trace (Perfetto JSON) export.

Checks, in order:

  1. the file parses as JSON and carries a non-empty ``traceEvents`` array;
  2. within each (pid, tid) row, ``X`` spans nest properly: sorted by
     start time, a span that begins inside an open span must also end
     inside it (a small epsilon absorbs µs rounding from the ns journal);
  3. the transfer lifecycle conserves: every ``complete`` event's
     correlation id (``args.id``) also appears on an ``enqueue`` event;
  4. ``process_name`` metadata covers every configured lane and device
     track (``--lanes N`` / ``--devices D``), so a renamed or dropped
     track fails loudly instead of rendering an anonymous row.

Exits non-zero listing every violation. CI runs this on the trace the
rust/tests/obs.rs drain writes to rust/target/obs_trace.json.

Usage: python3 tools/check_trace.py TRACE.json --lanes 4 --devices 2
"""

import argparse
import json
import sys
from collections import defaultdict

# µs of slack when comparing span edges: the journal stamps ns, the
# Chrome export rounds to fractional µs.
EPS = 0.005


def check_nesting(events, errors):
    rows = defaultdict(list)
    for ev in events:
        if ev.get("ph") == "X":
            rows[(ev.get("pid"), ev.get("tid"))].append(ev)
    for (pid, tid), spans in sorted(rows.items()):
        spans.sort(key=lambda e: (e["ts"], -e.get("dur", 0.0)))
        stack = []  # (name, start, end)
        for ev in spans:
            start, end = ev["ts"], ev["ts"] + ev.get("dur", 0.0)
            while stack and start >= stack[-1][2] - EPS:
                stack.pop()
            if stack and end > stack[-1][2] + EPS:
                errors.append(
                    f"pid={pid} tid={tid}: span '{ev['name']}' "
                    f"[{start:.3f}, {end:.3f}] overflows enclosing "
                    f"'{stack[-1][0]}' [{stack[-1][1]:.3f}, {stack[-1][2]:.3f}]"
                )
            stack.append((ev["name"], start, end))


def check_lifecycle(events, errors):
    enqueued = set()
    completes = []
    for ev in events:
        if ev.get("ph") == "M":
            continue
        corr = ev.get("args", {}).get("id")
        if ev.get("name") == "enqueue":
            enqueued.add(corr)
        elif ev.get("name") == "complete":
            completes.append(corr)
    for corr in completes:
        if corr not in enqueued:
            errors.append(f"complete id={corr} has no matching enqueue")
    if completes and not enqueued:
        errors.append("trace has completes but no enqueues at all")


def check_tracks(events, n_lanes, n_devices, errors):
    names = {
        ev.get("args", {}).get("name")
        for ev in events
        if ev.get("ph") == "M" and ev.get("name") == "process_name"
    }
    expected = ["decode", "server", "remote"]
    expected += [f"lane {i}" for i in range(n_lanes)]
    expected += [f"device {d}" for d in range(n_devices)]
    for want in expected:
        if want not in names:
            errors.append(f"missing process_name metadata for track '{want}'")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--lanes", type=int, default=1, help="configured lane count")
    ap.add_argument("--devices", type=int, default=1, help="configured device count")
    args = ap.parse_args()

    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_trace: cannot load {args.trace}: {e}", file=sys.stderr)
        return 1

    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        print("check_trace: no traceEvents array", file=sys.stderr)
        return 1

    errors = []
    check_nesting(events, errors)
    check_lifecycle(events, errors)
    check_tracks(events, args.lanes, args.devices, errors)

    if errors:
        for e in errors:
            print(f"check_trace: {e}", file=sys.stderr)
        print(f"check_trace: {len(errors)} violation(s) in {args.trace}", file=sys.stderr)
        return 1
    n_spans = sum(1 for e in events if e.get("ph") == "X")
    n_inst = sum(1 for e in events if e.get("ph") == "i")
    print(
        f"check_trace: OK — {len(events)} entries "
        f"({n_spans} spans, {n_inst} instants) in {args.trace}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
