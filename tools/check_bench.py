#!/usr/bin/env python3
"""Bench-regression guard for the expert-major hot path.

Compares the freshly refreshed rust/BENCH_hotpath.json (written in place
by ``cargo bench --bench micro``) against the committed copy
(``git show HEAD:rust/BENCH_hotpath.json``). Fails when any row's
expert-major speedup fell more than ``--tolerance`` (default 10%) below
the committed value — a structural slowdown in the batched compute or
coalesced transfer path shows up here even while correctness tests stay
green. Speedups may freely improve; only regressions fail.

Rows are matched on (batch, lanes). A row present in the committed table
but missing from the refreshed one is an error (silent coverage loss);
new rows in the refreshed table are ignored.

Usage: python3 tools/check_bench.py [--file rust/BENCH_hotpath.json]
                                    [--tolerance 0.10]
"""

import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def rows_by_key(doc):
    return {(r["batch"], r["lanes"]): r for r in doc["rows"]}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--file", default="rust/BENCH_hotpath.json")
    ap.add_argument("--tolerance", type=float, default=0.10)
    args = ap.parse_args()

    path = os.path.join(REPO, args.file)
    try:
        with open(path) as f:
            fresh = rows_by_key(json.load(f))
    except (OSError, ValueError, KeyError) as e:
        print(f"check_bench: cannot load {args.file}: {e}", file=sys.stderr)
        return 1

    try:
        committed_text = subprocess.run(
            ["git", "show", f"HEAD:{args.file}"],
            cwd=REPO,
            capture_output=True,
            text=True,
            check=True,
        ).stdout
        committed = rows_by_key(json.loads(committed_text))
    except (subprocess.CalledProcessError, ValueError, KeyError) as e:
        print(f"check_bench: cannot load committed {args.file}: {e}", file=sys.stderr)
        return 1

    failures = []
    for key, base in sorted(committed.items()):
        row = fresh.get(key)
        if row is None:
            failures.append(f"row batch={key[0]} lanes={key[1]} vanished from {args.file}")
            continue
        floor = base["speedup"] * (1.0 - args.tolerance)
        if row["speedup"] < floor:
            failures.append(
                f"batch={key[0]} lanes={key[1]}: speedup {row['speedup']:.3f} "
                f"fell below {floor:.3f} (committed {base['speedup']:.3f} "
                f"- {args.tolerance:.0%})"
            )
        else:
            print(
                f"check_bench: batch={key[0]} lanes={key[1]} speedup "
                f"{row['speedup']:.3f} vs committed {base['speedup']:.3f} — ok"
            )

    if failures:
        for f_ in failures:
            print(f"check_bench: {f_}", file=sys.stderr)
        return 1
    print(f"check_bench: OK — {len(committed)} rows within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
