"""L1 Pallas kernel: tiled SwiGLU expert FFN — AdapMoE's compute hot-spot.

One call computes a single expert's contribution for a (padded) batch of
tokens routed to it:

    y = coef[:, None] * ((silu(x @ w1) * (x @ w3)) @ w2)

The grid iterates over tiles of the FFN hidden dimension `f`. Each step
stages one (d × f_blk) slice of w1/w3 and one (f_blk × d) slice of w2 from
HBM into VMEM, runs two MXU matmuls + the SwiGLU elementwise, and
accumulates the down-projection into the output block. This mirrors the
paper's tile-wise scheduling (§5, Fig. 6): on a real TPU, tile j's compute
overlaps tile j+1's HBM→VMEM stream, exactly like the paper overlaps expert
tile PCIe transfers with CUDA compute.

TPU sizing (tiny config, f32): per-step VMEM = x (B·d) + w1,w3 (2·d·f_blk)
+ w2 (f_blk·d) + acc (B·d); with d=128, f_blk=128, B≤8 that is ~0.2 MiB —
far under the ~16 MiB VMEM budget, and the 128-wide tiles are MXU-aligned.
See DESIGN.md §Perf for the utilization estimate.

`interpret=True` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_f_block(d_ff: int) -> int:
    """Largest MXU-friendly tile (≤256) that divides d_ff."""
    for cand in (256, 128, 64, 32, 16, 8):
        if d_ff % cand == 0:
            return cand
    return d_ff


def _ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, coef_ref, o_ref):
    """One grid step: accumulate this f-tile's down-projection into o.

    Block shapes: x [B, d] (whole), w1/w3 [d, f_blk], w2 [f_blk, d],
    coef [B] (whole), o [B, d] (whole, accumulated across grid steps).
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    a = x @ w1_ref[...]            # [B, f_blk]  gate proj (MXU)
    b = x @ w3_ref[...]            # [B, f_blk]  up proj (MXU)
    h = a * (1.0 / (1.0 + jnp.exp(-a))) * b   # SwiGLU (VPU)
    # coef is linear in the output, so scaling each partial sum is exact.
    o_ref[...] += coef_ref[...][:, None] * (h @ w2_ref[...])


def expert_ffn(x, w1, w3, w2, coef, *, f_block: int | None = None,
               interpret: bool = True):
    """Pallas-tiled SwiGLU expert FFN. See module docstring.

    x [B, d], w1 [d, f], w3 [d, f], w2 [f, d], coef [B] -> [B, d]
    """
    B, d = x.shape
    f = w1.shape[1]
    assert w1.shape == (d, f) and w3.shape == (d, f) and w2.shape == (f, d)
    assert coef.shape == (B,)
    f_blk = f_block or _pick_f_block(f)
    assert f % f_blk == 0, f"f_block {f_blk} must divide d_ff {f}"
    grid = (f // f_blk,)

    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, d), lambda j: (0, 0)),        # x: resident
            pl.BlockSpec((d, f_blk), lambda j: (0, j)),    # w1 tile j
            pl.BlockSpec((d, f_blk), lambda j: (0, j)),    # w3 tile j
            pl.BlockSpec((f_blk, d), lambda j: (j, 0)),    # w2 tile j
            pl.BlockSpec((B,), lambda j: (0,)),            # coef: resident
        ],
        out_specs=pl.BlockSpec((B, d), lambda j: (0, 0)),  # o: accumulated
        out_shape=jax.ShapeDtypeStruct((B, d), x.dtype),
        interpret=interpret,
    )(x, w1, w3, w2, coef)


@functools.partial(jax.jit, static_argnames=("f_block",))
def expert_ffn_jit(x, w1, w3, w2, coef, f_block=None):
    return expert_ffn(x, w1, w3, w2, coef, f_block=f_block)
