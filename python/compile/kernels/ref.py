"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground-truth definitions the kernels (and the rust runtime,
transitively) are tested against. Keep them boring and obviously correct.
"""

import jax.numpy as jnp


def silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def expert_ffn_ref(x, w1, w3, w2, coef):
    """SwiGLU expert FFN, scaled per-row by `coef`.

    x:    [B, d]   MoE-block input (already RMSNormed)
    w1:   [d, f]   gate projection
    w3:   [d, f]   up projection
    w2:   [f, d]   down projection
    coef: [B]      per-row routing weight (0 for rows not routed here)

    returns [B, d] = coef[:, None] * ((silu(x @ w1) * (x @ w3)) @ w2)
    """
    h = silu(x @ w1) * (x @ w3)
    return coef[:, None] * (h @ w2)


def rmsnorm_ref(x, w, eps=1e-5):
    """RMSNorm over the last axis. x: [..., d], w: [d]."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * w


def softmax_ref(logits):
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def gate_ref(x, wg):
    """Router probabilities. x: [B, d] (normed), wg: [d, N] -> [B, N]."""
    return softmax_ref(x @ wg)
