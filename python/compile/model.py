"""L2: Mixtral-architecture MoE decoder in JAX.

Two usage modes:

1. **Training / profiling** (`forward_seq`, `loss_fn`): whole-sequence
   teacher-forced forward with dense-weighted top-k MoE — used by train.py
   and profile_offline.py at build time.

2. **Serving components** (`embed_step`, `attn_step`, `gate_step`,
   `pre_gate_step`, `unembed_step`, `dense_step`): per-decode-step functions
   with explicit weight arguments, each AOT-lowered to its own HLO artifact
   by aot.py. The rust L3 coordinator composes them and owns the residual
   stream, so it can schedule each expert's `expert_ffn` call against the
   expert cache / transfer engine (that is the whole point of AdapMoE).

All expert math funnels through the L1 Pallas kernel
(`kernels.expert_ffn.expert_ffn`), so the serving HLO contains the tiled
kernel, and training/serving share one definition.
"""

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .kernels.expert_ffn import expert_ffn
from .kernels.ref import rmsnorm_ref, softmax_ref

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Initialize all model parameters as a flat name->array dict.

    Flat naming (layer index embedded in the key) matches the weights.bin
    container read by rust/src/model/weights.rs.
    """
    rng = np.random.default_rng(seed)
    d, f, N = cfg.d_model, cfg.d_ff, cfg.n_experts

    def dense(shape, scale=None):
        scale = scale if scale is not None else (1.0 / np.sqrt(shape[0]))
        return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)

    p: Params = {
        "embed": dense((cfg.vocab_size, d), 0.02),
        "out_norm": jnp.ones((d,), jnp.float32),
        "unembed": dense((d, cfg.vocab_size)),
        # predictive gate for layer 0 (paper §4.3, eq. 9) — trained separately
        "pre_gate": dense((d, N)),
    }
    for i in range(cfg.n_layers):
        p[f"l{i}.attn_norm"] = jnp.ones((d,), jnp.float32)
        p[f"l{i}.wq"] = dense((d, d))
        p[f"l{i}.wk"] = dense((d, d))
        p[f"l{i}.wv"] = dense((d, d))
        p[f"l{i}.wo"] = dense((d, d))
        p[f"l{i}.moe_norm"] = jnp.ones((d,), jnp.float32)
        p[f"l{i}.gate"] = dense((d, N))
        for e in range(N):
            p[f"l{i}.e{e}.w1"] = dense((d, f))
            p[f"l{i}.e{e}.w3"] = dense((d, f))
            p[f"l{i}.e{e}.w2"] = dense((f, d))
    return p


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def rope_angles(cfg: ModelConfig, pos):
    """pos [...,] int32 -> cos/sin tables [..., head_dim/2]."""
    hd = cfg.head_dim
    inv_freq = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = pos[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., hd], cos/sin broadcastable [..., hd/2] — rotate (even, odd) pairs."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1)
    return out.reshape(x.shape)


def topk_mask(probs, k: int):
    """0/1 mask of the k largest entries along the last axis.

    Implemented as k rounds of masked max rather than jnp.sort: selection is
    non-differentiable anyway (the threshold sits under stop_gradient), and
    this image's jaxlib cannot differentiate through lax.sort (its gather
    lowering predates operand_batching_dims).
    """
    masked = probs
    thresh = None
    for _ in range(k):
        thresh = jnp.max(masked, axis=-1, keepdims=True)
        masked = jnp.where(masked >= thresh, -jnp.inf, masked)
    return (probs >= jax.lax.stop_gradient(thresh)).astype(probs.dtype)


def _moe_dense_mix(cfg: ModelConfig, params: Params, layer: int, xn,
                   use_kernel: bool = False):
    """Dense weighted top-k MoE over a [T, d] batch of normed inputs.

    Mixes every expert with renormalized top-k gate probabilities.
    use_kernel=True routes through the L1 Pallas kernel (serving artifacts);
    training/profiling use the jnp oracle because pallas_call's program_id
    has no JVP rule on this jax build — the two are assert_allclose-equal in
    python/tests/test_kernel.py, so gradients are identical.
    Returns (mix [T, d], probs [T, N]).
    """
    from .kernels.ref import expert_ffn_ref

    N, K = cfg.n_experts, cfg.top_k
    ffn = expert_ffn if use_kernel else expert_ffn_ref
    probs = softmax_ref(xn @ params[f"l{layer}.gate"])          # [T, N]
    # top-k mask + renormalization (Mixtral semantics)
    wk = probs * topk_mask(probs, K)
    wk = wk / jnp.sum(wk, axis=-1, keepdims=True)
    mix = jnp.zeros_like(xn)
    for e in range(N):
        mix = mix + ffn(
            xn,
            params[f"l{layer}.e{e}.w1"],
            params[f"l{layer}.e{e}.w3"],
            params[f"l{layer}.e{e}.w2"],
            wk[:, e],
        )
    return mix, probs


# ---------------------------------------------------------------------------
# Training-mode whole-sequence forward
# ---------------------------------------------------------------------------

def forward_seq(cfg: ModelConfig, params: Params, tokens, *, collect=False):
    """Teacher-forced forward over tokens [B, S] -> logits [B, S, V].

    collect=True additionally returns per-layer MoE-block inputs (for the
    cross-layer similarity study, Fig. 3) and gate probs (Fig. 2 / α_i).
    """
    B, S = tokens.shape
    d = cfg.d_model
    h = params["embed"][tokens]                                  # [B, S, d]
    pos = jnp.arange(S, dtype=jnp.int32)
    cos, sin = rope_angles(cfg, pos)                             # [S, hd/2]
    causal = jnp.tril(jnp.ones((S, S), jnp.bool_))

    moe_inputs: List[jnp.ndarray] = []
    gate_probs: List[jnp.ndarray] = []

    for i in range(cfg.n_layers):
        # -- attention ------------------------------------------------------
        xn = rmsnorm_ref(h, params[f"l{i}.attn_norm"], cfg.rms_eps)
        q = (xn @ params[f"l{i}.wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        k = (xn @ params[f"l{i}.wk"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        v = (xn @ params[f"l{i}.wv"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos[None, :, None, :], sin[None, :, None, :])
        k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(cfg.head_dim)
        att = jnp.where(causal[None, None], att, -1e30)
        att = softmax_ref(att)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B, S, d)
        h = h + o @ params[f"l{i}.wo"]

        # -- MoE FFN --------------------------------------------------------
        if collect:
            moe_inputs.append(h)
        xn = rmsnorm_ref(h, params[f"l{i}.moe_norm"], cfg.rms_eps)
        flat = xn.reshape(B * S, d)
        mix, probs = _moe_dense_mix(cfg, params, i, flat)
        if collect:
            gate_probs.append(probs.reshape(B, S, cfg.n_experts))
        h = h + mix.reshape(B, S, d)

    hn = rmsnorm_ref(h, params["out_norm"], cfg.rms_eps)
    logits = hn @ params["unembed"]
    if collect:
        return logits, {"moe_inputs": moe_inputs, "gate_probs": gate_probs,
                        "final": hn}
    return logits


def loss_fn(cfg: ModelConfig, params: Params, tokens, aux_coef: float):
    """Next-token CE + Switch-style load-balancing auxiliary loss."""
    logits, extras = forward_seq(cfg, params, tokens[:, :-1], collect=True)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(logp, targets[..., None], axis=-1))

    aux = 0.0
    N, K = cfg.n_experts, cfg.top_k
    for probs in extras["gate_probs"]:                # [B, S, N]
        p = probs.reshape(-1, N)
        # fraction of tokens whose top-k includes expert e
        sel = topk_mask(p, K)
        frac_tokens = jnp.mean(sel, axis=0) / K
        frac_probs = jnp.mean(p, axis=0)
        aux = aux + N * jnp.sum(frac_tokens * frac_probs)
    aux = aux / cfg.n_layers
    return ce + aux_coef * aux, (ce, aux)


# ---------------------------------------------------------------------------
# Serving components (one HLO artifact each; weights are ARGUMENTS)
# ---------------------------------------------------------------------------
# The rust engine owns the residual stream h [B, d] and the KV cache, and
# calls these in sequence per decode step. Expert FFN calls are issued per
# *expert* through the Pallas kernel, which is what lets L3 overlap expert
# transfers with compute.

def embed_step(tokens, embed):
    """tokens [B] int32, embed [V, d] -> h [B, d]."""
    return embed[tokens]


def attn_step(cfg: ModelConfig, h, attn_norm, wq, wk, wv, wo,
              k_cache, v_cache, pos):
    """One decode step of causal attention with RoPE + KV cache.

    h [B, d]; k_cache/v_cache [B, H, S, hd]; pos [B] int32 (index of the
    current token for each row — rows may be at different positions under
    continuous batching). Returns (h + attn_out, k_cache', v_cache').
    """
    B, d = h.shape
    H, S, hd = cfg.n_heads, cfg.max_seq, cfg.head_dim
    xn = rmsnorm_ref(h, attn_norm, cfg.rms_eps)
    q = (xn @ wq).reshape(B, H, hd)
    k = (xn @ wk).reshape(B, H, hd)
    v = (xn @ wv).reshape(B, H, hd)
    cos, sin = rope_angles(cfg, pos)                   # [B, hd/2]
    q = apply_rope(q, cos[:, None, :], sin[:, None, :])
    k = apply_rope(k, cos[:, None, :], sin[:, None, :])

    def upd(cache_b, val_b, p):
        # cache_b [H, S, hd], val_b [H, hd]
        return jax.lax.dynamic_update_slice(cache_b, val_b[:, None, :], (0, p, 0))

    k_cache = jax.vmap(upd)(k_cache, k, pos)
    v_cache = jax.vmap(upd)(v_cache, v, pos)

    att = jnp.einsum("bhd,bhsd->bhs", q, k_cache) / np.sqrt(hd)
    valid = jnp.arange(S)[None, :] <= pos[:, None]     # [B, S]
    att = jnp.where(valid[:, None, :], att, -1e30)
    att = softmax_ref(att)
    o = jnp.einsum("bhs,bhsd->bhd", att, v_cache).reshape(B, d)
    return h + o @ wo, k_cache, v_cache


def gate_step(cfg: ModelConfig, h, moe_norm, wg):
    """h [B, d] -> (probs [B, N], xn [B, d]).

    xn is the RMSNormed MoE-block input that the expert kernel consumes;
    probs drive routing, adaptive gating, and (applied with the *next*
    layer's wg) adaptive prefetching.
    """
    xn = rmsnorm_ref(h, moe_norm, cfg.rms_eps)
    return softmax_ref(xn @ wg), xn


def pre_gate_step(cfg: ModelConfig, h, out_norm, wpre):
    """Predictive gate for layer 0 (paper eq. 9).

    h [B, d] is the *unnormed* final residual of the previous token (what the
    serving engine naturally holds after the last layer); the final RMSNorm
    is folded in here so the serving path matches the training distribution
    (train.py fits W_pre on normed final activations).
    """
    return softmax_ref(rmsnorm_ref(h, out_norm, cfg.rms_eps) @ wpre)


def unembed_step(cfg: ModelConfig, h, out_norm, unembed):
    """h [B, d] -> logits [B, V]."""
    return rmsnorm_ref(h, out_norm, cfg.rms_eps) @ unembed


def dense_step(cfg: ModelConfig, params: Params, tokens, k_caches, v_caches, pos):
    """Monolithic single-step decode over ALL layers with dense top-k MoE.

    The no-offloading reference: used by rust integration tests to check the
    composed component path, and as the 'all weights resident' latency
    reference. k_caches/v_caches: [L, B, H, S, hd].
    """
    h = embed_step(tokens, params["embed"])
    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        h, kc, vc = attn_step(
            cfg, h, params[f"l{i}.attn_norm"], params[f"l{i}.wq"],
            params[f"l{i}.wk"], params[f"l{i}.wv"], params[f"l{i}.wo"],
            k_caches[i], v_caches[i], pos)
        new_k.append(kc)
        new_v.append(vc)
        probs, xn = gate_step(cfg, h, params[f"l{i}.moe_norm"], params[f"l{i}.gate"])
        wk_ = probs * topk_mask(probs, cfg.top_k)
        wk_ = wk_ / jnp.sum(wk_, axis=-1, keepdims=True)
        for e in range(cfg.n_experts):
            h = h + expert_ffn(
                xn,
                params[f"l{i}.e{e}.w1"],
                params[f"l{i}.e{e}.w3"],
                params[f"l{i}.e{e}.w2"],
                wk_[:, e],
            )
    logits = unembed_step(cfg, h, params["out_norm"], params["unembed"])
    return logits, jnp.stack(new_k), jnp.stack(new_v)
