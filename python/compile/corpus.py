"""Synthetic multi-domain byte corpus for build-time training.

MMLU/ARC and the Mixtral pretraining mix are unavailable offline; the
substitution (DESIGN.md) is a deterministic mixture of structurally distinct
"domains" so the trained router has something real to specialize on — which
is exactly the property AdapMoE's gate-score-skew and sensitivity analyses
depend on. Domains interleave in paragraphs, mimicking multi-domain
pretraining data.
"""

import numpy as np

DOMAINS = ["arith", "json", "english", "dna", "brackets", "code"]

_WORDS = (
    "the of and to in is that it for on with as are this be at or from by "
    "we you they model expert gate layer cache token load fetch memory fast "
    "slow system paper result method test value state run time new old"
).split()

_IDENTS = "xyzabcij"


def _gen_arith(rng: np.random.Generator, n: int) -> bytes:
    out = []
    while sum(len(s) for s in out) < n:
        a, b = rng.integers(0, 100, 2)
        op = rng.choice(["+", "-", "*"])
        r = {"+": a + b, "-": a - b, "*": a * b}[op]
        out.append(f"{a}{op}{b}={r};")
    return "".join(out).encode()[:n]


def _gen_json(rng: np.random.Generator, n: int) -> bytes:
    out = []
    while sum(len(s) for s in out) < n:
        k = rng.choice(_WORDS)
        v = rng.integers(0, 1000)
        out.append('{"%s":%d,"ok":%s}' % (k, v, "true" if rng.random() < 0.5 else "false"))
    return "".join(out).encode()[:n]


def _gen_english(rng: np.random.Generator, n: int) -> bytes:
    out = []
    while sum(len(s) + 1 for s in out) < n:
        ln = rng.integers(4, 12)
        out.append(" ".join(rng.choice(_WORDS, ln)) + ".")
    return " ".join(out).encode()[:n]


def _gen_dna(rng: np.random.Generator, n: int) -> bytes:
    return rng.choice([65, 67, 71, 84], n).astype(np.uint8).tobytes()  # ACGT


def _gen_brackets(rng: np.random.Generator, n: int) -> bytes:
    """Balanced bracket sequences — forces stack-like structure."""
    out, depth = [], 0
    pairs = [("(", ")"), ("[", "]"), ("{", "}")]
    stack = []
    while len(out) < n:
        if depth > 0 and (depth > 8 or rng.random() < 0.45):
            out.append(stack.pop())
            depth -= 1
        else:
            o, c = pairs[rng.integers(0, 3)]
            out.append(o)
            stack.append(c)
            depth += 1
    return "".join(out).encode()[:n]


def _gen_code(rng: np.random.Generator, n: int) -> bytes:
    out = []
    while sum(len(s) for s in out) < n:
        a, b = rng.choice(list(_IDENTS), 2)
        v = rng.integers(0, 256)
        out.append(f"let {a}={b}+{v};\n")
    return "".join(out).encode()[:n]


_GENS = {
    "arith": _gen_arith,
    "json": _gen_json,
    "english": _gen_english,
    "dna": _gen_dna,
    "brackets": _gen_brackets,
    "code": _gen_code,
}


def generate_corpus(total_bytes: int, seed: int = 0, para: int = 256) -> bytes:
    """Deterministic interleaved multi-domain corpus of `total_bytes`."""
    rng = np.random.default_rng(seed)
    chunks = []
    size = 0
    while size < total_bytes:
        dom = DOMAINS[rng.integers(0, len(DOMAINS))]
        c = _GENS[dom](rng, para) + b"\n"
        chunks.append(c)
        size += len(c)
    return b"".join(chunks)[:total_bytes]


def split_corpus(total_bytes: int, eval_bytes: int, seed: int = 0):
    """(train_bytes, eval_bytes) — eval is a held-out tail with a fresh seed
    so sequences never overlap the training stream."""
    train = generate_corpus(total_bytes, seed=seed)
    evald = generate_corpus(eval_bytes, seed=seed + 1)
    return train, evald


def sample_batch(data: np.ndarray, rng: np.random.Generator, batch: int, seq: int):
    """Random contiguous windows -> int32 [batch, seq+1] (inputs+target)."""
    starts = rng.integers(0, len(data) - seq - 1, batch)
    return np.stack([data[s: s + seq + 1] for s in starts]).astype(np.int32)
