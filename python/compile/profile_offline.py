"""Offline profiling pass (paper Fig. 4 'offline phase').

From the trained model and a sample of the corpus, measure everything the
online system needs as priors, plus the raw material for Figs. 2/3/9:

  * per-layer gate-score stats (top-1 normalized score mean/histogram)
  * cross-layer MoE-input cosine similarity (Observation 2 / Fig. 3)
  * per-layer Fisher sensitivity (from train.fisher_sensitivity)
  * α_i  — single-expert activation probability at the calibrated threshold
  * β_i  — prefetch accuracy per layer (gate-reuse for i>0, predictive gate
           for layer 0)
  * threshold calibration: T such that the mean single-expert ratio hits a
    target (the paper deploys 24%)

Everything is computed with the *training-mode* forward on whole sequences —
identical math to the serving path (shared components), enormously faster
than stepping the AOT path in python.
"""

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, TrainConfig
from .corpus import sample_batch
from .kernels.ref import rmsnorm_ref, softmax_ref
from .model import Params, forward_seq


def collect_traces(cfg: ModelConfig, params: Params, data: np.ndarray,
                   seed: int, batches: int = 8, batch: int = 8, seq: int = 96):
    """Run the model over samples; return stacked per-layer traces.

    Returns dict with:
      gate_probs   [L, T, N]  router probabilities per layer/token
      moe_inputs   [L, T, d]  residual-stream inputs to each MoE block
      final        [T, d]     final normed activations (for the pre-gate)
      tokens       [T]        flattened token stream (aligned with traces)
    """
    rng = np.random.default_rng(seed)
    fwd = jax.jit(lambda p, t: forward_seq(cfg, p, t, collect=True))
    gp, mi, fin = [], [], []
    for _ in range(batches):
        tokens = jnp.asarray(sample_batch(data, rng, batch, seq)[:, :-1])
        _, extras = fwd(params, tokens)
        gp.append(np.stack([np.asarray(g).reshape(-1, cfg.n_experts)
                            for g in extras["gate_probs"]]))
        mi.append(np.stack([np.asarray(m).reshape(-1, cfg.d_model)
                            for m in extras["moe_inputs"]]))
        fin.append(np.asarray(extras["final"]).reshape(-1, cfg.d_model))
    return {
        "gate_probs": np.concatenate(gp, axis=1),   # [L, T, N]
        "moe_inputs": np.concatenate(mi, axis=1),   # [L, T, d]
        "final": np.concatenate(fin, axis=0),       # [T, d]
        "batch": batch, "seq": seq,
    }


# ---------------------------------------------------------------------------
# Observation studies
# ---------------------------------------------------------------------------

def top1_score_stats(gate_probs: np.ndarray) -> Dict:
    """Fig. 2(a): per-layer stats of the *normalized* top-1 score α.

    α = p1 / (p1 + p2) — the top-1 share of the top-2 mass, the exact α in
    paper eq. 3.
    """
    sorted_p = np.sort(gate_probs, axis=-1)
    p1, p2 = sorted_p[..., -1], sorted_p[..., -2]
    alpha = p1 / (p1 + p2 + 1e-12)                   # [L, T]
    hist = [np.histogram(a, bins=20, range=(0.5, 1.0))[0].tolist()
            for a in alpha]
    return {
        "alpha_mean": alpha.mean(axis=1).tolist(),
        "alpha_p90": np.percentile(alpha, 90, axis=1).tolist(),
        "alpha_hist20": hist,
    }


def cross_layer_similarity(moe_inputs: np.ndarray) -> list:
    """Fig. 3: mean cosine similarity between MoE input of layer i and i+1."""
    sims = []
    for i in range(moe_inputs.shape[0] - 1):
        a, b = moe_inputs[i], moe_inputs[i + 1]
        num = np.sum(a * b, -1)
        den = np.linalg.norm(a, axis=-1) * np.linalg.norm(b, axis=-1) + 1e-12
        sims.append(float(np.mean(num / den)))
    return sims


# ---------------------------------------------------------------------------
# Adaptive gating calibration (paper eq. 8)
# ---------------------------------------------------------------------------

def single_expert_mask(gate_probs: np.ndarray, sensitivity: np.ndarray,
                       threshold: float) -> np.ndarray:
    """(1-α)² · S_i ≤ T  -> bool [L, T] (True = activate only top-1)."""
    sorted_p = np.sort(gate_probs, axis=-1)
    p1, p2 = sorted_p[..., -1], sorted_p[..., -2]
    alpha = p1 / (p1 + p2 + 1e-12)
    return (1.0 - alpha) ** 2 * sensitivity[:, None] <= threshold


def calibrate_threshold(gate_probs: np.ndarray, sensitivity: np.ndarray,
                        target_ratio: float = 0.24) -> float:
    """Binary-search T so the mean single-expert ratio hits target_ratio."""
    lo, hi = 0.0, float(sensitivity.max()) + 1e-6
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        r = single_expert_mask(gate_probs, sensitivity, mid).mean()
        if r < target_ratio:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def per_layer_alpha(gate_probs: np.ndarray, sensitivity: np.ndarray,
                    threshold: float) -> np.ndarray:
    """α_i of the DP formulation: P(layer i activates a single expert)."""
    return single_expert_mask(gate_probs, sensitivity, threshold).mean(axis=1)


# ---------------------------------------------------------------------------
# Prefetch accuracy β_i (paper §4.3 / Fig. 9(b))
# ---------------------------------------------------------------------------

def prefetch_accuracy(cfg: ModelConfig, params: Params,
                      traces: Dict, wpre: np.ndarray) -> np.ndarray:
    """β_i: fraction of layer-i top-2 experts found in the prefetch set.

    Layer 0: predicted from the previous token's final activation via the
    predictive gate (token-shifted). Layers i≥1: predicted by applying layer
    i's own norm+gate to layer (i-1)'s MoE-block input (gate reuse —
    the activations are nearly identical across layers, Observation 2).
    """
    L = cfg.n_layers
    K = cfg.top_k
    gate_probs = traces["gate_probs"]           # [L, T, N]
    moe_inputs = traces["moe_inputs"]           # [L, T, d]
    beta = np.zeros(L)

    def topk(p, k=K):
        return np.argsort(p, axis=-1)[..., -k:]

    # layer 0: previous-token final activation -> predictive gate
    final = traces["final"]                      # [T, d]
    pred0 = softmax_np(final[:-1] @ np.asarray(wpre))
    actual0 = topk(gate_probs[0][1:])
    hits = np.mean([np.isin(actual0[t], topk(pred0[t])).mean()
                    for t in range(actual0.shape[0])])
    beta[0] = hits

    for i in range(1, L):
        xn = rmsnorm_np(moe_inputs[i - 1],
                        np.asarray(params[f"l{i}.moe_norm"]), cfg.rms_eps)
        pred = softmax_np(xn @ np.asarray(params[f"l{i}.gate"]))
        actual = topk(gate_probs[i])
        beta[i] = np.mean([np.isin(actual[t], topk(pred[t])).mean()
                           for t in range(actual.shape[0])])
    return beta


def softmax_np(x):
    m = x.max(axis=-1, keepdims=True)
    e = np.exp(x - m)
    return e / e.sum(axis=-1, keepdims=True)


def rmsnorm_np(x, w, eps):
    ms = np.mean(np.square(x), axis=-1, keepdims=True)
    return x / np.sqrt(ms + eps) * w


# ---------------------------------------------------------------------------
# Entry point used by aot.py
# ---------------------------------------------------------------------------

def build_profile(cfg: ModelConfig, tc: TrainConfig, params: Params,
                  sensitivity: np.ndarray, train_data: np.ndarray,
                  target_ratio: float = 0.24) -> Dict:
    traces = collect_traces(cfg, params, train_data, tc.seed + 71)
    gp = traces["gate_probs"]
    thr = calibrate_threshold(gp, sensitivity, target_ratio)
    alpha_i = per_layer_alpha(gp, sensitivity, thr)
    beta_i = prefetch_accuracy(cfg, params, traces, params["pre_gate"])
    score_stats = top1_score_stats(gp)
    sims = cross_layer_similarity(traces["moe_inputs"])
    return {
        "sensitivity": sensitivity.tolist(),
        "threshold": float(thr),
        "target_single_ratio": target_ratio,
        "alpha": alpha_i.tolist(),              # P(single expert) per layer
        "beta": beta_i.tolist(),                # prefetch accuracy per layer
        "similarity": sims,                     # Fig. 3 series
        "score_stats": score_stats,             # Fig. 2 material
    }
