"""Artifact writers: HLO text lowering + the weights.bin tensor container.

weights.bin layout (little-endian), read by rust/src/model/weights.rs:

    magic   b"AMOE"
    u32     version (1)
    u32     n_tensors
    repeat n_tensors:
        u32         name_len
        bytes       name (utf-8)
        u8          dtype (0 = f32, 1 = i32, 2 = u8)
        u8          ndim
        u32 * ndim  dims
        bytes       raw data (row-major, LE)
"""

import json
import struct
from typing import Dict

import jax
import numpy as np
from jax._src.lib import xla_client as xc

MAGIC = b"AMOE"
VERSION = 1
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.uint8): 2}


def to_hlo_text(lowered) -> str:
    """jax lowered fn -> HLO text (the interchange the xla crate accepts).

    HLO *text*, not a serialized HloModuleProto: jax ≥ 0.5 emits 64-bit
    instruction ids that xla_extension 0.5.1 rejects; the text parser
    reassigns ids. `return_tuple=True` so rust unwraps with to_tuple-N.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> dict:
    """jit+lower fn at example_args, write HLO text, return shape metadata."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return {
        "path": path.split("/")[-1],
        "inputs": [
            {"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args
        ],
    }


def write_weights(path: str, tensors: Dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in DTYPES:
                raise ValueError(f"unsupported dtype {arr.dtype} for {name}")
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def read_weights(path: str) -> Dict[str, np.ndarray]:
    """Reader (python side — used by tests to round-trip the container)."""
    inv = {v: k for k, v in DTYPES.items()}
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        version, n = struct.unpack("<II", f.read(8))
        assert version == VERSION
        for _ in range(n):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = inv[dt]
            count = int(np.prod(dims)) if ndim else 1
            out[name] = np.frombuffer(
                f.read(count * dtype.itemsize), dtype
            ).reshape(dims)
    return out


def write_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
