"""Build-time training of the tiny Mixtral-style MoE + AdapMoE offline stats.

Produces (in memory; aot.py writes them out):
  * trained params
  * per-layer Fisher sensitivity  S_i = Σ diag(F_i)      (paper eq. 5–8)
  * trained predictive gate for layer 0                  (paper eq. 9)

The Fisher diagonal is estimated exactly as the paper prescribes: F_i =
E_d[g_d g_d^T] with g_d the gradient of the loss w.r.t. layer i's MoE-block
*output*. We obtain those gradients by injecting zero-valued perturbations
eps_i at each MoE output and differentiating w.r.t. eps_i.
"""

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, TrainConfig
from .corpus import sample_batch, split_corpus
from .kernels.ref import rmsnorm_ref, softmax_ref
from .model import (Params, apply_rope, forward_seq, init_params, loss_fn,
                    rope_angles)


# ---------------------------------------------------------------------------
# Hand-rolled Adam (no optax on this image)
# ---------------------------------------------------------------------------

def adam_init(params: Params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, wd, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * jnp.square(grads[k]) for k in params}
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    new = {}
    for k in params:
        upd = (m[k] / bc1) / (jnp.sqrt(v[k] / bc2) + eps)
        # no weight decay on norms / gates (keeps routing logits healthy)
        if not (k.endswith("norm") or "gate" in k):
            upd = upd + wd * params[k]
        new[k] = params[k] - lr * upd
    return new, {"m": m, "v": v, "t": t}


def lr_schedule(tc: TrainConfig, step: int) -> float:
    if step < tc.warmup:
        return tc.lr * (step + 1) / tc.warmup
    # cosine decay to 10%
    frac = (step - tc.warmup) / max(1, tc.steps - tc.warmup)
    return tc.lr * (0.1 + 0.9 * 0.5 * (1 + np.cos(np.pi * frac)))


# ---------------------------------------------------------------------------
# Main training loop
# ---------------------------------------------------------------------------

def train(cfg: ModelConfig, tc: TrainConfig, verbose: bool = True
          ) -> Tuple[Params, Dict]:
    """Train the model; returns (params, info dict with losses/corpus)."""
    train_b, eval_b = split_corpus(tc.corpus_bytes, tc.eval_bytes, tc.seed)
    data = np.frombuffer(train_b, np.uint8)
    rng = np.random.default_rng(tc.seed + 17)

    params = init_params(cfg, seed=tc.seed)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, tokens, lr):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens, tc.aux_loss_coef), has_aux=True
        )(params)
        params, opt = adam_update(params, grads, opt, lr, tc.weight_decay)
        return params, opt, loss, ce, aux

    losses = []
    t0 = time.time()
    for step in range(tc.steps):
        tokens = jnp.asarray(sample_batch(data, rng, tc.batch, tc.seq))
        lr = lr_schedule(tc, step)
        params, opt, loss, ce, aux = step_fn(params, opt, tokens, lr)
        if step % 50 == 0 or step == tc.steps - 1:
            losses.append((step, float(ce)))
            if verbose:
                print(f"  step {step:4d}  ce={float(ce):.4f} "
                      f"aux={float(aux):.4f}  ({time.time()-t0:.1f}s)")
    return params, {"losses": losses, "train_bytes": train_b, "eval_bytes": eval_b}


# ---------------------------------------------------------------------------
# Fisher sensitivity (paper §4.2, eq. 5–8)
# ---------------------------------------------------------------------------

def _forward_with_eps(cfg: ModelConfig, params: Params, tokens, eps):
    """forward_seq with additive perturbations at each MoE-block output.

    d loss / d eps_i == d loss / d (MoE output of layer i). Re-implements the
    training forward (kept in sync by test_train.py::test_eps_forward_matches).
    """
    from .model import _moe_dense_mix  # local import to avoid cycle at top

    B, S = tokens.shape
    d = cfg.d_model
    h = params["embed"][tokens]
    pos = jnp.arange(S, dtype=jnp.int32)
    cos, sin = rope_angles(cfg, pos)
    causal = jnp.tril(jnp.ones((S, S), jnp.bool_))
    for i in range(cfg.n_layers):
        xn = rmsnorm_ref(h, params[f"l{i}.attn_norm"], cfg.rms_eps)
        q = (xn @ params[f"l{i}.wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        k = (xn @ params[f"l{i}.wk"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        v = (xn @ params[f"l{i}.wv"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos[None, :, None, :], sin[None, :, None, :])
        k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(cfg.head_dim)
        att = jnp.where(causal[None, None], att, -1e30)
        att = softmax_ref(att)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B, S, d)
        h = h + o @ params[f"l{i}.wo"]

        xn = rmsnorm_ref(h, params[f"l{i}.moe_norm"], cfg.rms_eps)
        mix, _ = _moe_dense_mix(cfg, params, i, xn.reshape(B * S, d))
        h = h + mix.reshape(B, S, d) + eps[i]          # <- perturbation point
    hn = rmsnorm_ref(h, params["out_norm"], cfg.rms_eps)
    logits = hn @ params["unembed"]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    return -jnp.mean(jnp.take_along_axis(logp, tgt[..., None], axis=-1))


def fisher_sensitivity(cfg: ModelConfig, params: Params, data: np.ndarray,
                       tc: TrainConfig) -> np.ndarray:
    """Per-layer Σ diag(F_i), F_i = E[g g^T], g = dL/d(MoE output of layer i).

    diag(F)_k = E[g_k²], so Σdiag(F_i) = E[‖g_i‖²] over sample tokens.
    """
    rng = np.random.default_rng(tc.seed + 31)
    L = cfg.n_layers

    @jax.jit
    def grads_fn(params, tokens):
        B, S = tokens.shape
        eps = [jnp.zeros((B, S, cfg.d_model), jnp.float32) for _ in range(L)]
        g = jax.grad(lambda e: _forward_with_eps(cfg, params, tokens, e))(eps)
        # mean over tokens of squared grad, summed over features
        return jnp.stack([jnp.mean(jnp.sum(jnp.square(gi), -1)) for gi in g])

    acc = np.zeros(L)
    for _ in range(tc.fisher_batches):
        tokens = jnp.asarray(sample_batch(data, rng, 8, 64)[:, :-1])
        acc += np.asarray(grads_fn(params, tokens))
    return acc / tc.fisher_batches


# ---------------------------------------------------------------------------
# Predictive gate for layer 0 (paper §4.3, eq. 9)
# ---------------------------------------------------------------------------

def train_pre_gate(cfg: ModelConfig, params: Params, data: np.ndarray,
                   tc: TrainConfig, verbose: bool = True) -> jnp.ndarray:
    """Train W_pre: last-layer activation of token t -> layer-0 gate of t+1.

    Loss = KL(softmax(G_first(A_first))[:, 1:] || softmax(A_last @ W_pre)[:, :-1])
    (paper eq. 9, shifted by one token). Only W_pre is trained.
    """
    rng = np.random.default_rng(tc.seed + 47)
    wpre = params["pre_gate"]
    m = jnp.zeros_like(wpre)
    v = jnp.zeros_like(wpre)

    @jax.jit
    def batch_stats(params, tokens):
        _, extras = forward_seq(cfg, params, tokens, collect=True)
        target = extras["gate_probs"][0]        # [B, S, N] layer-0 gate probs
        a_last = extras["final"]                # [B, S, d] last-layer normed acts
        return target, a_last

    @jax.jit
    def step(wpre, m, v, t, target, a_last):
        def kl_loss(w):
            pred = jax.nn.log_softmax(a_last[:, :-1] @ w, axis=-1)
            tgt = target[:, 1:]
            return jnp.mean(jnp.sum(tgt * (jnp.log(tgt + 1e-9) - pred), -1))

        loss, g = jax.value_and_grad(kl_loss)(wpre)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * jnp.square(g)
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.999 ** t)
        return wpre - 1e-2 * mh / (jnp.sqrt(vh) + 1e-8), m, v, loss

    for i in range(tc.pre_gate_steps):
        tokens = jnp.asarray(sample_batch(data, rng, 8, 96)[:, :-1])
        target, a_last = batch_stats(params, tokens)
        wpre, m, v, loss = step(wpre, m, v, float(i + 1), target, a_last)
        if verbose and (i % 100 == 0 or i == tc.pre_gate_steps - 1):
            print(f"  pre_gate step {i:4d}  kl={float(loss):.4f}")
    return wpre
