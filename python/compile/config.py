"""Model/architecture configuration shared by train.py, model.py and aot.py.

The rust side reads the same values from artifacts/manifest.json — this file
is the single source of truth at build time.
"""

from dataclasses import dataclass, asdict, field
from typing import List


@dataclass
class ModelConfig:
    """Mixtral-architecture MoE decoder configuration.

    Defaults are the `tiny` build-time config: byte-level vocab, 8 layers of
    8 experts with top-2 routing — small enough to train on CPU in minutes,
    large enough that gate-score skew / cross-layer similarity / Fisher
    sensitivities (everything AdapMoE keys on) emerge from training.
    """

    name: str = "tiny"
    vocab_size: int = 256          # byte-level tokenizer
    d_model: int = 128
    n_heads: int = 4
    head_dim: int = 32             # d_model / n_heads
    n_layers: int = 8
    n_experts: int = 8             # N in the paper
    top_k: int = 2                 # K in the paper (Mixtral: 2 of 8)
    d_ff: int = 256                # per-expert SwiGLU hidden dim
    max_seq: int = 256             # KV-cache length
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    batch_sizes: List[int] = field(default_factory=lambda: [1, 4, 8])

    def __post_init__(self):
        assert self.d_model == self.n_heads * self.head_dim
        assert self.top_k <= self.n_experts

    # -- derived sizes ------------------------------------------------------
    @property
    def expert_params(self) -> int:
        """f32 parameter count of one expert (w1 + w3 + w2)."""
        return 3 * self.d_model * self.d_ff

    @property
    def expert_bytes_f32(self) -> int:
        return 4 * self.expert_params

    def to_dict(self) -> dict:
        return asdict(self)


@dataclass
class TrainConfig:
    """Build-time training hyperparameters (synthetic multi-domain corpus)."""

    steps: int = 300
    batch: int = 16
    seq: int = 96
    lr: float = 3e-3
    warmup: int = 40
    weight_decay: float = 0.01
    aux_loss_coef: float = 0.02    # Switch-style load-balancing loss
    seed: int = 0
    corpus_bytes: int = 1 << 19    # 512 KiB synthetic corpus
    eval_bytes: int = 1 << 15      # 32 KiB held-out split
    fisher_batches: int = 12       # batches used for Fisher diag estimate
    pre_gate_steps: int = 200      # predictive-gate (layer 0) training steps


def small_config() -> ModelConfig:
    """Larger config used to demonstrate scaling (Fig. 8 'model sizes')."""
    return ModelConfig(
        name="small",
        d_model=256,
        n_heads=8,
        head_dim=32,
        n_layers=12,
        d_ff=512,
    )


def tiny_config() -> ModelConfig:
    return ModelConfig()


def micro_config() -> ModelConfig:
    """2-layer smoke config for CI / export tests — not for experiments."""
    return ModelConfig(
        name="micro",
        d_model=32,
        n_heads=2,
        head_dim=16,
        n_layers=2,
        d_ff=64,
        max_seq=64,
        batch_sizes=[1, 4],
    )


CONFIGS = {"tiny": tiny_config, "small": small_config, "micro": micro_config}
