"""Build-time entry point: train -> profile -> AOT-export all artifacts.

    cd python && python -m compile.aot --out-dir ../artifacts [--config tiny]
                                       [--steps N] [--fast]

Outputs (see DESIGN.md 'Artifacts contract'):
    manifest.json     model config + artifact list + shapes
    weights.bin       all trained tensors (f32)
    profile.json      sensitivity / threshold / α / β / similarity / scores
    tokens_eval.bin   held-out byte stream for rust-side accuracy evals
    *.hlo.txt         one per serving component × batch size

Python never runs after this; the rust binary consumes the directory.
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .config import CONFIGS, ModelConfig, TrainConfig
from .export import lower_to_file, write_json, write_weights
from .model import (attn_step, dense_step, embed_step, gate_step,
                    pre_gate_step, unembed_step)
from .train import fisher_sensitivity, train, train_pre_gate


# Number of f-tiles per expert for tile-wise scheduling (must divide d_ff;
# keep in sync with rust --n-tiles default).
TILE_SPLIT = 4


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def export_components(cfg: ModelConfig, out_dir: str) -> dict:
    """Lower every serving component to HLO text; return manifest entries."""
    d, V, N, f = cfg.d_model, cfg.vocab_size, cfg.n_experts, cfg.d_ff
    H, S, hd = cfg.n_heads, cfg.max_seq, cfg.head_dim
    L = cfg.n_layers
    arts = {}

    for B in cfg.batch_sizes:
        arts[f"embed_b{B}"] = lower_to_file(
            embed_step,
            (spec([B], jnp.int32), spec([V, d])),
            f"{out_dir}/embed_b{B}.hlo.txt")

        arts[f"attn_step_b{B}"] = lower_to_file(
            lambda h, n, wq, wk, wv, wo, kc, vc, pos: attn_step(
                cfg, h, n, wq, wk, wv, wo, kc, vc, pos),
            (spec([B, d]), spec([d]), spec([d, d]), spec([d, d]),
             spec([d, d]), spec([d, d]), spec([B, H, S, hd]),
             spec([B, H, S, hd]), spec([B], jnp.int32)),
            f"{out_dir}/attn_step_b{B}.hlo.txt")

        arts[f"gate_b{B}"] = lower_to_file(
            lambda h, n, wg: gate_step(cfg, h, n, wg),
            (spec([B, d]), spec([d]), spec([d, N])),
            f"{out_dir}/gate_b{B}.hlo.txt")

        # L1 Pallas kernel is inside this one.
        from .kernels.expert_ffn import expert_ffn
        arts[f"expert_ffn_b{B}"] = lower_to_file(
            lambda x, w1, w3, w2, coef: (expert_ffn(x, w1, w3, w2, coef),),
            (spec([B, d]), spec([d, f]), spec([d, f]), spec([f, d]),
             spec([B])),
            f"{out_dir}/expert_ffn_b{B}.hlo.txt")

        # Tile-shaped expert FFN: the unit of tile-wise scheduling (Fig. 6).
        # SwiGLU f-tiles are independent and additive, so computing each
        # arrived tile separately and summing reproduces the full expert.
        ft = f // TILE_SPLIT
        arts[f"expert_ffn_tile_b{B}"] = lower_to_file(
            lambda x, w1, w3, w2, coef: (expert_ffn(x, w1, w3, w2, coef),),
            (spec([B, d]), spec([d, ft]), spec([d, ft]), spec([ft, d]),
             spec([B])),
            f"{out_dir}/expert_ffn_tile_b{B}.hlo.txt")

        arts[f"pre_gate_b{B}"] = lower_to_file(
            lambda h, n, w: (pre_gate_step(cfg, h, n, w),),
            (spec([B, d]), spec([d]), spec([d, N])),
            f"{out_dir}/pre_gate_b{B}.hlo.txt")

        arts[f"unembed_b{B}"] = lower_to_file(
            lambda h, n, w: (unembed_step(cfg, h, n, w),),
            (spec([B, d]), spec([d]), spec([d, V])),
            f"{out_dir}/unembed_b{B}.hlo.txt")

    # Monolithic dense reference, smallest batch only (it is L× bigger).
    B = cfg.batch_sizes[0]

    def dense_wrapper(tokens, kc, vc, pos, *flat):
        params = dict(zip(param_order, flat))
        return dense_step(cfg, params, tokens, kc, vc, pos)

    from .model import init_params
    # pre_gate is unused by dense_step; XLA prunes unused entry parameters
    # at compile time, so keep the supplied argument list in sync.
    param_order = [k for k in init_params(cfg, seed=0) if k != "pre_gate"]
    flat_specs = [spec(init_params(cfg, seed=0)[k].shape) for k in param_order]
    arts[f"dense_step_b{B}"] = lower_to_file(
        dense_wrapper,
        (spec([B], jnp.int32), spec([L, B, H, S, hd]), spec([L, B, H, S, hd]),
         spec([B], jnp.int32), *flat_specs),
        f"{out_dir}/dense_step_b{B}.hlo.txt")
    arts[f"dense_step_b{B}"]["param_order"] = param_order
    return arts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--config", default="tiny", choices=list(CONFIGS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--fast", action="store_true",
                    help="cut training for CI smoke builds")
    ap.add_argument("--target-ratio", type=float, default=0.24)
    args = ap.parse_args()

    cfg = CONFIGS[args.config]()
    tc = TrainConfig()
    if args.fast:
        tc.steps, tc.pre_gate_steps, tc.fisher_batches = 60, 40, 4
    if args.steps is not None:
        tc.steps = args.steps

    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    t0 = time.time()

    print(f"[aot] training {cfg.name} ({tc.steps} steps)...")
    params, info = train(cfg, tc)

    print("[aot] fisher sensitivity...")
    data = np.frombuffer(info["train_bytes"], np.uint8)
    sens = fisher_sensitivity(cfg, params, data, tc)
    print("  S_i =", np.array2string(sens, precision=4))

    print("[aot] predictive gate (layer 0)...")
    params["pre_gate"] = train_pre_gate(cfg, params, data, tc)

    print("[aot] offline profile...")
    from .profile_offline import build_profile
    profile = build_profile(cfg, tc, params, sens, data, args.target_ratio)
    profile["train_losses"] = info["losses"]
    write_json(f"{out}/profile.json", profile)

    print("[aot] exporting weights + eval tokens...")
    write_weights(f"{out}/weights.bin",
                  {k: np.asarray(v) for k, v in params.items()})
    with open(f"{out}/tokens_eval.bin", "wb") as fh:
        fh.write(info["eval_bytes"])

    print("[aot] lowering components to HLO text...")
    arts = export_components(cfg, out)

    manifest = {
        "config": cfg.to_dict(),
        "train": {"steps": tc.steps, "final_ce": info["losses"][-1][1]},
        "artifacts": arts,
        "files": ["weights.bin", "profile.json", "tokens_eval.bin"],
    }
    write_json(f"{out}/manifest.json", manifest)
    print(f"[aot] done in {time.time() - t0:.1f}s -> {out}")


if __name__ == "__main__":
    main()
