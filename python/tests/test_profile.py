"""Offline profiling math tests (gating calibration, similarity, β)."""

import numpy as np
import pytest

from compile.profile_offline import (calibrate_threshold,
                                     cross_layer_similarity, per_layer_alpha,
                                     rmsnorm_np, single_expert_mask,
                                     softmax_np, top1_score_stats)


@pytest.fixture
def gate_probs():
    rng = np.random.default_rng(0)
    L, T, N = 4, 512, 8
    logits = rng.standard_normal((L, T, N)) * 2.0
    return softmax_np(logits)


class TestSingleExpertMask:
    def test_threshold_zero_keeps_two(self, gate_probs):
        sens = np.ones(4)
        mask = single_expert_mask(gate_probs, sens, 0.0)
        assert mask.mean() < 0.01

    def test_huge_threshold_all_single(self, gate_probs):
        sens = np.ones(4)
        mask = single_expert_mask(gate_probs, sens, 1e9)
        assert mask.all()

    def test_sensitive_layers_less_single(self, gate_probs):
        sens = np.array([100.0, 0.01, 0.01, 0.01])
        mask = single_expert_mask(gate_probs, sens, 0.05)
        assert mask[0].mean() <= mask[1:].mean()


class TestCalibration:
    def test_hits_target(self, gate_probs):
        sens = np.array([2.0, 1.0, 0.5, 0.25])
        thr = calibrate_threshold(gate_probs, sens, target_ratio=0.24)
        ratio = single_expert_mask(gate_probs, sens, thr).mean()
        assert abs(ratio - 0.24) < 0.05

    def test_alpha_per_layer_in_unit(self, gate_probs):
        sens = np.ones(4)
        thr = calibrate_threshold(gate_probs, sens, 0.3)
        a = per_layer_alpha(gate_probs, sens, thr)
        assert a.shape == (4,)
        assert ((a >= 0) & (a <= 1)).all()


class TestObservationStats:
    def test_score_stats_shapes(self, gate_probs):
        s = top1_score_stats(gate_probs)
        assert len(s["alpha_mean"]) == 4
        assert len(s["alpha_hist20"][0]) == 20
        # α = p1/(p1+p2) ≥ 0.5 by construction
        assert min(s["alpha_mean"]) >= 0.5

    def test_similarity_identical_layers(self):
        x = np.random.default_rng(1).standard_normal((3, 64, 16))
        sims = cross_layer_similarity(np.concatenate([x[:1], x[:1]], axis=0))
        assert sims[0] == pytest.approx(1.0, abs=1e-5)

    def test_similarity_orthogonal(self):
        a = np.zeros((1, 4, 4))
        b = np.zeros((1, 4, 4))
        a[0, :, 0] = 1.0
        b[0, :, 1] = 1.0
        sims = cross_layer_similarity(np.concatenate([a, b], axis=0))
        assert abs(sims[0]) < 1e-6


class TestNumpyHelpers:
    def test_softmax_rows(self):
        x = np.random.default_rng(2).standard_normal((5, 8))
        p = softmax_np(x)
        np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-6)

    def test_rmsnorm_matches_jnp(self):
        import jax.numpy as jnp

        from compile.kernels.ref import rmsnorm_ref

        x = np.random.default_rng(3).standard_normal((4, 16)).astype(np.float32)
        w = np.random.default_rng(4).standard_normal(16).astype(np.float32)
        got = rmsnorm_np(x, w, 1e-5)
        want = np.asarray(rmsnorm_ref(jnp.asarray(x), jnp.asarray(w), 1e-5))
        np.testing.assert_allclose(got, want, rtol=1e-5)
