"""Synthetic corpus generator tests."""

import numpy as np
import pytest

from compile.corpus import (DOMAINS, generate_corpus, sample_batch,
                            split_corpus)


class TestGenerate:
    def test_deterministic(self):
        a = generate_corpus(4096, seed=3)
        b = generate_corpus(4096, seed=3)
        assert a == b

    def test_seed_changes_content(self):
        assert generate_corpus(4096, seed=1) != generate_corpus(4096, seed=2)

    def test_exact_length(self):
        for n in [100, 1024, 5000]:
            assert len(generate_corpus(n, seed=0)) == n

    def test_multi_domain_content(self):
        c = generate_corpus(1 << 16, seed=0)
        # arithmetic domain
        assert b"=" in c
        # json domain
        assert b'{"' in c
        # dna domain: ACGT-only runs exist somewhere
        assert any(
            len(c[i:i + 20]) == 20 and all(ch in b"ACGT" for ch in c[i:i + 20])
            for i in range(0, len(c) - 20)
        )

    def test_split_no_overlap_seeds(self):
        train, evald = split_corpus(1 << 14, 1 << 10, seed=0)
        assert len(train) == 1 << 14
        assert len(evald) == 1 << 10
        assert train[: 1 << 10] != evald


class TestSampleBatch:
    def test_shape_and_range(self):
        data = np.frombuffer(generate_corpus(4096, 0), np.uint8)
        rng = np.random.default_rng(0)
        b = sample_batch(data, rng, 4, 16)
        assert b.shape == (4, 17)
        assert b.dtype == np.int32
        assert (b >= 0).all() and (b < 256).all()

    def test_windows_are_contiguous(self):
        data = np.arange(300, dtype=np.uint8)
        rng = np.random.default_rng(1)
        b = sample_batch(data, rng, 2, 10)
        for row in b:
            assert (np.diff(row) == 1).all()


def test_domains_list():
    assert len(DOMAINS) >= 5
