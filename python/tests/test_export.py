"""Artifact writer tests: weights container round-trip + HLO lowering."""

import numpy as np
import pytest

from compile.export import lower_to_file, read_weights, write_weights


class TestWeightsContainer:
    def test_roundtrip(self, tmp_path):
        tensors = {
            "a": np.random.default_rng(0).standard_normal((3, 4)).astype(np.float32),
            "l0.e1.w2": np.arange(6, dtype=np.float32).reshape(2, 3),
            "scalar_ish": np.array([7.5], dtype=np.float32),
        }
        p = tmp_path / "w.bin"
        write_weights(str(p), tensors)
        back = read_weights(str(p))
        assert set(back) == set(tensors)
        for k in tensors:
            np.testing.assert_array_equal(back[k], tensors[k])

    def test_rejects_unsupported_dtype(self, tmp_path):
        with pytest.raises(ValueError):
            write_weights(str(tmp_path / "bad.bin"),
                          {"x": np.zeros(3, dtype=np.float64)})

    def test_empty_container(self, tmp_path):
        p = tmp_path / "empty.bin"
        write_weights(str(p), {})
        assert read_weights(str(p)) == {}


class TestLowering:
    def test_lower_writes_hlo_text(self, tmp_path):
        import jax
        import jax.numpy as jnp

        def fn(x, y):
            return (jnp.matmul(x, y) + 1.0,)

        spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
        out = tmp_path / "fn.hlo.txt"
        entry = lower_to_file(fn, (spec, spec), str(out))
        text = out.read_text()
        assert "HloModule" in text
        assert entry["path"] == "fn.hlo.txt"
        assert entry["inputs"][0]["shape"] == [2, 2]

    def test_lower_pallas_kernel(self, tmp_path):
        """The Pallas kernel must lower to plain HLO ops (interpret mode)."""
        import jax
        import jax.numpy as jnp

        from compile.kernels.expert_ffn import expert_ffn

        B, d, f = 2, 16, 32
        specs = (
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((d, f), jnp.float32),
            jax.ShapeDtypeStruct((d, f), jnp.float32),
            jax.ShapeDtypeStruct((f, d), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        )
        out = tmp_path / "k.hlo.txt"
        lower_to_file(lambda *a: (expert_ffn(*a),), specs, str(out))
        text = out.read_text()
        assert "HloModule" in text
        # interpret=True means no mosaic/tpu custom-calls survive lowering
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
