"""L1 correctness: Pallas expert-FFN kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the whole stack — the serving HLO
the rust engine executes contains exactly this kernel. hypothesis sweeps
shapes/dtypes; fixed cases pin the behaviours the sweep may not hit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.expert_ffn import _pick_f_block, expert_ffn
from compile.kernels.ref import expert_ffn_ref, gate_ref, rmsnorm_ref, silu


def _rand(rng, shape, dtype=np.float32, scale=0.05):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


def _run_pair(B, d, f, dtype, seed, f_block=None, coef=None):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (B, d), dtype, 1.0)
    w1 = _rand(rng, (d, f), dtype)
    w3 = _rand(rng, (d, f), dtype)
    w2 = _rand(rng, (f, d), dtype)
    if coef is None:
        coef = jnp.asarray(rng.uniform(0, 1, B), dtype)
    out = expert_ffn(x, w1, w3, w2, coef, f_block=f_block)
    ref = expert_ffn_ref(x, w1, w3, w2, coef)
    return np.asarray(out), np.asarray(ref)


class TestFixedCases:
    def test_basic_f32(self):
        out, ref = _run_pair(4, 128, 256, np.float32, 0)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_batch_one(self):
        out, ref = _run_pair(1, 128, 256, np.float32, 1)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_zero_coef_rows_are_zero(self):
        coef = jnp.asarray([1.0, 0.0, 0.5, 0.0], jnp.float32)
        out, ref = _run_pair(4, 64, 128, np.float32, 2, coef=coef)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
        assert np.all(out[1] == 0.0) and np.all(out[3] == 0.0)

    def test_all_zero_coef(self):
        coef = jnp.zeros((4,), jnp.float32)
        out, _ = _run_pair(4, 64, 128, np.float32, 3, coef=coef)
        assert np.all(out == 0.0)

    def test_single_grid_step(self):
        # f == f_block -> grid of 1, accumulation init path only
        out, ref = _run_pair(2, 64, 64, np.float32, 4, f_block=64)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_many_grid_steps(self):
        out, ref = _run_pair(2, 32, 256, np.float32, 5, f_block=8)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        out, ref = _run_pair(4, 128, 256, jnp.bfloat16, 6)
        np.testing.assert_allclose(
            out.astype(np.float32), ref.astype(np.float32), rtol=0.08, atol=0.08)

    def test_linearity_in_coef(self):
        """Scaling coef scales output — partial-sum scaling must be exact."""
        rng = np.random.default_rng(7)
        B, d, f = 3, 64, 128
        x = _rand(rng, (B, d), np.float32, 1.0)
        ws = [_rand(rng, s) for s in [(d, f), (d, f), (f, d)]]
        c1 = jnp.ones((B,), jnp.float32)
        c2 = 2.0 * c1
        o1 = np.asarray(expert_ffn(x, *ws, c1))
        o2 = np.asarray(expert_ffn(x, *ws, c2))
        np.testing.assert_allclose(o2, 2 * o1, rtol=1e-6)

    def test_jit_wrapped(self):
        fn = jax.jit(lambda *a: expert_ffn(*a))
        rng = np.random.default_rng(8)
        B, d, f = 4, 128, 256
        args = (_rand(rng, (B, d), np.float32, 1.0), _rand(rng, (d, f)),
                _rand(rng, (d, f)), _rand(rng, (f, d)),
                jnp.ones((B,), jnp.float32))
        np.testing.assert_allclose(
            np.asarray(fn(*args)), np.asarray(expert_ffn_ref(*args)),
            rtol=2e-5, atol=2e-5)


class TestPickFBlock:
    def test_divides(self):
        for f in [8, 16, 64, 128, 256, 384, 512, 1024]:
            blk = _pick_f_block(f)
            assert f % blk == 0 and blk <= 256

    def test_prefers_large_tiles(self):
        assert _pick_f_block(512) == 256
        assert _pick_f_block(256) == 256
        assert _pick_f_block(128) == 128

    def test_odd_f(self):
        assert _pick_f_block(24) == 8


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 8),
    d=st.sampled_from([16, 32, 64, 128]),
    f=st.sampled_from([16, 32, 64, 128, 256]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shapes_f32(B, d, f, seed):
    out, ref = _run_pair(B, d, f, np.float32, seed)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    B=st.integers(1, 4),
    d=st.sampled_from([32, 64]),
    f=st.sampled_from([32, 128]),
    seed=st.integers(0, 2**16),
    dtype=st.sampled_from([np.float32, jnp.bfloat16]),
)
def test_hypothesis_dtypes(B, d, f, seed, dtype):
    out, ref = _run_pair(B, d, f, dtype, seed)
    tol = 3e-5 if dtype == np.float32 else 0.1
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol)


class TestRefHelpers:
    def test_silu_matches_jax(self):
        x = jnp.linspace(-5, 5, 64)
        np.testing.assert_allclose(silu(x), jax.nn.silu(x), rtol=1e-6, atol=1e-6)

    def test_rmsnorm_unit_scale(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
        out = rmsnorm_ref(x, jnp.ones(32))
        ms = np.mean(np.square(np.asarray(out)), -1)
        np.testing.assert_allclose(ms, 1.0, rtol=1e-3)

    def test_gate_rows_sum_to_one(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((6, 16)), jnp.float32)
        wg = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
        p = np.asarray(gate_ref(x, wg))
        np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-5)
        assert (p >= 0).all()
