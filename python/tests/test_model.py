"""L2 model tests: shapes, component/serving-path equivalence, invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import ModelConfig
from compile.kernels.ref import rmsnorm_ref, softmax_ref
from compile.model import (attn_step, dense_step, embed_step, forward_seq,
                           gate_step, init_params, pre_gate_step, topk_mask,
                           unembed_step)


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, head_dim=16,
                      d_ff=64, max_seq=32, vocab_size=64)
    return cfg, init_params(cfg, seed=0)


class TestShapes:
    def test_forward_seq(self, tiny):
        cfg, params = tiny
        tokens = jnp.zeros((2, 8), jnp.int32)
        logits = forward_seq(cfg, params, tokens)
        assert logits.shape == (2, 8, cfg.vocab_size)

    def test_collect_extras(self, tiny):
        cfg, params = tiny
        tokens = jnp.zeros((2, 8), jnp.int32)
        _, ex = forward_seq(cfg, params, tokens, collect=True)
        assert len(ex["moe_inputs"]) == cfg.n_layers
        assert ex["gate_probs"][0].shape == (2, 8, cfg.n_experts)
        assert ex["final"].shape == (2, 8, cfg.d_model)

    def test_components(self, tiny):
        cfg, params = tiny
        B = 3
        h = embed_step(jnp.array([1, 2, 3]), params["embed"])
        assert h.shape == (B, cfg.d_model)
        probs, xn = gate_step(cfg, h, params["l0.moe_norm"], params["l0.gate"])
        assert probs.shape == (B, cfg.n_experts)
        np.testing.assert_allclose(np.asarray(probs).sum(-1), 1.0, rtol=1e-5)
        logits = unembed_step(cfg, h, params["out_norm"], params["unembed"])
        assert logits.shape == (B, cfg.vocab_size)
        pre = pre_gate_step(cfg, h, params["out_norm"], params["pre_gate"])
        assert pre.shape == (B, cfg.n_experts)
        np.testing.assert_allclose(np.asarray(pre).sum(-1), 1.0, rtol=1e-5)


class TestTopkMask:
    def test_selects_k(self):
        rng = np.random.default_rng(0)
        p = softmax_ref(jnp.asarray(rng.standard_normal((16, 8)), jnp.float32))
        for k in (1, 2, 3):
            m = np.asarray(topk_mask(p, k))
            assert (m.sum(-1) == k).all()

    def test_matches_argsort(self):
        rng = np.random.default_rng(1)
        p = jnp.asarray(rng.uniform(size=(32, 8)), jnp.float32)
        m = np.asarray(topk_mask(p, 2))
        top2 = np.argsort(np.asarray(p), -1)[:, -2:]
        for t in range(32):
            assert set(np.nonzero(m[t])[0]) == set(top2[t])


class TestAttnStep:
    def test_kv_cache_write(self, tiny):
        cfg, params = tiny
        B, H, S, hd = 2, cfg.n_heads, cfg.max_seq, cfg.head_dim
        h = jnp.asarray(np.random.default_rng(0).standard_normal((B, cfg.d_model)),
                        jnp.float32)
        kc = jnp.zeros((B, H, S, hd))
        vc = jnp.zeros((B, H, S, hd))
        pos = jnp.array([0, 3], jnp.int32)
        out, kc2, vc2 = attn_step(cfg, h, params["l0.attn_norm"],
                                  params["l0.wq"], params["l0.wk"],
                                  params["l0.wv"], params["l0.wo"], kc, vc, pos)
        assert out.shape == (B, cfg.d_model)
        # row 0 wrote position 0; row 1 wrote position 3
        assert np.abs(np.asarray(kc2)[0, :, 0]).sum() > 0
        assert np.abs(np.asarray(kc2)[1, :, 3]).sum() > 0
        assert np.abs(np.asarray(kc2)[1, :, 0]).sum() == 0

    def test_masked_future_is_ignored(self, tiny):
        """Garbage in cache positions > pos must not affect the output."""
        cfg, params = tiny
        rng = np.random.default_rng(2)
        B, H, S, hd = 1, cfg.n_heads, cfg.max_seq, cfg.head_dim
        h = jnp.asarray(rng.standard_normal((B, cfg.d_model)), jnp.float32)
        kc = jnp.zeros((B, H, S, hd))
        vc = jnp.zeros((B, H, S, hd))
        pos = jnp.array([2], jnp.int32)
        kc_g = kc.at[:, :, 5:].set(99.0)
        vc_g = vc.at[:, :, 5:].set(-99.0)
        args = (cfg, h, params["l0.attn_norm"], params["l0.wq"],
                params["l0.wk"], params["l0.wv"], params["l0.wo"])
        o1, _, _ = attn_step(*args, kc, vc, pos)
        o2, _, _ = attn_step(*args, kc_g, vc_g, pos)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5)


class TestServingEqualsTraining:
    """Stepping the serving components token-by-token must reproduce the
    whole-sequence training forward (same math, different decomposition)."""

    def test_stepwise_matches_forward_seq(self, tiny):
        cfg, params = tiny
        rng = np.random.default_rng(3)
        S_in = 6
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S_in)), jnp.int32)
        ref_logits = np.asarray(forward_seq(cfg, params, tokens))  # [1, S, V]

        B, H, S, hd = 1, cfg.n_heads, cfg.max_seq, cfg.head_dim
        kcs = jnp.zeros((cfg.n_layers, B, H, S, hd))
        vcs = jnp.zeros((cfg.n_layers, B, H, S, hd))
        step_logits = []
        for t in range(S_in):
            logits, kcs, vcs = dense_step(cfg, params, tokens[:, t],
                                          kcs, vcs, jnp.array([t], jnp.int32))
            step_logits.append(np.asarray(logits)[0])
        step_logits = np.stack(step_logits)
        np.testing.assert_allclose(step_logits, ref_logits[0],
                                   rtol=2e-4, atol=2e-4)
