"""Training-loop / Fisher / predictive-gate tests on the micro config."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import TrainConfig, micro_config
from compile.corpus import sample_batch
from compile.model import forward_seq, init_params, loss_fn
from compile.train import (adam_init, adam_update, fisher_sensitivity,
                           lr_schedule, train, train_pre_gate)


@pytest.fixture(scope="module")
def trained():
    cfg = micro_config()
    tc = TrainConfig()
    tc.steps, tc.pre_gate_steps, tc.fisher_batches = 25, 10, 2
    tc.corpus_bytes, tc.eval_bytes = 1 << 15, 1 << 12
    params, info = train(cfg, tc, verbose=False)
    return cfg, tc, params, info


class TestAdam:
    def test_update_moves_params(self):
        params = {"w": jnp.ones((4,))}
        grads = {"w": jnp.full((4,), 0.5)}
        st = adam_init(params)
        new, st = adam_update(params, grads, st, lr=0.1, wd=0.0)
        assert not np.allclose(np.asarray(new["w"]), 1.0)
        assert int(st["t"]) == 1

    def test_norms_skip_weight_decay(self):
        params = {"l0.moe_norm": jnp.ones((4,)), "w": jnp.ones((4,))}
        grads = {k: jnp.zeros((4,)) for k in params}
        st = adam_init(params)
        new, _ = adam_update(params, grads, st, lr=0.1, wd=0.5)
        # zero grad + wd: plain weight shrinks, norm does not
        np.testing.assert_allclose(np.asarray(new["l0.moe_norm"]), 1.0)
        assert np.all(np.asarray(new["w"]) < 1.0)

    def test_lr_schedule_warmup_and_decay(self):
        tc = TrainConfig()
        tc.steps, tc.warmup, tc.lr = 100, 10, 1.0
        assert lr_schedule(tc, 0) < lr_schedule(tc, 9) <= 1.0
        assert lr_schedule(tc, 99) < 0.2


class TestTraining:
    def test_loss_decreases(self, trained):
        _, _, _, info = trained
        losses = info["losses"]
        assert losses[-1][1] < losses[0][1] * 0.8, f"no learning: {losses}"

    def test_loss_fn_finite(self, trained):
        cfg, tc, params, info = trained
        data = np.frombuffer(info["train_bytes"], np.uint8)
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(sample_batch(data, rng, 2, 32))
        loss, (ce, aux) = loss_fn(cfg, params, tokens, 0.01)
        assert np.isfinite(float(loss)) and float(aux) > 0


class TestFisher:
    def test_sensitivity_positive_per_layer(self, trained):
        cfg, tc, params, info = trained
        data = np.frombuffer(info["train_bytes"], np.uint8)
        s = fisher_sensitivity(cfg, params, data, tc)
        assert s.shape == (cfg.n_layers,)
        assert (s > 0).all()

    def test_eps_forward_matches_plain(self, trained):
        """Zero perturbations must not change the loss — keeps the Fisher
        forward in sync with the training forward."""
        from compile.train import _forward_with_eps

        cfg, tc, params, info = trained
        data = np.frombuffer(info["train_bytes"], np.uint8)
        rng = np.random.default_rng(1)
        tokens = jnp.asarray(sample_batch(data, rng, 2, 24)[:, :-1])
        eps = [jnp.zeros((2, 24, cfg.d_model)) for _ in range(cfg.n_layers)]
        loss_eps = float(_forward_with_eps(cfg, params, tokens, eps))

        logits = forward_seq(cfg, params, tokens)
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = tokens[:, 1:]
        loss_plain = float(
            -jnp.mean(jnp.take_along_axis(logp, tgt[..., None], axis=-1))
        )
        assert loss_eps == pytest.approx(loss_plain, rel=1e-5)


class TestPreGate:
    def test_pre_gate_learns(self, trained):
        cfg, tc, params, info = trained
        data = np.frombuffer(info["train_bytes"], np.uint8)
        before = np.asarray(params["pre_gate"]).copy()
        wpre = train_pre_gate(cfg, params, data, tc, verbose=False)
        assert not np.allclose(np.asarray(wpre), before)
        assert np.isfinite(np.asarray(wpre)).all()
