//! End-to-end serving demo (the E2E validation run recorded in
//! EXPERIMENTS.md): starts the TCP server with the full AdapMoE stack and
//! drives it with concurrent clients sampling prompts from the eval corpus
//! — mixed greedy/sampled, streamed/non-streamed, plus a live cancellation
//! — then reports client latency and the server's own `{"cmd":"stats"}`.
//!
//!     cargo run --release --example serve_demo [-- --clients 6 --requests 12]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use adapmoe::coordinator::engine::Engine;
use adapmoe::coordinator::policy::{method, RunSettings};
use adapmoe::coordinator::profile::Profile;
use adapmoe::memory::platform::Platform;
use adapmoe::memory::quant::QuantKind;
use adapmoe::model::tokenizer::{ByteTokenizer, EvalStream};
use adapmoe::server::api::GenerationRequest;
use adapmoe::server::tcp;
use adapmoe::util::cli::Args;
use adapmoe::util::json::Json;
use adapmoe::util::rng::Rng;
use adapmoe::util::stats::Summary;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let dir = std::path::PathBuf::from(args.str_or("artifacts", "artifacts"));
    let n_clients = args.usize_or("clients", 6);
    let n_requests = args.usize_or("requests", 12);
    let max_new = args.usize_or("max-new", 24);
    let addr = args.str_or("addr", "127.0.0.1:17412");
    let platform = args.str_or("platform", "rtx4090");

    let eval = EvalStream::load(&dir.join("tokens_eval.bin"))
        .context("run `make artifacts` first")?;

    // server thread (PJRT is single-threaded: engine lives entirely there)
    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = Arc::clone(&shutdown);
    let (sdir, saddr, splat) = (dir.clone(), addr.clone(), platform.clone());
    let server = std::thread::spawn(move || -> Result<u64> {
        let profile = Profile::load(&sdir)?;
        let settings = RunSettings::new(
            4,
            32,
            QuantKind::Int4,
            Platform::preset(&splat).context("bad platform")?,
        );
        let ecfg = method("adapmoe", &settings, &profile).unwrap();
        let engine = Engine::from_artifacts(&sdir, ecfg)?;
        tcp::serve(engine, &saddr, sd)
    });
    // wait for bind + engine compile
    std::thread::sleep(std::time::Duration::from_millis(2500));

    println!(
        "serve_demo: {n_clients} clients × {n_requests} requests, {max_new} tokens each, \
         platform={platform}, batch=4, int4, cache 32/64 \
         (odd clients stream with temperature 0.7 / top-k 8)"
    );
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let addr = addr.clone();
            let tokens = eval.tokens.clone();
            std::thread::spawn(move || -> Result<Vec<(f64, f64)>> {
                let eval = EvalStream::from_tokens(tokens);
                let mut rng = Rng::new(c as u64 + 1);
                let mut lat = Vec::new();
                for r in 0..n_requests {
                    let prompt_toks = eval.sample_prompt(&mut rng, 12);
                    let mut req =
                        GenerationRequest::new(&ByteTokenizer::decode(&prompt_toks));
                    req.max_new = max_new;
                    if c % 2 == 1 {
                        // exercise the per-request sampling + streaming path
                        req.stream = true;
                        req.temperature = 0.7;
                        req.top_k = 8;
                        req.seed = Some((c * 1000 + r) as u64);
                    }
                    let done = tcp::client_generate(&addr, &req)?;
                    if req.stream && done.token_lines != done.tokens.len() {
                        anyhow::bail!(
                            "streamed {} token lines but completion has {}",
                            done.token_lines,
                            done.tokens.len()
                        );
                    }
                    lat.push((done.queue_ms, done.total_ms));
                }
                Ok(lat)
            })
        })
        .collect();

    let mut queue = Summary::new();
    let mut total = Summary::new();
    for h in handles {
        for (q, t) in h.join().unwrap()? {
            queue.add(q);
            total.add(t);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let completions = (n_clients * n_requests) as f64;

    // live cancellation: stream a long generation on one connection, cancel
    // it by id from another mid-flight
    let cancelled = cancel_demo(&addr, &eval)?;
    println!("cancellation:     request {cancelled} cancelled mid-stream ✓");

    println!("\n== serving results ==");
    println!("completions:      {completions}");
    println!("wall time:        {wall:.2}s");
    println!(
        "throughput:       {:.2} req/s | {:.1} tok/s",
        completions / wall,
        completions * max_new as f64 / wall
    );
    println!(
        "request latency:  p50 {:.0}ms  p99 {:.0}ms  mean {:.0}ms",
        total.p50(),
        total.p99(),
        total.mean()
    );
    println!("queue wait:       p50 {:.0}ms  p99 {:.0}ms", queue.p50(), queue.p99());

    let stats = tcp::client_stats(&addr)?;
    println!("\n== server stats ({{\"cmd\":\"stats\"}}) ==");
    for key in [
        "served",
        "cancelled",
        "tokens_generated",
        "tokens_per_sec",
        "token_p50_ms",
        "request_p50_ms",
        "queue_p50_ms",
    ] {
        if let Some(v) = stats.get(key).and_then(Json::as_f64) {
            println!("{key:18} {v:.2}");
        }
    }

    shutdown.store(true, Ordering::SeqCst);
    let served = server.join().unwrap()?;
    println!("server saw {served} completions");
    Ok(())
}

/// Stream a deliberately long generation, cancel it from a second
/// connection once tokens start flowing, and confirm the stream terminates
/// with a cancelled line. Returns the cancelled request id.
fn cancel_demo(addr: &str, eval: &EvalStream) -> Result<u64> {
    let mut rng = Rng::new(99);
    let mut req = GenerationRequest::new(&ByteTokenizer::decode(
        &eval.sample_prompt(&mut rng, 12),
    ));
    req.max_new = 10_000;
    req.stream = true;

    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{}", req.to_json().to_string())?;
    let mut reader = BufReader::new(stream);
    let mut id = None;
    let mut sent_cancel = false;
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            anyhow::bail!("server closed the stream before cancellation");
        }
        let j = Json::parse(&line)?;
        if let Some(err) = j.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error mid-demo: {err}");
        }
        if id.is_none() {
            id = j.get("id").and_then(Json::as_f64).map(|v| v as u64);
        }
        match j.get("event").and_then(Json::as_str) {
            Some("token") if !sent_cancel => {
                // tokens are flowing: cancel from a different connection
                let id = id.context("stream line without id")?;
                if !tcp::client_cancel(addr, id)? {
                    anyhow::bail!("server did not know id {id}");
                }
                sent_cancel = true;
            }
            Some("cancelled") => return Ok(id.unwrap_or(0)),
            Some("done") => anyhow::bail!("generation finished before the cancel landed"),
            _ => {}
        }
    }
}
