//! Tour of every serving method on the same workload — a narrative walk
//! through the paper's §6.3/§6.4 story on one prompt batch: how gating,
//! prefetching and DP caching each change where the time goes.
//!
//!     cargo run --release --example ablation_tour

use anyhow::{Context, Result};

use adapmoe::bench_support::{decode_eval, eval_stream, method_engine, timed_settings};
use adapmoe::coordinator::policy::METHODS;
use adapmoe::memory::quant::QuantKind;
use adapmoe::util::timer::Table;

fn main() -> Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    eval_stream(&dir).context("run `make artifacts` first")?;
    let eval = eval_stream(&dir)?;
    let tokens = 32;

    println!("ablation tour: {tokens} eval tokens per method (rtx4090, int4, cache 32/64)\n");
    let mut table = Table::new(&[
        "method",
        "tok/s",
        "p50 ms",
        "stall %",
        "on-demand/tok",
        "cache hit %",
        "single %",
    ]);
    for &m in METHODS {
        let settings = timed_settings(32, QuantKind::Int4, "rtx4090");
        let mut engine = method_engine(&dir, m, &settings)?;
        decode_eval(&mut engine, &eval, tokens, 0)?;
        let tr = &engine.trace;
        let total = tr.token_latency.sum();
        let stall = tr.stall_ns as f64 / 1e9;
        let od: u64 = tr.on_demand.iter().sum();
        let (h, miss, _) = engine.cache.stats();
        table.row(&[
            m.to_string(),
            format!("{:.2}", tr.tokens_per_sec()),
            format!("{:.1}", tr.token_latency.p50() * 1e3),
            format!("{:.0}%", 100.0 * stall / total.max(1e-12)),
            format!("{:.2}", od as f64 / tr.token_latency.len().max(1) as f64),
            format!("{:.0}%", 100.0 * h as f64 / (h + miss).max(1) as f64),
            format!("{:.0}%", 100.0 * tr.mean_single_ratio()),
        ]);
    }
    table.print();
    println!(
        "\nreading guide: baseline drowns in on-demand loads; prefetching converts\n\
         them to hits; gating removes ~25% of expert work outright; DP caching\n\
         shifts slots to early (sensitive, hard-to-prefetch) layers."
    );
    Ok(())
}
