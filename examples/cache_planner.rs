//! Offline cache planning walkthrough (paper §4.4): sweep the cache budget
//! and show how the DP shifts slots toward early (sensitive, hard-to-
//! prefetch) layers, and what that buys over a uniform split.
//!
//!     cargo run --release --example cache_planner [-- artifacts]

use anyhow::{Context, Result};

use adapmoe::coordinator::cache_plan::{allocation_cost, plan, PlanInputs};
use adapmoe::coordinator::profile::Profile;
use adapmoe::memory::device_cache::DeviceCache;
use adapmoe::util::timer::Table;

fn main() -> Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let profile = Profile::load(&dir).context("run `make artifacts` first")?;
    let l = profile.alpha.len();
    let n = 8usize;

    println!("offline profile (α = P(single expert), β = prefetch accuracy):");
    let mut t = Table::new(&["layer", "sensitivity", "alpha", "beta"]);
    for i in 0..l {
        t.row(&[
            format!("{i}"),
            format!("{:.2e}", profile.sensitivity[i]),
            format!("{:.3}", profile.alpha[i]),
            format!("{:.3}", profile.beta[i]),
        ]);
    }
    t.print();

    println!("\nDP allocation vs uniform across budgets:");
    let mut t = Table::new(&["budget", "allocation t_i", "E[loads] DP", "E[loads] uniform", "gain"]);
    for budget in [8, 16, 24, 32, 40, 48, 56] {
        let inputs = PlanInputs {
            n_experts: n,
            budget,
            alpha: profile.alpha.clone(),
            beta: profile.beta.clone(),
        };
        let p = plan(&inputs);
        let uni = DeviceCache::uniform_allocation(budget, l, n);
        let uni_cost = allocation_cost(&inputs, &uni);
        t.row(&[
            format!("{budget}"),
            format!("{:?}", p.allocation),
            format!("{:.3}", p.expected_loads),
            format!("{uni_cost:.3}"),
            format!(
                "{:+.1}%",
                100.0 * (uni_cost - p.expected_loads) / uni_cost.max(1e-12)
            ),
        ]);
    }
    t.print();
    println!(
        "\n(paper Fig. 9(c): early layers get more slots — they are more sensitive\n\
         and their prefetch predictions are weakest)"
    );
    Ok(())
}
