//! Quickstart: load the AOT artifacts and generate text through the full
//! AdapMoE stack (sensitivity gating + prefetch + DP cache + tile-wise
//! overlap) on the calibrated rtx4090 link.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::{Context, Result};

use adapmoe::coordinator::policy::{method, RunSettings};
use adapmoe::coordinator::engine::Engine;
use adapmoe::coordinator::profile::Profile;
use adapmoe::memory::platform::Platform;
use adapmoe::memory::quant::QuantKind;
use adapmoe::model::tokenizer::ByteTokenizer;

fn main() -> Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let profile = Profile::load(&dir).context("run `make artifacts` first")?;

    // AdapMoE on the paper's 4090 testbed: 4-bit experts, half the experts
    // cached (paper: 128 of 256 — here 32 of 64).
    let settings = RunSettings::new(
        1,
        32,
        QuantKind::Int4,
        Platform::preset("rtx4090").unwrap(),
    );
    let ecfg = method("adapmoe", &settings, &profile).unwrap();
    let mut engine = Engine::from_artifacts(&dir, ecfg)?;

    let prompt = "let x=";
    println!("prompt: {prompt:?}");
    let tokens = ByteTokenizer::encode(prompt);
    let t0 = std::time::Instant::now();
    let out = engine.generate(&tokens, 96)?;
    let dt = t0.elapsed().as_secs_f64();

    println!("output: {:?}", ByteTokenizer::decode(&out));
    let (hits, misses, _) = engine.cache.stats();
    println!(
        "\n{} tokens in {:.2}s -> {:.1} tok/s | single-expert {:.0}% | \
         cache hit {:.0}% | prefetch β(mean) {:.2}",
        out.len(),
        dt,
        out.len() as f64 / dt,
        100.0 * engine.trace.mean_single_ratio(),
        100.0 * hits as f64 / (hits + misses).max(1) as f64,
        engine.trace.beta().iter().sum::<f64>() / engine.cfg.n_layers as f64,
    );
    Ok(())
}
