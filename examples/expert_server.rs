//! Artifact server for cacheless coordinators (docs/remote-store.md).
//!
//! Publishes an expert store over the length-prefixed TCP protocol so a
//! coordinator started with `--remote <addr>` can run without local expert
//! weights. Two modes:
//!
//! * **serve** (default): build a store — from `--artifacts DIR` weights,
//!   or a synthetic micro-model with `--synthetic SEED` — freeze it into an
//!   [`ArtifactImage`], and serve until killed. `--corrupt-every N` /
//!   `--drop-every N` arm deterministic chaos for fault drills, and
//!   `--no-ranges` emulates a server built before the batched `GET_RANGES`
//!   op existed (clients must fall back to per-range fetches).
//! * **probe** (`--probe ADDR`): connect as a client, warm each layer up
//!   with one batched `GET_RANGES` prefetch, then fetch every expert at
//!   every published tier and verify each one is bit-identical to the
//!   locally rebuilt twin (requires the same `--synthetic SEED` or
//!   `--artifacts DIR` the server was started with). Exits non-zero on any
//!   mismatch — CI uses this as the two-process round-trip check.
//!
//!     cargo run --release --example expert_server -- --synthetic 7 --addr 127.0.0.1:7501
//!     cargo run --release --example expert_server -- --synthetic 7 --probe 127.0.0.1:7501

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use adapmoe::memory::quant::QuantKind;
use adapmoe::memory::tiered_store::TieredStore;
use adapmoe::model::config::ModelConfig;
use adapmoe::model::weights::Weights;
use adapmoe::net::{connect_store, ArtifactImage, ChaosKnobs, StoreServer};
use adapmoe::testutil::{micro_config, synthetic_weights};
use adapmoe::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let (cfg, weights) = load_model(&args)?;
    let kinds = match args.get("tiers") {
        Some(list) => TieredStore::parse_tiers(list).context("bad --tiers")?,
        None => vec![QuantKind::Int4],
    };
    let local = Arc::new(TieredStore::build(&cfg, &weights, &kinds)?);

    if let Some(addr) = args.get("probe") {
        return probe(addr, &local);
    }

    let image = Arc::new(ArtifactImage::from_tiered(&local, cfg.d_model, cfg.d_ff));
    let knobs = ChaosKnobs {
        corrupt_every: args.u64_or("corrupt-every", 0),
        drop_every: args.u64_or("drop-every", 0),
        disable_ranges: args.flag("no-ranges"),
    };
    let addr = args.str_or("addr", "127.0.0.1:7501");
    let srv = StoreServer::spawn_chaotic(image, &addr, knobs)
        .with_context(|| format!("binding {addr}"))?;
    // The READY line is the handshake scripts wait for before probing.
    println!("READY {}", srv.local_addr());
    eprintln!(
        "[expert_server] serving {} tiers x {} experts on {} \
         (corrupt_every={} drop_every={} no_ranges={}); kill to stop",
        kinds.len(),
        cfg.n_layers * cfg.n_experts,
        srv.local_addr(),
        knobs.corrupt_every,
        knobs.drop_every,
        knobs.disable_ranges,
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(1));
    }
}

/// Build the reference store the server publishes / the probe compares to.
fn load_model(args: &Args) -> Result<(ModelConfig, Weights)> {
    if let Some(dir) = args.get("artifacts") {
        let dir = PathBuf::from(dir);
        let (cfg, _manifest) = ModelConfig::load_manifest(&dir)?;
        let weights = Weights::load(&dir.join("weights.bin"))?;
        return Ok((cfg, weights));
    }
    let seed = args.u64_or("synthetic", 7);
    let cfg = micro_config();
    let weights = synthetic_weights(&cfg, seed);
    Ok((cfg, weights))
}

/// Fetch every expert at every tier from `addr` and bit-compare against the
/// local twin store.
fn probe(addr: &str, local: &TieredStore) -> Result<()> {
    let (remote, manifest) =
        connect_store(addr).with_context(|| format!("connecting to {addr}"))?;
    if manifest.tiers != local.tiers() {
        bail!(
            "server publishes tiers {:?}, probe built {:?} — pass the same --tiers",
            manifest.tiers,
            local.tiers()
        );
    }
    let mut verified = 0usize;
    for &kind in &manifest.tiers {
        let (r, l) = (remote.store(kind), local.store(kind));
        for layer in 0..manifest.n_layers {
            // Warm the layer up the way a coalesced transfer group does:
            // one GET_RANGES round trip on servers that speak it, per-range
            // fallback on old ones. The loop below then verifies the
            // batch-landed bytes bit-for-bit.
            let ids: Vec<_> = (0..manifest.n_experts).map(|e| (layer, e)).collect();
            r.prefetch(&ids);
            for expert in 0..manifest.n_experts {
                let id = (layer, expert);
                let (got, want) = (r.get(id), l.get(id));
                if got != want {
                    bail!("expert ({layer},{expert}) at {} differs from twin", kind.name());
                }
                verified += 1;
            }
        }
    }
    let c = remote.remote_counters().context("remote store has no counters")?;
    use std::sync::atomic::Ordering::Relaxed;
    if c.batched_fetches.load(Relaxed) == 0 {
        bail!("probe expected at least one batched warm-up to land");
    }
    println!(
        "PROBE OK {verified} experts bit-identical | fetches={} bytes={} \
         batched_fetches={} retries={} checksum_failures={} reconnects={}",
        c.fetches.load(Relaxed),
        c.fetched_bytes.load(Relaxed),
        c.batched_fetches.load(Relaxed),
        c.retries.load(Relaxed),
        c.checksum_failures.load(Relaxed),
        c.reconnects.load(Relaxed),
    );
    Ok(())
}
