//! Offline stand-in for the `anyhow` crate, at the size this project
//! needs (`Result`, `Error`, `Context` on `Result`/`Option`, `bail!`,
//! `anyhow!`).
//!
//! The build image vendors no crates.io registry, so the workspace points
//! `anyhow = { path = "vendor/anyhow" }` here. The API subset is
//! call-compatible with the real crate; swapping back is a one-line
//! Cargo.toml change. Error context is flattened into a single message
//! string (`"context: cause"`) instead of a source chain — enough for
//! every `{err}` / `{err:?}` rendering in this repo.

use std::error::Error as StdError;
use std::fmt;

/// Flattened error: the accumulated context string.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors anyhow: Error deliberately does NOT implement std::error::Error,
// which is what makes this blanket From (used by `?` on io/parse/xla
// errors) coherent alongside core's reflexive `From<T> for T`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(|| ..)` on `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

// The two Result impls are disjoint because `Error` (a local type) does
// not implement std::error::Error — the same coherence argument the real
// anyhow relies on.
impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))
    }

    #[test]
    fn context_flattens() {
        let e = io_err().context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file: boom");
        let e = io_err()
            .with_context(|| format!("step {}", 3))
            .unwrap_err();
        assert_eq!(e.to_string(), "step 3: boom");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(7u32).context("missing").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            io_err()?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "boom");
    }

    #[test]
    fn anyhow_result_context_chains() {
        fn inner() -> Result<()> {
            bail!("root {}", 42)
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: root 42");
        let _ = anyhow!("standalone");
    }
}
