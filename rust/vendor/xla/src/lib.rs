//! Offline stand-in for the `xla` crate (the xla-rs bindings over
//! `xla_extension`), providing exactly the API surface this repo uses.
//!
//! * [`Literal`] is fully functional on the host (f32/i32 storage, shape,
//!   reshape, readback) — the runtime's literal<->tensor conversions and
//!   every unit test that only moves host data work unchanged.
//! * PJRT entry points ([`PjRtClient::compile`],
//!   [`HloModuleProto::from_text_file`], execution, device buffers) return
//!   [`Error`] — on this image there is no `xla_extension` shared library,
//!   so engines built from AOT artifacts fail at load time with a clear
//!   message instead of at link time. Integration tests already skip when
//!   the artifacts directory is absent.
//!
//! Swap back to the real bindings by repointing the `xla` path dependency
//! in `rust/Cargo.toml`.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the real `xla` crate (xla_extension) — this build \
         uses the offline host-literal stub (rust/vendor/xla)"
    ))
}

// ---------------------------------------------------------------------------
// Literals (fully functional on the host)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host literal: typed buffer + dims. Row-major, like the real crate.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Element types [`Literal`] can hold in this stub.
pub trait NativeType: Copy + Sized {
    fn wrap(v: &[Self]) -> Data;
    fn unwrap(d: &Data) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: &[Self]) -> Data {
        Data::F32(v.to_vec())
    }

    fn unwrap(d: &Data) -> Result<Vec<Self>> {
        match d {
            Data::F32(v) => Ok(v.clone()),
            Data::I32(_) => Err(Error("literal holds i32, asked for f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: &[Self]) -> Data {
        Data::I32(v.to_vec())
    }

    fn unwrap(d: &Data) -> Result<Vec<Self>> {
        match d {
            Data::I32(v) => Ok(v.clone()),
            Data::F32(_) => Err(Error("literal holds f32, asked for i32".into())),
        }
    }
}

/// Array shape of a literal (dims only; element type is implicit here).
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v) }
    }

    fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(Error(format!(
                "cannot reshape {} elements to {:?}",
                self.len(),
                dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Tuple literals only come out of PJRT execution, which the stub
    /// cannot perform.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("decomposing an executable's tuple output"))
    }
}

// ---------------------------------------------------------------------------
// HLO + PJRT surface (stubs that fail at runtime, not at link time)
// ---------------------------------------------------------------------------

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing HLO text {path}")))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtDevice {
    _private: (),
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host readback"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Succeeds: creating the client allocates nothing; failures surface at
    /// artifact compile/upload time with a precise message.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling an HLO computation"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("host-to-device upload"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_validates() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
        assert!(l.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn pjrt_surface_fails_cleanly() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto { _private: () });
        assert!(client.compile(&comp).is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let lit = Literal::vec1(&[0f32]);
        assert!(client.buffer_from_host_literal(None, &lit).is_err());
        assert!(lit.to_tuple().is_err());
    }
}
