//! Fig. 7 reproduction: model accuracy vs single-expert activation ratio
//! for sensitivity-based (AdapMoE) vs score-based (Adap-gating) gating.
//!
//! MMLU/ARC substitution (DESIGN.md): held-out next-token top-1 accuracy +
//! NLL on the synthetic eval split, measured through the full serving stack
//! (instant link — gating changes outputs, not transfer timing).
//!
//! Expected shape: both curves flat near ratio 0; the score-based curve
//! degrades earlier/steeper as the ratio grows; sensitivity-based holds
//! accuracy to higher ratios. Run: `cargo bench --bench fig7_accuracy`.

use adapmoe::bench_support::{artifacts_dir, eval_accuracy, eval_stream, instant_settings, scaled};
use adapmoe::coordinator::engine::Engine;
use adapmoe::coordinator::gating::GatingPolicy;
use adapmoe::coordinator::policy;
use adapmoe::coordinator::profile::Profile;
use adapmoe::memory::quant::QuantKind;
use adapmoe::util::timer::Table;

fn main() {
    let Some(dir) = artifacts_dir() else { return };
    let eval = eval_stream(&dir).expect("eval stream");
    let profile = Profile::load(&dir).expect("profile");
    let window = 36;
    let max_windows = scaled(24);

    let settings = instant_settings(32, QuantKind::Int4);

    // threshold sweeps spanning ratio ~0 .. ~0.9
    let sens_scales = [0.0, 1.0, 16.0, 256.0, 8192.0];
    let score_mins = [0.995, 0.8, 0.65, 0.55, 0.505];

    println!(
        "\n== Fig. 7: accuracy vs single-expert ratio ({max_windows} windows × {window} ctx tokens) =="
    );
    let mut table = Table::new(&["gating", "param", "single-ratio", "top1-acc", "nll"]);

    for &scale in &sens_scales {
        let gating = GatingPolicy::Sensitivity {
            k: 2,
            threshold: profile.threshold * scale,
            sensitivity: profile.sensitivity.clone(),
        };
        run_row(&dir, &settings, gating, &format!("T={scale}xT0"), &eval, window, max_windows, &mut table);
    }
    for &amin in &score_mins {
        let gating = GatingPolicy::Score { k: 2, alpha_min: amin };
        run_row(&dir, &settings, gating, &format!("a>={amin}"), &eval, window, max_windows, &mut table);
    }
    table.print();
    println!("(paper shape: sensitivity-based tolerates higher ratios at iso-accuracy)");
}

#[allow(clippy::too_many_arguments)]
fn run_row(
    dir: &std::path::PathBuf,
    settings: &policy::RunSettings,
    gating: GatingPolicy,
    param: &str,
    eval: &adapmoe::model::tokenizer::EvalStream,
    window: usize,
    max_windows: usize,
    table: &mut Table,
) {
    let name = gating.name().to_string();
    let profile = Profile::load(dir).expect("profile");
    let mut ecfg = policy::method("adapmoe", settings, &profile).expect("cfg");
    ecfg.gating = gating;
    let mut engine = Engine::from_artifacts(dir, ecfg).expect("engine");
    let (acc, nll) = eval_accuracy(&mut engine, eval, window, max_windows).expect("accuracy");
    let ratio = engine.trace.mean_single_ratio();
    table.row(&[
        name,
        param.to_string(),
        format!("{:.1}%", ratio * 100.0),
        format!("{:.1}%", acc * 100.0),
        format!("{nll:.3}"),
    ]);
}
