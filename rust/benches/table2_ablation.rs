//! Table 2 reproduction: per-token latency breakdown of the proposed
//! techniques (gating / prefetch / DP-cache combos) on the rtx4090 preset
//! with a 50%-of-experts cache, 4-bit experts — mirroring the paper's
//! "Mixtral-8x7b 4bit on 4090 with 128 cached experts" setup scaled to
//! this model (32 of 64 experts).
//!
//! Expected shape: every technique helps alone; all three combined win
//! (paper: 1.36×). Run: `cargo bench --bench table2_ablation`.

use adapmoe::bench_support::{artifacts_dir, decode_eval, eval_stream, scaled, timed_settings};
use adapmoe::coordinator::engine::Engine;
use adapmoe::coordinator::policy;
use adapmoe::coordinator::profile::Profile;
use adapmoe::memory::quant::QuantKind;
use adapmoe::util::timer::Table;

fn main() {
    let Some(dir) = artifacts_dir() else { return };
    let eval = eval_stream(&dir).expect("eval stream");
    let profile = Profile::load(&dir).expect("profile");
    let tokens = scaled(96);
    let settings = timed_settings(32, QuantKind::Int4, "rtx4090");

    // (label, gating, prefetch, dp-cache) — the paper's seven rows.
    let rows = [
        ("baseline", false, false, false),
        ("baseline+gating", true, false, false),
        ("baseline+prefetch", false, true, false),
        ("baseline+gating+cache", true, false, true),
        ("baseline+prefetch+cache", false, true, true),
        ("baseline+gating+prefetch", true, true, false),
        ("all (AdapMoE)", true, true, true),
    ];

    println!("\n== Table 2: technique ablation ({tokens} eval tokens/row, rtx4090, int4, cache=32/64) ==");
    println!("(p50 per-token latency — robust to single-core scheduler bursts)");
    let mut table = Table::new(&["technique", "latency(s/token)", "speedup"]);
    let mut base_latency = 0.0f64;
    for (label, gating, prefetching, dp_cache) in rows {
        let ecfg = policy::ablation(gating, prefetching, dp_cache, &settings, &profile);
        let mut engine = Engine::from_artifacts(&dir, ecfg).expect("engine");
        decode_eval(&mut engine, &eval, tokens, 0).expect("decode");
        let lat = engine.trace.token_latency.p50();
        if base_latency == 0.0 {
            base_latency = lat;
        }
        table.row(&[
            label.to_string(),
            format!("{lat:.4}"),
            if lat > 0.0 { format!("{:.2}x", base_latency / lat) } else { "-".into() },
        ]);
    }
    table.print();
    println!("(paper: gating 1.25x, prefetch 1.22x, all 1.36x — shape should match)");
}
