//! Fig. 2 reproduction: expert weight-score distributions.
//!
//! (a) mean normalized top-1 score α per layer; (b/c) per-layer α
//! histograms (shown as sparklines) — demonstrating the skew that makes
//! adaptive gating possible. Run: `cargo bench --bench fig2_scores`.

use adapmoe::bench_support::{artifacts_dir, decode_eval, eval_stream, instant_settings, scaled};
use adapmoe::bench_support::method_engine;
use adapmoe::memory::quant::QuantKind;
use adapmoe::util::timer::Table;

fn main() {
    let Some(dir) = artifacts_dir() else { return };
    let eval = eval_stream(&dir).expect("eval stream");
    let tokens = scaled(200);

    // top-k gating so every token contributes an unbiased α sample
    let settings = instant_settings(32, QuantKind::Int4);
    let mut engine = method_engine(&dir, "mixtral-offloading", &settings).expect("engine");
    decode_eval(&mut engine, &eval, tokens, 0).expect("decode");

    println!("\n== Fig. 2: top-1 normalized score α per layer ({tokens} eval tokens) ==");
    let mut table = Table::new(&["layer", "alpha_mean", "hist α∈[0.5,1.0] (20 bins)"]);
    let am = engine.trace.alpha_mean();
    for (layer, hist) in engine.trace.alpha_hist.iter().enumerate() {
        table.row(&[
            format!("{layer}"),
            format!("{:.3}", am[layer]),
            hist.sparkline(),
        ]);
    }
    table.print();
    println!("(paper shape: biased distributions — α mass well above 0.5 in every layer)");
}
