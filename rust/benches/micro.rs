//! Micro benchmarks: per-component latencies + the Fig. 1 motivation
//! numbers (where does decode time go under offloading?) + overlap
//! efficiency of the two-stream scheduler.
//!
//! Run: `cargo bench --bench micro`.

use std::sync::Arc;
use std::time::Instant;

use adapmoe::bench_support::{artifacts_dir, decode_eval, eval_stream, method_engine, scaled, timed_settings};
use adapmoe::coordinator::cache_plan::{plan, PlanInputs};
use adapmoe::coordinator::executor::{run_layer_parallel, run_layer_serial};
use adapmoe::coordinator::gating::GatingPolicy;
use adapmoe::coordinator::scheduler::{build_plan, ScheduleMode};
use adapmoe::memory::device_cache::DeviceCache;
use adapmoe::memory::host_store::HostStore;
use adapmoe::memory::platform::Platform;
use adapmoe::memory::quant::{QuantKind, QuantTensor};
use adapmoe::memory::sharded_cache::{Placement, ShardedCache};
use adapmoe::memory::tiered_store::{PrecisionPolicy, TieredStore};
use adapmoe::memory::faults::FaultPlan;
use adapmoe::memory::transfer::{LaneConfig, LanePolicy, Priority, TransferEngine};
use adapmoe::model::config::ModelConfig;
use adapmoe::model::weights::Weights;
use adapmoe::net::{connect_store, ArtifactImage, ChaosKnobs, StoreServer};
use adapmoe::runtime::{f32_literal, tensor_to_literal, Runtime};
use adapmoe::tensor::Tensor;
use adapmoe::testutil::synthetic_weights;
use adapmoe::util::json::Json;
use adapmoe::util::rng::Rng;
use adapmoe::util::threadpool::ThreadPool;
use adapmoe::util::timer::{fmt_duration, measure, Bench, Table};

/// MoE-phase drain: serial plan-order waits vs the completion-driven
/// executor, with the calibrated (slow) simulated link and transfers
/// arriving in **inverted** plan order — the head-of-line-blocking regime.
/// Needs no artifacts: host-math FFNs over synthetic weights.
fn moe_pipeline_case() {
    let cfg = ModelConfig {
        name: "bench-moe".into(),
        vocab_size: 64,
        d_model: 128,
        n_heads: 2,
        head_dim: 64,
        n_layers: 1,
        n_experts: 8,
        top_k: 2,
        d_ff: 512,
        max_seq: 8,
        rms_eps: 1e-5,
        batch_sizes: vec![1, 4, 16],
    };
    let weights = synthetic_weights(&cfg, 42);
    let store = Arc::new(HostStore::build(&cfg, &weights, QuantKind::Int4).unwrap());
    let n = cfg.n_experts;

    println!("\n=== MoE-phase drain: serial vs completion-driven (rtx4090 link, int4, time_scale=1.0) ===");
    println!("(8 on-demand experts whose transfers arrive in inverted plan order)");
    let mut table = Table::new(&[
        "batch", "drain", "wall (ms)", "stall (ms)", "queue-delay (ms)",
    ]);
    for &b in &[1usize, 4, 16] {
        let mut rng = Rng::new(7 + b as u64);
        let x = Tensor::new(
            vec![b, cfg.d_model],
            (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
        )
        .unwrap();
        let coef: Vec<Vec<f32>> = (0..n)
            .map(|e| vec![1.0 / (e as f32 + 2.0); b])
            .collect();
        for mode in ["serial", "completion"] {
            let cache = Arc::new(DeviceCache::new(vec![2]));
            let xfer = TransferEngine::new(
                Arc::clone(&store),
                Arc::clone(&cache),
                Platform::preset("rtx4090").unwrap(),
                4,
                1.0,
            );
            // enqueue so arrivals run 7, 6, ..., 0 — the inverse of plan order
            for e in (0..n).rev() {
                xfer.request((0, e), Priority::Prefetch);
            }
            let computes: Vec<usize> = (0..n).collect();
            let plan = build_plan(0, &computes, &[], &cache, &xfer);
            // pool spawned outside the timed region — thread startup is
            // engine-construction cost, not per-layer drain cost
            let pool = ThreadPool::new(4);
            let t0 = Instant::now();
            let out = if mode == "serial" {
                run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &cache)
            } else {
                run_layer_parallel(
                    &plan,
                    &x,
                    &coef,
                    ScheduleMode::ExpertWise,
                    4,
                    &cache,
                    &xfer,
                    &pool,
                )
            };
            let wall = t0.elapsed().as_secs_f64();
            table.row(&[
                format!("{b}"),
                mode.to_string(),
                format!("{:.1}", wall * 1e3),
                format!("{:.1}", out.stall_ns as f64 / 1e6),
                format!("{:.1}", out.queue_delay_ns as f64 / 1e6),
            ]);
        }
    }
    table.print();
    println!("(completion-driven stall must be strictly lower at batch >= 4: pending-expert");
    println!(" compute overlaps the remaining transfers instead of head-of-line blocking)");
}

/// Multi-lane drain: the same inverted-arrival completion-driven drain as
/// [`moe_pipeline_case`], at 1 vs 2 vs 4 comm lanes. With one lane the
/// eight transfers serialize on a single simulated wire; extra lanes move
/// experts concurrently, so wall-clock and stall drop as lanes are added.
/// Needs no artifacts.
fn lane_drain_case() {
    let cfg = ModelConfig {
        name: "bench-lanes".into(),
        vocab_size: 64,
        d_model: 128,
        n_heads: 2,
        head_dim: 64,
        n_layers: 1,
        n_experts: 8,
        top_k: 2,
        d_ff: 512,
        max_seq: 8,
        rms_eps: 1e-5,
        batch_sizes: vec![1, 4, 16],
    };
    let weights = synthetic_weights(&cfg, 43);
    let store = Arc::new(HostStore::build(&cfg, &weights, QuantKind::Int4).unwrap());
    let n = cfg.n_experts;

    println!("\n=== multi-lane drain: completion-driven, 1 vs 2 vs 4 comm lanes (rtx4090, int4) ===");
    println!("(8 on-demand experts, inverted enqueue order, round-robin lane assignment)");
    let mut table = Table::new(&[
        "batch", "lanes", "wall (ms)", "stall (ms)", "queue-delay (ms)",
    ]);
    let mut rows = Vec::new();
    for &b in &[1usize, 4, 16] {
        let mut rng = Rng::new(11 + b as u64);
        let x = Tensor::new(
            vec![b, cfg.d_model],
            (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
        )
        .unwrap();
        let coef: Vec<Vec<f32>> = (0..n)
            .map(|e| vec![1.0 / (e as f32 + 2.0); b])
            .collect();
        for &lanes in &[1usize, 2, 4] {
            let cache = Arc::new(DeviceCache::new(vec![2]));
            let xfer = TransferEngine::with_lanes(
                Arc::clone(&store),
                Arc::clone(&cache),
                Platform::preset("rtx4090").unwrap(),
                4,
                1.0,
                LaneConfig::new(lanes, LanePolicy::RoundRobin),
            );
            for e in (0..n).rev() {
                xfer.request((0, e), Priority::Prefetch);
            }
            let computes: Vec<usize> = (0..n).collect();
            let plan = build_plan(0, &computes, &[], &cache, &xfer);
            let pool = ThreadPool::new(4);
            let t0 = Instant::now();
            let out = run_layer_parallel(
                &plan,
                &x,
                &coef,
                ScheduleMode::ExpertWise,
                4,
                &cache,
                &xfer,
                &pool,
            );
            let wall = t0.elapsed().as_secs_f64();
            table.row(&[
                format!("{b}"),
                format!("{lanes}"),
                format!("{:.1}", wall * 1e3),
                format!("{:.1}", out.stall_ns as f64 / 1e6),
                format!("{:.1}", out.queue_delay_ns as f64 / 1e6),
            ]);
            rows.push(Json::obj(vec![
                ("batch", Json::Num(b as f64)),
                ("lanes", Json::Num(lanes as f64)),
                ("wall_ms", Json::Num(wall * 1e3)),
                ("stall_ms", Json::Num(out.stall_ns as f64 / 1e6)),
                ("queue_delay_ms", Json::Num(out.queue_delay_ns as f64 / 1e6)),
            ]));
        }
    }
    table.print();
    let artifact = Json::obj(vec![
        ("bench", Json::Str("lanes".into())),
        ("platform", Json::Str("rtx4090".into())),
        ("quant", Json::Str("int4".into())),
        ("experts", Json::Num(n as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    match std::fs::write("BENCH_lanes.json", artifact.to_string() + "\n") {
        Ok(()) => println!("(perf trajectory written to BENCH_lanes.json)"),
        Err(e) => println!("(could not write BENCH_lanes.json: {e})"),
    }
    println!("(wall-clock must shrink as lanes are added: each lane is an independent");
    println!(" simulated wire, so the eight transfers overlap instead of serializing)");
}

/// Hot-path drain: row-major baseline vs the expert-major hot path at
/// b = 1/4/16 and 1 vs 4 comm lanes. The baseline submits eight singleton
/// transfer requests and drains them serially in plan order with the
/// row-major kernel; the hot path lets the planner coalesce the misses
/// into per-device group jobs and drains completion-driven with the
/// grouped (expert-major, pooled-scratch) kernel. The wire is the
/// `instant` platform at time_scale 0 so wall-clock measures compute and
/// orchestration — exactly the part the expert-major rework changes —
/// and both drains must produce bit-identical accumulators
/// (rust/tests/hotpath.rs locks the same invariant down). Needs no
/// artifacts.
fn hotpath_drain_case() {
    let cfg = ModelConfig {
        name: "bench-hotpath".into(),
        vocab_size: 64,
        d_model: 128,
        n_heads: 2,
        head_dim: 64,
        n_layers: 1,
        n_experts: 8,
        top_k: 2,
        d_ff: 512,
        max_seq: 8,
        rms_eps: 1e-5,
        batch_sizes: vec![1, 4, 16],
    };
    let weights = synthetic_weights(&cfg, 47);
    let store = Arc::new(HostStore::build(&cfg, &weights, QuantKind::Int4).unwrap());
    let n = cfg.n_experts;

    println!("\n=== hot-path drain: row-major serial vs expert-major coalesced (instant wire, int4) ===");
    println!("(8 experts per layer; wire removed so wall-clock isolates compute + orchestration)");
    let mut table = Table::new(&[
        "batch", "lanes", "row-major (ms)", "expert-major (ms)", "speedup", "wire jobs",
    ]);
    let mut rows = Vec::new();
    for &b in &[1usize, 4, 16] {
        let mut rng = Rng::new(13 + b as u64);
        let x = Tensor::new(
            vec![b, cfg.d_model],
            (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
        )
        .unwrap();
        let coef: Vec<Vec<f32>> = (0..n)
            .map(|e| vec![1.0 / (e as f32 + 2.0); b])
            .collect();
        for &lanes in &[1usize, 4] {
            // One timed drain; `grouped` picks the submission shape and
            // kernel. Fresh cache/engine per run so every rep replays the
            // same all-miss decode layer.
            let run = |grouped: bool| {
                let cache = Arc::new(DeviceCache::new(vec![2]));
                let xfer = TransferEngine::with_lanes(
                    Arc::clone(&store),
                    Arc::clone(&cache),
                    Platform::preset("instant").unwrap(),
                    4,
                    0.0,
                    LaneConfig::new(lanes, LanePolicy::RoundRobin),
                );
                if !grouped {
                    // Historical shape: one wire job per expert.
                    for e in (0..n).rev() {
                        xfer.request((0, e), Priority::Prefetch);
                    }
                }
                let computes: Vec<usize> = (0..n).collect();
                let plan = build_plan(0, &computes, &[], &cache, &xfer);
                let pool = ThreadPool::new(4);
                let t0 = Instant::now();
                let out = if grouped {
                    run_layer_parallel(
                        &plan,
                        &x,
                        &coef,
                        ScheduleMode::ExpertWise,
                        4,
                        &cache,
                        &xfer,
                        &pool,
                    )
                } else {
                    run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &cache)
                };
                let wall = t0.elapsed().as_secs_f64();
                xfer.quiesce().unwrap();
                use std::sync::atomic::Ordering::Relaxed;
                (wall, out, xfer.stats.wire_jobs.load(Relaxed))
            };
            // Best-of-3 per shape; keep one outcome per shape for the
            // bit-identity check.
            let (mut wall_row, mut wall_grp) = (f64::INFINITY, f64::INFINITY);
            let (mut out_row, mut out_grp) = (None, None);
            let (mut jobs_row, mut jobs_grp) = (0u64, 0u64);
            for _ in 0..3 {
                let (w, o, j) = run(false);
                wall_row = wall_row.min(w);
                out_row = Some(o);
                jobs_row = j;
                let (w, o, j) = run(true);
                wall_grp = wall_grp.min(w);
                out_grp = Some(o);
                jobs_grp = j;
            }
            let (out_row, out_grp) = (out_row.unwrap(), out_grp.unwrap());
            assert_eq!(
                out_row.acc.data, out_grp.acc.data,
                "hot-path drains must stay bit-identical (b={b} lanes={lanes})"
            );
            let speedup = wall_row / wall_grp;
            table.row(&[
                format!("{b}"),
                format!("{lanes}"),
                format!("{:.2}", wall_row * 1e3),
                format!("{:.2}", wall_grp * 1e3),
                format!("{speedup:.2}x"),
                format!("{jobs_grp} vs {jobs_row}"),
            ]);
            rows.push(Json::obj(vec![
                ("batch", Json::Num(b as f64)),
                ("lanes", Json::Num(lanes as f64)),
                ("row_major_ms", Json::Num(wall_row * 1e3)),
                ("expert_major_ms", Json::Num(wall_grp * 1e3)),
                ("speedup", Json::Num(speedup)),
                ("wire_jobs_row_major", Json::Num(jobs_row as f64)),
                ("wire_jobs_expert_major", Json::Num(jobs_grp as f64)),
            ]));
        }
    }
    table.print();
    let artifact = Json::obj(vec![
        ("bench", Json::Str("hotpath".into())),
        ("platform", Json::Str("instant".into())),
        ("quant", Json::Str("int4".into())),
        ("experts", Json::Num(n as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    match std::fs::write("BENCH_hotpath.json", artifact.to_string() + "\n") {
        Ok(()) => println!("(perf trajectory written to BENCH_hotpath.json)"),
        Err(e) => println!("(could not write BENCH_hotpath.json: {e})"),
    }
    println!("(speedup must clear 1.2x at batch 16: the grouped kernel reuses pooled");
    println!(" scratch and the drain overlaps experts, while wire jobs drop 8 -> 1)");
}

/// Sharded-device drain: the inverted-arrival completion-driven drain at
/// 1 vs 2 vs 4 device backends, lanes == devices so every device owns one
/// comm lane. Unlike [`lane_drain_case`] the cache *capacity* scales with
/// the device count too (each shard brings its own per-layer budget) —
/// lanes buy wire bandwidth, devices buy bandwidth AND memory. Needs no
/// artifacts.
fn device_drain_case() {
    let cfg = ModelConfig {
        name: "bench-devices".into(),
        vocab_size: 64,
        d_model: 128,
        n_heads: 2,
        head_dim: 64,
        n_layers: 1,
        n_experts: 8,
        top_k: 2,
        d_ff: 512,
        max_seq: 8,
        rms_eps: 1e-5,
        batch_sizes: vec![1, 4, 16],
    };
    let weights = synthetic_weights(&cfg, 44);
    let store = Arc::new(HostStore::build(&cfg, &weights, QuantKind::Int4).unwrap());
    let n = cfg.n_experts;

    println!("\n=== sharded-device drain: 1 vs 2 vs 4 device backends (rtx4090, int4, hash placement) ===");
    println!("(8 on-demand experts, inverted enqueue order, one lane per device, 2 cache slots per shard)");
    let mut table = Table::new(&[
        "batch", "devices", "wall (ms)", "stall (ms)", "queue-delay (ms)", "capacity",
    ]);
    let mut rows = Vec::new();
    for &b in &[1usize, 4, 16] {
        let mut rng = Rng::new(13 + b as u64);
        let x = Tensor::new(
            vec![b, cfg.d_model],
            (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
        )
        .unwrap();
        let coef: Vec<Vec<f32>> = (0..n)
            .map(|e| vec![1.0 / (e as f32 + 2.0); b])
            .collect();
        for &devices in &[1usize, 2, 4] {
            let cache = Arc::new(ShardedCache::new(
                vec![vec![2]; devices],
                Placement::ExpertHash,
            ));
            let xfer = TransferEngine::with_devices(
                Arc::clone(&store),
                Arc::clone(&cache),
                Platform::preset("rtx4090").unwrap(),
                4,
                1.0,
                LaneConfig::new(devices, LanePolicy::RoundRobin),
            );
            for e in (0..n).rev() {
                xfer.request((0, e), Priority::Prefetch);
            }
            let computes: Vec<usize> = (0..n).collect();
            let plan = build_plan(0, &computes, &[], &cache, &xfer);
            let pool = ThreadPool::new(4);
            let t0 = Instant::now();
            let out = run_layer_parallel(
                &plan,
                &x,
                &coef,
                ScheduleMode::ExpertWise,
                4,
                &cache,
                &xfer,
                &pool,
            );
            let wall = t0.elapsed().as_secs_f64();
            let capacity: usize = xfer
                .device_snapshots()
                .iter()
                .map(|s| s.capacity)
                .sum();
            table.row(&[
                format!("{b}"),
                format!("{devices}"),
                format!("{:.1}", wall * 1e3),
                format!("{:.1}", out.stall_ns as f64 / 1e6),
                format!("{:.1}", out.queue_delay_ns as f64 / 1e6),
                format!("{capacity}"),
            ]);
            rows.push(Json::obj(vec![
                ("batch", Json::Num(b as f64)),
                ("devices", Json::Num(devices as f64)),
                ("wall_ms", Json::Num(wall * 1e3)),
                ("stall_ms", Json::Num(out.stall_ns as f64 / 1e6)),
                ("queue_delay_ms", Json::Num(out.queue_delay_ns as f64 / 1e6)),
                ("capacity", Json::Num(capacity as f64)),
            ]));
        }
    }
    table.print();
    let artifact = Json::obj(vec![
        ("bench", Json::Str("devices".into())),
        ("platform", Json::Str("rtx4090".into())),
        ("quant", Json::Str("int4".into())),
        ("placement", Json::Str("expert-hash".into())),
        ("experts", Json::Num(n as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    match std::fs::write("BENCH_devices.json", artifact.to_string() + "\n") {
        Ok(()) => println!("(perf trajectory written to BENCH_devices.json)"),
        Err(e) => println!("(could not write BENCH_devices.json: {e})"),
    }
    println!("(wall-clock shrinks like the lane table — each device's lane is an independent");
    println!(" wire — while aggregate cache capacity grows with the device count)");
}

/// Tiered-precision drain: the completion-driven drain over a
/// `--tiers int2,int4` store with the urgency policy — on-demand loads
/// ride the int2 encoding (fewest bytes on the stall path), prefetches
/// the int4 one. The table attributes bytes moved per tier alongside the
/// drain's stall/queue-delay, so the low-tier share of the wire is
/// visible directly. Needs no artifacts.
fn tier_drain_case() {
    let cfg = ModelConfig {
        name: "bench-tiers".into(),
        vocab_size: 64,
        d_model: 128,
        n_heads: 2,
        head_dim: 64,
        n_layers: 1,
        n_experts: 8,
        top_k: 2,
        d_ff: 512,
        max_seq: 8,
        rms_eps: 1e-5,
        batch_sizes: vec![1, 4, 16],
    };
    let weights = synthetic_weights(&cfg, 45);
    let tiers = Arc::new(
        TieredStore::build(&cfg, &weights, &[QuantKind::Int2, QuantKind::Int4]).unwrap(),
    );
    let n = cfg.n_experts;

    println!(
        "\n=== tiered-precision drain: --tiers int2,int4, urgency policy (rtx4090, \
         4 on-demand + 4 prefetch) ==="
    );
    println!("(evens load on demand at int2, odds prefetch at int4, inverted enqueue order)");
    let mut table = Table::new(&[
        "batch", "tier", "transfers", "bytes moved", "stall (ms)", "queue-delay (ms)",
    ]);
    let mut rows = Vec::new();
    for &b in &[1usize, 4, 16] {
        let mut rng = Rng::new(17 + b as u64);
        let x = Tensor::new(
            vec![b, cfg.d_model],
            (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
        )
        .unwrap();
        let coef: Vec<Vec<f32>> = (0..n)
            .map(|e| vec![1.0 / (e as f32 + 2.0); b])
            .collect();
        let cache = Arc::new(DeviceCache::new(vec![2]));
        let xfer = TransferEngine::with_tiers(
            Arc::clone(&tiers),
            PrecisionPolicy::Urgency,
            Arc::new(ShardedCache::single(Arc::clone(&cache))),
            Platform::preset("rtx4090").unwrap(),
            4,
            1.0,
            LaneConfig::default(),
        );
        for e in (0..n).rev() {
            if e % 2 == 0 {
                xfer.request((0, e), Priority::OnDemand);
            } else {
                xfer.request((0, e), Priority::Prefetch);
            }
        }
        let computes: Vec<usize> = (0..n).collect();
        let plan = build_plan(0, &computes, &[], &cache, &xfer);
        let pool = ThreadPool::new(4);
        let out = run_layer_parallel(
            &plan,
            &x,
            &coef,
            ScheduleMode::ExpertWise,
            4,
            &cache,
            &xfer,
            &pool,
        );
        for snap in xfer.tier_snapshots() {
            let qd = out
                .queue_delay_by_tier
                .get(&snap.kind.tier_index())
                .copied()
                .unwrap_or(0);
            table.row(&[
                format!("{b}"),
                snap.kind.name().to_string(),
                format!("{}", snap.transfers),
                format!("{}", snap.bytes),
                format!("{:.1}", out.stall_ns as f64 / 1e6),
                format!("{:.1}", qd as f64 / 1e6),
            ]);
            rows.push(Json::obj(vec![
                ("batch", Json::Num(b as f64)),
                ("tier", Json::Str(snap.kind.name().into())),
                ("transfers", Json::Num(snap.transfers as f64)),
                ("bytes", Json::Num(snap.bytes as f64)),
                ("stall_ms", Json::Num(out.stall_ns as f64 / 1e6)),
                ("queue_delay_ms", Json::Num(qd as f64 / 1e6)),
            ]));
        }
    }
    table.print();
    let artifact = Json::obj(vec![
        ("bench", Json::Str("tiers".into())),
        ("platform", Json::Str("rtx4090".into())),
        ("tiers", Json::Str("int2,int4".into())),
        ("policy", Json::Str("urgency".into())),
        ("experts", Json::Num(n as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    match std::fs::write("BENCH_tiers.json", artifact.to_string() + "\n") {
        Ok(()) => println!("(perf trajectory written to BENCH_tiers.json)"),
        Err(e) => println!("(could not write BENCH_tiers.json: {e})"),
    }
    println!("(the int2 rows carry the compute-stalling loads at a fraction of the int4");
    println!(" byte volume — the win the urgency-driven bitwidth selection buys)");
}

/// Fault-layer overhead: the two-lane completion-driven drain under
/// three regimes — fault-free, one lane dead mid-drain (failover), and a
/// retry storm (one lane drops every admit). The per-regime wall/stall
/// figures are also written to `BENCH_faults.json` so CI keeps a recorded
/// perf trajectory for the degraded paths. Needs no artifacts.
fn faults_drain_case() {
    let cfg = ModelConfig {
        name: "bench-faults".into(),
        vocab_size: 64,
        d_model: 128,
        n_heads: 2,
        head_dim: 64,
        n_layers: 1,
        n_experts: 8,
        top_k: 2,
        d_ff: 512,
        max_seq: 8,
        rms_eps: 1e-5,
        batch_sizes: vec![4],
    };
    let weights = synthetic_weights(&cfg, 46);
    let store = Arc::new(HostStore::build(&cfg, &weights, QuantKind::Int4).unwrap());
    let n = cfg.n_experts;
    let b = 4usize;
    let mut rng = Rng::new(19);
    let x = Tensor::new(
        vec![b, cfg.d_model],
        (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
    )
    .unwrap();
    let coef: Vec<Vec<f32>> = (0..n)
        .map(|e| vec![1.0 / (e as f32 + 2.0); b])
        .collect();

    println!("\n=== fault-layer drain: fault-free vs dead lane vs retry storm (rtx4090, int4, 2 lanes) ===");
    println!("(8 on-demand experts; the chaos regimes must finish with zero dropped experts)");
    let mut table = Table::new(&[
        "regime", "wall (ms)", "stall (ms)", "retries", "failovers", "dropped",
    ]);
    let mut rows = Vec::new();
    for regime in ["fault-free", "dead-lane", "retry-storm"] {
        let cache = Arc::new(DeviceCache::new(vec![2]));
        let xfer = TransferEngine::with_lanes(
            Arc::clone(&store),
            Arc::clone(&cache),
            Platform::preset("rtx4090").unwrap(),
            4,
            1.0,
            LaneConfig::new(2, LanePolicy::RoundRobin),
        );
        if regime == "retry-storm" {
            // lane 0 drops every job it admits: each of its experts costs
            // one timeout-free retry hop onto lane 1
            xfer.apply_fault_plan(&FaultPlan::parse("0:flaky:0:1").unwrap(), 0);
        }
        for e in (0..n).rev() {
            xfer.request((0, e), Priority::Prefetch);
        }
        let computes: Vec<usize> = (0..n).collect();
        let plan = build_plan(0, &computes, &[], &cache, &xfer);
        if regime == "dead-lane" {
            xfer.halt_lane(1);
        }
        let pool = ThreadPool::new(4);
        let t0 = Instant::now();
        let out = run_layer_parallel(
            &plan,
            &x,
            &coef,
            ScheduleMode::ExpertWise,
            4,
            &cache,
            &xfer,
            &pool,
        );
        let wall = t0.elapsed().as_secs_f64();
        let report = xfer.quiesce().expect("chaos drain must quiesce clean");
        table.row(&[
            regime.to_string(),
            format!("{:.1}", wall * 1e3),
            format!("{:.1}", out.stall_ns as f64 / 1e6),
            format!("{}", report.retries),
            format!("{}", report.failovers),
            format!("{}", out.dropped.len()),
        ]);
        rows.push(Json::obj(vec![
            ("regime", Json::Str(regime.into())),
            ("wall_ms", Json::Num(wall * 1e3)),
            ("stall_ms", Json::Num(out.stall_ns as f64 / 1e6)),
            ("retries", Json::Num(report.retries as f64)),
            ("timeouts", Json::Num(report.timeouts as f64)),
            ("failovers", Json::Num(report.failovers as f64)),
            ("failed", Json::Num(report.failed.len() as f64)),
            ("consumed", Json::Num(out.consumed.len() as f64)),
            ("recovered", Json::Num(out.recovered as f64)),
            ("dropped", Json::Num(out.dropped.len() as f64)),
        ]));
    }
    table.print();
    let artifact = Json::obj(vec![
        ("bench", Json::Str("faults".into())),
        ("platform", Json::Str("rtx4090".into())),
        ("quant", Json::Str("int4".into())),
        ("lanes", Json::Num(2.0)),
        ("experts", Json::Num(n as f64)),
        ("batch", Json::Num(b as f64)),
        ("regimes", Json::Arr(rows)),
    ]);
    match std::fs::write("BENCH_faults.json", artifact.to_string() + "\n") {
        Ok(()) => println!("(perf trajectory written to BENCH_faults.json)"),
        Err(e) => println!("(could not write BENCH_faults.json: {e})"),
    }
    println!("(dead-lane adds one failover hop, retry-storm one retry per lane-0 expert;");
    println!(" both must keep dropped at 0 — degradation only begins past the retry budget)");
}

/// Local vs remote expert sourcing: the completion-driven drain with the
/// store (a) host-resident, (b) behind a loopback artifact server, and
/// (c) behind a *flaky* artifact server (periodic corrupt payloads +
/// dropped connections, absorbed by the transport's bounded retries). The
/// wire clocks charge identical simulated bytes in all three regimes —
/// what the table shows is the real fetch latency and retry traffic the
/// remote hop adds (docs/remote-store.md). Written to `BENCH_remote.json`.
/// Needs no artifacts.
fn remote_drain_case() {
    let cfg = ModelConfig {
        name: "bench-remote".into(),
        vocab_size: 64,
        d_model: 128,
        n_heads: 2,
        head_dim: 64,
        n_layers: 1,
        n_experts: 8,
        top_k: 2,
        d_ff: 512,
        max_seq: 8,
        rms_eps: 1e-5,
        batch_sizes: vec![4],
    };
    let weights = synthetic_weights(&cfg, 47);
    let local = Arc::new(TieredStore::build(&cfg, &weights, &[QuantKind::Int4]).unwrap());
    let image = Arc::new(ArtifactImage::from_tiered(&local, cfg.d_model, cfg.d_ff));
    let n = cfg.n_experts;
    let b = 4usize;
    let mut rng = Rng::new(23);
    let x = Tensor::new(
        vec![b, cfg.d_model],
        (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
    )
    .unwrap();
    let coef: Vec<Vec<f32>> = (0..n)
        .map(|e| vec![1.0 / (e as f32 + 2.0); b])
        .collect();

    println!("\n=== expert sourcing: local vs remote vs flaky-remote store (rtx4090, int4) ===");
    println!("(8 on-demand experts over a loopback artifact server; identical simulated wire bytes)");
    let mut table = Table::new(&[
        "source", "wall (ms)", "stall (ms)", "remote KiB", "fetch (ms)", "retries", "reconnects",
    ]);
    let mut rows = Vec::new();
    // servers outlive their engines: each connection must stay answerable
    // through the whole drain
    let mut servers = Vec::new();
    for source in ["local", "remote", "remote-flaky"] {
        let tiers = match source {
            "local" => Arc::clone(&local),
            _ => {
                let knobs = if source == "remote-flaky" {
                    // periodic faults, never two in a row — converges
                    // within the transport's bounded attempts
                    ChaosKnobs { corrupt_every: 5, drop_every: 8, ..ChaosKnobs::default() }
                } else {
                    ChaosKnobs::default()
                };
                let srv = StoreServer::spawn_chaotic(Arc::clone(&image), "127.0.0.1:0", knobs)
                    .expect("loopback artifact server");
                let (store, _manifest) = connect_store(&srv.local_addr()).expect("connect");
                servers.push(srv);
                Arc::new(store)
            }
        };
        let cache = Arc::new(DeviceCache::new(vec![2]));
        let xfer = TransferEngine::with_tiers(
            Arc::clone(&tiers),
            PrecisionPolicy::Fixed,
            Arc::new(ShardedCache::single(Arc::clone(&cache))),
            Platform::preset("rtx4090").unwrap(),
            4,
            1.0,
            LaneConfig::default(),
        );
        for e in (0..n).rev() {
            xfer.request((0, e), Priority::Prefetch);
        }
        let computes: Vec<usize> = (0..n).collect();
        let plan = build_plan(0, &computes, &[], &cache, &xfer);
        let pool = ThreadPool::new(4);
        let t0 = Instant::now();
        let out = run_layer_parallel(
            &plan,
            &x,
            &coef,
            ScheduleMode::ExpertWise,
            4,
            &cache,
            &xfer,
            &pool,
        );
        let wall = t0.elapsed().as_secs_f64();
        xfer.quiesce().expect("remote drain must quiesce clean");
        let s = xfer.source_snapshot();
        table.row(&[
            source.to_string(),
            format!("{:.1}", wall * 1e3),
            format!("{:.1}", out.stall_ns as f64 / 1e6),
            format!("{:.1}", s.remote_bytes as f64 / 1024.0),
            format!("{:.2}", s.fetch_ms),
            format!("{}", s.retries),
            format!("{}", s.reconnects),
        ]);
        rows.push(Json::obj(vec![
            ("source", Json::Str(source.into())),
            ("wall_ms", Json::Num(wall * 1e3)),
            ("stall_ms", Json::Num(out.stall_ns as f64 / 1e6)),
            ("local_bytes", Json::Num(s.local_bytes as f64)),
            ("remote_bytes", Json::Num(s.remote_bytes as f64)),
            ("fetches", Json::Num(s.fetches as f64)),
            ("fetch_ms", Json::Num(s.fetch_ms)),
            ("retries", Json::Num(s.retries as f64)),
            ("checksum_failures", Json::Num(s.checksum_failures as f64)),
            ("reconnects", Json::Num(s.reconnects as f64)),
            ("remote_faults", Json::Num(s.remote_faults as f64)),
        ]));
    }
    table.print();
    let artifact = Json::obj(vec![
        ("bench", Json::Str("remote".into())),
        ("platform", Json::Str("rtx4090".into())),
        ("quant", Json::Str("int4".into())),
        ("experts", Json::Num(n as f64)),
        ("batch", Json::Num(b as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    match std::fs::write("BENCH_remote.json", artifact.to_string() + "\n") {
        Ok(()) => println!("(perf trajectory written to BENCH_remote.json)"),
        Err(e) => println!("(could not write BENCH_remote.json: {e})"),
    }
    println!("(remote rows pay each expert's wire fetch exactly once — the flaky row adds");
    println!(" only retry/reconnect traffic, never a dropped expert or different bits)");
}

fn main() {
    moe_pipeline_case();
    lane_drain_case();
    hotpath_drain_case();
    device_drain_case();
    tier_drain_case();
    faults_drain_case();
    remote_drain_case();

    let Some(dir) = artifacts_dir() else { return };
    let (cfg, manifest) = ModelConfig::load_manifest(&dir).expect("manifest");
    let weights = Weights::load(&dir.join("weights.bin")).expect("weights");

    // ---- runtime component latencies ------------------------------------
    let rt = Runtime::load_all(&dir, &manifest).expect("runtime");
    let mut bench = Bench::new("runtime components (b1)");
    let d = cfg.d_model;
    let x = f32_literal(&vec![0.1; d], &[1, d]).unwrap();
    let (w1, w3, w2) = weights.expert(0, 0).unwrap();
    let (w1l, w3l, w2l) = (
        tensor_to_literal(w1).unwrap(),
        tensor_to_literal(w3).unwrap(),
        tensor_to_literal(w2).unwrap(),
    );
    let coef = f32_literal(&[1.0], &[1]).unwrap();
    bench.run_with("expert_ffn_b1 (Pallas kernel)", 3, 30, || {
        rt.run("expert_ffn_b1", &[&x, &w1l, &w3l, &w2l, &coef]).unwrap();
    });
    let norm = tensor_to_literal(weights.get("l0.moe_norm").unwrap()).unwrap();
    let gate = tensor_to_literal(weights.get("l0.gate").unwrap()).unwrap();
    bench.run_with("gate_b1", 3, 30, || {
        rt.run("gate_b1", &[&x, &norm, &gate]).unwrap();
    });

    // ---- quant codec ------------------------------------------------------
    let mut bench = Bench::new("quant codec (one expert, int4)");
    let vals: Vec<f32> = {
        let mut rng = Rng::new(0);
        (0..cfg.expert_params()).map(|_| rng.f32() - 0.5).collect()
    };
    bench.run("quantize", || {
        QuantTensor::quantize(&vals, QuantKind::Int4);
    });
    let q = QuantTensor::quantize(&vals, QuantKind::Int4);
    bench.run("dequantize", || {
        q.dequantize();
    });

    // ---- transfer engine ---------------------------------------------------
    let store = Arc::new(HostStore::build(&cfg, &weights, QuantKind::Int4).unwrap());
    let cache = Arc::new(DeviceCache::new(vec![cfg.n_experts; cfg.n_layers]));
    let xfer = TransferEngine::new(
        Arc::clone(&store),
        Arc::clone(&cache),
        Platform::preset("rtx4090").unwrap(),
        4,
        1.0,
    );
    let s = measure(
        || {
            xfer.request((0, 0), Priority::OnDemand).wait_full();
        },
        1,
        5,
    );
    println!("\n=== transfer: one int4 expert over calibrated rtx4090 link ===");
    println!(
        "  per-expert load: {} (paper-scale: ~4ms for Mixtral-8x7b 4bit)",
        fmt_duration(s.mean())
    );

    // ---- gating + DP planner (host-side coordinator overhead) -------------
    let mut bench = Bench::new("coordinator overhead");
    let pol = GatingPolicy::Sensitivity {
        k: 2,
        threshold: 0.1,
        sensitivity: vec![1.0; cfg.n_layers],
    };
    let probs: Vec<f32> = (0..cfg.n_experts).map(|i| 1.0 / (i as f32 + 1.5)).collect();
    bench.run_with("gating decide (1 token)", 10, 50, || {
        std::hint::black_box(pol.decide(3, &probs));
    });
    let inputs = PlanInputs {
        n_experts: cfg.n_experts,
        budget: 32,
        alpha: vec![0.2; cfg.n_layers],
        beta: vec![0.7; cfg.n_layers],
    };
    bench.run_with("DP cache plan (full)", 2, 20, || {
        std::hint::black_box(plan(&inputs));
    });

    // ---- Fig. 1 motivation: where does decode time go? --------------------
    let eval = eval_stream(&dir).expect("eval");
    let tokens = scaled(24);
    println!("\n=== Fig. 1 motivation: time split under offloading (rtx4090, int4, cache=16) ===");
    for method in ["baseline", "adapmoe"] {
        let settings = timed_settings(16, QuantKind::Int4, "rtx4090");
        let mut engine = method_engine(&dir, method, &settings).expect("engine");
        decode_eval(&mut engine, &eval, tokens, 0).expect("decode");
        let total = engine.trace.token_latency.sum();
        let stall = engine.trace.stall_ns as f64 / 1e9;
        println!(
            "  {:20} per-token {:.1}ms | blocked on loads {:.0}% | overlap efficiency {:.0}%",
            method,
            1e3 * engine.trace.token_latency.mean(),
            100.0 * stall / total,
            100.0 * (1.0 - stall / total),
        );
    }
    println!("(paper Fig. 1: on-demand loading dominates the baseline timeline)");

    // ---- Fig. 6 design ablation: expert-wise vs tile-wise scheduling ------
    use adapmoe::coordinator::engine::Engine;
    use adapmoe::coordinator::policy;
    use adapmoe::coordinator::profile::Profile;
    let profile = Profile::load(&dir).expect("profile");
    println!("\n=== Fig. 6 ablation: expert-wise vs tile-wise on-demand consumption ===");
    for (name, mode) in [("expert-wise", ScheduleMode::ExpertWise), ("tile-wise", ScheduleMode::TileWise)] {
        let settings = timed_settings(16, QuantKind::Int4, "rtx4090");
        let mut ecfg = policy::method("adapmoe", &settings, &profile).expect("cfg");
        ecfg.schedule = mode;
        let mut engine = Engine::from_artifacts(&dir, ecfg).expect("engine");
        decode_eval(&mut engine, &eval, tokens, 0).expect("decode");
        println!(
            "  {:12} per-token p50 {:.1}ms | stall {:.1}ms/tok",
            name,
            1e3 * engine.trace.token_latency.p50(),
            engine.trace.stall_ns as f64 / 1e6 / engine.trace.token_latency.len() as f64,
        );
    }
    println!("(tile-wise should shave part of each on-demand wait — Fig. 6(b))");
}
