//! Fig. 3 reproduction: cosine similarity between the input of layer i's
//! MoE block and layer i+1's — the residual-stream consistency that makes
//! gate-reuse prefetching accurate (Observation 2).
//!
//! Measured online by the engine during decode; compared against the
//! offline python profile series. Run: `cargo bench --bench fig3_similarity`.

use adapmoe::bench_support::{artifacts_dir, decode_eval, eval_stream, instant_settings, method_engine, scaled};
use adapmoe::coordinator::profile::Profile;
use adapmoe::memory::quant::QuantKind;
use adapmoe::util::timer::Table;

fn main() {
    let Some(dir) = artifacts_dir() else { return };
    let eval = eval_stream(&dir).expect("eval stream");
    let profile = Profile::load(&dir).expect("profile");
    let tokens = scaled(200);

    let settings = instant_settings(32, QuantKind::Int4);
    let mut engine = method_engine(&dir, "mixtral-offloading", &settings).expect("engine");
    engine.trace.enable_similarity(); // gated off by default on the hot path
    decode_eval(&mut engine, &eval, tokens, 0).expect("decode");

    println!("\n== Fig. 3: successive-layer MoE-input cosine similarity ({tokens} eval tokens) ==");
    let online = engine.trace.similarity();
    let mut table = Table::new(&["layer pair", "online (rust)", "offline (python)"]);
    for (i, &s) in online.iter().enumerate() {
        let offline = profile
            .similarity
            .get(i)
            .map(|v| format!("{v:.3}"))
            .unwrap_or_else(|| "-".into());
        table.row(&[format!("{i}->{}", i + 1), format!("{s:.3}"), offline]);
    }
    table.print();
    println!("(paper shape: high similarity, rising with depth — enables gate reuse)");
}
