//! Fig. 9 reproduction: per-layer breakdown of the three adaptive
//! mechanisms.
//!
//! (a) single-expert activation ratios, score-based vs sensitivity-based
//!     at the same mean ratio (sensitivity keeps early layers conservative);
//! (b) expert prefetch prediction accuracy per layer (layer 0 = trained
//!     predictive gate, others = gate reuse);
//! (c) DP cache allocation per layer at the paper's 50% budget.
//!
//! Run: `cargo bench --bench fig9_breakdown`.

use adapmoe::bench_support::{
    artifacts_dir, decode_eval, eval_stream, instant_settings, scaled, timed_settings,
};
use adapmoe::coordinator::cache_plan;
use adapmoe::coordinator::engine::Engine;
use adapmoe::coordinator::gating::{calibrate_score_threshold, GatingPolicy};
use adapmoe::coordinator::policy;
use adapmoe::coordinator::profile::Profile;
use adapmoe::memory::quant::QuantKind;
use adapmoe::memory::sharded_cache::Placement;
use adapmoe::memory::tiered_store::PrecisionPolicy;
use adapmoe::memory::transfer::LanePolicy;
use adapmoe::util::timer::Table;

fn main() {
    let Some(dir) = artifacts_dir() else { return };
    let eval = eval_stream(&dir).expect("eval stream");
    let profile = Profile::load(&dir).expect("profile");
    let tokens = scaled(240);
    let settings = instant_settings(32, QuantKind::Int4);

    // --- sensitivity-based engine (also yields Fig 9(b) β and the trace) ---
    let ecfg = policy::method("adapmoe", &settings, &profile).expect("cfg");
    let mut sens_engine = Engine::from_artifacts(&dir, ecfg).expect("engine");
    decode_eval(&mut sens_engine, &eval, tokens, 0).expect("decode");
    let sens_ratio = sens_engine.trace.mean_single_ratio();

    // --- score-based engine calibrated to the same mean ratio -------------
    // calibrate on an α trace gathered from the sensitivity run's histogram
    // is biased; instead calibrate on a fresh top-k trace.
    let mut probe = {
        let c = policy::method("mixtral-offloading", &settings, &profile).expect("cfg");
        Engine::from_artifacts(&dir, c).expect("probe engine")
    };
    decode_eval(&mut probe, &eval, scaled(120), 3).expect("probe decode");
    // build a (layer, probs)-like trace from α means is not enough: use the
    // analytic calibration over the recorded α histogram instead.
    let trace_pairs = alpha_trace(&probe);
    let alpha_min = calibrate_score_threshold(&trace_pairs, 2, sens_ratio);

    let mut score_cfg = policy::method("adapmoe", &settings, &profile).expect("cfg");
    score_cfg.gating = GatingPolicy::Score { k: 2, alpha_min };
    let mut score_engine = Engine::from_artifacts(&dir, score_cfg).expect("engine");
    decode_eval(&mut score_engine, &eval, tokens, 0).expect("decode");

    println!("\n== Fig. 9(a): single-expert ratio per layer (mean ratio ≈ {:.0}%) ==", sens_ratio * 100.0);
    let mut t = Table::new(&["layer", "score-based", "sensitivity-based"]);
    let s1 = score_engine.trace.single_ratio();
    let s2 = sens_engine.trace.single_ratio();
    for l in 0..sens_engine.cfg.n_layers {
        t.row(&[
            format!("{l}"),
            format!("{:.1}%", s1[l] * 100.0),
            format!("{:.1}%", s2[l] * 100.0),
        ]);
    }
    t.print();
    println!("(paper shape: sensitivity-based activates MORE experts in early layers)");

    println!("\n== Fig. 9(b): prefetch prediction accuracy per layer ==");
    let mut t = Table::new(&["layer", "beta (online)", "beta (offline prior)", "predictor"]);
    let beta = sens_engine.trace.beta();
    for l in 0..sens_engine.cfg.n_layers {
        t.row(&[
            format!("{l}"),
            format!("{:.2}", beta[l]),
            format!("{:.2}", profile.beta[l]),
            if l == 0 { "pre-gate (trained)".into() } else { "gate reuse".to_string() },
        ]);
    }
    t.print();

    println!("\n== Fig. 9(c): DP cache allocation (budget 32 of 64 experts) ==");
    let inputs = cache_plan::PlanInputs {
        n_experts: sens_engine.cfg.n_experts,
        budget: 32,
        alpha: profile.alpha.clone(),
        beta: profile.beta.clone(),
    };
    let plan = cache_plan::plan(&inputs);
    let mut t = Table::new(&["layer", "alpha", "beta", "cache slots"]);
    for l in 0..plan.allocation.len() {
        t.row(&[
            format!("{l}"),
            format!("{:.2}", profile.alpha[l]),
            format!("{:.2}", profile.beta[l]),
            format!("{} {}", plan.allocation[l], "#".repeat(plan.allocation[l])),
        ]);
    }
    t.print();
    println!(
        "expected on-demand loads/token: {:.3} (uniform: {:.3})",
        plan.expected_loads,
        cache_plan::allocation_cost(&inputs, &vec![4; plan.allocation.len()])
    );

    // --- pipeline attribution: queue delay vs stall per layer --------------
    // Timed run on the calibrated link: shows how much of the MoE wait is
    // head-of-line queueing (removed by arrival-order consumption) vs the
    // irreducible wait for the simulated PCIe link.
    println!("\n== completion-driven pipeline: where the MoE wait goes (rtx4090, int4, 2 pinned lanes) ==");
    let mut timed = timed_settings(16, QuantKind::Int4, "rtx4090");
    timed.n_lanes = 2;
    timed.lane_policy = LanePolicy::Pinned;
    let mut pipe_engine = {
        let cfg = policy::method("adapmoe", &timed, &profile).expect("cfg");
        Engine::from_artifacts(&dir, cfg).expect("engine")
    };
    decode_eval(&mut pipe_engine, &eval, scaled(48), 0).expect("decode");
    let mut t = Table::new(&["layer", "on-demand", "queue-delay (ms)", "stall (ms)"]);
    for (l, (q, s)) in pipe_engine.trace.stall_attribution().iter().enumerate() {
        t.row(&[
            format!("{l}"),
            format!("{}", pipe_engine.trace.on_demand[l]),
            format!("{:.2}", q * 1e3),
            format!("{:.2}", s * 1e3),
        ]);
    }
    t.print();
    println!("(queue delay = arrived data waiting on compute; stall = compute idle on the link)");

    // Per-lane attribution: lane 0 is pinned to on-demand loads, the rest
    // carry prefetches — where did the head-of-line cost ride?
    println!("\n== per-lane attribution (lane 0 reserved for on-demand) ==");
    let lane_delay = pipe_engine.trace.lane_queue_delay();
    let mut t = Table::new(&[
        "lane", "transfers", "on-demand", "prefetch", "busy (ms)", "queue-delay (ms)",
    ]);
    for snap in pipe_engine.xfer.lane_snapshots() {
        t.row(&[
            format!("{}", snap.lane),
            format!("{}", snap.transfers),
            format!("{}", snap.on_demand),
            format!("{}", snap.prefetch),
            format!("{:.1}", snap.busy_ms),
            format!("{:.2}", lane_delay.get(snap.lane).unwrap_or(&0.0) * 1e3),
        ]);
    }
    t.print();
    println!("(prefetch queue delay is overlap working as intended; on-demand queue delay is waste)");

    // Per-device shard attribution: the same adaptive config over two
    // device backends (hash placement, one lane per device) — where did
    // the cache traffic land, and did either shard back up?
    println!("\n== per-device cache shards (2 devices, hash placement, lane per device) ==");
    let mut sharded = timed_settings(16, QuantKind::Int4, "rtx4090");
    sharded.n_lanes = 2;
    sharded.n_devices = 2;
    sharded.placement = Placement::ExpertHash;
    let mut shard_engine = {
        let cfg = policy::method("adapmoe", &sharded, &profile).expect("cfg");
        Engine::from_artifacts(&dir, cfg).expect("engine")
    };
    decode_eval(&mut shard_engine, &eval, scaled(48), 0).expect("decode");
    let mut t = Table::new(&[
        "device", "hits", "misses", "evictions", "resident", "capacity", "queued bytes",
    ]);
    for snap in shard_engine.xfer.device_snapshots() {
        t.row(&[
            format!("{}", snap.device),
            format!("{}", snap.hits),
            format!("{}", snap.misses),
            format!("{}", snap.evictions),
            format!("{}", snap.resident),
            format!("{}", snap.capacity),
            format!("{}", snap.queued_bytes),
        ]);
    }
    t.print();
    let (gh, gm, ge) = shard_engine.cache.stats();
    println!(
        "global: hits {gh} misses {gm} evictions {ge} (per-device rows sum to these — \
         the shard split conserves the single-cache counters)"
    );

    // Per-tier attribution: the tiered mixed-precision store under the
    // urgency policy — on-demand loads ride int2, prefetches int4, idle
    // lanes upgrade residents — where did the bytes and the queue delay
    // ride? (docs/tiered-precision.md)
    println!("\n== per-tier attribution (--tiers int2,int4, urgency policy, upgrade budget 2) ==");
    let mut tiered = timed_settings(16, QuantKind::Int4, "rtx4090");
    tiered.tiers = vec![QuantKind::Int2, QuantKind::Int4];
    tiered.precision = PrecisionPolicy::Urgency;
    tiered.upgrade_budget = 2;
    let mut tier_engine = {
        let cfg = policy::method("adapmoe", &tiered, &profile).expect("cfg");
        Engine::from_artifacts(&dir, cfg).expect("engine")
    };
    decode_eval(&mut tier_engine, &eval, scaled(48), 0).expect("decode");
    let tier_delay = tier_engine.trace.tier_queue_delay();
    let mut t = Table::new(&[
        "tier", "transfers", "bytes moved", "upgrades", "queue-delay (ms)",
    ]);
    for snap in tier_engine.xfer.tier_snapshots() {
        t.row(&[
            snap.kind.name().to_string(),
            format!("{}", snap.transfers),
            format!("{}", snap.bytes),
            format!("{}", snap.upgrades),
            format!(
                "{:.2}",
                tier_delay.get(snap.kind.tier_index()).unwrap_or(&0.0) * 1e3
            ),
        ]);
    }
    t.print();
    println!(
        "degraded hits: {} (resident low-tier copies served instead of stalling on int4)",
        tier_engine.trace.degraded_hits
    );
    println!("(on-demand bytes concentrate in the int2 row: the stall path moves the");
    println!(" cheapest encoding while prefetch/upgrade traffic carries the precision)");
}

/// Reconstruct (layer, top2-prob-pair) samples from the probe's α histogram
/// for score-threshold calibration.
fn alpha_trace(engine: &Engine) -> Vec<(usize, Vec<f32>)> {
    let mut out = Vec::new();
    for (layer, hist) in engine.trace.alpha_hist.iter().enumerate() {
        for (bin, &count) in hist.counts.iter().enumerate() {
            let alpha = 0.5 + (bin as f32 + 0.5) * 0.5 / hist.counts.len() as f32;
            // represent α by a 2-expert prob row; decide() only uses p1/(p1+p2)
            for _ in 0..count {
                out.push((layer, vec![alpha, 1.0 - alpha]));
            }
        }
    }
    out
}
