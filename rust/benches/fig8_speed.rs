//! Fig. 8 reproduction: decode speed of AdapMoE vs baselines across
//! quantization configs, cache sizes and platforms.
//!
//! Paper series: baseline offloading (whole-layer), Mixtral-offloading,
//! Pre-gated MoE, AdapMoE w/o gating, AdapMoE. Expected shape: AdapMoE
//! fastest everywhere (~1.35× over Mixtral-offloading), AdapMoE-no-gating
//! ≈ Pre-gated, whole-layer baseline slowest; gaps shrink as the cache
//! grows; everything scales with link bandwidth and quant byte volume.
//!
//! Run: `cargo bench --bench fig8_speed` (after `make artifacts`).

use adapmoe::bench_support::{
    artifacts_dir, decode_eval, eval_stream, fast_mode, method_engine, scaled, timed_settings,
};
use adapmoe::coordinator::policy::METHODS;
use adapmoe::memory::quant::QuantKind;
use adapmoe::util::timer::Table;

fn main() {
    let Some(dir) = artifacts_dir() else { return };
    let eval = eval_stream(&dir).expect("eval stream");
    let tokens = scaled(24);

    // paper axes: 2 quant configs × cache sizes × 2 platforms
    let quants = [("4bit", QuantKind::Int4), ("4+2bit", QuantKind::Int2)];
    let caches: &[usize] = if fast_mode() { &[32] } else { &[16, 32, 48] };
    // a6000-22b calibrates per-expert transfer times against Mixtral-8x22b
    // experts — the paper's "model sizes" axis.
    let platforms: &[&str] = if fast_mode() {
        &["rtx4090"]
    } else {
        &["rtx4090", "a6000-22b"]
    };

    println!("\n== Fig. 8: decode speed (tokens/s; {tokens} eval tokens per config) ==");
    for &platform in platforms {
        for (qname, quant) in quants {
            let mut headers: Vec<String> = vec!["method".into()];
            headers.extend(caches.iter().map(|c| format!("cache={c}")));
            let mut table = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());

            let mut base_speed = vec![0.0f64; caches.len()];
            for &method in METHODS {
                let mut cells = vec![method.to_string()];
                for (ci, &cache) in caches.iter().enumerate() {
                    let settings = timed_settings(cache, quant, platform);
                    let mut engine = method_engine(&dir, method, &settings).expect("engine");
                    decode_eval(&mut engine, &eval, tokens, 7 * ci).expect("decode");
                    // p50-based rate: robust to single-core scheduler bursts
                    let tps = 1.0 / engine.trace.token_latency.p50().max(1e-9);
                    if method == "mixtral-offloading" {
                        base_speed[ci] = tps;
                    }
                    let speedup = if base_speed[ci] > 0.0 && method != "mixtral-offloading" {
                        format!(" ({:.2}x)", tps / base_speed[ci])
                    } else {
                        String::new()
                    };
                    cells.push(format!("{tps:.2}{speedup}"));
                }
                table.row(&cells);
            }
            println!("\n-- platform={platform} quant={qname} (speedup vs mixtral-offloading) --");
            table.print();
        }
    }
}
