//! Host-side tensor type shared by the weights container, the memory
//! hierarchy and the PJRT runtime boundary.

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", dims, n, data.len());
        }
        Ok(Tensor { dims, data })
    }

    pub fn zeros(dims: Vec<usize>) -> Tensor {
        let n = dims.iter().product();
        Tensor { dims, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let w = self.dims[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// Column-slice [start, end) of the second axis of a rank-2 tensor:
    /// returns a new [rows, end-start] tensor (used for f-tile slicing).
    pub fn col_slice(&self, start: usize, end: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (r, c) = (self.dims[0], self.dims[1]);
        assert!(start < end && end <= c);
        let w = end - start;
        let mut data = Vec::with_capacity(r * w);
        for i in 0..r {
            data.extend_from_slice(&self.data[i * c + start..i * c + end]);
        }
        Tensor { dims: vec![r, w], data }
    }

    /// Row-slice [start, end) of the first axis of a rank-2 tensor.
    pub fn row_slice(&self, start: usize, end: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        let c = self.dims[1];
        assert!(start < end && end <= self.dims[0]);
        Tensor {
            dims: vec![end - start, c],
            data: self.data[start * c..end * c].to_vec(),
        }
    }

    /// Element-wise in-place add.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.dims, other.dims);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// argmax over the last axis for each row of a rank-2 tensor.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.rank(), 2);
        (0..self.dims[0])
            .map(|i| {
                let row = self.row(i);
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn row_and_slices() {
        let t = Tensor::new(vec![2, 4], (0..8).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0, 7.0]);
        let c = t.col_slice(1, 3);
        assert_eq!(c.dims, vec![2, 2]);
        assert_eq!(c.data, vec![1.0, 2.0, 5.0, 6.0]);
        let r = t.row_slice(1, 2);
        assert_eq!(r.data, vec![4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn add_and_scale() {
        let mut a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(vec![3], vec![0.5, 0.5, 0.5]).unwrap();
        a.add_assign(&b);
        a.scale(2.0);
        assert_eq!(a.data, vec![3.0, 5.0, 7.0]);
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0]).unwrap();
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }
}
