//! Test support: synthetic weights/configs shared by unit tests,
//! integration tests and property tests. Compiled into the lib (it has no
//! cost at runtime) so `rust/tests/` can use it too.

use crate::model::config::ModelConfig;
use crate::model::weights::Weights;
use crate::server::service::{Backend, PerfSnapshot};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Deterministic artifact-free [`Backend`]: every decode step maps each
/// row's input token `t` to logits whose argmax is `(t + 1) % 256`, so a
/// greedy generation from prompt "ab" reads "cde…". Lets the full service
/// + TCP protocol stack be tested without PJRT artifacts.
pub struct MockBackend {
    active: Vec<bool>,
    pos: Vec<usize>,
    max_seq: usize,
    /// Sleep per decode step — widens the cancellation window so tests can
    /// reliably intercept in-flight requests.
    pub step_delay: std::time::Duration,
    /// Return an error from decode_step after this many successful steps.
    pub fail_after: Option<u64>,
    steps: u64,
}

impl MockBackend {
    pub fn new(slots: usize, max_seq: usize) -> MockBackend {
        MockBackend {
            active: vec![false; slots],
            pos: vec![0; slots],
            max_seq,
            step_delay: std::time::Duration::ZERO,
            fail_after: None,
            steps: 0,
        }
    }
}

impl Backend for MockBackend {
    fn acquire_slot(&mut self) -> Option<usize> {
        let row = self.active.iter().position(|a| !a)?;
        self.active[row] = true;
        self.pos[row] = 0;
        Some(row)
    }

    fn release_slot(&mut self, row: usize) {
        self.active[row] = false;
        self.pos[row] = 0;
    }

    fn slot_full(&self, row: usize) -> bool {
        self.pos[row] >= self.max_seq
    }

    fn decode_step(
        &mut self,
        inputs: &[(usize, u32)],
    ) -> anyhow::Result<Vec<(usize, Vec<f32>)>> {
        if let Some(n) = self.fail_after {
            if self.steps >= n {
                anyhow::bail!("mock backend failure injected after {n} steps");
            }
        }
        self.steps += 1;
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let mut outs = Vec::with_capacity(inputs.len());
        for &(row, t) in inputs {
            assert!(self.active[row], "row {row} not active");
            self.pos[row] += 1;
            let mut logits = vec![0.0f32; 256];
            logits[((t + 1) % 256) as usize] = 1.0;
            outs.push((row, logits));
        }
        Ok(outs)
    }

    fn perf(&self) -> PerfSnapshot {
        // Nonzero histograms so protocol tests can assert the histogram and
        // quantile fields survive the stats/metrics round trip.
        let token_hist = crate::util::stats::LogHistogram::default();
        token_hist.record(0.00001);
        token_hist.record(0.0001);
        token_hist.record(0.001);
        let lane_queue_hist = crate::util::stats::LogHistogram::default();
        lane_queue_hist.record(0.0002);
        lane_queue_hist.record(0.002);
        PerfSnapshot {
            tokens_per_sec: self.steps as f64,
            token_p50_ms: 0.01,
            token_p99_ms: 0.02,
            // Nonzero per-consumer counters so protocol tests can assert
            // the sensitivity block survives the stats round trip.
            sensitivity: crate::memory::transfer::SensitivitySnapshot {
                tier_assigns: 5,
                plans: 4,
                evictions: 3,
                prefetches: 2,
                upgrades: 1,
            },
            token_hist,
            lane_queue_hist,
            ..PerfSnapshot::default()
        }
    }
}

/// Micro config mirroring `python/compile/config.py::micro_config`.
pub fn micro_config() -> ModelConfig {
    ModelConfig {
        name: "micro".into(),
        vocab_size: 64,
        d_model: 32,
        n_heads: 2,
        head_dim: 16,
        n_layers: 2,
        n_experts: 8,
        top_k: 2,
        d_ff: 64,
        max_seq: 64,
        rms_eps: 1e-5,
        batch_sizes: vec![1, 4],
    }
}

/// Random full model weights (experts + attention + norms + gates) for a
/// config — enough for every host-side substrate test.
pub fn synthetic_weights(cfg: &ModelConfig, seed: u64) -> Weights {
    let mut rng = Rng::new(seed);
    let mut w = Weights::default();
    let d = cfg.d_model;
    let mut put = |name: String, dims: Vec<usize>, rng: &mut Rng, scale: f32| {
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 2.0 * scale).collect();
        w.tensors.insert(name, Tensor::new(dims, data).unwrap());
    };
    put("embed".into(), vec![cfg.vocab_size, d], &mut rng, 0.02);
    put("out_norm".into(), vec![d], &mut rng, 1.0);
    put("unembed".into(), vec![d, cfg.vocab_size], &mut rng, 0.1);
    put("pre_gate".into(), vec![d, cfg.n_experts], &mut rng, 0.1);
    for l in 0..cfg.n_layers {
        put(format!("l{l}.attn_norm"), vec![d], &mut rng, 1.0);
        for m in ["wq", "wk", "wv", "wo"] {
            put(format!("l{l}.{m}"), vec![d, d], &mut rng, 0.1);
        }
        put(format!("l{l}.moe_norm"), vec![d], &mut rng, 1.0);
        put(format!("l{l}.gate"), vec![d, cfg.n_experts], &mut rng, 0.1);
        for e in 0..cfg.n_experts {
            put(format!("l{l}.e{e}.w1"), vec![d, cfg.d_ff], &mut rng, 0.1);
            put(format!("l{l}.e{e}.w3"), vec![d, cfg.d_ff], &mut rng, 0.1);
            put(format!("l{l}.e{e}.w2"), vec![cfg.d_ff, d], &mut rng, 0.1);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_weights_complete() {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 0);
        assert!(w.get("embed").is_ok());
        assert!(w.expert(1, 7).is_ok());
        assert_eq!(w.get("l0.wq").unwrap().dims, vec![32, 32]);
    }
}
