//! Test support: synthetic weights/configs shared by unit tests,
//! integration tests and property tests. Compiled into the lib (it has no
//! cost at runtime) so `rust/tests/` can use it too.

use crate::model::config::ModelConfig;
use crate::model::weights::Weights;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Micro config mirroring `python/compile/config.py::micro_config`.
pub fn micro_config() -> ModelConfig {
    ModelConfig {
        name: "micro".into(),
        vocab_size: 64,
        d_model: 32,
        n_heads: 2,
        head_dim: 16,
        n_layers: 2,
        n_experts: 8,
        top_k: 2,
        d_ff: 64,
        max_seq: 64,
        rms_eps: 1e-5,
        batch_sizes: vec![1, 4],
    }
}

/// Random full model weights (experts + attention + norms + gates) for a
/// config — enough for every host-side substrate test.
pub fn synthetic_weights(cfg: &ModelConfig, seed: u64) -> Weights {
    let mut rng = Rng::new(seed);
    let mut w = Weights::default();
    let d = cfg.d_model;
    let mut put = |name: String, dims: Vec<usize>, rng: &mut Rng, scale: f32| {
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 2.0 * scale).collect();
        w.tensors.insert(name, Tensor::new(dims, data).unwrap());
    };
    put("embed".into(), vec![cfg.vocab_size, d], &mut rng, 0.02);
    put("out_norm".into(), vec![d], &mut rng, 1.0);
    put("unembed".into(), vec![d, cfg.vocab_size], &mut rng, 0.1);
    put("pre_gate".into(), vec![d, cfg.n_experts], &mut rng, 0.1);
    for l in 0..cfg.n_layers {
        put(format!("l{l}.attn_norm"), vec![d], &mut rng, 1.0);
        for m in ["wq", "wk", "wv", "wo"] {
            put(format!("l{l}.{m}"), vec![d, d], &mut rng, 0.1);
        }
        put(format!("l{l}.moe_norm"), vec![d], &mut rng, 1.0);
        put(format!("l{l}.gate"), vec![d, cfg.n_experts], &mut rng, 0.1);
        for e in 0..cfg.n_experts {
            put(format!("l{l}.e{e}.w1"), vec![d, cfg.d_ff], &mut rng, 0.1);
            put(format!("l{l}.e{e}.w3"), vec![d, cfg.d_ff], &mut rng, 0.1);
            put(format!("l{l}.e{e}.w2"), vec![cfg.d_ff, d], &mut rng, 0.1);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_weights_complete() {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 0);
        assert!(w.get("embed").is_ok());
        assert!(w.expert(1, 7).is_ok());
        assert_eq!(w.get("l0.wq").unwrap().dims, vec![32, 32]);
    }
}
