//! Artifact server: publish a [`TieredStore`]'s experts over the wire.
//!
//! [`ArtifactImage`] freezes a tiered store into a (manifest, blob) pair —
//! every expert encoded at every tier, concatenated, with per-chunk FNV
//! checksums recorded in the manifest. [`StoreServer`] then serves that
//! image over the `crate::net::wire` protocol from a background accept
//! loop (same nonblocking-listener shape as `crate::server::tcp`), so a
//! cacheless coordinator on another process — `examples/expert_server.rs`
//! is the standalone binary — can run entirely against it.
//!
//! [`ChaosKnobs`] makes the server deterministically misbehave for the
//! fault-injection suite: corrupt every k-th range payload *after* the
//! chunk checksums were sealed into the manifest (so the frame verifies
//! but chunk verification fails client-side), or drop every k-th
//! connection mid-request (client sees a short read and reconnects). Both
//! count requests globally across connections, so a single-client test
//! sees an exact fault schedule.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::memory::tiered_store::TieredStore;
use crate::net::checksum::fnv1a;
use crate::net::manifest::{encode_expert, ArtifactEntry, Manifest, DEFAULT_CHUNK};
use crate::net::wire::{
    read_frame, write_frame, WireError, OP_ERR, OP_GET_MANIFEST, OP_GET_RANGE, OP_GET_RANGES,
    OP_MANIFEST, OP_RANGE, OP_RANGES,
};

/// A tiered store frozen into servable bytes: the manifest (already
/// serialized once — it is immutable) plus the artifact blob the range
/// requests index into.
pub struct ArtifactImage {
    pub manifest: Manifest,
    pub manifest_bytes: Vec<u8>,
    pub blob: Vec<u8>,
}

impl ArtifactImage {
    /// Encode every `(tier, layer, expert)` artifact of `store` with
    /// `DEFAULT_CHUNK`-sized checksum chunks.
    pub fn from_tiered(store: &TieredStore, d_model: usize, d_ff: usize) -> ArtifactImage {
        Self::from_tiered_chunked(store, d_model, d_ff, DEFAULT_CHUNK)
    }

    /// Same, with an explicit chunk size (tests use small chunks so a
    /// single expert spans several).
    pub fn from_tiered_chunked(
        store: &TieredStore,
        d_model: usize,
        d_ff: usize,
        chunk_size: u32,
    ) -> ArtifactImage {
        assert!(chunk_size > 0, "chunk size must be positive");
        let (n_layers, n_experts) = (store.n_layers(), store.n_experts());
        let mut blob = Vec::new();
        let mut entries = Vec::with_capacity(store.n_tiers() * n_layers * n_experts);
        for &kind in store.tiers() {
            let hs = store.store(kind);
            for l in 0..n_layers {
                for e in 0..n_experts {
                    let q = hs.get((l, e));
                    let enc = encode_expert(q);
                    let chunks =
                        enc.chunks(chunk_size as usize).map(fnv1a).collect();
                    entries.push(ArtifactEntry {
                        offset: blob.len() as u64,
                        len: enc.len() as u64,
                        transfer_bytes: q.size_bytes() as u64,
                        chunks,
                    });
                    blob.extend_from_slice(&enc);
                }
            }
        }
        let manifest = Manifest {
            n_layers,
            n_experts,
            d_model,
            d_ff,
            expert_bytes_f32: store.expert_bytes_f32() as u64,
            chunk_size,
            tiers: store.tiers().to_vec(),
            entries,
        };
        let manifest_bytes = manifest.encode();
        ArtifactImage { manifest, manifest_bytes, blob }
    }
}

/// Deterministic misbehaviour for the chaos suite. Zero = off (default).
#[derive(Clone, Copy, Debug, Default)]
pub struct ChaosKnobs {
    /// Flip one byte in every k-th range response payload (1-indexed by a
    /// global request counter). The frame checksum is computed over the
    /// corrupted bytes, so the *frame* verifies and the corruption is only
    /// caught by the manifest's chunk checksums — exactly the line-noise
    /// case the integrity layer exists for.
    pub corrupt_every: u64,
    /// Close the connection instead of answering every k-th request —
    /// the client sees a short read and must reconnect.
    pub drop_every: u64,
    /// Pretend to be a server built before `GET_RANGES` existed: answer
    /// the op with `OP_ERR` ("unknown op"), exercising the client's
    /// per-range fallback path.
    pub disable_ranges: bool,
}

/// Background artifact server. Binds on construction (use port 0 for an
/// ephemeral test port — `local_addr` reports the real one); serves until
/// dropped or `shutdown` flips.
pub struct StoreServer {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// Requests answered (all ops), for test assertions.
    served: Arc<AtomicU64>,
}

impl StoreServer {
    pub fn spawn(image: Arc<ArtifactImage>, addr: &str) -> Result<StoreServer, WireError> {
        Self::spawn_chaotic(image, addr, ChaosKnobs::default())
    }

    pub fn spawn_chaotic(
        image: Arc<ArtifactImage>,
        addr: &str,
        knobs: ChaosKnobs,
    ) -> Result<StoreServer, WireError> {
        let listener =
            TcpListener::bind(addr).map_err(|e| WireError::Io(format!("binding {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| WireError::Io(e.to_string()))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| WireError::Io(e.to_string()))?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        // one global request counter so the chaos schedule is exact even
        // across reconnects
        let requests = Arc::new(AtomicU64::new(0));
        let accept_thread = {
            let shutdown = Arc::clone(&shutdown);
            let served = Arc::clone(&served);
            std::thread::Builder::new()
                .name("adapmoe-store-accept".into())
                .spawn(move || {
                    while !shutdown.load(Ordering::SeqCst) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                let image = Arc::clone(&image);
                                let shutdown = Arc::clone(&shutdown);
                                let served = Arc::clone(&served);
                                let requests = Arc::clone(&requests);
                                let _ = std::thread::Builder::new()
                                    .name("adapmoe-store-conn".into())
                                    .spawn(move || {
                                        serve_conn(stream, &image, knobs, &shutdown, &served, &requests)
                                    });
                            }
                            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(2));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .map_err(|e| WireError::Io(format!("spawn acceptor: {e}")))?
        };
        Ok(StoreServer { addr: local, shutdown, accept_thread: Some(accept_thread), served })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> String {
        self.addr.to_string()
    }

    /// Requests answered so far (manifest + range, across connections).
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::SeqCst)
    }

    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

impl Drop for StoreServer {
    fn drop(&mut self) {
        self.stop();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// One connection's request loop. Request frames are read with a short
/// socket timeout so the thread notices `shutdown` while idle; a timeout
/// can only fire *between* frames (the client writes each request frame
/// in one `write_all`, and the loop re-reads from scratch only when zero
/// bytes of the next frame have arrived — mid-frame the blocking reads
/// below run to completion or error).
fn serve_conn(
    stream: TcpStream,
    image: &ArtifactImage,
    knobs: ChaosKnobs,
    shutdown: &AtomicBool,
    served: &AtomicU64,
    requests: &AtomicU64,
) {
    let mut stream = stream;
    let _ = stream.set_nodelay(true);
    while !shutdown.load(Ordering::SeqCst) {
        // Wait (bounded) for the next request's first byte, then read the
        // whole frame in blocking mode.
        let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
        let mut first = [0u8; 1];
        match stream.peek(&mut first) {
            Ok(0) => return, // client closed
            Ok(_) => {}
            Err(ref e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            Err(_) => return,
        }
        let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
        let (op, payload) = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return, // malformed or vanished client: drop the conn
        };
        let n = requests.fetch_add(1, Ordering::SeqCst) + 1;
        if knobs.drop_every > 0 && n % knobs.drop_every == 0 {
            return; // simulated connection loss
        }
        let ok = match op {
            OP_GET_MANIFEST => {
                write_frame(&mut stream, OP_MANIFEST, &image.manifest_bytes).is_ok()
            }
            OP_GET_RANGE => answer_range(&mut stream, image, knobs, n, &payload).is_ok(),
            // With disable_ranges set the op falls through to the
            // unknown-op arm below — the exact answer of an old server.
            OP_GET_RANGES if !knobs.disable_ranges => {
                answer_ranges(&mut stream, image, knobs, n, &payload).is_ok()
            }
            other => {
                let msg = format!("unknown op {other:#04x}");
                write_frame(&mut stream, OP_ERR, msg.as_bytes()).is_ok()
            }
        };
        if !ok {
            return;
        }
        served.fetch_add(1, Ordering::SeqCst);
    }
}

fn answer_range(
    stream: &mut (impl Write + ?Sized),
    image: &ArtifactImage,
    knobs: ChaosKnobs,
    request_n: u64,
    payload: &[u8],
) -> Result<(), WireError> {
    if payload.len() != 16 {
        return write_frame(stream, OP_ERR, b"range request wants 16 payload bytes");
    }
    let offset = u64::from_le_bytes(payload[..8].try_into().expect("8 bytes")) as usize;
    let len = u64::from_le_bytes(payload[8..].try_into().expect("8 bytes")) as usize;
    let end = offset.checked_add(len).filter(|&e| e <= image.blob.len());
    let Some(end) = end else {
        let msg = format!(
            "range {offset}+{len} outside blob of {} bytes",
            image.blob.len()
        );
        return write_frame(stream, OP_ERR, msg.as_bytes());
    };
    let mut bytes = image.blob[offset..end].to_vec();
    if knobs.corrupt_every > 0 && request_n % knobs.corrupt_every == 0 && !bytes.is_empty() {
        // deterministic single-byte flip; the frame checksum below is
        // computed over the corrupted payload, so only the manifest's
        // chunk checksums can catch it
        let at = (request_n as usize * 131) % bytes.len();
        bytes[at] ^= 0x40;
    }
    write_frame(stream, OP_RANGE, &bytes)
}

/// Answer a multi-range request: the payload is a concatenation of
/// `(offset u64 LE, len u64 LE)` pairs; the response is every range's
/// bytes concatenated in request order. Any bad pair rejects the whole
/// request (the client's batch is all-or-nothing and falls back to
/// per-range fetches). The corruption knob flips one byte of the combined
/// payload — one `GET_RANGES` counts as one request on the chaos
/// schedule, like the single round trip it is.
fn answer_ranges(
    stream: &mut (impl Write + ?Sized),
    image: &ArtifactImage,
    knobs: ChaosKnobs,
    request_n: u64,
    payload: &[u8],
) -> Result<(), WireError> {
    if payload.is_empty() || payload.len() % 16 != 0 {
        return write_frame(stream, OP_ERR, b"ranges request wants 16 bytes per range");
    }
    let mut bytes = Vec::new();
    for pair in payload.chunks_exact(16) {
        let offset = u64::from_le_bytes(pair[..8].try_into().expect("8 bytes")) as usize;
        let len = u64::from_le_bytes(pair[8..].try_into().expect("8 bytes")) as usize;
        let end = offset.checked_add(len).filter(|&e| e <= image.blob.len());
        let Some(end) = end else {
            let msg = format!(
                "range {offset}+{len} outside blob of {} bytes",
                image.blob.len()
            );
            return write_frame(stream, OP_ERR, msg.as_bytes());
        };
        bytes.extend_from_slice(&image.blob[offset..end]);
    }
    if knobs.corrupt_every > 0 && request_n % knobs.corrupt_every == 0 && !bytes.is_empty() {
        let at = (request_n as usize * 131) % bytes.len();
        bytes[at] ^= 0x40;
    }
    write_frame(stream, OP_RANGES, &bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::host_store::HostStore;
    use crate::memory::quant::QuantKind;
    use crate::net::manifest::decode_expert;
    use crate::net::wire::RangedReader;
    use crate::testutil::{micro_config, synthetic_weights};

    fn image() -> Arc<ArtifactImage> {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 21);
        let ts =
            TieredStore::build(&cfg, &w, &[QuantKind::Int2, QuantKind::Int8]).unwrap();
        Arc::new(ArtifactImage::from_tiered_chunked(&ts, cfg.d_model, cfg.d_ff, 256))
    }

    fn connect(srv: &StoreServer) -> RangedReader {
        RangedReader::connect(&srv.local_addr(), Duration::from_secs(5)).unwrap()
    }

    #[test]
    fn image_entries_cover_blob_and_verify() {
        let img = image();
        let m = &img.manifest;
        assert_eq!(m.entries.len(), 2 * m.n_layers * m.n_experts);
        let mut expect_offset = 0u64;
        for e in &m.entries {
            assert_eq!(e.offset, expect_offset);
            let bytes = &img.blob[e.offset as usize..(e.offset + e.len) as usize];
            assert_eq!(e.verify(bytes, m.chunk_size), Ok(()));
            let q = decode_expert(bytes).unwrap();
            assert_eq!(q.size_bytes() as u64, e.transfer_bytes);
            expect_offset += e.len;
        }
        assert_eq!(expect_offset as usize, img.blob.len());
    }

    #[test]
    fn serves_manifest_and_ranges_over_loopback() {
        let img = image();
        let srv = StoreServer::spawn(Arc::clone(&img), "127.0.0.1:0").unwrap();
        let mut r = connect(&srv);
        let mbytes = r.fetch_manifest().unwrap();
        let m = Manifest::decode(&mbytes).unwrap();
        assert_eq!(m, img.manifest);
        let e = &m.entries[3];
        let bytes = r.fetch_range(e.offset, e.len).unwrap();
        assert_eq!(e.verify(&bytes, m.chunk_size), Ok(()));
        // several requests on one connection
        let e2 = &m.entries[7];
        let bytes2 = r.fetch_range(e2.offset, e2.len).unwrap();
        assert_eq!(e2.verify(&bytes2, m.chunk_size), Ok(()));
        // the server bumps `served` after writing each response; give its
        // thread a moment to finish the bookkeeping for the last one
        for _ in 0..200 {
            if srv.served() >= 3 {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(srv.served() >= 3, "served={}", srv.served());
    }

    #[test]
    fn serves_multi_ranges_in_one_round_trip() {
        let img = image();
        let srv = StoreServer::spawn(Arc::clone(&img), "127.0.0.1:0").unwrap();
        let mut r = connect(&srv);
        let m = &img.manifest;
        let picks = [3usize, 7, 1];
        let ranges: Vec<(u64, u64)> =
            picks.iter().map(|&i| (m.entries[i].offset, m.entries[i].len)).collect();
        let batched = r.fetch_ranges(&ranges).unwrap();
        assert_eq!(batched.len(), picks.len());
        for (&i, bytes) in picks.iter().zip(&batched) {
            let e = &m.entries[i];
            assert_eq!(bytes.len(), e.len as usize);
            assert_eq!(e.verify(bytes, m.chunk_size), Ok(()));
            // the batch answers exactly what per-range fetches would
            assert_eq!(bytes, &r.fetch_range(e.offset, e.len).unwrap());
        }
        // a bad pair rejects the whole batch, and the connection survives
        let blob_len = img.blob.len() as u64;
        assert!(matches!(
            r.fetch_ranges(&[(0, 8), (blob_len, 16)]),
            Err(WireError::Remote(_))
        ));
        assert!(matches!(r.fetch_ranges(&[]), Err(WireError::Remote(_))));
        assert!(r.fetch_range(m.entries[0].offset, m.entries[0].len).is_ok());
    }

    #[test]
    fn disabled_ranges_answers_unknown_op_like_an_old_server() {
        let img = image();
        let srv = StoreServer::spawn_chaotic(
            Arc::clone(&img),
            "127.0.0.1:0",
            ChaosKnobs { disable_ranges: true, ..ChaosKnobs::default() },
        )
        .unwrap();
        let mut r = connect(&srv);
        let e = &img.manifest.entries[0];
        match r.fetch_ranges(&[(e.offset, e.len)]) {
            Err(WireError::Remote(msg)) => {
                assert!(msg.contains("unknown op"), "msg={msg}")
            }
            other => panic!("expected Remote(unknown op), got {other:?}"),
        }
        // per-range fetches still work on the same connection — the
        // client's fallback path needs no reconnect
        assert!(r.fetch_range(e.offset, e.len).is_ok());
    }

    #[test]
    fn corrupt_every_hits_batched_ranges_too() {
        let img = image();
        let srv = StoreServer::spawn_chaotic(
            Arc::clone(&img),
            "127.0.0.1:0",
            ChaosKnobs { corrupt_every: 1, ..ChaosKnobs::default() },
        )
        .unwrap();
        let mut r = connect(&srv);
        let m = &img.manifest;
        let ranges: Vec<(u64, u64)> =
            (0..2).map(|i| (m.entries[i].offset, m.entries[i].len)).collect();
        // the frame verifies (checksum covers the corrupted bytes)...
        let batched = r.fetch_ranges(&ranges).unwrap();
        // ...but exactly one member fails its chunk checksums
        let bad = (0..2)
            .filter(|&i| m.entries[i].verify(&batched[i], m.chunk_size).is_err())
            .count();
        assert_eq!(bad, 1, "one flipped byte lands in exactly one member");
    }

    #[test]
    fn out_of_range_request_is_remote_error_not_hang() {
        let img = image();
        let srv = StoreServer::spawn(Arc::clone(&img), "127.0.0.1:0").unwrap();
        let mut r = connect(&srv);
        let blob_len = img.blob.len() as u64;
        assert!(matches!(
            r.fetch_range(blob_len, 16),
            Err(WireError::Remote(_))
        ));
        // the connection survives a rejected request
        let e = &img.manifest.entries[0];
        assert!(r.fetch_range(e.offset, e.len).is_ok());
        // overflowing offset+len is rejected, not panicking
        assert!(matches!(
            r.fetch_range(u64::MAX - 4, 16),
            Err(WireError::Remote(_))
        ));
    }

    #[test]
    fn corrupt_every_passes_frame_but_fails_chunks() {
        let img = image();
        let srv = StoreServer::spawn_chaotic(
            Arc::clone(&img),
            "127.0.0.1:0",
            ChaosKnobs { corrupt_every: 1, ..ChaosKnobs::default() },
        )
        .unwrap();
        let mut r = connect(&srv);
        let e = &img.manifest.entries[0];
        // frame-level fetch succeeds (checksum covers the corrupted bytes)
        let bytes = r.fetch_range(e.offset, e.len).unwrap();
        // ...but the manifest's chunk checksums catch the flip
        assert!(e.verify(&bytes, img.manifest.chunk_size).is_err());
    }

    #[test]
    fn drop_every_closes_connection() {
        let img = image();
        let srv = StoreServer::spawn_chaotic(
            Arc::clone(&img),
            "127.0.0.1:0",
            ChaosKnobs { drop_every: 2, ..ChaosKnobs::default() },
        )
        .unwrap();
        let mut r = connect(&srv);
        let e = &img.manifest.entries[0];
        assert!(r.fetch_range(e.offset, e.len).is_ok()); // request 1
        let second = r.fetch_range(e.offset, e.len); // request 2: dropped
        assert!(second.is_err());
        assert!(second.unwrap_err().connection_lost());
        // a fresh connection works again
        let mut r2 = connect(&srv);
        assert!(r2.fetch_range(e.offset, e.len).is_ok()); // request 3
    }
}
