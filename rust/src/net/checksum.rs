//! FNV-1a 64-bit checksums for manifest and artifact-chunk verification.
//!
//! The remote store needs a checksum that is (a) dependency-free, (b) fast
//! enough to run over every fetched chunk on the comm lane, and (c) strong
//! enough that any single-byte corruption is detected with certainty —
//! FNV-1a mixes every input byte into all 64 state bits, so two inputs
//! differing in one byte can never collide at the same length (the
//! property rust/tests/remote.rs locks down). This is *integrity* against
//! line noise and truncation, not *authentication*: a deliberate attacker
//! can forge FNV, which is fine for the trusted-cluster artifact fetch
//! this subsystem models (docs/remote-store.md#integrity).

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64 of `bytes` in one call.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Hasher::new();
    h.update(bytes);
    h.finish()
}

/// Incremental FNV-1a 64 — feed bytes as they stream in, then `finish`.
#[derive(Clone)]
pub struct Hasher {
    state: u64,
}

impl Hasher {
    pub fn new() -> Hasher {
        Hasher { state: FNV_OFFSET }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.state;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.state = h;
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

impl Default for Hasher {
    fn default() -> Hasher {
        Hasher::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Reference vectors from the FNV specification.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut h = Hasher::new();
        for chunk in data.chunks(37) {
            h.update(chunk);
        }
        assert_eq!(h.finish(), fnv1a(&data));
    }

    #[test]
    fn any_single_byte_flip_changes_hash() {
        let data: Vec<u8> = (0..512u32).map(|i| (i * 31 % 251) as u8).collect();
        let base = fnv1a(&data);
        let mut flipped = data.clone();
        for i in 0..data.len() {
            flipped[i] ^= 0x5a;
            assert_ne!(fnv1a(&flipped), base, "flip at {i} undetected");
            flipped[i] = data[i];
        }
    }
}
