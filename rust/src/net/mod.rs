//! Remote expert store: multi-node expert fetch over the wire.
//!
//! The offloading hierarchy historically ended at the local
//! [`crate::memory::host_store::HostStore`] — every byte a transfer moved
//! was already in this process's memory. This module family opens the
//! distributed regime (OD-MoE's cacheless edge nodes, the artifact
//! services of production MoE fleets — PAPERS.md): a coordinator started
//! with `--remote <addr>` holds *no* expert weights; each expert's bytes
//! are pulled from an artifact server on first use, verified, decoded,
//! and pinned host-side, after which everything downstream (tiered
//! transfers, caches, upgrade/retry/failover ladders) behaves exactly as
//! if the store had been local — bit-for-bit.
//!
//! * [`checksum`] — FNV-1a 64 for manifest + chunk integrity.
//! * [`manifest`] — the versioned `(tier, layer, expert)` artifact index
//!   and the artifact byte codec.
//! * [`wire`] — length-prefixed TCP frames, typed [`wire::WireError`]s,
//!   and the [`wire::RangedReader`] client.
//! * [`server`] — [`server::ArtifactImage`] (a frozen `TieredStore`) and
//!   [`server::StoreServer`] (the accept loop `examples/expert_server.rs`
//!   wraps), plus deterministic [`server::ChaosKnobs`] misbehaviour.
//! * [`remote`] — [`remote::RemoteClient`] retry/reconnect policy,
//!   [`remote::RemoteFetcher`] (the `ExpertFetcher` impl), and
//!   [`remote::connect_store`] (what the engine calls).
//!
//! Format, protocol, failure semantics and the determinism argument are
//! specified in docs/remote-store.md.

pub mod checksum;
pub mod manifest;
pub mod remote;
pub mod server;
pub mod wire;

pub use manifest::{ArtifactEntry, Manifest};
pub use remote::{connect_store, RemoteClient, RemoteFetcher};
pub use server::{ArtifactImage, ChaosKnobs, StoreServer};
pub use wire::{RangedReader, WireError};
