//! Versioned, checksum-verified manifest of every `(tier, layer, expert)`
//! artifact — plus the artifact byte codec itself.
//!
//! The manifest is the remote store's source of truth: for each precision
//! tier and each expert it records where the encoded artifact lives in the
//! server's blob (`offset`, `len`), what the transfer engine should charge
//! for it on the simulated link (`transfer_bytes` — exactly the local
//! twin's [`QuantExpert::size_bytes`], which is what keeps remote runs
//! bit-identical in the clock domain), and an FNV-1a checksum per
//! fixed-size chunk so corruption is localized and detected before any
//! byte reaches a cache. The serialized form carries its own trailing
//! checksum; a manifest that fails it never parses. Layout spec:
//! docs/remote-store.md#manifest.

use crate::memory::host_store::QuantExpert;
use crate::memory::quant::{QuantKind, QuantTensor, BLOCK};
use crate::net::checksum::fnv1a;
use crate::net::wire::WireError;

/// Manifest codec version this build reads and writes.
pub const MANIFEST_VERSION: u16 = 1;

/// Serialized-manifest magic: `b"AMMF"` (AdapMoE ManiFest), little-endian.
pub const MANIFEST_MAGIC: u32 = u32::from_le_bytes(*b"AMMF");

/// Default chunk size for artifact checksums (64 KiB).
pub const DEFAULT_CHUNK: u32 = 64 << 10;

/// One `(tier, layer, expert)` artifact's location and verification data.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactEntry {
    /// Byte offset of the encoded artifact in the server blob.
    pub offset: u64,
    /// Encoded length in bytes.
    pub len: u64,
    /// What the transfer engine charges on the simulated link — the local
    /// twin's `QuantExpert::size_bytes()`, *not* the encoded length (the
    /// encoding adds framing the link-model shouldn't see).
    pub transfer_bytes: u64,
    /// FNV-1a 64 per `chunk_size` slice of the encoded bytes (last chunk
    /// ragged). Verified chunk-by-chunk after every fetch.
    pub chunks: Vec<u64>,
}

impl ArtifactEntry {
    /// Verify `bytes` (the full encoded artifact) against the per-chunk
    /// checksums. Returns the index of the first bad chunk.
    pub fn verify(&self, bytes: &[u8], chunk_size: u32) -> Result<(), usize> {
        if bytes.len() as u64 != self.len {
            return Err(0);
        }
        let cs = chunk_size as usize;
        let n_chunks = if self.len == 0 { 0 } else { bytes.len().div_ceil(cs) };
        if n_chunks != self.chunks.len() {
            return Err(0);
        }
        for (i, chunk) in bytes.chunks(cs).enumerate() {
            if fnv1a(chunk) != self.chunks[i] {
                return Err(i);
            }
        }
        Ok(())
    }
}

/// The full artifact index a server publishes and a cacheless coordinator
/// runs against.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    pub n_layers: usize,
    pub n_experts: usize,
    pub d_model: usize,
    pub d_ff: usize,
    /// f32 bytes of one expert — the platform-calibration denominator.
    pub expert_bytes_f32: u64,
    /// Chunk size the per-artifact checksums were computed over.
    pub chunk_size: u32,
    /// Precision tiers, ascending bits, matching a `TieredStore`'s order.
    pub tiers: Vec<QuantKind>,
    /// Entries in tier-major order:
    /// `entries[t * n_layers * n_experts + layer * n_experts + expert]`.
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Entry index for `(kind, layer, expert)`; `None` if the kind is not
    /// a published tier.
    pub fn entry(&self, kind: QuantKind, layer: usize, expert: usize) -> Option<&ArtifactEntry> {
        let t = self.tiers.iter().position(|&k| k == kind)?;
        if layer >= self.n_layers || expert >= self.n_experts {
            return None;
        }
        let per_tier = self.n_layers * self.n_experts;
        Some(&self.entries[t * per_tier + layer * self.n_experts + expert])
    }

    /// Per-expert `transfer_bytes` table for one tier, in the
    /// `layer * n_experts + expert` order [`HostStore::remote`] wants.
    pub fn tier_sizes(&self, kind: QuantKind) -> Option<Vec<usize>> {
        let t = self.tiers.iter().position(|&k| k == kind)?;
        let per_tier = self.n_layers * self.n_experts;
        Some(
            self.entries[t * per_tier..(t + 1) * per_tier]
                .iter()
                .map(|e| e.transfer_bytes as usize)
                .collect(),
        )
    }

    /// Serialize: magic, version, shape, tiers, entries, then an FNV-1a
    /// checksum of everything before it. Little-endian throughout.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&MANIFEST_MAGIC.to_le_bytes());
        out.extend_from_slice(&MANIFEST_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.n_layers as u32).to_le_bytes());
        out.extend_from_slice(&(self.n_experts as u32).to_le_bytes());
        out.extend_from_slice(&(self.d_model as u32).to_le_bytes());
        out.extend_from_slice(&(self.d_ff as u32).to_le_bytes());
        out.extend_from_slice(&self.expert_bytes_f32.to_le_bytes());
        out.extend_from_slice(&self.chunk_size.to_le_bytes());
        out.push(self.tiers.len() as u8);
        for t in &self.tiers {
            out.push(t.tier_index() as u8);
        }
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for e in &self.entries {
            out.extend_from_slice(&e.offset.to_le_bytes());
            out.extend_from_slice(&e.len.to_le_bytes());
            out.extend_from_slice(&e.transfer_bytes.to_le_bytes());
            out.extend_from_slice(&(e.chunks.len() as u32).to_le_bytes());
            for c in &e.chunks {
                out.extend_from_slice(&c.to_le_bytes());
            }
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse + verify a serialized manifest. The trailing checksum is
    /// checked first, so *any* single-byte corruption anywhere in the
    /// buffer is rejected before field parsing begins.
    pub fn decode(bytes: &[u8]) -> Result<Manifest, WireError> {
        if bytes.len() < 8 {
            return Err(WireError::ShortRead { want: 8, got: bytes.len() });
        }
        let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
        let want = u64::from_le_bytes(sum_bytes.try_into().expect("8-byte split"));
        let got = fnv1a(body);
        if got != want {
            return Err(WireError::Corrupt(format!(
                "manifest checksum {got:#018x} != {want:#018x}"
            )));
        }
        let mut r = Reader { buf: body, pos: 0 };
        let magic = r.u32()?;
        if magic != MANIFEST_MAGIC {
            return Err(WireError::BadFrame(format!("manifest magic {magic:#010x}")));
        }
        let version = r.u16()?;
        if version != MANIFEST_VERSION {
            return Err(WireError::VersionMismatch { got: version, want: MANIFEST_VERSION });
        }
        let n_layers = r.u32()? as usize;
        let n_experts = r.u32()? as usize;
        let d_model = r.u32()? as usize;
        let d_ff = r.u32()? as usize;
        let expert_bytes_f32 = r.u64()?;
        let chunk_size = r.u32()?;
        if chunk_size == 0 {
            return Err(WireError::Corrupt("manifest chunk_size 0".into()));
        }
        let n_tiers = r.u8()? as usize;
        let mut tiers = Vec::with_capacity(n_tiers);
        for _ in 0..n_tiers {
            let idx = r.u8()?;
            tiers.push(kind_from_tier_index(idx)?);
        }
        for w in tiers.windows(2) {
            if w[0].bits() >= w[1].bits() {
                return Err(WireError::Corrupt(format!(
                    "manifest tiers not ascending: {} then {}",
                    w[0].name(),
                    w[1].name()
                )));
            }
        }
        let n_entries = r.u32()? as usize;
        if n_entries != n_tiers * n_layers * n_experts {
            return Err(WireError::Corrupt(format!(
                "manifest has {n_entries} entries, shape wants {}",
                n_tiers * n_layers * n_experts
            )));
        }
        let mut entries = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            let offset = r.u64()?;
            let len = r.u64()?;
            let transfer_bytes = r.u64()?;
            let n_chunks = r.u32()? as usize;
            let want_chunks = if len == 0 { 0 } else { (len as usize).div_ceil(chunk_size as usize) };
            if n_chunks != want_chunks {
                return Err(WireError::Corrupt(format!(
                    "entry of {len} bytes carries {n_chunks} chunk sums, wants {want_chunks}"
                )));
            }
            let mut chunks = Vec::with_capacity(n_chunks);
            for _ in 0..n_chunks {
                chunks.push(r.u64()?);
            }
            entries.push(ArtifactEntry { offset, len, transfer_bytes, chunks });
        }
        if r.pos != body.len() {
            return Err(WireError::Corrupt(format!(
                "{} trailing manifest bytes",
                body.len() - r.pos
            )));
        }
        Ok(Manifest {
            n_layers,
            n_experts,
            d_model,
            d_ff,
            expert_bytes_f32,
            chunk_size,
            tiers,
            entries,
        })
    }
}

/// Inverse of [`QuantKind::tier_index`].
fn kind_from_tier_index(idx: u8) -> Result<QuantKind, WireError> {
    match idx {
        0 => Ok(QuantKind::Int2),
        1 => Ok(QuantKind::Int4),
        2 => Ok(QuantKind::Int8),
        3 => Ok(QuantKind::F32),
        _ => Err(WireError::Corrupt(format!("unknown tier index {idx}"))),
    }
}

/// Bounds-checked little-endian reader over a byte slice.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Reader<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8], WireError> {
        // `n` comes off the wire; compare without `pos + n` so a huge
        // length field cannot overflow the bound check.
        if n > self.buf.len() - self.pos {
            return Err(WireError::ShortRead {
                want: n,
                got: self.buf.len() - self.pos,
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("2 bytes")))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
}

// ---------------------------------------------------------------------------
// Artifact byte codec: one QuantExpert <-> encoded bytes.
// ---------------------------------------------------------------------------

fn encode_tensor(out: &mut Vec<u8>, t: &QuantTensor) {
    out.push(t.kind.tier_index() as u8);
    out.extend_from_slice(&(t.len as u64).to_le_bytes());
    out.extend_from_slice(&(t.scales.len() as u32).to_le_bytes());
    for s in &t.scales {
        out.extend_from_slice(&s.to_le_bytes());
    }
    out.extend_from_slice(&(t.mins.len() as u32).to_le_bytes());
    for m in &t.mins {
        out.extend_from_slice(&m.to_le_bytes());
    }
    out.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
    out.extend_from_slice(&t.data);
}

fn decode_tensor(r: &mut Reader) -> Result<QuantTensor, WireError> {
    let kind = kind_from_tier_index(r.u8()?)?;
    let len = r.u64()? as usize;
    // Every count below is implied by (kind, len); validate against the
    // codec's own invariants *before* allocating, so a lying length field
    // is a typed error rather than a giant allocation.
    let want_blocks = if kind == QuantKind::F32 { 0 } else { len.div_ceil(BLOCK) };
    let n_scales = r.u32()? as usize;
    if n_scales != want_blocks {
        return Err(WireError::Corrupt(format!(
            "{} tensor of {len} values claims {n_scales} scale blocks, wants {want_blocks}",
            kind.name()
        )));
    }
    let mut scales = Vec::with_capacity(n_scales);
    for _ in 0..n_scales {
        scales.push(f32::from_le_bytes(r.take(4)?.try_into().expect("4 bytes")));
    }
    let n_mins = r.u32()? as usize;
    if n_mins != want_blocks {
        return Err(WireError::Corrupt(format!(
            "{} tensor of {len} values claims {n_mins} min blocks, wants {want_blocks}",
            kind.name()
        )));
    }
    let mut mins = Vec::with_capacity(n_mins);
    for _ in 0..n_mins {
        mins.push(f32::from_le_bytes(r.take(4)?.try_into().expect("4 bytes")));
    }
    let n_data = r.u64()? as usize;
    if n_data != kind.bytes_for(len) {
        return Err(WireError::Corrupt(format!(
            "{} tensor of {len} values claims {n_data} code bytes, wants {}",
            kind.name(),
            kind.bytes_for(len)
        )));
    }
    let data = r.take(n_data)?.to_vec();
    Ok(QuantTensor { kind, len, scales, mins, data })
}

/// Serialize one quantized expert as an artifact payload.
pub fn encode_expert(q: &QuantExpert) -> Vec<u8> {
    let mut out = Vec::with_capacity(q.size_bytes() + 64);
    out.extend_from_slice(&(q.d as u32).to_le_bytes());
    out.extend_from_slice(&(q.f as u32).to_le_bytes());
    encode_tensor(&mut out, &q.w1);
    encode_tensor(&mut out, &q.w3);
    encode_tensor(&mut out, &q.w2);
    out
}

/// Decode an artifact payload back into a quantized expert, validating
/// every length field against the codec's own invariants. Chunk checksums
/// are verified *before* this runs ([`ArtifactEntry::verify`]), so a
/// decode failure here means a server-side bug, not line corruption — it
/// is still surfaced as a retryable `Corrupt` so a flaky server can't
/// wedge a lane.
pub fn decode_expert(bytes: &[u8]) -> Result<QuantExpert, WireError> {
    let mut r = Reader { buf: bytes, pos: 0 };
    let d = r.u32()? as usize;
    let f = r.u32()? as usize;
    let w1 = decode_tensor(&mut r)?;
    let w3 = decode_tensor(&mut r)?;
    let w2 = decode_tensor(&mut r)?;
    for (name, t, want) in [("w1", &w1, d * f), ("w3", &w3, d * f), ("w2", &w2, f * d)] {
        if t.len != want {
            return Err(WireError::Corrupt(format!(
                "{name} has {} values, dims {d}x{f} want {want}",
                t.len
            )));
        }
    }
    if r.pos != bytes.len() {
        return Err(WireError::Corrupt(format!(
            "{} trailing artifact bytes",
            bytes.len() - r.pos
        )));
    }
    Ok(QuantExpert { w1, w3, w2, d, f })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::host_store::HostStore;
    use crate::testutil::{micro_config, synthetic_weights};

    fn sample_manifest() -> Manifest {
        Manifest {
            n_layers: 2,
            n_experts: 3,
            d_model: 8,
            d_ff: 16,
            expert_bytes_f32: 4096,
            chunk_size: 32,
            tiers: vec![QuantKind::Int2, QuantKind::Int8],
            entries: (0..12u64)
                .map(|i| ArtifactEntry {
                    offset: i * 100,
                    len: 70,
                    transfer_bytes: 64 + i,
                    chunks: vec![i, i + 1, i + 2], // ceil(70/32) = 3
                })
                .collect(),
        }
    }

    #[test]
    fn manifest_roundtrip() {
        let m = sample_manifest();
        let enc = m.encode();
        let dec = Manifest::decode(&enc).unwrap();
        assert_eq!(dec, m);
    }

    #[test]
    fn manifest_every_single_byte_corruption_detected() {
        let enc = sample_manifest().encode();
        let mut bad = enc.clone();
        for i in 0..enc.len() {
            bad[i] ^= 0x01;
            assert!(
                Manifest::decode(&bad).is_err(),
                "flip at byte {i} decoded successfully"
            );
            bad[i] = enc[i];
        }
    }

    #[test]
    fn manifest_truncation_detected() {
        let enc = sample_manifest().encode();
        for cut in [0, 4, 7, enc.len() / 2, enc.len() - 1] {
            assert!(Manifest::decode(&enc[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn wrong_version_is_typed() {
        let m = sample_manifest();
        let mut enc = m.encode();
        // bump version field (offset 4..6), then re-seal the checksum
        enc[4] = 9;
        let body_len = enc.len() - 8;
        let sum = crate::net::checksum::fnv1a(&enc[..body_len]);
        enc[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(
            Manifest::decode(&enc),
            Err(WireError::VersionMismatch { got: 9, want: MANIFEST_VERSION })
        ));
    }

    #[test]
    fn entry_lookup_and_tier_sizes() {
        let m = sample_manifest();
        let per_tier = m.n_layers * m.n_experts;
        let e = m.entry(QuantKind::Int8, 1, 2).unwrap();
        assert_eq!(e.offset, ((per_tier + 5) * 100) as u64);
        assert!(m.entry(QuantKind::Int4, 0, 0).is_none());
        assert!(m.entry(QuantKind::Int8, 2, 0).is_none());
        let sizes = m.tier_sizes(QuantKind::Int2).unwrap();
        assert_eq!(sizes.len(), per_tier);
        assert_eq!(sizes[0], 64);
        assert!(m.tier_sizes(QuantKind::F32).is_none());
    }

    #[test]
    fn entry_verify_catches_chunk_corruption() {
        let bytes: Vec<u8> = (0..70u8).collect();
        let chunks = bytes.chunks(32).map(fnv1a).collect();
        let e = ArtifactEntry { offset: 0, len: 70, transfer_bytes: 70, chunks };
        assert_eq!(e.verify(&bytes, 32), Ok(()));
        let mut bad = bytes.clone();
        bad[40] ^= 0x80; // second chunk
        assert_eq!(e.verify(&bad, 32), Err(1));
        assert!(e.verify(&bytes[..69], 32).is_err());
    }

    #[test]
    fn expert_codec_roundtrips_every_kind() {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 11);
        for kind in [QuantKind::Int2, QuantKind::Int4, QuantKind::Int8, QuantKind::F32] {
            let hs = HostStore::build(&cfg, &w, kind).unwrap();
            let q = hs.get((0, 1));
            let enc = encode_expert(q);
            let dec = decode_expert(&enc).unwrap();
            assert_eq!(dec.d, q.d);
            assert_eq!(dec.f, q.f);
            for (a, b) in [(&dec.w1, &q.w1), (&dec.w3, &q.w3), (&dec.w2, &q.w2)] {
                assert_eq!(a.kind, b.kind);
                assert_eq!(a.len, b.len);
                assert_eq!(a.scales, b.scales);
                assert_eq!(a.mins, b.mins);
                assert_eq!(a.data, b.data);
            }
        }
    }

    #[test]
    fn expert_codec_rejects_truncation_and_dim_lies() {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 12);
        let hs = HostStore::build(&cfg, &w, QuantKind::Int4).unwrap();
        let enc = encode_expert(hs.get((0, 0)));
        assert!(decode_expert(&enc[..enc.len() - 1]).is_err());
        let mut grown = enc.clone();
        grown.push(0);
        assert!(decode_expert(&grown).is_err());
        // lie about d: w1.len no longer matches d*f
        let mut lied = enc.clone();
        lied[0] = lied[0].wrapping_add(1);
        assert!(decode_expert(&lied).is_err());
    }
}
