//! Length-prefixed TCP frames + the ranged artifact reader.
//!
//! One frame = `MAGIC(u32) | op(u8) | payload_len(u32) | payload |
//! fnv1a(payload)(u64)`, all integers little-endian. Two requests
//! (manifest, byte range) and three responses (manifest bytes, range
//! bytes, error string) are enough for a cacheless coordinator: the
//! manifest tells it where every `(tier, layer, expert)` artifact lives
//! in the server's blob, and ranged reads pull exactly those bytes. Every
//! failure mode is a typed [`WireError`] so the transfer engine can tell
//! retryable transport faults (short read, connection loss, corrupt
//! frame) from real protocol bugs. Full protocol spec:
//! docs/remote-store.md.

use std::fmt;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::net::checksum::fnv1a;

/// Frame magic: `b"AMRS"` (AdapMoE Remote Store), little-endian.
pub const MAGIC: u32 = u32::from_le_bytes(*b"AMRS");

/// Hard cap on a single frame's payload. Large enough for any expert
/// artifact of a real model tier; small enough that a corrupt length
/// field cannot make a reader allocate unboundedly.
pub const MAX_FRAME: usize = 256 << 20;

/// Request: send me the manifest (empty payload).
pub const OP_GET_MANIFEST: u8 = 1;
/// Request: send me `len` blob bytes from `offset` (payload: two u64 LE).
pub const OP_GET_RANGE: u8 = 2;
/// Request: send me several blob ranges in one round trip (payload: a
/// concatenation of `(offset u64 LE, len u64 LE)` pairs). Servers that
/// predate the op answer `OP_ERR` ("unknown op"), which clients treat as
/// the signal to fall back to per-range fetches.
pub const OP_GET_RANGES: u8 = 3;
/// Response: serialized manifest bytes.
pub const OP_MANIFEST: u8 = 0x81;
/// Response: raw blob bytes for a range request.
pub const OP_RANGE: u8 = 0x82;
/// Response: the requested ranges' bytes, concatenated in request order
/// (the requester splits by its own lengths).
pub const OP_RANGES: u8 = 0x83;
/// Response: server-side failure, payload is a UTF-8 message.
pub const OP_ERR: u8 = 0xff;

/// Everything that can go wrong on the wire (or while decoding what came
/// off it). `Io`/`ShortRead` mean the *connection* is suspect — drop it
/// and reconnect; `Corrupt` means the bytes arrived but failed
/// verification — the connection is fine, re-request; `BadFrame` /
/// `VersionMismatch` are protocol-level bugs and not retryable; `Remote`
/// carries a server-reported error.
#[derive(Debug)]
pub enum WireError {
    /// Socket-level failure (connect, read, write).
    Io(String),
    /// The peer closed mid-frame: wanted `want` bytes, got `got`.
    ShortRead { want: usize, got: usize },
    /// Bytes arrived but a checksum or codec check rejected them.
    Corrupt(String),
    /// Malformed frame: bad magic, oversized length, unknown op.
    BadFrame(String),
    /// Manifest version this build does not speak.
    VersionMismatch { got: u16, want: u16 },
    /// The server answered with `OP_ERR`.
    Remote(String),
}

impl WireError {
    /// Should the caller drop the connection before retrying? (`Corrupt`
    /// re-requests on the same socket; `Io`/`ShortRead` must reconnect.)
    pub fn connection_lost(&self) -> bool {
        matches!(self, WireError::Io(_) | WireError::ShortRead { .. })
    }

    /// Is retrying this failure ever useful? Protocol-level mismatches
    /// (`BadFrame`, `VersionMismatch`) will fail identically forever.
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            WireError::Io(_) | WireError::ShortRead { .. } | WireError::Corrupt(_)
        )
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(m) => write!(f, "io: {m}"),
            WireError::ShortRead { want, got } => {
                write!(f, "short read: wanted {want} bytes, got {got}")
            }
            WireError::Corrupt(m) => write!(f, "corrupt: {m}"),
            WireError::BadFrame(m) => write!(f, "bad frame: {m}"),
            WireError::VersionMismatch { got, want } => {
                write!(f, "manifest version {got}, this build speaks {want}")
            }
            WireError::Remote(m) => write!(f, "server error: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> WireError {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            // read_exact lost the partial count; 0-of-unknown is still
            // honest about the failure class.
            WireError::ShortRead { want: 0, got: 0 }
        } else {
            WireError::Io(e.to_string())
        }
    }
}

/// Serialize one frame.
pub fn encode_frame(op: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(17 + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(op);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&fnv1a(payload).to_le_bytes());
    out
}

/// Write one frame to a stream.
pub fn write_frame(w: &mut impl Write, op: u8, payload: &[u8]) -> Result<(), WireError> {
    w.write_all(&encode_frame(op, payload))?;
    w.flush()?;
    Ok(())
}

/// Read one frame from a stream, verifying magic, length cap and payload
/// checksum. Blocks until a full frame (or an error) arrives.
pub fn read_frame(r: &mut impl Read) -> Result<(u8, Vec<u8>), WireError> {
    let mut head = [0u8; 9];
    read_exact_counted(r, &mut head)?;
    let magic = u32::from_le_bytes([head[0], head[1], head[2], head[3]]);
    if magic != MAGIC {
        return Err(WireError::BadFrame(format!("magic {magic:#010x}")));
    }
    let op = head[4];
    let len = u32::from_le_bytes([head[5], head[6], head[7], head[8]]) as usize;
    if len > MAX_FRAME {
        return Err(WireError::BadFrame(format!("payload length {len} over cap")));
    }
    let mut payload = vec![0u8; len];
    read_exact_counted(r, &mut payload)?;
    let mut sum = [0u8; 8];
    read_exact_counted(r, &mut sum)?;
    let want = u64::from_le_bytes(sum);
    let got = fnv1a(&payload);
    if got != want {
        return Err(WireError::Corrupt(format!(
            "frame checksum {got:#018x} != {want:#018x}"
        )));
    }
    Ok((op, payload))
}

/// `read_exact` that reports how many bytes actually arrived on EOF —
/// the diagnostic the typed `ShortRead` carries.
fn read_exact_counted(r: &mut impl Read, buf: &mut [u8]) -> Result<(), WireError> {
    let want = buf.len();
    let mut got = 0;
    while got < want {
        match r.read(&mut buf[got..]) {
            Ok(0) => return Err(WireError::ShortRead { want, got }),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Client half of the protocol: a connected stream plus the two request
/// shapes. One outstanding request at a time (request/response lockstep),
/// which keeps the protocol trivially ordered — the transfer engine's
/// lanes get their parallelism from multiple readers, not pipelining.
pub struct RangedReader {
    stream: TcpStream,
}

impl RangedReader {
    /// Connect with a bounded dial + I/O timeout so a dead server surfaces
    /// as a retryable fault instead of a hang.
    pub fn connect(addr: &str, timeout: Duration) -> Result<RangedReader, WireError> {
        let sock_addr = addr
            .parse()
            .map_err(|e| WireError::Io(format!("bad address {addr}: {e}")))?;
        let stream = TcpStream::connect_timeout(&sock_addr, timeout)?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        stream.set_nodelay(true)?;
        Ok(RangedReader { stream })
    }

    fn roundtrip(&mut self, op: u8, payload: &[u8]) -> Result<(u8, Vec<u8>), WireError> {
        write_frame(&mut self.stream, op, payload)?;
        let (resp_op, resp) = read_frame(&mut self.stream)?;
        if resp_op == OP_ERR {
            return Err(WireError::Remote(String::from_utf8_lossy(&resp).into_owned()));
        }
        Ok((resp_op, resp))
    }

    /// Fetch the serialized manifest (decode + verify is the caller's job
    /// via [`crate::net::manifest::Manifest::decode`]).
    pub fn fetch_manifest(&mut self) -> Result<Vec<u8>, WireError> {
        let (op, resp) = self.roundtrip(OP_GET_MANIFEST, &[])?;
        if op != OP_MANIFEST {
            return Err(WireError::BadFrame(format!("expected manifest, got op {op:#04x}")));
        }
        Ok(resp)
    }

    /// Fetch exactly `len` blob bytes starting at `offset`. A frame that
    /// arrives intact but with the wrong byte count is a `ShortRead` —
    /// the server misbehaved, treat the connection as suspect.
    pub fn fetch_range(&mut self, offset: u64, len: u64) -> Result<Vec<u8>, WireError> {
        let mut req = [0u8; 16];
        req[..8].copy_from_slice(&offset.to_le_bytes());
        req[8..].copy_from_slice(&len.to_le_bytes());
        let (op, resp) = self.roundtrip(OP_GET_RANGE, &req)?;
        if op != OP_RANGE {
            return Err(WireError::BadFrame(format!("expected range, got op {op:#04x}")));
        }
        if resp.len() != len as usize {
            return Err(WireError::ShortRead { want: len as usize, got: resp.len() });
        }
        Ok(resp)
    }

    /// Fetch several blob ranges in one round trip (`GET_RANGES`),
    /// returning one byte vector per requested `(offset, len)` pair, in
    /// request order. The response is a single concatenated payload split
    /// by the requested lengths — a total that doesn't add up is a
    /// `ShortRead` (misbehaving server, connection suspect). An old
    /// server answers `OP_ERR`, surfaced as [`WireError::Remote`] so the
    /// caller can fall back to [`RangedReader::fetch_range`] per range.
    pub fn fetch_ranges(&mut self, ranges: &[(u64, u64)]) -> Result<Vec<Vec<u8>>, WireError> {
        let mut req = Vec::with_capacity(16 * ranges.len());
        for &(offset, len) in ranges {
            req.extend_from_slice(&offset.to_le_bytes());
            req.extend_from_slice(&len.to_le_bytes());
        }
        let (op, resp) = self.roundtrip(OP_GET_RANGES, &req)?;
        if op != OP_RANGES {
            return Err(WireError::BadFrame(format!("expected ranges, got op {op:#04x}")));
        }
        let want: usize = ranges.iter().map(|&(_, len)| len as usize).sum();
        if resp.len() != want {
            return Err(WireError::ShortRead { want, got: resp.len() });
        }
        let mut out = Vec::with_capacity(ranges.len());
        let mut at = 0;
        for &(_, len) in ranges {
            out.push(resp[at..at + len as usize].to_vec());
            at += len as usize;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let payload = b"expert bytes".to_vec();
        let framed = encode_frame(OP_RANGE, &payload);
        let (op, got) = read_frame(&mut framed.as_slice()).unwrap();
        assert_eq!(op, OP_RANGE);
        assert_eq!(got, payload);
    }

    #[test]
    fn empty_payload_roundtrip() {
        let framed = encode_frame(OP_GET_MANIFEST, &[]);
        let (op, got) = read_frame(&mut framed.as_slice()).unwrap();
        assert_eq!(op, OP_GET_MANIFEST);
        assert!(got.is_empty());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut framed = encode_frame(OP_RANGE, b"x");
        framed[0] ^= 0xff;
        assert!(matches!(
            read_frame(&mut framed.as_slice()),
            Err(WireError::BadFrame(_))
        ));
    }

    #[test]
    fn payload_corruption_detected() {
        let mut framed = encode_frame(OP_RANGE, b"some expert data here");
        // flip one payload byte; header (9) is intact, checksum must catch it
        framed[12] ^= 0x01;
        assert!(matches!(
            read_frame(&mut framed.as_slice()),
            Err(WireError::Corrupt(_))
        ));
    }

    #[test]
    fn truncation_is_short_read() {
        let framed = encode_frame(OP_RANGE, b"some expert data here");
        let cut = &framed[..framed.len() - 3];
        match read_frame(&mut &cut[..]) {
            Err(WireError::ShortRead { want, got }) => assert!(got < want),
            other => panic!("expected ShortRead, got {other:?}"),
        }
    }

    #[test]
    fn oversized_length_rejected_without_allocation() {
        let mut framed = encode_frame(OP_RANGE, b"x");
        framed[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            read_frame(&mut framed.as_slice()),
            Err(WireError::BadFrame(_))
        ));
    }

    #[test]
    fn error_taxonomy() {
        assert!(WireError::Io("x".into()).connection_lost());
        assert!(WireError::ShortRead { want: 4, got: 0 }.connection_lost());
        assert!(!WireError::Corrupt("x".into()).connection_lost());
        assert!(WireError::Corrupt("x".into()).retryable());
        assert!(!WireError::BadFrame("x".into()).retryable());
        assert!(!WireError::VersionMismatch { got: 9, want: 1 }.retryable());
    }
}
