//! Remote expert store client: verified artifact fetch over the wire.
//!
//! [`RemoteClient`] owns one lazily-(re)connected [`RangedReader`] and the
//! retry taxonomy: a `Corrupt` response (chunk checksum mismatch) retries
//! on the same connection — the socket is fine, the payload was not —
//! while `Io`/`ShortRead` drop the socket and reconnect. Both paths are
//! *bounded*; when attempts run out the error propagates to
//! [`RemoteFetcher::fetch`], which surfaces it as the retryable `Err` the
//! transfer engine's fault pump treats like a dropped job (retry ladder →
//! failover → degradation, docs/fault-tolerance.md). So a flaky artifact
//! server degrades service exactly like a flaky PCIe lane — no new
//! failure semantics, just a new fault source.
//!
//! [`connect_store`] is the one-call entry point `Engine::new` uses for
//! `--remote <addr>`: fetch + verify the manifest, then build one
//! lazily-fetching [`HostStore::remote`] per published tier, all sharing
//! this client and one [`FetchCounters`] set.

use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::memory::host_store::{ExpertFetcher, FetchCounters, HostStore, QuantExpert};
use crate::memory::quant::QuantKind;
use crate::memory::tiered_store::TieredStore;
use crate::model::ExpertId;
use crate::net::manifest::{decode_expert, ArtifactEntry, Manifest};
use crate::net::wire::{RangedReader, WireError};

/// Dial + per-request I/O timeout.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Fetch attempts per artifact before the failure propagates to the
/// engine's own fault ladder (which has retries of its own — transport
/// attempts stay small so a dead server fails fast).
const MAX_ATTEMPTS: u32 = 3;

fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One connection to an artifact server, with bounded retry/reconnect.
pub struct RemoteClient {
    addr: String,
    conn: Option<RangedReader>,
    counters: Arc<FetchCounters>,
    max_attempts: u32,
    /// Whether the server understands the batched `GET_RANGES` op.
    /// Optimistically `true`; flipped off for the rest of the session the
    /// first time the server answers it with "unknown op" (an old server),
    /// so every later batch goes straight to per-range fetches.
    ranges_supported: bool,
}

impl RemoteClient {
    /// Lazy client — no socket until the first request.
    pub fn new(addr: &str, counters: Arc<FetchCounters>) -> RemoteClient {
        RemoteClient {
            addr: addr.to_string(),
            conn: None,
            counters,
            max_attempts: MAX_ATTEMPTS,
            ranges_supported: true,
        }
    }

    #[cfg(test)]
    pub(crate) fn with_attempts(mut self, n: u32) -> RemoteClient {
        self.max_attempts = n.max(1);
        self
    }

    fn conn(&mut self) -> Result<&mut RangedReader, WireError> {
        if self.conn.is_none() {
            let fresh = RangedReader::connect(&self.addr, IO_TIMEOUT)?;
            self.conn = Some(fresh);
        }
        Ok(self.conn.as_mut().expect("just connected"))
    }

    /// Classify a failure for the retry loop: drop the socket when the
    /// connection itself is suspect, count what the counters track, and
    /// say whether another attempt could help.
    fn note_failure(&mut self, err: &WireError) -> bool {
        use std::sync::atomic::Ordering;
        if err.connection_lost() {
            self.conn = None;
            self.counters.reconnects.fetch_add(1, Ordering::Relaxed);
        }
        if matches!(err, WireError::Corrupt(_)) {
            self.counters.checksum_failures.fetch_add(1, Ordering::Relaxed);
        }
        err.retryable()
    }

    /// Fetch + verify the manifest (retrying like any other request).
    pub fn manifest(&mut self) -> Result<Manifest, WireError> {
        self.with_retry(|conn| {
            let bytes = conn.fetch_manifest()?;
            Manifest::decode(&bytes)
        })
    }

    /// Fetch one artifact's bytes and verify every chunk checksum. The
    /// returned bytes are exactly `entry.len` long and chunk-verified —
    /// but not yet decoded ([`RemoteFetcher`] does that).
    pub fn fetch_artifact(
        &mut self,
        entry: &ArtifactEntry,
        chunk_size: u32,
    ) -> Result<Vec<u8>, WireError> {
        self.with_retry(|conn| {
            let bytes = conn.fetch_range(entry.offset, entry.len)?;
            if let Err(chunk) = entry.verify(&bytes, chunk_size) {
                return Err(WireError::Corrupt(format!(
                    "artifact chunk {chunk} failed checksum"
                )));
            }
            Ok(bytes)
        })
    }

    /// Fetch + chunk-verify several artifacts in one `GET_RANGES` round
    /// trip. Falls back to per-artifact [`RemoteClient::fetch_artifact`]
    /// calls when the batch is too small to pay off or the server predates
    /// the op (sticky — see [`RemoteClient::ranges_supported`]). Results
    /// are positional with `entries`.
    pub fn fetch_artifacts(
        &mut self,
        entries: &[&ArtifactEntry],
        chunk_size: u32,
    ) -> Result<Vec<Vec<u8>>, WireError> {
        if entries.len() < 2 || !self.ranges_supported {
            return entries.iter().map(|e| self.fetch_artifact(e, chunk_size)).collect();
        }
        let ranges: Vec<(u64, u64)> = entries.iter().map(|e| (e.offset, e.len)).collect();
        let batched = self.with_retry(|conn| {
            let parts = conn.fetch_ranges(&ranges)?;
            for (e, bytes) in entries.iter().zip(&parts) {
                if let Err(chunk) = e.verify(bytes, chunk_size) {
                    return Err(WireError::Corrupt(format!(
                        "batched artifact chunk {chunk} failed checksum"
                    )));
                }
            }
            Ok(parts)
        });
        match batched {
            Ok(parts) => Ok(parts),
            Err(WireError::Remote(_)) => {
                // An old server answered "unknown op" (manifest entries
                // can't be out of range, so that's the only ERR source).
                // Remember, and serve this batch — and all later ones —
                // over the per-range path the server does speak.
                self.ranges_supported = false;
                entries.iter().map(|e| self.fetch_artifact(e, chunk_size)).collect()
            }
            Err(e) => Err(e),
        }
    }

    fn with_retry<T>(
        &mut self,
        mut op: impl FnMut(&mut RangedReader) -> Result<T, WireError>,
    ) -> Result<T, WireError> {
        use std::sync::atomic::Ordering;
        let mut attempt = 0;
        loop {
            attempt += 1;
            let result = self.conn().and_then(&mut op);
            let err = match result {
                Ok(v) => return Ok(v),
                Err(e) => e,
            };
            let retryable = self.note_failure(&err);
            if !retryable || attempt >= self.max_attempts {
                return Err(err);
            }
            self.counters.retries.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// [`ExpertFetcher`] for one precision tier, backed by a shared
/// [`RemoteClient`]. Looks the artifact up in the manifest, pulls +
/// verifies its bytes, decodes, and sanity-checks the decoded tier.
pub struct RemoteFetcher {
    client: Arc<Mutex<RemoteClient>>,
    manifest: Arc<Manifest>,
    kind: QuantKind,
    counters: Arc<FetchCounters>,
}

impl RemoteFetcher {
    pub fn new(
        client: Arc<Mutex<RemoteClient>>,
        manifest: Arc<Manifest>,
        kind: QuantKind,
        counters: Arc<FetchCounters>,
    ) -> RemoteFetcher {
        RemoteFetcher { client, manifest, kind, counters }
    }

    fn entry(&self, id: ExpertId) -> Result<&ArtifactEntry, String> {
        self.manifest.entry(self.kind, id.0, id.1).ok_or_else(|| {
            format!("manifest has no {} artifact for ({},{})", self.kind.name(), id.0, id.1)
        })
    }

    /// Decode verified artifact bytes and sanity-check the decoded tier.
    fn decode_checked(&self, id: ExpertId, bytes: &[u8]) -> Result<QuantExpert, String> {
        let q = decode_expert(bytes).map_err(|e| e.to_string())?;
        for (name, t) in [("w1", &q.w1), ("w3", &q.w3), ("w2", &q.w2)] {
            if t.kind != self.kind {
                return Err(format!(
                    "artifact for ({},{}) decodes {name} as {}, wanted {}",
                    id.0,
                    id.1,
                    t.kind.name(),
                    self.kind.name()
                ));
            }
        }
        Ok(q)
    }
}

impl ExpertFetcher for RemoteFetcher {
    fn fetch(&self, id: ExpertId) -> Result<QuantExpert, String> {
        use std::sync::atomic::Ordering;
        let entry = self.entry(id)?;
        let start = Instant::now();
        let fetched = lock_unpoisoned(&self.client)
            .fetch_artifact(entry, self.manifest.chunk_size)
            .map_err(|e| e.to_string());
        self.counters
            .fetch_ns
            .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.counters.fetch_hist.record(start.elapsed().as_secs_f64());
        crate::obs::span(
            crate::obs::Track::Remote,
            crate::obs::Name::RemoteFetch,
            crate::obs::expert_corr(id),
            start,
        );
        let q = self.decode_checked(id, &fetched?)?;
        self.counters.fetches.fetch_add(1, Ordering::Relaxed);
        self.counters.fetched_bytes.fetch_add(entry.len, Ordering::Relaxed);
        Ok(q)
    }

    /// Batched fetch: one `GET_RANGES` round trip for the whole set (with
    /// per-artifact chunk verification), per-range fallback on old
    /// servers. Counter accounting mirrors [`RemoteFetcher::fetch`]:
    /// every expert that lands counts one fetch and its wire bytes.
    fn fetch_many(&self, ids: &[ExpertId]) -> Result<Vec<QuantExpert>, String> {
        use std::sync::atomic::Ordering;
        let entries: Vec<&ArtifactEntry> =
            ids.iter().map(|&id| self.entry(id)).collect::<Result<_, _>>()?;
        let start = Instant::now();
        let fetched = lock_unpoisoned(&self.client)
            .fetch_artifacts(&entries, self.manifest.chunk_size)
            .map_err(|e| e.to_string());
        self.counters
            .fetch_ns
            .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.counters.fetch_hist.record(start.elapsed().as_secs_f64());
        crate::obs::span(
            crate::obs::Track::Remote,
            crate::obs::Name::RemoteFetch,
            ids.first().map(|&id| crate::obs::expert_corr(id)).unwrap_or(0),
            start,
        );
        let all_bytes = fetched?;
        let mut out = Vec::with_capacity(ids.len());
        for ((&id, entry), bytes) in ids.iter().zip(&entries).zip(&all_bytes) {
            out.push(self.decode_checked(id, bytes)?);
            self.counters.fetches.fetch_add(1, Ordering::Relaxed);
            self.counters.fetched_bytes.fetch_add(entry.len, Ordering::Relaxed);
        }
        Ok(out)
    }
}

/// Connect to an artifact server and assemble the cacheless store:
/// manifest fetch + verify, then one [`HostStore::remote`] per published
/// tier — every tier sharing one client connection and one counter set.
/// Returns the tiered store plus the manifest (the engine cross-checks
/// its shape against the local `ModelConfig`).
pub fn connect_store(addr: &str) -> Result<(TieredStore, Arc<Manifest>), WireError> {
    let counters = Arc::new(FetchCounters::default());
    let mut client = RemoteClient::new(addr, Arc::clone(&counters));
    let manifest = Arc::new(client.manifest()?);
    let client = Arc::new(Mutex::new(client));
    let mut stores = Vec::with_capacity(manifest.tiers.len());
    for &kind in &manifest.tiers {
        let sizes = manifest
            .tier_sizes(kind)
            .expect("tier list and entries are shape-checked at decode");
        let fetcher = Arc::new(RemoteFetcher::new(
            Arc::clone(&client),
            Arc::clone(&manifest),
            kind,
            Arc::clone(&counters),
        ));
        let store = HostStore::remote(
            kind,
            manifest.n_layers,
            manifest.n_experts,
            manifest.expert_bytes_f32 as usize,
            sizes,
            fetcher as Arc<dyn ExpertFetcher>,
            Arc::clone(&counters),
        )
        .map_err(|e| WireError::Corrupt(format!("manifest shape: {e}")))?;
        stores.push(Arc::new(store));
    }
    let tiered = TieredStore::from_parts(stores)
        .map_err(|e| WireError::Corrupt(format!("manifest tiers: {e}")))?;
    Ok((tiered, manifest))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::server::{ArtifactImage, ChaosKnobs, StoreServer};
    use crate::testutil::{micro_config, synthetic_weights};

    fn serve(knobs: ChaosKnobs) -> (StoreServer, Arc<ArtifactImage>) {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 31);
        let ts = TieredStore::build(&cfg, &w, &[QuantKind::Int2, QuantKind::Int8]).unwrap();
        let img = Arc::new(ArtifactImage::from_tiered_chunked(&ts, cfg.d_model, cfg.d_ff, 128));
        let srv = StoreServer::spawn_chaotic(Arc::clone(&img), "127.0.0.1:0", knobs).unwrap();
        (srv, img)
    }

    /// The server bumps `served` *after* answering, so a client can hold a
    /// response the counter doesn't show yet — spin briefly before
    /// asserting on exact request counts.
    fn wait_served(srv: &StoreServer, want: u64) -> u64 {
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let n = srv.served();
            if n >= want || Instant::now() > deadline {
                return n;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn connect_store_builds_remote_tiers_matching_manifest() {
        let (srv, img) = serve(ChaosKnobs::default());
        let (ts, m) = connect_store(&srv.local_addr()).unwrap();
        assert_eq!(*m, img.manifest);
        assert!(ts.is_remote());
        assert!(ts.remote_counters().is_some());
        assert_eq!(ts.tiers(), img.manifest.tiers.as_slice());
        // metadata reads are manifest-backed, no fetch
        let c = ts.remote_counters().unwrap();
        assert_eq!(
            ts.expert_transfer_bytes((0, 0), QuantKind::Int2) as u64,
            img.manifest.entries[0].transfer_bytes
        );
        assert_eq!(c.fetches.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn fetched_expert_counts_bytes_and_latency() {
        let (srv, img) = serve(ChaosKnobs::default());
        let (ts, _) = connect_store(&srv.local_addr()).unwrap();
        let store = ts.store(QuantKind::Int8);
        let (_, src) = store.try_fetch((1, 2)).unwrap();
        assert_eq!(src, crate::memory::host_store::FetchSource::Remote);
        let c = ts.remote_counters().unwrap();
        use std::sync::atomic::Ordering;
        assert_eq!(c.fetches.load(Ordering::Relaxed), 1);
        let e = img.manifest.entry(QuantKind::Int8, 1, 2).unwrap();
        assert_eq!(c.fetched_bytes.load(Ordering::Relaxed), e.len);
        assert!(c.fetch_ns.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn prefetch_lands_a_layer_in_one_ranges_round_trip() {
        let (srv, img) = serve(ChaosKnobs::default());
        let (ts, m) = connect_store(&srv.local_addr()).unwrap();
        let store = ts.store(QuantKind::Int2);
        assert_eq!(wait_served(&srv, 1), 1); // the manifest fetch
        let ids: Vec<_> = (0..m.n_experts).map(|e| (0, e)).collect();
        store.prefetch(&ids);
        // One GET_RANGES request covers the whole layer.
        assert_eq!(wait_served(&srv, 2), 2);
        let c = ts.remote_counters().unwrap();
        use std::sync::atomic::Ordering;
        assert_eq!(c.batched_fetches.load(Ordering::Relaxed), 1);
        assert_eq!(c.fetches.load(Ordering::Relaxed), m.n_experts as u64);
        for &id in &ids {
            // Pinned by the batch: no further wire traffic, and the bytes
            // decode to exactly what a per-range fetch would land.
            let (q, src) = store.try_fetch(id).unwrap();
            assert_eq!(src, crate::memory::host_store::FetchSource::Local);
            let e = img.manifest.entry(QuantKind::Int2, id.0, id.1).unwrap();
            let blob = &img.blob[e.offset as usize..(e.offset + e.len) as usize];
            assert_eq!(q, &decode_expert(blob).unwrap());
        }
        assert_eq!(srv.served(), 2);
    }

    #[test]
    fn old_server_falls_back_per_range_and_remembers() {
        let (srv, _img) =
            serve(ChaosKnobs { disable_ranges: true, ..ChaosKnobs::default() });
        let (ts, m) = connect_store(&srv.local_addr()).unwrap();
        let store = ts.store(QuantKind::Int8);
        assert_eq!(wait_served(&srv, 1), 1); // the manifest fetch
        let n = m.n_experts as u64;
        let ids: Vec<_> = (0..m.n_experts).map(|e| (0, e)).collect();
        store.prefetch(&ids);
        // The batch still lands every expert: one refused GET_RANGES,
        // then per-range fetches.
        assert_eq!(wait_served(&srv, 2 + n), 2 + n);
        for &id in &ids {
            let (_, src) = store.try_fetch(id).unwrap();
            assert_eq!(src, crate::memory::host_store::FetchSource::Local);
        }
        // The refusal is sticky: the next batch never retries the op.
        let ids: Vec<_> = (0..m.n_experts).map(|e| (1, e)).collect();
        store.prefetch(&ids);
        assert_eq!(wait_served(&srv, 2 + 2 * n), 2 + 2 * n);
    }

    #[test]
    fn corrupt_responses_retry_until_clean() {
        // every 2nd response corrupted: each fetch may need a retry but
        // always converges; checksum_failures records the rejects
        let (srv, _img) = serve(ChaosKnobs { corrupt_every: 2, ..ChaosKnobs::default() });
        let (ts, m) = connect_store(&srv.local_addr()).unwrap();
        let store = ts.store(QuantKind::Int2);
        for l in 0..m.n_layers {
            for e in 0..m.n_experts {
                assert!(store.try_fetch((l, e)).is_ok(), "expert ({l},{e})");
            }
        }
        let c = ts.remote_counters().unwrap();
        use std::sync::atomic::Ordering;
        assert!(c.checksum_failures.load(Ordering::Relaxed) > 0);
        assert!(c.retries.load(Ordering::Relaxed) > 0);
        assert_eq!(
            c.fetches.load(Ordering::Relaxed),
            (m.n_layers * m.n_experts) as u64
        );
    }

    #[test]
    fn dropped_connections_reconnect() {
        let (srv, _img) = serve(ChaosKnobs { drop_every: 3, ..ChaosKnobs::default() });
        let (ts, m) = connect_store(&srv.local_addr()).unwrap();
        let store = ts.store(QuantKind::Int8);
        for l in 0..m.n_layers {
            for e in 0..m.n_experts {
                assert!(store.try_fetch((l, e)).is_ok(), "expert ({l},{e})");
            }
        }
        let c = ts.remote_counters().unwrap();
        use std::sync::atomic::Ordering;
        assert!(c.reconnects.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn exhausted_attempts_surface_as_retryable_error() {
        // every response corrupted: attempts run dry and the fetch fails,
        // but a *store-level* retry is still possible (nothing sticky)
        let (srv, img) = serve(ChaosKnobs { corrupt_every: 1, ..ChaosKnobs::default() });
        let counters = Arc::new(FetchCounters::default());
        let mut client =
            RemoteClient::new(&srv.local_addr(), Arc::clone(&counters)).with_attempts(2);
        let e = &img.manifest.entries[0];
        let got = client.fetch_artifact(e, img.manifest.chunk_size);
        assert!(matches!(got, Err(WireError::Corrupt(_))));
        use std::sync::atomic::Ordering;
        assert_eq!(counters.checksum_failures.load(Ordering::Relaxed), 2);
        assert_eq!(counters.retries.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn connect_to_dead_address_fails_typed() {
        // bind-then-drop grabs a port nobody is listening on
        let free = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        assert!(matches!(connect_store(&free), Err(WireError::Io(_))));
    }
}
