//! Token sampling over logits rows.

use crate::util::rng::Rng;

/// Greedy argmax.
pub fn greedy(logits: &[f32]) -> u32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as u32)
        .unwrap()
}

/// Temperature sampling (temperature <= 0 degrades to greedy).
pub fn sample(logits: &[f32], temperature: f64, rng: &mut Rng) -> u32 {
    if temperature <= 0.0 {
        return greedy(logits);
    }
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let probs: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - max) as f64) / temperature).exp())
        .collect();
    let total: f64 = probs.iter().sum();
    let mut u = rng.f64() * total;
    for (i, p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return i as u32;
        }
    }
    (probs.len() - 1) as u32
}

/// Sample under a request's [`SamplingParams`]: restrict to the `top_k`
/// highest logits (0 = unrestricted), then temperature-sample within them.
/// Temperature `<= 0` is greedy and ignores `top_k` (argmax is always in
/// the window).
pub fn sample_params(
    logits: &[f32],
    params: &crate::coordinator::batcher::SamplingParams,
    rng: &mut Rng,
) -> u32 {
    if params.temperature <= 0.0 {
        return greedy(logits);
    }
    if params.top_k == 0 || params.top_k >= logits.len() {
        return sample(logits, params.temperature, rng);
    }
    let keep = top_k_indices(logits, params.top_k);
    let sub: Vec<f32> = keep.iter().map(|&i| logits[i]).collect();
    keep[sample(&sub, params.temperature, rng) as usize] as u32
}

/// Top-k indices (descending by value). Small k, small n — selection sort.
pub fn top_k_indices(values: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| values[b].partial_cmp(&values[a]).unwrap());
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        assert_eq!(greedy(&[0.1, 3.0, 2.0]), 1);
    }

    #[test]
    fn zero_temperature_is_greedy() {
        let mut rng = Rng::new(0);
        assert_eq!(sample(&[0.0, 9.0, 1.0], 0.0, &mut rng), 1);
    }

    #[test]
    fn sampling_respects_distribution() {
        let mut rng = Rng::new(1);
        let logits = [0.0f32, 5.0, 0.0];
        let mut hits = 0;
        for _ in 0..500 {
            if sample(&logits, 1.0, &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 450, "hits={hits}");
    }

    #[test]
    fn params_top_k_restricts_support() {
        use crate::coordinator::batcher::SamplingParams;
        let mut rng = Rng::new(2);
        let logits = [0.0f32, 1.0, 2.0, 3.0];
        let p = SamplingParams { temperature: 2.0, top_k: 2, seed: None };
        for _ in 0..200 {
            let t = sample_params(&logits, &p, &mut rng);
            assert!(t == 2 || t == 3, "token {t} outside top-2");
        }
        // greedy shortcut ignores rng entirely
        let g = SamplingParams { temperature: 0.0, top_k: 1, seed: None };
        assert_eq!(sample_params(&logits, &g, &mut rng), 3);
    }

    #[test]
    fn top_k_ordering() {
        assert_eq!(top_k_indices(&[0.1, 0.9, 0.5, 0.7], 2), vec![1, 3]);
        assert_eq!(top_k_indices(&[1.0], 3), vec![0]);
    }
}
