//! Model-facing substrates: config, weight container, tokenizer, KV cache,
//! sampling. (The compute itself lives in AOT-compiled HLO artifacts, run by
//! [`crate::runtime`]; the decode loop composing everything is
//! [`crate::coordinator::engine`].)

pub mod config;
pub mod kv;
pub mod sampling;
pub mod tokenizer;
pub mod weights;

/// Identifier of one expert: (layer, expert index).
pub type ExpertId = (usize, usize);
