//! Byte-level tokenizer + workload (prompt) sampling from the eval stream.
//!
//! The build-time model is a byte LM (vocab 256), so tokenization is
//! identity over bytes; this module exists to give the serving layer a
//! stable interface and to source realistic prompts (the MT-Bench
//! substitution — see DESIGN.md) from the held-out corpus.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::rng::Rng;

pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn encode(text: &str) -> Vec<u32> {
        text.as_bytes().iter().map(|&b| b as u32).collect()
    }

    pub fn decode(tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xff) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// Held-out token stream (tokens_eval.bin) + prompt sampling.
pub struct EvalStream {
    pub tokens: Vec<u32>,
}

impl EvalStream {
    pub fn load(path: &Path) -> Result<EvalStream> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.is_empty() {
            bail!("empty eval stream");
        }
        Ok(EvalStream { tokens: bytes.iter().map(|&b| b as u32).collect() })
    }

    pub fn from_tokens(tokens: Vec<u32>) -> EvalStream {
        EvalStream { tokens }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Random contiguous window of `len` tokens — a "prompt".
    pub fn sample_prompt(&self, rng: &mut Rng, len: usize) -> Vec<u32> {
        assert!(len < self.tokens.len());
        let start = rng.usize_below(self.tokens.len() - len);
        self.tokens[start..start + len].to_vec()
    }

    /// Deterministic evaluation windows covering the stream without overlap:
    /// (context, next-token) pairs for the accuracy benches.
    pub fn eval_windows(&self, window: usize, max_windows: usize) -> Vec<(&[u32], u32)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i + window + 1 < self.tokens.len() && out.len() < max_windows {
            out.push((&self.tokens[i..i + window], self.tokens[i + window]));
            i += window + 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let s = "hello {x:1}";
        assert_eq!(ByteTokenizer::decode(&ByteTokenizer::encode(s)), s);
    }

    #[test]
    fn sample_prompt_in_range() {
        let es = EvalStream::from_tokens((0..1000).map(|i| (i % 256) as u32).collect());
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let p = es.sample_prompt(&mut rng, 16);
            assert_eq!(p.len(), 16);
            assert!(p.iter().all(|&t| t < 256));
        }
    }

    #[test]
    fn eval_windows_disjoint() {
        let es = EvalStream::from_tokens((0..100).map(|i| i as u32).collect());
        let ws = es.eval_windows(9, 100);
        assert!(!ws.is_empty());
        // windows step by window+1, so contexts are disjoint
        assert_eq!(ws[0].0[0], 0);
        assert_eq!(ws[0].1, 9);
        assert_eq!(ws[1].0[0], 10);
    }

    #[test]
    fn eval_windows_respects_cap() {
        let es = EvalStream::from_tokens((0..1000).map(|i| i as u32).collect());
        assert_eq!(es.eval_windows(8, 5).len(), 5);
    }
}
