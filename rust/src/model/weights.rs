//! Reader for the `weights.bin` tensor container written by
//! `python/compile/export.py` (see its docstring for the layout).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"AMOE";
const VERSION: u32 = 1;

/// All named tensors from a weights.bin file.
#[derive(Debug, Default)]
pub struct Weights {
    pub tensors: HashMap<String, Tensor>,
}

impl Weights {
    pub fn load(path: &Path) -> Result<Weights> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(&bytes)
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Weights> {
        let mut r = Cursor { b: bytes, i: 0 };
        if r.take(4)? != MAGIC {
            bail!("bad magic in weights container");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("unsupported weights version {version}");
        }
        let n = r.u32()? as usize;
        let mut tensors = HashMap::with_capacity(n);
        for _ in 0..n {
            let name_len = r.u32()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())
                .context("tensor name not utf-8")?;
            let dtype = r.u8()?;
            let ndim = r.u8()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r.u32()? as usize);
            }
            let count: usize = dims.iter().product::<usize>().max(1);
            let data = match dtype {
                0 => {
                    let raw = r.take(count * 4)?;
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect::<Vec<f32>>()
                }
                1 => {
                    // i32 stored as f32 host-side (only used for metadata)
                    let raw = r.take(count * 4)?;
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f32)
                        .collect()
                }
                2 => r.take(count)?.iter().map(|&b| b as f32).collect(),
                d => bail!("unknown dtype tag {d} for tensor {name}"),
            };
            tensors.insert(name, Tensor::new(dims, data)?);
        }
        Ok(Weights { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor '{name}'"))
    }

    /// Expert FFN weights for (layer, expert): (w1 [d,f], w3 [d,f], w2 [f,d]).
    pub fn expert(&self, layer: usize, expert: usize) -> Result<(&Tensor, &Tensor, &Tensor)> {
        Ok((
            self.get(&format!("l{layer}.e{expert}.w1"))?,
            self.get(&format!("l{layer}.e{expert}.w3"))?,
            self.get(&format!("l{layer}.e{expert}.w2"))?,
        ))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("weights container truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a container in-memory mirroring export.py's writer.
    fn container(tensors: &[(&str, &[usize], &[f32])]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for (name, dims, data) in tensors {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(0u8); // f32
            out.push(dims.len() as u8);
            for d in *dims {
                out.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            for v in *data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn parses_container() {
        let bytes = container(&[
            ("a", &[2, 2], &[1.0, 2.0, 3.0, 4.0]),
            ("l0.e1.w1", &[2], &[5.0, 6.0]),
        ]);
        let w = Weights::from_bytes(&bytes).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w.get("a").unwrap().dims, vec![2, 2]);
        assert_eq!(w.get("l0.e1.w1").unwrap().data, vec![5.0, 6.0]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = container(&[("a", &[1], &[0.0])]);
        bytes[0] = b'X';
        assert!(Weights::from_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let bytes = container(&[("a", &[4], &[0.0; 4])]);
        assert!(Weights::from_bytes(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn missing_tensor_is_error() {
        let bytes = container(&[("a", &[1], &[0.0])]);
        let w = Weights::from_bytes(&bytes).unwrap();
        assert!(w.get("nope").is_err());
        assert!(w.expert(0, 0).is_err());
    }
}
