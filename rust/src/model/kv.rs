//! KV-cache manager for the batched decode loop.
//!
//! Per layer, holds K and V caches of shape [B, H, S, hd] as host tensors;
//! they round-trip through the `attn_step` HLO executable each decode step.
//! Slot management supports continuous batching: rows are leased to
//! requests, reset on completion, and each row tracks its own position.

use crate::model::config::ModelConfig;
use crate::tensor::Tensor;

pub struct KvCache {
    pub batch: usize,
    /// k\[layer\], v\[layer\]: [B, H, S, hd]
    pub k: Vec<Tensor>,
    pub v: Vec<Tensor>,
    /// Next write position per row (== tokens processed so far).
    pub pos: Vec<usize>,
    /// Whether a row is currently leased to a request.
    pub active: Vec<bool>,
    max_seq: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig, batch: usize) -> KvCache {
        let dims = vec![batch, cfg.n_heads, cfg.max_seq, cfg.head_dim];
        KvCache {
            batch,
            k: (0..cfg.n_layers).map(|_| Tensor::zeros(dims.clone())).collect(),
            v: (0..cfg.n_layers).map(|_| Tensor::zeros(dims.clone())).collect(),
            pos: vec![0; batch],
            active: vec![false; batch],
            max_seq: cfg.max_seq,
        }
    }

    /// Lease a free row; None if the batch is full.
    pub fn acquire_row(&mut self) -> Option<usize> {
        let row = self.active.iter().position(|a| !a)?;
        self.active[row] = true;
        self.pos[row] = 0;
        Some(row)
    }

    /// Release a row and zero its position (cache contents are masked out by
    /// position anyway, so no need to scrub the tensors).
    pub fn release_row(&mut self, row: usize) {
        self.active[row] = false;
        self.pos[row] = 0;
    }

    pub fn active_rows(&self) -> Vec<usize> {
        (0..self.batch).filter(|&r| self.active[r]).collect()
    }

    pub fn row_full(&self, row: usize) -> bool {
        self.pos[row] >= self.max_seq
    }

    /// Advance positions for the given rows after a decode step.
    pub fn advance(&mut self, rows: &[usize]) {
        for &r in rows {
            debug_assert!(self.active[r]);
            self.pos[r] += 1;
        }
    }

    /// Positions vector (i32) for the HLO call — inactive rows get 0.
    pub fn positions_i32(&self) -> Vec<i32> {
        self.pos.iter().map(|&p| p as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::test_config;

    #[test]
    fn shapes() {
        let cfg = test_config();
        let kv = KvCache::new(&cfg, 4);
        assert_eq!(kv.k.len(), cfg.n_layers);
        assert_eq!(kv.k[0].dims, vec![4, cfg.n_heads, cfg.max_seq, cfg.head_dim]);
    }

    #[test]
    fn acquire_release_cycle() {
        let cfg = test_config();
        let mut kv = KvCache::new(&cfg, 2);
        let a = kv.acquire_row().unwrap();
        let b = kv.acquire_row().unwrap();
        assert_ne!(a, b);
        assert!(kv.acquire_row().is_none());
        kv.release_row(a);
        assert_eq!(kv.acquire_row(), Some(a));
    }

    #[test]
    fn advance_only_listed_rows() {
        let cfg = test_config();
        let mut kv = KvCache::new(&cfg, 3);
        let a = kv.acquire_row().unwrap();
        let b = kv.acquire_row().unwrap();
        kv.advance(&[a]);
        kv.advance(&[a, b]);
        assert_eq!(kv.pos[a], 2);
        assert_eq!(kv.pos[b], 1);
    }

    #[test]
    fn row_full_at_max_seq() {
        let cfg = test_config();
        let mut kv = KvCache::new(&cfg, 1);
        let r = kv.acquire_row().unwrap();
        for _ in 0..cfg.max_seq {
            assert!(!kv.row_full(r));
            kv.advance(&[r]);
        }
        assert!(kv.row_full(r));
    }
}
