//! Model configuration, parsed from `artifacts/manifest.json`.
//! Mirrors `python/compile/config.py::ModelConfig`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rms_eps: f64,
    pub batch_sizes: Vec<usize>,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        let g = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .with_context(|| format!("manifest config missing '{k}'"))
        };
        Ok(ModelConfig {
            name: j
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("unknown")
                .to_string(),
            vocab_size: g("vocab_size")?,
            d_model: g("d_model")?,
            n_heads: g("n_heads")?,
            head_dim: g("head_dim")?,
            n_layers: g("n_layers")?,
            n_experts: g("n_experts")?,
            top_k: g("top_k")?,
            d_ff: g("d_ff")?,
            max_seq: g("max_seq")?,
            rms_eps: j.get("rms_eps").and_then(|v| v.as_f64()).unwrap_or(1e-5),
            batch_sizes: j
                .get("batch_sizes")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_else(|| vec![1]),
        })
    }

    pub fn load_manifest(dir: &Path) -> Result<(ModelConfig, Json)> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let manifest = Json::parse(&text).context("parsing manifest.json")?;
        let cfg = ModelConfig::from_json(
            manifest.get("config").context("manifest missing 'config'")?,
        )?;
        Ok((cfg, manifest))
    }

    /// Total experts across all layers (the paper's cache budget unit).
    pub fn total_experts(&self) -> usize {
        self.n_layers * self.n_experts
    }

    /// f32 parameter count of one expert.
    pub fn expert_params(&self) -> usize {
        3 * self.d_model * self.d_ff
    }

    pub fn expert_bytes_f32(&self) -> usize {
        4 * self.expert_params()
    }

    /// Largest exported batch bucket that fits `n` rows, or the max bucket.
    pub fn batch_bucket(&self, n: usize) -> usize {
        let mut sizes = self.batch_sizes.clone();
        sizes.sort_unstable();
        for &b in &sizes {
            if b >= n {
                return b;
            }
        }
        *sizes.last().expect("batch_sizes non-empty")
    }
}

/// Test-only config builder matching python's micro config.
#[cfg(test)]
pub fn test_config() -> ModelConfig {
    ModelConfig {
        name: "test".into(),
        vocab_size: 64,
        d_model: 32,
        n_heads: 2,
        head_dim: 16,
        n_layers: 2,
        n_experts: 8,
        top_k: 2,
        d_ff: 64,
        max_seq: 64,
        rms_eps: 1e-5,
        batch_sizes: vec![1, 4],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_config() {
        let j = Json::parse(
            r#"{"name":"tiny","vocab_size":256,"d_model":128,"n_heads":4,
                "head_dim":32,"n_layers":8,"n_experts":8,"top_k":2,
                "d_ff":256,"max_seq":256,"rms_eps":1e-5,
                "batch_sizes":[1,4,8]}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c.d_model, 128);
        assert_eq!(c.total_experts(), 64);
        assert_eq!(c.expert_bytes_f32(), 4 * 3 * 128 * 256);
        assert_eq!(c.batch_sizes, vec![1, 4, 8]);
    }

    #[test]
    fn batch_bucket_selection() {
        let c = test_config();
        assert_eq!(c.batch_bucket(1), 1);
        assert_eq!(c.batch_bucket(2), 4);
        assert_eq!(c.batch_bucket(4), 4);
        assert_eq!(c.batch_bucket(9), 4); // clamps to max bucket
    }

    #[test]
    fn missing_field_is_error() {
        let j = Json::parse(r#"{"vocab_size": 10}"#).unwrap();
        assert!(ModelConfig::from_json(&j).is_err());
    }
}
