//! Shared support for the `rust/benches/*` paper-reproduction binaries and
//! the examples: artifact discovery, engine construction from method names,
//! eval-stream decoding and accuracy measurement.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::coordinator::engine::Engine;
use crate::coordinator::policy::{self, RunSettings};
use crate::coordinator::profile::Profile;
use crate::memory::platform::Platform;
use crate::memory::quant::QuantKind;
use crate::model::sampling;
use crate::model::tokenizer::EvalStream;

/// Locate the artifacts directory (repo root). None => print a skip notice.
pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built — run `make artifacts` first");
        None
    }
}

/// Reduced-size run for CI (`ADAPMOE_BENCH_FAST=1` / `make bench-fast`).
pub fn fast_mode() -> bool {
    std::env::var("ADAPMOE_BENCH_FAST").is_ok()
}

/// Scale a token/window count down in fast mode.
pub fn scaled(n: usize) -> usize {
    if fast_mode() {
        (n / 4).max(4)
    } else {
        n
    }
}

/// Build an engine for a named method at the given settings.
pub fn method_engine(
    dir: &PathBuf,
    method: &str,
    settings: &RunSettings,
) -> Result<Engine> {
    let profile = Profile::load(dir)?;
    let ecfg = policy::method(method, settings, &profile)
        .with_context(|| format!("unknown method {method}"))?;
    Engine::from_artifacts(dir, ecfg)
}

/// Settings with the simulated link active (performance benches).
pub fn timed_settings(
    cache: usize,
    quant: QuantKind,
    platform: &str,
) -> RunSettings {
    RunSettings::new(1, cache, quant, Platform::preset(platform).unwrap())
}

/// Settings with an instant link (logic/accuracy benches).
pub fn instant_settings(cache: usize, quant: QuantKind) -> RunSettings {
    let mut s = RunSettings::new(1, cache, quant, Platform::preset("instant").unwrap());
    s.time_scale = 0.0;
    s
}

/// Load the held-out eval stream.
pub fn eval_stream(dir: &PathBuf) -> Result<EvalStream> {
    EvalStream::load(&dir.join("tokens_eval.bin"))
}

/// Decode `n` eval tokens through one slot (teacher-forced). Returns decoded
/// token count. Wraps to a fresh slot when the KV cache fills.
pub fn decode_eval(engine: &mut Engine, eval: &EvalStream, n: usize, offset: usize) -> Result<usize> {
    let window = engine.cfg.max_seq - 1;
    let mut fed = 0;
    let mut idx = offset % (eval.len() / 2);
    while fed < n {
        let take = (n - fed).min(window).min(eval.len() - idx - 1);
        let row = engine.acquire_slot().context("no slot")?;
        for &t in &eval.tokens[idx..idx + take] {
            engine.decode_step(&[(row, t)])?;
        }
        engine.release_slot(row);
        fed += take;
        idx = (idx + take) % (eval.len() / 2);
    }
    Ok(fed)
}

/// Accuracy measurement on held-out windows: feed `window` context tokens,
/// then score the model's greedy prediction of the next token. Also returns
/// mean negative log-likelihood of the target (a perplexity proxy).
pub fn eval_accuracy(
    engine: &mut Engine,
    eval: &EvalStream,
    window: usize,
    max_windows: usize,
) -> Result<(f64, f64)> {
    let windows = eval.eval_windows(window, max_windows);
    let mut correct = 0usize;
    let mut nll = 0f64;
    let total = windows.len();
    for (ctx, target) in windows {
        let row = engine.acquire_slot().context("no slot")?;
        let mut last = Vec::new();
        for &t in ctx {
            let outs = engine.decode_step(&[(row, t)])?;
            last = outs.into_iter().next().unwrap().1;
        }
        if sampling::greedy(&last) == target {
            correct += 1;
        }
        // log-softmax of the target logit
        let max = last.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logsum: f64 = last
            .iter()
            .map(|&l| ((l - max) as f64).exp())
            .sum::<f64>()
            .ln()
            + max as f64;
        nll += logsum - last[target as usize] as f64;
        engine.release_slot(row);
    }
    Ok((correct as f64 / total as f64, nll / total as f64))
}
