//! DP-based expert cache allocation (paper §4.4, eq. 10–19).
//!
//! Given the total cache budget T (in experts), per-layer single-expert
//! gating probability α_i and prefetch accuracy β_i (offline profile or
//! online trace), computes per-layer cache sizes t_i minimizing the expected
//! number of on-demand expert loads per token:
//!
//!   f_{i,t} = α_i · f¹ + (1-α_i) · (f² + f³ + f⁴)        (eq. 15)
//!
//! with the four cases of §4.4.2, then the knapsack DP
//!   F[i][j] = min_k ( F[i-1][j-k] + f_{i,k} )             (eq. 19)
//! and a backtrace for the argmin allocation.

/// Per-layer inputs to the planner.
#[derive(Clone, Debug)]
pub struct PlanInputs {
    /// Number of experts per layer (N).
    pub n_experts: usize,
    /// Total cache budget in experts (T).
    pub budget: usize,
    /// P(layer i activates a single expert) — α_i.
    pub alpha: Vec<f64>,
    /// Prefetch accuracy of layer i — β_i.
    pub beta: Vec<f64>,
}

/// Expected on-demand loads for layer `i` with cache size `t` (eq. 11–15).
pub fn on_demand_cost(inp: &PlanInputs, i: usize, t: usize) -> f64 {
    let n = inp.n_experts as f64;
    let t = t.min(inp.n_experts) as f64;
    let alpha = inp.alpha[i];
    let beta = inp.beta[i];

    // Cache hit probability of one specified expert: t/N (eq. 10).
    let p_hit1 = t / n;
    // Both of two specified experts miss (eq. 12 numerator).
    let p_miss2 = (((n - t) * (n - t - 1.0)) / (n * (n - 1.0))).max(0.0);
    // Exactly one of two specified experts hits.
    let p_one = (2.0 * (n - t) * t) / (n * (n - 1.0));

    // One expert required (eq. 11): miss and prefetch wrong.
    let f1 = (1.0 - p_hit1) * (1.0 - beta);
    // Two required, both miss, prefetch wrong -> load 2 (eq. 12).
    let f2 = 2.0 * p_miss2 * (1.0 - beta);
    // Two required, both miss, prefetch right for one -> load 1 (eq. 13).
    let f3 = p_miss2 * beta;
    // Two required, one hits, prefetch wrong for the other (eq. 14).
    let f4 = p_one * (1.0 - beta);

    alpha * f1 + (1.0 - alpha) * (f2 + f3 + f4)
}

/// Result of the DP.
#[derive(Clone, Debug)]
pub struct Plan {
    /// Per-layer cache sizes t_i.
    pub allocation: Vec<usize>,
    /// Minimum total expected on-demand loads per token, Σ f_{i,t_i}.
    pub expected_loads: f64,
}

/// Solve the knapsack DP (eq. 16–19) and backtrace the allocation.
pub fn plan(inp: &PlanInputs) -> Plan {
    let l = inp.alpha.len();
    assert_eq!(inp.beta.len(), l, "alpha/beta length mismatch");
    let n = inp.n_experts;
    let t_total = inp.budget.min(l * n);

    // F[i][j]: min cost over first i layers using ≤ j cache slots.
    // choice[i][j]: the k chosen for layer i at budget j.
    let mut f_prev = vec![0.0f64; t_total + 1];
    let mut f_cur = vec![0.0f64; t_total + 1];
    let mut choice = vec![vec![0usize; t_total + 1]; l];

    for i in 0..l {
        for j in 0..=t_total {
            let mut best = f64::INFINITY;
            let mut best_k = 0;
            for k in 0..=n.min(j) {
                let c = f_prev[j - k] + on_demand_cost(inp, i, k);
                if c < best - 1e-15 {
                    best = c;
                    best_k = k;
                }
            }
            f_cur[j] = best;
            choice[i][j] = best_k;
        }
        std::mem::swap(&mut f_prev, &mut f_cur);
    }

    // backtrace from (l-1, t_total)
    let mut allocation = vec![0usize; l];
    let mut j = t_total;
    for i in (0..l).rev() {
        allocation[i] = choice[i][j];
        j -= choice[i][j];
    }
    Plan { allocation, expected_loads: f_prev[t_total] }
}

/// Expected loads of an arbitrary allocation (baseline comparison).
pub fn allocation_cost(inp: &PlanInputs, allocation: &[usize]) -> f64 {
    allocation
        .iter()
        .enumerate()
        .map(|(i, &t)| on_demand_cost(inp, i, t))
        .sum()
}

/// Byte-denominated planner inputs: device memory is budgeted in bytes
/// and converted to expert slots at the resident tier's per-expert wire
/// footprint. The tiered store's cache layer is byte-denominated
/// (docs/tiered-precision.md): the DP still reasons in experts — the
/// quantity the hit-rate model of §4.4 is written in — but the budget
/// arrives and leaves in bytes.
#[derive(Clone, Debug)]
pub struct BytePlanInputs {
    pub n_experts: usize,
    /// Total cache budget in bytes.
    pub budget_bytes: usize,
    /// Wire bytes of one expert at the tier the cache holds resident
    /// (the highest configured tier).
    pub bytes_per_expert: usize,
    pub alpha: Vec<f64>,
    pub beta: Vec<f64>,
}

/// Result of the byte-denominated DP.
#[derive(Clone, Debug)]
pub struct BytePlan {
    /// Per-layer cache sizes in experts (at the resident tier).
    pub allocation: Vec<usize>,
    /// Per-layer byte ceilings (`allocation[i] * bytes_per_expert`) —
    /// what [`crate::memory::device_cache::DeviceCache::set_byte_budget`]
    /// takes. Lower-tier residents under-fill these ceilings, which is
    /// exactly the degrade-mode headroom.
    pub byte_budgets: Vec<usize>,
    pub expected_loads: f64,
}

/// Solve the knapsack over a byte budget: convert bytes → expert slots
/// at the resident tier, run [`plan`], and emit the per-layer byte
/// ceilings alongside the expert counts.
pub fn plan_bytes(inp: &BytePlanInputs) -> BytePlan {
    let per = inp.bytes_per_expert.max(1);
    let p = plan(&PlanInputs {
        n_experts: inp.n_experts,
        budget: inp.budget_bytes / per,
        alpha: inp.alpha.clone(),
        beta: inp.beta.clone(),
    });
    BytePlan {
        byte_budgets: p.allocation.iter().map(|&t| t * per).collect(),
        allocation: p.allocation,
        expected_loads: p.expected_loads,
    }
}

/// Tier-priced planner inputs: each layer prices a cache slot at its own
/// per-expert wire footprint — the observed resident-tier byte mix the
/// [`crate::coordinator::sensitivity::SensitivityMap`] cache-planning
/// consumer feeds in. A layer whose residents sit at a low tier gets
/// cheaper slots, so the same byte budget buys it more experts.
#[derive(Clone, Debug)]
pub struct TierPlanInputs {
    pub n_experts: usize,
    /// Total cache budget in bytes.
    pub budget_bytes: usize,
    /// Wire bytes of one resident expert, per layer.
    pub bytes_per_expert: Vec<usize>,
    pub alpha: Vec<f64>,
    pub beta: Vec<f64>,
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Ceiling of the DP byte-axis length; real byte budgets are compressed
/// to at most this many units (prices round *up*, so the budget is never
/// exceeded — the plan just turns slightly conservative).
const MAX_BYTE_UNITS: usize = 1 << 16;

/// Knapsack over a byte budget with per-layer slot prices (eq. 19 with a
/// byte-denominated axis). Uniform prices defer to [`plan_bytes`], so
/// the uniform configuration stays bit-for-bit identical to the flat
/// planner; heterogeneous prices run a unit-compressed DP (gcd of the
/// prices, raised if the table would exceed [`MAX_BYTE_UNITS`]).
pub fn plan_bytes_tiered(inp: &TierPlanInputs) -> BytePlan {
    let l = inp.alpha.len();
    assert_eq!(inp.beta.len(), l, "alpha/beta length mismatch");
    assert_eq!(inp.bytes_per_expert.len(), l, "bytes_per_expert length mismatch");
    if l == 0 {
        return BytePlan { allocation: vec![], byte_budgets: vec![], expected_loads: 0.0 };
    }
    let prices: Vec<usize> = inp.bytes_per_expert.iter().map(|&b| b.max(1)).collect();
    if prices.iter().all(|&p| p == prices[0]) {
        return plan_bytes(&BytePlanInputs {
            n_experts: inp.n_experts,
            budget_bytes: inp.budget_bytes,
            bytes_per_expert: prices[0],
            alpha: inp.alpha.clone(),
            beta: inp.beta.clone(),
        });
    }

    let mut unit = prices.iter().fold(0usize, |g, &p| gcd(g, p)).max(1);
    if inp.budget_bytes / unit > MAX_BYTE_UNITS {
        unit = (inp.budget_bytes + MAX_BYTE_UNITS - 1) / MAX_BYTE_UNITS;
    }
    let unit_price: Vec<usize> =
        prices.iter().map(|&p| ((p + unit - 1) / unit).max(1)).collect();
    let t_units = inp.budget_bytes / unit;
    let n = inp.n_experts;
    let costs = PlanInputs {
        n_experts: n,
        budget: 0, // unused by on_demand_cost
        alpha: inp.alpha.clone(),
        beta: inp.beta.clone(),
    };

    let mut f_prev = vec![0.0f64; t_units + 1];
    let mut f_cur = vec![0.0f64; t_units + 1];
    let mut choice = vec![vec![0usize; t_units + 1]; l];
    for i in 0..l {
        let price = unit_price[i];
        for j in 0..=t_units {
            let mut best = f64::INFINITY;
            let mut best_k = 0;
            for k in 0..=n.min(j / price) {
                let c = f_prev[j - k * price] + on_demand_cost(&costs, i, k);
                if c < best - 1e-15 {
                    best = c;
                    best_k = k;
                }
            }
            f_cur[j] = best;
            choice[i][j] = best_k;
        }
        std::mem::swap(&mut f_prev, &mut f_cur);
    }

    let mut allocation = vec![0usize; l];
    let mut j = t_units;
    for i in (0..l).rev() {
        allocation[i] = choice[i][j];
        j -= choice[i][j] * unit_price[i];
    }
    BytePlan {
        byte_budgets: allocation.iter().zip(&prices).map(|(&t, &p)| t * p).collect(),
        allocation,
        expected_loads: f_prev[t_units],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::device_cache::DeviceCache;
    use crate::util::prop;

    fn inputs(l: usize, budget: usize) -> PlanInputs {
        PlanInputs {
            n_experts: 8,
            budget,
            alpha: (0..l).map(|i| 0.1 + 0.04 * i as f64).collect(),
            beta: (0..l).map(|i| 0.6 + 0.03 * i as f64).collect(),
        }
    }

    #[test]
    fn cost_decreases_with_cache() {
        let inp = inputs(4, 16);
        for i in 0..4 {
            for t in 0..8 {
                assert!(
                    on_demand_cost(&inp, i, t + 1) <= on_demand_cost(&inp, i, t) + 1e-12,
                    "layer {i}: cost not monotone at t={t}"
                );
            }
        }
    }

    #[test]
    fn full_cache_costs_zero_misses_only_on_prefetch() {
        let inp = inputs(2, 16);
        // t = N: p_hit = 1, p_miss2 = 0, p_one = 0 -> cost 0
        assert!(on_demand_cost(&inp, 0, 8) < 1e-12);
    }

    #[test]
    fn plan_respects_budget_and_bounds() {
        let inp = inputs(8, 24);
        let p = plan(&inp);
        assert_eq!(p.allocation.len(), 8);
        assert!(p.allocation.iter().sum::<usize>() <= 24);
        assert!(p.allocation.iter().all(|&t| t <= 8));
    }

    #[test]
    fn plan_beats_uniform() {
        // strongly heterogeneous β: DP must shift cache to hard layers
        let inp = PlanInputs {
            n_experts: 8,
            budget: 16,
            alpha: vec![0.1, 0.4, 0.4, 0.4],
            beta: vec![0.3, 0.95, 0.95, 0.95],
        };
        let p = plan(&inp);
        let uniform = DeviceCache::uniform_allocation(16, 4, 8);
        assert!(
            p.expected_loads <= allocation_cost(&inp, &uniform) + 1e-12,
            "DP {} vs uniform {}",
            p.expected_loads,
            allocation_cost(&inp, &uniform)
        );
        // the low-β layer gets at least as much as any high-β layer
        assert!(p.allocation[0] >= p.allocation[1]);
    }

    #[test]
    fn low_prefetch_accuracy_attracts_cache() {
        let inp = PlanInputs {
            n_experts: 8,
            budget: 8,
            alpha: vec![0.2; 4],
            beta: vec![0.2, 0.9, 0.9, 0.9],
        };
        let p = plan(&inp);
        let max_other = p.allocation[1..].iter().max().unwrap();
        assert!(
            p.allocation[0] >= *max_other,
            "hard-to-prefetch layer under-cached: {:?}",
            p.allocation
        );
    }

    #[test]
    fn prop_dp_optimal_vs_exhaustive() {
        // On small instances the DP must match brute force exactly.
        prop::check("dp-matches-bruteforce", 40, |rng| {
            let l = 2 + rng.usize_below(2); // 2..3 layers
            let n = 3;
            let budget = rng.usize_below(7);
            let inp = PlanInputs {
                n_experts: n,
                budget,
                alpha: (0..l).map(|_| rng.f64()).collect(),
                beta: (0..l).map(|_| rng.f64()).collect(),
            };
            let p = plan(&inp);
            // brute force over all allocations with t_i <= n
            let mut best = f64::INFINITY;
            let mut stack = vec![Vec::<usize>::new()];
            while let Some(cur) = stack.pop() {
                if cur.len() == l {
                    if cur.iter().sum::<usize>() <= budget {
                        best = best.min(allocation_cost(&inp, &cur));
                    }
                    continue;
                }
                for t in 0..=n {
                    let mut nxt = cur.clone();
                    nxt.push(t);
                    stack.push(nxt);
                }
            }
            crate::prop_assert!(
                (p.expected_loads - best).abs() < 1e-9,
                "dp={} brute={} inp={:?}",
                p.expected_loads,
                best,
                inp
            );
            Ok(())
        });
    }

    #[test]
    fn prop_more_budget_never_hurts() {
        prop::check("budget-monotone", 60, |rng| {
            let l = 4;
            let b1 = rng.usize_below(24);
            let b2 = b1 + rng.usize_below(8);
            let mk = |budget| PlanInputs {
                n_experts: 8,
                budget,
                alpha: (0..l).map(|i| 0.05 * i as f64).collect(),
                beta: (0..l).map(|i| 0.5 + 0.1 * i as f64).collect(),
            };
            let p1 = plan(&mk(b1));
            let p2 = plan(&mk(b2));
            crate::prop_assert!(
                p2.expected_loads <= p1.expected_loads + 1e-12,
                "budget {b1} -> {}, {b2} -> {}",
                p1.expected_loads,
                p2.expected_loads
            );
            Ok(())
        });
    }

    #[test]
    fn zero_budget_allocates_nothing() {
        let inp = inputs(4, 0);
        let p = plan(&inp);
        assert_eq!(p.allocation, vec![0; 4]);
        assert!(p.expected_loads > 0.0);
    }

    #[test]
    fn byte_plan_matches_expert_plan_at_equivalent_budget() {
        let inp = inputs(4, 16);
        let per = 12_345usize;
        let bp = plan_bytes(&BytePlanInputs {
            n_experts: inp.n_experts,
            budget_bytes: 16 * per + per / 2, // partial expert truncates
            bytes_per_expert: per,
            alpha: inp.alpha.clone(),
            beta: inp.beta.clone(),
        });
        let p = plan(&inp);
        assert_eq!(bp.allocation, p.allocation);
        assert!((bp.expected_loads - p.expected_loads).abs() < 1e-12);
        // byte ceilings are exactly allocation × per-expert bytes
        for (t, b) in bp.allocation.iter().zip(&bp.byte_budgets) {
            assert_eq!(*b, t * per);
        }
        assert!(bp.byte_budgets.iter().sum::<usize>() <= 16 * per + per / 2);
        // degenerate: zero-size experts must not divide by zero
        let z = plan_bytes(&BytePlanInputs {
            n_experts: 8,
            budget_bytes: 4,
            bytes_per_expert: 0,
            alpha: vec![0.2; 2],
            beta: vec![0.5; 2],
        });
        assert_eq!(z.allocation.len(), 2);
    }

    #[test]
    fn tiered_uniform_prices_defer_to_flat_byte_planner() {
        // All layers priced alike must be bit-identical to plan_bytes —
        // the uniform-SensitivityMap determinism guarantee.
        let inp = inputs(4, 16);
        let per = 777usize;
        let flat = plan_bytes(&BytePlanInputs {
            n_experts: inp.n_experts,
            budget_bytes: 16 * per + 3,
            bytes_per_expert: per,
            alpha: inp.alpha.clone(),
            beta: inp.beta.clone(),
        });
        let tiered = plan_bytes_tiered(&TierPlanInputs {
            n_experts: inp.n_experts,
            budget_bytes: 16 * per + 3,
            bytes_per_expert: vec![per; 4],
            alpha: inp.alpha.clone(),
            beta: inp.beta.clone(),
        });
        assert_eq!(tiered.allocation, flat.allocation);
        assert_eq!(tiered.byte_budgets, flat.byte_budgets);
        assert!((tiered.expected_loads - flat.expected_loads).abs() == 0.0);
        // empty instance is a no-op, not a panic
        let e = plan_bytes_tiered(&TierPlanInputs {
            n_experts: 8,
            budget_bytes: 100,
            bytes_per_expert: vec![],
            alpha: vec![],
            beta: vec![],
        });
        assert!(e.allocation.is_empty() && e.expected_loads == 0.0);
    }

    #[test]
    fn tiered_cheap_layers_buy_more_experts() {
        // Layer 0 residents sit at a quarter the bytes of layer 1's: the
        // same budget should tilt expert counts toward the cheap layer.
        let p = plan_bytes_tiered(&TierPlanInputs {
            n_experts: 8,
            budget_bytes: 8 * 100,
            bytes_per_expert: vec![25, 100],
            alpha: vec![0.2; 2],
            beta: vec![0.6; 2],
        });
        assert!(
            p.allocation[0] > p.allocation[1],
            "cheap layer under-cached: {:?}",
            p.allocation
        );
        assert!(p.byte_budgets[0] == p.allocation[0] * 25);
        assert!(p.byte_budgets.iter().sum::<usize>() <= 800);
    }

    #[test]
    fn prop_tiered_dp_matches_bruteforce() {
        // Heterogeneous small prices: the unit-compressed DP must still
        // find the byte-feasible optimum (unit = gcd, so no rounding).
        prop::check("tiered-dp-matches-bruteforce", 40, |rng| {
            let l = 2 + rng.usize_below(2); // 2..3 layers
            let n = 3;
            let prices: Vec<usize> = (0..l).map(|_| 1 + rng.usize_below(4)).collect();
            let budget = rng.usize_below(20);
            let inp = TierPlanInputs {
                n_experts: n,
                budget_bytes: budget,
                bytes_per_expert: prices.clone(),
                alpha: (0..l).map(|_| rng.f64()).collect(),
                beta: (0..l).map(|_| rng.f64()).collect(),
            };
            let p = plan_bytes_tiered(&inp);
            let costs = PlanInputs {
                n_experts: n,
                budget: 0,
                alpha: inp.alpha.clone(),
                beta: inp.beta.clone(),
            };
            let used: usize = p.allocation.iter().zip(&prices).map(|(&t, &c)| t * c).sum();
            crate::prop_assert!(used <= budget, "plan over budget: {used} > {budget}");
            let mut best = f64::INFINITY;
            let mut stack = vec![Vec::<usize>::new()];
            while let Some(cur) = stack.pop() {
                if cur.len() == l {
                    let bytes: usize =
                        cur.iter().zip(&prices).map(|(&t, &c)| t * c).sum();
                    if bytes <= budget {
                        best = best.min(allocation_cost(&costs, &cur));
                    }
                    continue;
                }
                for t in 0..=n {
                    let mut nxt = cur.clone();
                    nxt.push(t);
                    stack.push(nxt);
                }
            }
            crate::prop_assert!(
                (p.expected_loads - best).abs() < 1e-9,
                "dp={} brute={} inp={:?}",
                p.expected_loads,
                best,
                inp
            );
            Ok(())
        });
    }

    #[test]
    fn prop_tiered_budget_never_exceeded_and_monotone() {
        prop::check("tiered-budget-monotone", 60, |rng| {
            let l = 2 + rng.usize_below(3); // 2..4 layers
            let prices: Vec<usize> = (0..l).map(|_| 1 + rng.usize_below(8)).collect();
            let b1 = rng.usize_below(64);
            let b2 = b1 + rng.usize_below(32);
            let mk = |budget_bytes| TierPlanInputs {
                n_experts: 6,
                budget_bytes,
                bytes_per_expert: prices.clone(),
                alpha: (0..l).map(|i| 0.05 + 0.07 * i as f64).collect(),
                beta: (0..l).map(|i| 0.4 + 0.1 * i as f64).collect(),
            };
            let p1 = plan_bytes_tiered(&mk(b1));
            let p2 = plan_bytes_tiered(&mk(b2));
            for (p, b) in [(&p1, b1), (&p2, b2)] {
                let used: usize =
                    p.allocation.iter().zip(&prices).map(|(&t, &c)| t * c).sum();
                crate::prop_assert!(used <= b, "over budget: {used} > {b}");
                crate::prop_assert!(
                    p.byte_budgets.iter().sum::<usize>() <= b,
                    "byte ceilings over budget"
                );
            }
            crate::prop_assert!(
                p2.expected_loads <= p1.expected_loads + 1e-12,
                "budget {b1} -> {}, {b2} -> {}",
                p1.expected_loads,
                p2.expected_loads
            );
            Ok(())
        });
    }
}
