//! L3 coordinator — the paper's system contribution.
//!
//! * [`gating`] — fixed top-k / score-based / sensitivity-based adaptive
//!   gating (§4.2, eq. 8)
//! * [`prefetch`] — gate-reuse multi-layer prefetch + predictive gate (§4.3)
//! * [`cache_plan`] — knapsack-DP cache allocation (§4.4, eq. 10–19)
//! * [`scheduler`] — compute/comm overlap planning, expert- and tile-wise (§5)
//! * [`executor`] — completion-driven MoE layer execution (arrival-order
//!   consumption + threadpool fan-out over the unified work queue)
//! * [`engine`] — the decode engine tying it all together
//! * [`policy`] — paper-method presets (baselines + AdapMoE + ablations)
//! * [`batcher`] — continuous batching for the serving front
//! * [`trace`] — online profiling (α, β, scores, similarity, latency)
//! * [`profile`] — offline profile loader (artifacts/profile.json)
//! * [`sensitivity`] — one [`sensitivity::SensitivityMap`] shared by tier
//!   assignment, cache planning, eviction/prefetch priority and upgrade
//!   scheduling (docs/sensitivity.md)

pub mod batcher;
pub mod cache_plan;
pub mod engine;
pub mod executor;
pub mod gating;
pub mod policy;
pub mod prefetch;
pub mod profile;
pub mod scheduler;
pub mod sensitivity;
pub mod trace;
