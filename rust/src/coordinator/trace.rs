//! Online trace collection (the paper's offline-phase profiling, plus all
//! the raw material for Figs. 2/3/9 and the DP planner inputs).

use std::collections::HashSet;

use crate::coordinator::cache_plan::PlanInputs;
use crate::util::stats::{Histogram, LogHistogram, Summary};

/// Decode-step phases for the time breakdown (perf-pass instrumentation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Attn = 0,
    Gate = 1,
    Decide = 2,
    Predict = 3,
    MoeReady = 4,
    MoeWait = 5,
    Residual = 6,
    EmbedUnembed = 7,
}

impl Phase {
    pub const COUNT: usize = 8;

    pub const NAMES: [&'static str; Phase::COUNT] = [
        "attn", "gate", "decide", "predict", "moe_ready", "moe_wait",
        "residual", "embed/unembed",
    ];
}

/// Per-layer accumulators gathered while the engine decodes.
pub struct TraceCollector {
    n_layers: usize,
    /// decisions: (single-expert count, total decisions) per layer.
    pub singles: Vec<u64>,
    pub decisions: Vec<u64>,
    /// Normalized top-1 score α samples per layer (Fig. 2).
    pub alpha_hist: Vec<Histogram>,
    pub alpha_sum: Vec<f64>,
    /// Cosine similarity between successive MoE-block inputs (Fig. 3):
    /// entry i = sim(input of layer i, input of layer i+1).
    pub sim: Vec<Summary>,
    /// Prefetch accuracy per layer: (predicted-hit experts, needed experts).
    pub prefetch_hits: Vec<u64>,
    pub prefetch_needed: Vec<u64>,
    /// On-demand loads issued per layer.
    pub on_demand: Vec<u64>,
    /// Wall-clock the compute stream spent blocked on transfers (ns).
    pub stall_ns: u64,
    /// True idle time per layer: compute had nothing runnable and slept on
    /// the completion board (ns).
    pub layer_stall_ns: Vec<u64>,
    /// Head-of-line cost per layer: time transferred expert/tile data sat
    /// ready before compute consumed it (ns).
    pub queue_delay_ns: Vec<u64>,
    /// Queue delay split by comm lane (indexed by lane id, grown on
    /// demand): which lane's arrivals sat waiting on compute. Fig. 9
    /// pipeline-attribution input for multi-lane engines.
    pub queue_delay_lane_ns: Vec<u64>,
    /// Queue delay split by precision tier (indexed by
    /// `QuantKind::tier_index`, grown on demand): which tier's bytes sat
    /// waiting on compute — the tiered store's fig9 attribution input.
    pub queue_delay_tier_ns: Vec<u64>,
    /// Lookups served from a resident copy below the preferred tier
    /// (degrade-instead-of-miss accepted lower precision over a stall).
    pub degraded_hits: u64,
    /// Experts served through the degradation ladder after their transfer
    /// failed (resident copy of any tier, or a replica shard) — see
    /// docs/fault-tolerance.md.
    pub fault_recovered: u64,
    /// Experts dropped from a layer plan entirely (transfer failed and no
    /// fallback copy existed), as (layer, expert) pairs in drop order —
    /// the audit trail that marks a token as degraded.
    pub dropped_experts: Vec<(usize, usize)>,
    /// Whether to collect the Fig. 3 similarity series. Off by default:
    /// it forces the engine to keep a copy of the previous layer's hidden
    /// state every layer, which is pure overhead on the serving path.
    collect_similarity: bool,
    /// Per-phase decode-step time (ns): see [`Phase`].
    pub phase_ns: [u64; Phase::COUNT],
    /// Per-token decode latency (seconds).
    pub token_latency: Summary,
    /// Log-bucketed per-token latency distribution (for p50/p95/p99 in
    /// `ServerStats` and the metrics exposition).
    pub token_hist: LogHistogram,
    /// Log-bucketed per-arrival lane queue-delay distribution.
    pub lane_queue_hist: LogHistogram,
    /// Tokens decoded.
    pub tokens: u64,
}

impl TraceCollector {
    pub fn new(n_layers: usize) -> TraceCollector {
        TraceCollector {
            n_layers,
            singles: vec![0; n_layers],
            decisions: vec![0; n_layers],
            alpha_hist: (0..n_layers).map(|_| Histogram::new(0.5, 1.0, 20)).collect(),
            alpha_sum: vec![0.0; n_layers],
            sim: (0..n_layers.saturating_sub(1)).map(|_| Summary::new()).collect(),
            prefetch_hits: vec![0; n_layers],
            prefetch_needed: vec![0; n_layers],
            on_demand: vec![0; n_layers],
            stall_ns: 0,
            layer_stall_ns: vec![0; n_layers],
            queue_delay_ns: vec![0; n_layers],
            queue_delay_lane_ns: Vec::new(),
            queue_delay_tier_ns: Vec::new(),
            degraded_hits: 0,
            fault_recovered: 0,
            dropped_experts: Vec::new(),
            collect_similarity: false,
            phase_ns: [0; Phase::COUNT],
            token_latency: Summary::new(),
            token_hist: LogHistogram::new(),
            lane_queue_hist: LogHistogram::new(),
            tokens: 0,
        }
    }

    /// Builder: turn the Fig. 3 similarity trace on/off (see
    /// [`TraceCollector::collect_similarity`]).
    pub fn with_similarity(mut self, on: bool) -> TraceCollector {
        self.collect_similarity = on;
        self
    }

    pub fn enable_similarity(&mut self) {
        self.collect_similarity = true;
    }

    pub fn similarity_enabled(&self) -> bool {
        self.collect_similarity
    }

    pub fn record_decision(&mut self, layer: usize, alpha: f64, single: bool) {
        self.decisions[layer] += 1;
        if single {
            self.singles[layer] += 1;
        }
        self.alpha_hist[layer].add(alpha);
        self.alpha_sum[layer] += alpha;
    }

    pub fn record_similarity(&mut self, layer: usize, cos: f64) {
        if layer < self.sim.len() {
            self.sim[layer].add(cos);
        }
    }

    /// Compare a layer's actual per-row needed experts against the predicted
    /// sets (same row order). β accounting is per *expert*: each needed
    /// expert found in the prediction counts as a hit (paper Fig. 9(b)).
    pub fn record_prefetch_outcome(
        &mut self,
        layer: usize,
        predicted: &[HashSet<usize>],
        actual: &[Vec<usize>],
    ) {
        for (pred, act) in predicted.iter().zip(actual) {
            for e in act {
                self.prefetch_needed[layer] += 1;
                if pred.contains(e) {
                    self.prefetch_hits[layer] += 1;
                }
            }
        }
    }

    pub fn record_on_demand(&mut self, layer: usize, count: u64) {
        self.on_demand[layer] += count;
    }

    pub fn record_stall(&mut self, ns: u64) {
        self.stall_ns += ns;
    }

    /// True idle wait attributed to a layer (also counts toward the global
    /// [`TraceCollector::stall_ns`]).
    pub fn record_layer_stall(&mut self, layer: usize, ns: u64) {
        self.stall_ns += ns;
        self.layer_stall_ns[layer] += ns;
    }

    /// Arrived-but-unconsumed time for one expert/tile of a layer.
    pub fn record_queue_delay(&mut self, layer: usize, ns: u64) {
        self.queue_delay_ns[layer] += ns;
    }

    /// Queue delay attributed to the comm lane that carried the data.
    pub fn record_lane_queue_delay(&mut self, lane: usize, ns: u64) {
        if lane >= self.queue_delay_lane_ns.len() {
            self.queue_delay_lane_ns.resize(lane + 1, 0);
        }
        self.queue_delay_lane_ns[lane] += ns;
        self.lane_queue_hist.record(ns as f64 / 1e9);
    }

    /// Per-lane queue-delay seconds (index = lane id; empty when the run
    /// recorded no lane-attributed delay).
    pub fn lane_queue_delay(&self) -> Vec<f64> {
        self.queue_delay_lane_ns
            .iter()
            .map(|&ns| ns as f64 / 1e9)
            .collect()
    }

    /// Queue delay attributed to the precision tier the data was encoded
    /// at (index = `QuantKind::tier_index`).
    pub fn record_tier_queue_delay(&mut self, tier: usize, ns: u64) {
        if tier >= self.queue_delay_tier_ns.len() {
            self.queue_delay_tier_ns.resize(tier + 1, 0);
        }
        self.queue_delay_tier_ns[tier] += ns;
    }

    /// Per-tier queue-delay seconds (index = `QuantKind::tier_index`;
    /// empty when the run recorded no tier-attributed delay).
    pub fn tier_queue_delay(&self) -> Vec<f64> {
        self.queue_delay_tier_ns
            .iter()
            .map(|&ns| ns as f64 / 1e9)
            .collect()
    }

    /// Count degrade-instead-of-miss hits (resident copy served below
    /// the preferred tier).
    pub fn record_degraded_hits(&mut self, count: u64) {
        self.degraded_hits += count;
    }

    /// Degradation-ladder accounting for one layer's drain: experts
    /// served from a fallback copy after a failed transfer, and experts
    /// dropped from the plan outright.
    pub fn record_faults(&mut self, layer: usize, recovered: u64, dropped: &[usize]) {
        self.fault_recovered += recovered;
        self.dropped_experts.extend(dropped.iter().map(|&e| (layer, e)));
    }

    pub fn record_phase(&mut self, phase: Phase, ns: u64) {
        self.phase_ns[phase as usize] += ns;
        crate::obs::span_ending_now(crate::obs::Track::Decode, crate::obs::Name::Phase(phase), ns);
    }

    /// (name, seconds) pairs for the phase breakdown.
    pub fn phase_seconds(&self) -> Vec<(&'static str, f64)> {
        Phase::NAMES
            .iter()
            .zip(self.phase_ns.iter())
            .map(|(n, &ns)| (*n, ns as f64 / 1e9))
            .collect()
    }

    pub fn record_token(&mut self, latency_s: f64, rows: u64) {
        self.token_latency.add(latency_s);
        self.token_hist.record(latency_s);
        self.tokens += rows;
    }

    // -- derived metrics -----------------------------------------------------

    /// Single-expert activation ratio per layer (Fig. 9(a)).
    pub fn single_ratio(&self) -> Vec<f64> {
        (0..self.n_layers)
            .map(|i| {
                if self.decisions[i] == 0 {
                    0.0
                } else {
                    self.singles[i] as f64 / self.decisions[i] as f64
                }
            })
            .collect()
    }

    /// Mean single-expert ratio across layers.
    pub fn mean_single_ratio(&self) -> f64 {
        let d: u64 = self.decisions.iter().sum();
        if d == 0 {
            return 0.0;
        }
        self.singles.iter().sum::<u64>() as f64 / d as f64
    }

    /// Prefetch accuracy β_i per layer (Fig. 9(b)).
    pub fn beta(&self) -> Vec<f64> {
        (0..self.n_layers)
            .map(|i| {
                if self.prefetch_needed[i] == 0 {
                    0.0
                } else {
                    self.prefetch_hits[i] as f64 / self.prefetch_needed[i] as f64
                }
            })
            .collect()
    }

    /// Mean α per layer (Fig. 2(a) series).
    pub fn alpha_mean(&self) -> Vec<f64> {
        (0..self.n_layers)
            .map(|i| {
                if self.decisions[i] == 0 {
                    0.0
                } else {
                    self.alpha_sum[i] / self.decisions[i] as f64
                }
            })
            .collect()
    }

    /// Mean cross-layer similarity series (Fig. 3).
    pub fn similarity(&self) -> Vec<f64> {
        self.sim.iter().map(|s| s.mean()).collect()
    }

    /// Per-layer (queue-delay seconds, stall seconds): where the MoE wait
    /// went. Queue delay is head-of-line blocking the completion-driven
    /// executor removes; stall is the irreducible wait for the link.
    pub fn stall_attribution(&self) -> Vec<(f64, f64)> {
        self.queue_delay_ns
            .iter()
            .zip(&self.layer_stall_ns)
            .map(|(&q, &s)| (q as f64 / 1e9, s as f64 / 1e9))
            .collect()
    }

    /// DP planner inputs measured from this trace; `fallback_beta` fills
    /// layers with no prefetch data (e.g. prefetch disabled).
    pub fn plan_inputs(&self, n_experts: usize, budget: usize, fallback_beta: f64) -> PlanInputs {
        let beta = (0..self.n_layers)
            .map(|i| {
                if self.prefetch_needed[i] == 0 {
                    fallback_beta
                } else {
                    self.prefetch_hits[i] as f64 / self.prefetch_needed[i] as f64
                }
            })
            .collect();
        PlanInputs { n_experts, budget, alpha: self.single_ratio(), beta }
    }

    /// Tokens decoded per second of recorded latency.
    pub fn tokens_per_sec(&self) -> f64 {
        let total = self.token_latency.sum();
        if total == 0.0 {
            return 0.0;
        }
        self.tokens as f64 / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_and_beta() {
        let mut t = TraceCollector::new(2);
        t.record_decision(0, 0.9, true);
        t.record_decision(0, 0.6, false);
        t.record_decision(1, 0.7, false);
        assert_eq!(t.single_ratio(), vec![0.5, 0.0]);
        assert!((t.mean_single_ratio() - 1.0 / 3.0).abs() < 1e-9);

        let pred = vec![HashSet::from([1usize, 2]), HashSet::from([3usize])];
        let actual = vec![vec![1, 4], vec![3]];
        t.record_prefetch_outcome(0, &pred, &actual);
        assert_eq!(t.beta()[0], 2.0 / 3.0);
    }

    #[test]
    fn plan_inputs_fallback() {
        let mut t = TraceCollector::new(2);
        t.record_decision(0, 0.8, true);
        t.record_decision(1, 0.8, false);
        let p = t.plan_inputs(8, 10, 0.55);
        assert_eq!(p.beta, vec![0.55, 0.55]);
        assert_eq!(p.alpha, vec![1.0, 0.0]);
        assert_eq!(p.budget, 10);
    }

    #[test]
    fn throughput_math() {
        let mut t = TraceCollector::new(1);
        t.record_token(0.5, 4);
        t.record_token(0.5, 4);
        assert!((t.tokens_per_sec() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn similarity_series_len() {
        let t = TraceCollector::new(4);
        assert_eq!(t.similarity().len(), 3);
    }

    #[test]
    fn similarity_gate_defaults_off() {
        let t = TraceCollector::new(2);
        assert!(!t.similarity_enabled());
        let t = TraceCollector::new(2).with_similarity(true);
        assert!(t.similarity_enabled());
        let mut t = TraceCollector::new(2);
        t.enable_similarity();
        assert!(t.similarity_enabled());
    }

    #[test]
    fn stall_attribution_per_layer() {
        let mut t = TraceCollector::new(2);
        t.record_layer_stall(0, 1_000_000);
        t.record_layer_stall(1, 2_000_000);
        t.record_queue_delay(1, 500_000);
        assert_eq!(t.stall_ns, 3_000_000);
        let attr = t.stall_attribution();
        assert_eq!(attr.len(), 2);
        assert!((attr[0].1 - 1e-3).abs() < 1e-12);
        assert!((attr[1].0 - 0.5e-3).abs() < 1e-12);
        assert!((attr[1].1 - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn tier_queue_delay_and_degraded_hits_accumulate() {
        let mut t = TraceCollector::new(2);
        assert!(t.tier_queue_delay().is_empty());
        t.record_tier_queue_delay(1, 1_000_000); // int4
        t.record_tier_queue_delay(0, 500_000); // int2
        t.record_tier_queue_delay(1, 1_000_000);
        let tiers = t.tier_queue_delay();
        assert_eq!(tiers.len(), 2);
        assert!((tiers[0] - 0.5e-3).abs() < 1e-12);
        assert!((tiers[1] - 2e-3).abs() < 1e-12);
        assert_eq!(t.degraded_hits, 0);
        t.record_degraded_hits(3);
        t.record_degraded_hits(1);
        assert_eq!(t.degraded_hits, 4);
    }

    #[test]
    fn fault_recovery_and_drops_accumulate() {
        let mut t = TraceCollector::new(3);
        assert_eq!(t.fault_recovered, 0);
        assert!(t.dropped_experts.is_empty());
        t.record_faults(1, 2, &[5]);
        t.record_faults(2, 0, &[0, 7]);
        assert_eq!(t.fault_recovered, 2);
        assert_eq!(t.dropped_experts, vec![(1, 5), (2, 0), (2, 7)]);
    }

    #[test]
    fn lane_queue_delay_grows_and_accumulates() {
        let mut t = TraceCollector::new(2);
        assert!(t.lane_queue_delay().is_empty());
        t.record_lane_queue_delay(2, 1_000_000);
        t.record_lane_queue_delay(0, 500_000);
        t.record_lane_queue_delay(2, 1_000_000);
        let lanes = t.lane_queue_delay();
        assert_eq!(lanes.len(), 3, "vector grows to the highest lane seen");
        assert!((lanes[0] - 0.5e-3).abs() < 1e-12);
        assert_eq!(lanes[1], 0.0);
        assert!((lanes[2] - 2e-3).abs() < 1e-12);
    }
}
