//! The decode engine — the compute "stream" of Algorithm 1, driven as a
//! **completion-driven pipeline**.
//!
//! Owns the PJRT runtime, the resident (non-expert) weights, the KV cache
//! and the memory hierarchy, and drives batched decode steps: for each
//! layer, attention → gate → adaptive gating decisions → prefetch for
//! upcoming layers → MoE execution. The MoE half works off the unified
//! work queue emitted by [`super::scheduler::build_plan`]:
//!
//! 1. **Ready** (cache/staging-resident) experts compute first, overlapping
//!    whatever the comm stream is still moving.
//! 2. **Pending** experts are consumed in **arrival order**: the engine
//!    parks on the transfer engine's completion board and picks up
//!    whichever expert — or, in tile-wise mode, whichever f-tile — lands
//!    next, rather than blocking on plan order (no head-of-line blocking).
//!    Arrived-but-unconsumed time is traced as per-layer *queue delay*,
//!    true idle time as *stall*, so `fig9_breakdown` can show where the
//!    overlap win comes from.
//! 3. Consumed experts are promoted into the **owning device shard** of
//!    the [`ShardedCache`] on completion (one shard total in the
//!    historical single-device shape); whole-layer "extra" loads ride
//!    the same queue but are never waited on.
//!
//! Expert kernels run on this thread (PJRT handles are not `Send`). With
//! [`EngineConfig::compute_workers`] > 0 the engine instead fans host-side
//! SwiGLU FFNs across the [`ThreadPool`] via
//! [`super::executor::run_layer_parallel`], computing cached experts in
//! parallel while pending transfers stream in (partial results are reduced
//! in canonical order at the end of the layer, so output bits do not
//! depend on scheduling). Everything the paper's §4–5 describe meets here;
//! the policy knobs live in [`EngineConfig`] so baselines and ablations
//! are just different configs (see [`super::policy`]).

use std::collections::HashSet;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::coordinator::cache_plan;
use crate::coordinator::executor;
use crate::coordinator::gating::GatingPolicy;
use crate::coordinator::prefetch::{self, PrefetchConfig};
use crate::coordinator::profile::Profile;
use crate::coordinator::scheduler::{build_plan_tiered, ScheduleMode, TierMode};
use crate::coordinator::sensitivity::{LaneIdlePredictor, SensitivityMap, SensitivityPolicy};
use crate::coordinator::trace::{Phase, TraceCollector};
use crate::memory::device_cache::DeviceCache;
use crate::memory::faults::FaultPlan;
use crate::memory::host_store::{ExpertF32, HostStore};
use crate::memory::platform::Platform;
use crate::memory::quant::QuantKind;
use crate::memory::sharded_cache::{Placement, ShardedCache};
use crate::memory::tiered_store::{PrecisionPolicy, TieredStore};
use crate::memory::transfer::{LaneConfig, Priority, TransferEngine, TransferHandle};
use crate::model::config::ModelConfig;
use crate::model::weights::Weights;
use crate::runtime::{f32_literal, i32_literal, literal_to_tensor, tensor_to_literal, Runtime};
use crate::tensor::Tensor;
use crate::util::stats::cosine;
use crate::util::threadpool::{RowBufferPool, ThreadPool};

/// Per-layer cache budget policy.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Equal split across layers (Mixtral-offloading / baselines).
    Uniform,
    /// Knapsack DP over the offline α/β profile (AdapMoE §4.4).
    Planned,
}

/// Everything that distinguishes AdapMoE from its baselines and ablations.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Fixed decode batch (must be one of the exported batch buckets).
    pub batch: usize,
    pub gating: GatingPolicy,
    pub prefetch: PrefetchConfig,
    pub alloc: AllocPolicy,
    /// Total expert-cache budget (in experts) — the paper's T.
    pub cache_budget: usize,
    pub schedule: ScheduleMode,
    pub quant: QuantKind,
    /// Precision tiers of the expert store (`--tiers`). Empty = the
    /// single `quant` tier, which reproduces the historical one-kind
    /// store bit-for-bit; more tiers make the store mixed-precision and
    /// the cache byte-denominated (docs/tiered-precision.md).
    pub tiers: Vec<QuantKind>,
    /// Which tier a fresh transfer rides (`--precision-policy`).
    pub precision: PrecisionPolicy,
    /// Max background upgrade transfers issued per idle moment
    /// (`--upgrade-budget`; 0 disables the upgrade path).
    pub upgrade_budget: usize,
    /// Serve resident below-preferred-tier copies (degrade) or re-fetch
    /// them at the preferred tier (strict).
    pub tier_mode: TierMode,
    pub platform: Platform,
    /// Tiles per expert transfer (must match the exported tile artifact).
    pub n_tiles: usize,
    /// Simulated-time multiplier (1.0 calibrated; 0.0 logic-only tests).
    pub time_scale: f64,
    /// Comm-lane set: how many parallel transfer streams feed the
    /// CompletionBoard and how jobs are assigned to them (`--lanes` /
    /// `--lane-policy`; see docs/transfer-lanes.md).
    pub lanes: LaneConfig,
    /// Device backends sharding the expert cache (`--devices`). 1 keeps
    /// the historical single-pool engine bit-for-bit; more devices
    /// partition the budget T across per-device caches and give comm
    /// lanes device affinity (docs/sharded-backends.md).
    pub devices: usize,
    /// ExpertId → device mapping when `devices > 1` (`--placement`).
    pub placement: Placement,
    /// DeepSpeed/FlexGen-style baseline: load ALL experts of each layer.
    pub whole_layer: bool,
    /// Worker threads for host-side parallel expert FFNs (see
    /// [`super::executor`]). 0 (default for every preset) keeps expert
    /// compute on the engine thread via the XLA kernel path; PJRT handles
    /// are not `Send`, so the parallel path trades the Pallas kernel for
    /// host math with identical-bits reduction.
    pub compute_workers: usize,
    /// Scripted lane/device fault injection (`--fault-plan`,
    /// docs/fault-tolerance.md): each event fires when decode reaches its
    /// step. `None` (every preset) leaves the engine bit-for-bit
    /// identical to a fault-free build.
    pub fault_plan: Option<FaultPlan>,
    /// Artifact-server address (`--remote`, docs/remote-store.md): when
    /// set, the expert store is built cacheless against that server —
    /// expert bytes are fetched, checksum-verified and pinned on first
    /// use instead of loaded from local weights. `None` (every preset)
    /// keeps the store fully local and bit-for-bit identical.
    pub remote: Option<String>,
    /// Which [`SensitivityMap`] drives the four resource consumers —
    /// tier floors, cache re-planning, eviction/prefetch priority and
    /// upgrade scheduling (`--sensitivity-policy`, docs/sensitivity.md).
    /// `Uniform` (every preset) is the identity map, bit-for-bit
    /// today's behavior.
    pub sensitivity: SensitivityPolicy,
}

/// Non-expert weights kept device-resident as literals.
struct Resident {
    embed: Literal,
    out_norm: Literal,
    unembed: Literal,
    pre_gate: Literal,
    attn_norm: Vec<Literal>,
    wq: Vec<Literal>,
    wk: Vec<Literal>,
    wv: Vec<Literal>,
    wo: Vec<Literal>,
    moe_norm: Vec<Literal>,
    gate: Vec<Literal>,
}

impl Resident {
    fn build(cfg: &ModelConfig, w: &Weights) -> Result<Resident> {
        let lit = |name: &str| -> Result<Literal> { tensor_to_literal(w.get(name)?) };
        let per_layer = |field: &str| -> Result<Vec<Literal>> {
            (0..cfg.n_layers).map(|l| lit(&format!("l{l}.{field}"))).collect()
        };
        Ok(Resident {
            embed: lit("embed")?,
            out_norm: lit("out_norm")?,
            unembed: lit("unembed")?,
            pre_gate: lit("pre_gate")?,
            attn_norm: per_layer("attn_norm")?,
            wq: per_layer("wq")?,
            wk: per_layer("wk")?,
            wv: per_layer("wv")?,
            wo: per_layer("wo")?,
            moe_norm: per_layer("moe_norm")?,
            gate: per_layer("gate")?,
        })
    }
}

/// Row slot bookkeeping for continuous batching.
struct Slots {
    pos: Vec<usize>,
    active: Vec<bool>,
}

pub struct Engine {
    pub cfg: ModelConfig,
    pub ecfg: EngineConfig,
    rt: Runtime,
    resident: Resident,
    /// Highest-tier host store (the sole store for single-tier runs).
    pub store: Arc<HostStore>,
    /// Every precision tier's encodings (one entry for single-tier runs).
    pub tiered: Arc<TieredStore>,
    /// Device-sharded expert cache set (a single shard when
    /// `EngineConfig::devices == 1`).
    pub cache: Arc<ShardedCache>,
    pub xfer: TransferEngine,
    pub profile: Profile,
    /// The shared sensitivity map — the single source every resource
    /// consumer reads (docs/sensitivity.md). Also installed on `xfer`
    /// (tier floors) and `cache` (eviction weights) at construction.
    sensitivity: Arc<SensitivityMap>,
    /// Lane idle-time predictor (EWMA of inter-completion gaps) gating
    /// background upgrades when the map is non-uniform.
    idle: LaneIdlePredictor,
    kv_k: Vec<Literal>,
    kv_v: Vec<Literal>,
    slots: Slots,
    /// Literal-converted expert weights, keyed by expert id and the Arc
    /// identity of the host tensor (invalidates automatically when the
    /// cache entry is replaced by a fresh transfer). Saves re-converting
    /// ~400 KB of f32 per expert call on the hot path.
    lit_cache: std::collections::HashMap<crate::model::ExpertId, (usize, [Literal; 3])>,
    /// Host-FFN worker pool (only when `compute_workers > 0`).
    pool: Option<ThreadPool>,
    /// Recycled scratch for per-row hidden-state copies on the decode hot
    /// path (similarity snapshots): steady-state decode reuses capacity
    /// instead of allocating a fresh `Vec<f32>` per row per layer.
    row_pool: RowBufferPool,
    pub trace: TraceCollector,
    /// Latest per-layer predicted expert sets (per row), for β tracking and
    /// the prefetch-extension rule.
    predicted: Vec<Option<Vec<HashSet<usize>>>>,
    /// Decode steps completed — the clock [`EngineConfig::fault_plan`]
    /// events are keyed by.
    decode_steps: usize,
    /// Artifact name suffix for the configured batch.
    suffix: String,
}

impl Engine {
    /// Build an engine from an artifacts directory.
    pub fn from_artifacts(dir: &Path, ecfg: EngineConfig) -> Result<Engine> {
        let (cfg, manifest) = ModelConfig::load_manifest(dir)?;
        let weights = Weights::load(&dir.join("weights.bin"))?;
        let profile = Profile::load(dir)?;
        Self::new(dir, cfg, manifest_names(&ecfg), &weights, profile, ecfg, &manifest)
    }

    fn new(
        dir: &Path,
        cfg: ModelConfig,
        names: Vec<String>,
        weights: &Weights,
        profile: Profile,
        ecfg: EngineConfig,
        manifest: &crate::util::json::Json,
    ) -> Result<Engine> {
        if !cfg.batch_sizes.contains(&ecfg.batch) {
            bail!("batch {} not among exported buckets {:?}", ecfg.batch, cfg.batch_sizes);
        }
        let rt = Runtime::load(dir, manifest, &names)
            .context("loading runtime artifacts")?;
        let resident = Resident::build(&cfg, weights)?;
        // Empty tier list = the single --quant tier (historical shape,
        // bit-for-bit); otherwise every listed tier gets its own store.
        let tier_kinds: Vec<QuantKind> = if ecfg.tiers.is_empty() {
            vec![ecfg.quant]
        } else {
            ecfg.tiers.clone()
        };
        let tiered = match &ecfg.remote {
            None => Arc::new(TieredStore::build(&cfg, weights, &tier_kinds)?),
            Some(addr) => {
                // Cacheless mode: the store's encodings live on an artifact
                // server; the manifest must describe exactly the model and
                // tier set this engine was configured for, or the transfer
                // clocks and cache budgets would silently diverge from the
                // local baseline.
                let (remote, man) = crate::net::remote::connect_store(addr)
                    .with_context(|| format!("connecting to remote expert store {addr}"))?;
                if man.n_layers != cfg.n_layers
                    || man.n_experts != cfg.n_experts
                    || man.d_model != cfg.d_model
                    || man.d_ff != cfg.d_ff
                {
                    bail!(
                        "remote store {addr} serves {}x{} experts ({}x{}), \
                         model wants {}x{} ({}x{})",
                        man.n_layers,
                        man.n_experts,
                        man.d_model,
                        man.d_ff,
                        cfg.n_layers,
                        cfg.n_experts,
                        cfg.d_model,
                        cfg.d_ff
                    );
                }
                let mut want = tier_kinds.clone();
                want.sort_by_key(|k| k.bits());
                want.dedup();
                if man.tiers != want {
                    bail!(
                        "remote store {addr} publishes tiers {:?}, engine configured for {:?}",
                        man.tiers,
                        want
                    );
                }
                Arc::new(remote)
            }
        };
        let store = Arc::clone(tiered.base());

        let cache = Arc::new(build_sharded_cache(&cfg, &ecfg, &profile));
        if tiered.n_tiers() > 1 {
            // Byte-denominate the cache: each layer's count budget becomes
            // a byte ceiling at the resident (highest) tier, and the count
            // cap is raised to what the bytes could hold at the lowest
            // tier — degraded residents pack more experts into the same
            // memory (docs/tiered-precision.md).
            apply_byte_budgets(&cache, &tiered);
        }
        let xfer = TransferEngine::with_tiers(
            Arc::clone(&tiered),
            ecfg.precision,
            Arc::clone(&cache),
            ecfg.platform.clone(),
            ecfg.n_tiles,
            ecfg.time_scale,
            ecfg.lanes.clone(),
        );
        // One map, four consumers: install it on the transfer engine
        // (tier floors) and the cache shards (eviction weights); the
        // engine itself reads it for prefetch priority, re-planning and
        // upgrade ordering. Uniform policy installs the identity map —
        // eviction weights stay `None`, so nothing changes bits.
        let sensitivity =
            Arc::new(SensitivityMap::from_profile(&profile, ecfg.sensitivity));
        xfer.set_sensitivity(Arc::clone(&sensitivity));
        cache.set_eviction_weights(sensitivity.eviction_weights());

        let b = ecfg.batch;
        let kv_dims = [b, cfg.n_heads, cfg.max_seq, cfg.head_dim];
        let zeros = vec![0f32; kv_dims.iter().product()];
        let kv_k = (0..cfg.n_layers)
            .map(|_| f32_literal(&zeros, &kv_dims))
            .collect::<Result<Vec<_>>>()?;
        let kv_v = (0..cfg.n_layers)
            .map(|_| f32_literal(&zeros, &kv_dims))
            .collect::<Result<Vec<_>>>()?;

        let n_layers = cfg.n_layers;
        let pool = if ecfg.compute_workers > 0 {
            Some(ThreadPool::new(ecfg.compute_workers))
        } else {
            None
        };
        Ok(Engine {
            cfg,
            suffix: format!("b{b}"),
            rt,
            resident,
            store,
            tiered,
            cache,
            xfer,
            profile,
            sensitivity,
            idle: LaneIdlePredictor::new(),
            kv_k,
            kv_v,
            slots: Slots { pos: vec![0; b], active: vec![false; b] },
            lit_cache: std::collections::HashMap::new(),
            pool,
            row_pool: RowBufferPool::new(),
            trace: TraceCollector::new(n_layers),
            predicted: (0..n_layers).map(|_| None).collect(),
            decode_steps: 0,
            ecfg,
        })
    }

    // -- slots ---------------------------------------------------------------

    pub fn acquire_slot(&mut self) -> Option<usize> {
        let row = self.slots.active.iter().position(|a| !a)?;
        self.slots.active[row] = true;
        self.slots.pos[row] = 0;
        Some(row)
    }

    pub fn release_slot(&mut self, row: usize) {
        self.slots.active[row] = false;
        self.slots.pos[row] = 0;
    }

    pub fn slot_pos(&self, row: usize) -> usize {
        self.slots.pos[row]
    }

    pub fn free_slots(&self) -> usize {
        self.slots.active.iter().filter(|a| !**a).count()
    }

    pub fn slot_full(&self, row: usize) -> bool {
        self.slots.pos[row] >= self.cfg.max_seq
    }

    // -- decode ---------------------------------------------------------------

    /// One decode step for the given (row, token) pairs. Rows must hold
    /// active slots. Returns (row, logits) for each input row.
    pub fn decode_step(&mut self, inputs: &[(usize, u32)]) -> Result<Vec<(usize, Vec<f32>)>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        // Fire this step's scripted faults before any transfer is issued,
        // so a recorded plan replays against the same engine state.
        if let Some(plan) = &self.ecfg.fault_plan {
            self.xfer.apply_fault_plan(plan, self.decode_steps);
        }
        self.decode_steps += 1;
        let b = self.ecfg.batch;
        let l_total = self.cfg.n_layers;
        let mut tok = vec![0i32; b];
        let mut stepping = vec![false; b];
        for &(row, t) in inputs {
            assert!(self.slots.active[row], "row {row} not active");
            assert!(!self.slot_full(row), "row {row} KV cache full");
            tok[row] = t as i32;
            stepping[row] = true;
        }

        // embed
        let t_phase = Instant::now();
        let tok_lit = i32_literal(&tok, &[b])?;
        let mut outs = self.rt.run(
            &format!("embed_{}", self.suffix),
            &[&tok_lit, &self.resident.embed],
        )?;
        let mut h = outs.remove(0);
        self.trace
            .record_phase(Phase::EmbedUnembed, t_phase.elapsed().as_nanos() as u64);

        let pos: Vec<i32> = self.slots.pos.iter().map(|&p| p as i32).collect();
        let pos_lit = i32_literal(&pos, &[b])?;
        // Fig. 3 similarity needs last layer's MoE input; keep only the
        // stepped rows, and only when the trace asks for it — copying the
        // full hidden state every layer is pure overhead when serving.
        let mut prev_rows: Option<Vec<(usize, Vec<f32>)>> = None;

        for layer in 0..l_total {
            // ---- attention ----
            let t_phase = Instant::now();
            let mut outs = self.rt.run(
                &format!("attn_step_{}", self.suffix),
                &[
                    &h,
                    &self.resident.attn_norm[layer],
                    &self.resident.wq[layer],
                    &self.resident.wk[layer],
                    &self.resident.wv[layer],
                    &self.resident.wo[layer],
                    &self.kv_k[layer],
                    &self.kv_v[layer],
                    &pos_lit,
                ],
            )?;
            h = outs.remove(0);
            self.kv_k[layer] = outs.remove(0);
            self.kv_v[layer] = outs.remove(0);
            self.trace
                .record_phase(Phase::Attn, t_phase.elapsed().as_nanos() as u64);

            // ---- gate ----
            let t_phase = Instant::now();
            let mut outs = self.rt.run(
                &format!("gate_{}", self.suffix),
                &[
                    &h,
                    &self.resident.moe_norm[layer],
                    &self.resident.gate[layer],
                ],
            )?;
            let probs = literal_to_tensor(&outs[0])?; // [B, N]
            let xn = outs.remove(1); // [B, d] normed MoE input

            let mut h_host = literal_to_tensor(&h)?;
            self.trace
                .record_phase(Phase::Gate, t_phase.elapsed().as_nanos() as u64);
            let t_phase = Instant::now();

            // Fig. 3 trace: similarity between successive MoE-block inputs.
            if self.trace.similarity_enabled() {
                if let Some(prev) = prev_rows.take() {
                    let mut sims = 0.0;
                    let mut cnt = 0;
                    for (r, row) in &prev {
                        if stepping[*r] {
                            sims += cosine(row, h_host.row(*r));
                            cnt += 1;
                        }
                    }
                    if cnt > 0 {
                        self.trace.record_similarity(layer - 1, sims / cnt as f64);
                    }
                    for (_, row) in prev {
                        self.row_pool.put(row);
                    }
                }
                // Snapshot into pooled buffers — the next layer returns
                // them above, so steady state recycles the same capacity.
                prev_rows = Some(
                    (0..b)
                        .filter(|&r| stepping[r])
                        .map(|r| {
                            let src = h_host.row(r);
                            let mut buf = self.row_pool.take(src.len());
                            buf.copy_from_slice(src);
                            (r, buf)
                        })
                        .collect(),
                );
            }

            // ---- adaptive gating decisions ----
            let n = self.cfg.n_experts;
            let mut coef: Vec<Vec<f32>> = vec![vec![0.0; b]; n];
            let mut needed: HashSet<usize> = HashSet::new();
            let mut actual_per_row: Vec<Vec<usize>> = vec![Vec::new(); b];
            for r in 0..b {
                if !stepping[r] {
                    continue;
                }
                let row = probs.row(r);
                let decision = self.ecfg.gating.decide(layer, row);
                let sorted = crate::model::sampling::top_k_indices(row, 2);
                let p1 = row[sorted[0]];
                let p2 = if sorted.len() > 1 { row[sorted[1]] } else { 0.0 };
                self.trace.record_decision(
                    layer,
                    (p1 / (p1 + p2 + 1e-12)) as f64,
                    decision.single(),
                );
                for &(e, w) in &decision.experts {
                    coef[e][r] = w;
                    needed.insert(e);
                    actual_per_row[r].push(e);
                }
            }

            crate::obs::instant(
                crate::obs::Track::Decode,
                crate::obs::Name::GateDecision,
                layer as u64,
                needed.len() as u64,
            );

            // β tracking against the prediction made earlier for this layer.
            if let Some(pred) = self.predicted[layer].take() {
                self.trace.record_prefetch_outcome(layer, &pred, &actual_per_row);
            }

            // ---- build exec plan (issues on-demand transfers) ----
            let computes: Vec<usize> = {
                let mut v: Vec<usize> = needed.iter().copied().collect();
                v.sort_unstable();
                v
            };
            let extra: Vec<usize> = if self.ecfg.whole_layer {
                (0..n).filter(|e| !needed.contains(e)).collect()
            } else {
                Vec::new()
            };
            let plan = build_plan_tiered(
                layer,
                &computes,
                &extra,
                &self.cache,
                &self.xfer,
                self.ecfg.tier_mode,
            );
            self.trace.record_on_demand(layer, plan.on_demand_issued);
            self.trace.record_degraded_hits(plan.degraded);
            self.trace
                .record_phase(Phase::Decide, t_phase.elapsed().as_nanos() as u64);

            // ---- prefetch upcoming layers (comm overlaps what follows) ----
            if self.ecfg.prefetch.enabled {
                let t_phase = Instant::now();
                self.issue_prefetches(layer, &h, &stepping)?;
                self.trace
                    .record_phase(Phase::Predict, t_phase.elapsed().as_nanos() as u64);
            }

            // ---- execute MoE: completion-driven drain of the work queue ----
            let acc = if self.ecfg.compute_workers > 0 {
                // Host-math path: ready experts fan out across the pool
                // immediately; pending experts/tiles are dispatched in
                // arrival order (see executor.rs for the determinism story).
                // Ready compute overlaps the drain here, so there is no
                // separate ready phase: MoeReady covers only the host-side
                // input conversion and the whole drain lands in MoeWait.
                let t_phase = Instant::now();
                let xn_host = literal_to_tensor(&xn)?;
                self.trace
                    .record_phase(Phase::MoeReady, t_phase.elapsed().as_nanos() as u64);
                let t_phase = Instant::now();
                let outcome = executor::run_layer_parallel(
                    &plan,
                    &xn_host,
                    &coef,
                    self.ecfg.schedule,
                    self.ecfg.n_tiles,
                    &self.cache,
                    &self.xfer,
                    self.pool.as_ref().expect("pool exists when compute_workers > 0"),
                );
                self.trace.record_layer_stall(layer, outcome.stall_ns);
                self.trace.record_queue_delay(layer, outcome.queue_delay_ns);
                for (&lane, &ns) in &outcome.queue_delay_by_lane {
                    self.trace.record_lane_queue_delay(lane, ns);
                }
                for (&tier, &ns) in &outcome.queue_delay_by_tier {
                    self.trace.record_tier_queue_delay(tier, ns);
                }
                self.trace.record_faults(layer, outcome.recovered, &outcome.dropped);
                self.trace
                    .record_phase(Phase::MoeWait, t_phase.elapsed().as_nanos() as u64);
                outcome.acc
            } else {
                // Kernel path (PJRT handles are not Send, so kernels stay on
                // this thread): ready experts first — their compute overlaps
                // the in-flight transfers — then pending via the shared
                // arrival-order drain.
                let t_phase = Instant::now();
                let mut acc = Tensor::zeros(vec![b, self.cfg.d_model]);
                let ready: Vec<(usize, Arc<ExpertF32>)> = plan
                    .ready_items()
                    .map(|(e, w)| (e, Arc::clone(w)))
                    .collect();
                for (e, wts) in &ready {
                    let y = self.run_expert_cached(layer, *e, &xn, wts, &coef[*e])?;
                    acc.add_assign(&y);
                }
                self.trace
                    .record_phase(Phase::MoeReady, t_phase.elapsed().as_nanos() as u64);

                let t_phase = Instant::now();
                let pending: Vec<(usize, Arc<TransferHandle>)> = plan
                    .pending_items()
                    .map(|(e, h)| (e, Arc::clone(h)))
                    .collect();
                // Per-pending partial accumulators, reduced in plan order at
                // the end: consumption follows arrival order (which varies
                // run to run), but the float summation order — and thus the
                // output bits — must not.
                let mut parts: std::collections::HashMap<usize, Tensor> = pending
                    .iter()
                    .map(|(e, _)| (*e, Tensor::zeros(vec![b, self.cfg.d_model])))
                    .collect();
                let stats = executor::drain_arrival_order(
                    layer,
                    &pending,
                    self.ecfg.schedule,
                    self.ecfg.n_tiles,
                    &self.cache,
                    &self.xfer,
                    |arrived| {
                        let (expert, y) = match arrived {
                            executor::Arrived::Full { expert, weights } => {
                                (expert, self.run_expert_full(&xn, weights, &coef[expert])?)
                            }
                            executor::Arrived::Tile { expert, tile, .. } => {
                                (expert, self.run_expert_tile(&xn, tile, &coef[expert])?)
                            }
                        };
                        parts.get_mut(&expert).expect("pending expert").add_assign(&y);
                        Ok(())
                    },
                    || true, // no worker pool here: every idle wait is a stall
                )?;
                for (e, _) in &pending {
                    acc.add_assign(&parts[e]);
                }
                self.trace.record_queue_delay(layer, stats.queue_delay_ns);
                for (&lane, &ns) in &stats.queue_delay_by_lane {
                    self.trace.record_lane_queue_delay(lane, ns);
                }
                for (&tier, &ns) in &stats.queue_delay_by_tier {
                    self.trace.record_tier_queue_delay(tier, ns);
                }
                self.trace.record_faults(layer, stats.recovered, &stats.dropped);
                self.trace.record_layer_stall(layer, stats.stall_ns);
                self.trace
                    .record_phase(Phase::MoeWait, t_phase.elapsed().as_nanos() as u64);
                acc
            };

            let t_phase = Instant::now();
            h_host.add_assign(&acc);
            h = tensor_to_literal(&h_host)?;
            self.trace
                .record_phase(Phase::Residual, t_phase.elapsed().as_nanos() as u64);
        }

        // ---- pre-gate prefetch for the next token's first layer ----
        if self.ecfg.prefetch.enabled
            && self.ecfg.prefetch.use_pre_gate
            && self.xfer.pending() < self.ecfg.prefetch.max_outstanding
        {
            let outs = self.rt.run(
                &format!("pre_gate_{}", self.suffix),
                &[&h, &self.resident.out_norm, &self.resident.pre_gate],
            )?;
            let probs = literal_to_tensor(&outs[0])?;
            self.predict_and_request(0, &probs, &stepping)?;
        }

        // ---- background precision upgrades (idle lanes only) ----
        if self.ecfg.upgrade_budget > 0 {
            self.issue_upgrades();
        }

        // ---- unembed ----
        let t_phase = Instant::now();
        let outs = self.rt.run(
            &format!("unembed_{}", self.suffix),
            &[&h, &self.resident.out_norm, &self.resident.unembed],
        )?;
        let logits = literal_to_tensor(&outs[0])?;
        self.trace
            .record_phase(Phase::EmbedUnembed, t_phase.elapsed().as_nanos() as u64);

        // advance positions for stepped rows
        for &(row, _) in inputs {
            self.slots.pos[row] += 1;
        }

        self.trace
            .record_token(t0.elapsed().as_secs_f64(), inputs.len() as u64);
        crate::obs::span(
            crate::obs::Track::Decode,
            crate::obs::Name::DecodeStep,
            0,
            t0,
        );

        // Park the final layer's similarity snapshot for the next step.
        if let Some(prev) = prev_rows.take() {
            for (_, row) in prev {
                self.row_pool.put(row);
            }
        }

        // Single-slot decode (the common serving shape): the logits tensor
        // *is* the row — move it out instead of copying vocab floats.
        if b == 1 && inputs.len() == 1 {
            return Ok(vec![(inputs[0].0, logits.data)]);
        }
        Ok(inputs
            .iter()
            .map(|&(row, _)| (row, logits.row(row).to_vec()))
            .collect())
    }

    /// Predict expert needs for layers `layer+1 ..= layer+lookahead` and
    /// request prefetches. Horizon extends past depth 1 only while the
    /// shallower predicted layers are fully satisfied (paper §4.3).
    fn issue_prefetches(&mut self, layer: usize, h: &Literal, stepping: &[bool]) -> Result<()> {
        for depth in 1..=self.ecfg.prefetch.lookahead {
            let j = layer + depth;
            if j >= self.cfg.n_layers {
                break;
            }
            // Serial link: don't pile prefetches past what it can drain.
            if self.xfer.pending() >= self.ecfg.prefetch.max_outstanding {
                break;
            }
            let outs = self.rt.run(
                &format!("gate_{}", self.suffix),
                &[h, &self.resident.moe_norm[j], &self.resident.gate[j]],
            )?;
            let probs = literal_to_tensor(&outs[0])?;
            let satisfied = self.predict_and_request(j, &probs, stepping)?;
            if !satisfied {
                break; // don't extend the horizon past an unsatisfied layer
            }
        }
        Ok(())
    }

    /// Decide predicted sets for `layer` from router probs, issue prefetch
    /// requests, store the prediction for β tracking. Returns whether the
    /// layer was already fully satisfied (all predicted experts resident).
    fn predict_and_request(
        &mut self,
        layer: usize,
        probs: &Tensor,
        stepping: &[bool],
    ) -> Result<bool> {
        let b = self.ecfg.batch;
        // Borrowed rows: the prefetch planners only read, so there is no
        // reason to copy the router probabilities per row.
        let rows: Vec<&[f32]> = (0..b).map(|r| probs.row(r)).collect();
        let sets = prefetch::predict_sets(&self.ecfg.gating, layer, &rows, stepping);
        // Extension rule evaluated BEFORE issuing this layer's requests:
        // the horizon only moves past layers whose predictions were already
        // covered (resident / staged / in flight from earlier steps).
        let satisfied = prefetch::layer_satisfied(layer, &sets, &self.cache, &self.xfer);
        let reqs = prefetch::plan_requests_with_mass(
            layer,
            &sets,
            &rows,
            &self.cache,
            &self.xfer,
            self.ecfg.prefetch.max_outstanding_per_device,
        );
        // Sensitivity re-rank (consumer 3): important layers jump the
        // queue. Identity under the uniform map, so the request order —
        // and therefore every lane assignment — is unchanged there.
        let shaped = !self.sensitivity.is_uniform();
        let reqs = prefetch::prioritize(reqs, &self.sensitivity);
        for (id, p) in reqs {
            // Slack = 1 - predicted probability: a near-certain expert is
            // close to urgent (lower tier, lands sooner); a speculative
            // one can afford the high-precision bytes. A non-uniform map
            // floors the slack at the layer's importance so sensitive
            // layers never ride the lowest tier speculatively.
            let slack = self.sensitivity.prefetch_slack(id.0, p);
            self.xfer.request_with_slack(id, Priority::Prefetch, slack);
            if shaped {
                self.xfer.note_sensitivity_prefetch();
            }
        }
        self.predicted[layer] = Some(sets);
        Ok(satisfied)
    }

    /// Background upgrade pass: when the lanes are fully idle, re-request
    /// up to `upgrade_budget` resident below-top-tier experts at the
    /// highest tier. Upgrades ride the prefetch queues (and, under the
    /// pinned lane policy, never the reserved on-demand lane), so they
    /// can never delay an urgent load — and because this only fires with
    /// zero transfers in flight, they never contend with prefetches
    /// either.
    fn issue_upgrades(&mut self) {
        if self.tiered.n_tiers() < 2 {
            return;
        }
        // Idle gate (consumer 4). Uniform map: the historical "zero
        // transfers in flight" test, bit-for-bit. Non-uniform map: the
        // lane idle-time predictor — an EWMA of each lane's
        // inter-completion gaps — which also fires when the lanes are
        // drained *and* past their typical completion cadence, so
        // upgrades stop thrashing against a prefetch burst that is
        // about to land.
        let shaped = !self.sensitivity.is_uniform();
        if shaped {
            let snaps = self.xfer.lane_snapshots();
            self.idle.observe(&snaps);
            if self.xfer.pending() > 0 || !self.idle.predicted_idle(&snaps) {
                return;
            }
        } else if self.xfer.pending() > 0 {
            return;
        }
        let top = self.tiered.highest();
        let mut budget = self.ecfg.upgrade_budget;
        // Layer order is the map's upgrade ranking: identity (0..L) when
        // uniform, importance-descending otherwise — the most sensitive
        // layers reach the top tier first.
        for layer in self.sensitivity.upgrade_order(self.cfg.n_layers) {
            for e in self.cache.resident(layer) {
                let id = (layer, e);
                let Some(meta) = self.cache.resident_meta(id) else { continue };
                if self.tiered.above(meta.kind).is_none() {
                    continue; // already at (or above) the top tier
                }
                self.xfer.request_at(id, Priority::Upgrade, top);
                crate::obs::instant(
                    crate::obs::Track::Decode,
                    crate::obs::Name::Upgrade,
                    crate::obs::expert_corr(id),
                    top.tier_index() as u64,
                );
                if shaped {
                    self.xfer.note_sensitivity_upgrade();
                }
                budget -= 1;
                if budget == 0 {
                    return;
                }
            }
        }
    }

    fn run_expert_full(&self, xn: &Literal, wts: &ExpertF32, coef: &[f32]) -> Result<Tensor> {
        let w1 = tensor_to_literal(&wts.w1)?;
        let w3 = tensor_to_literal(&wts.w3)?;
        let w2 = tensor_to_literal(&wts.w2)?;
        let c = f32_literal(coef, &[coef.len()])?;
        let outs = self.rt.run(
            &format!("expert_ffn_{}", self.suffix),
            &[xn, &w1, &w3, &w2, &c],
        )?;
        literal_to_tensor(&outs[0])
    }

    /// Like run_expert_full, but memoizes the tensor→literal conversion of
    /// the expert weights keyed by the cache entry's Arc identity.
    fn run_expert_cached(
        &mut self,
        layer: usize,
        e: usize,
        xn: &Literal,
        wts: &std::sync::Arc<ExpertF32>,
        coef: &[f32],
    ) -> Result<Tensor> {
        let key = (layer, e);
        let ident = std::sync::Arc::as_ptr(wts) as usize;
        let fresh = match self.lit_cache.get(&key) {
            Some((id, _)) if *id == ident => false,
            _ => true,
        };
        if fresh {
            let lits = [
                tensor_to_literal(&wts.w1)?,
                tensor_to_literal(&wts.w3)?,
                tensor_to_literal(&wts.w2)?,
            ];
            self.lit_cache.insert(key, (ident, lits));
        }
        let (_, lits) = &self.lit_cache[&key];
        let c = f32_literal(coef, &[coef.len()])?;
        let outs = self.rt.run(
            &format!("expert_ffn_{}", self.suffix),
            &[xn, &lits[0], &lits[1], &lits[2], &c],
        )?;
        literal_to_tensor(&outs[0])
    }

    fn run_expert_tile(&self, xn: &Literal, tile: &ExpertF32, coef: &[f32]) -> Result<Tensor> {
        let w1 = tensor_to_literal(&tile.w1)?;
        let w3 = tensor_to_literal(&tile.w3)?;
        let w2 = tensor_to_literal(&tile.w2)?;
        let c = f32_literal(coef, &[coef.len()])?;
        let outs = self.rt.run(
            &format!("expert_ffn_tile_{}", self.suffix),
            &[xn, &w1, &w3, &w2, &c],
        )?;
        literal_to_tensor(&outs[0])
    }

    // -- conveniences ----------------------------------------------------------

    /// Feed a prompt through one slot and greedily generate `max_new` tokens.
    /// Returns the generated tokens (prompt excluded).
    pub fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<Vec<u32>> {
        let row = self
            .acquire_slot()
            .context("no free slot for generate()")?;
        let mut last_logits: Option<Vec<f32>> = None;
        for &t in prompt {
            let outs = self.decode_step(&[(row, t)])?;
            last_logits = Some(outs.into_iter().next().unwrap().1);
        }
        let mut out = Vec::with_capacity(max_new);
        let mut next = crate::model::sampling::greedy(
            last_logits.as_ref().context("empty prompt")?,
        );
        for _ in 0..max_new {
            out.push(next);
            if self.slot_full(row) {
                break;
            }
            let outs = self.decode_step(&[(row, next)])?;
            next = crate::model::sampling::greedy(&outs[0].1);
        }
        self.release_slot(row);
        Ok(out)
    }

    /// Re-run the DP planner on the *online* trace and apply the resulting
    /// allocation (the adaptive-caching feedback loop). With several
    /// devices, each shard re-plans within its own budget share — a
    /// global DP pushed through `set_allocation` could concentrate most
    /// of T on one shard under `layer` placement, silently exceeding
    /// that device's memory pool.
    pub fn replan_cache(&mut self) {
        let inputs = self.trace.plan_inputs(
            self.cfg.n_experts,
            self.ecfg.cache_budget,
            if self.ecfg.prefetch.enabled { 0.5 } else { 0.0 },
        );
        let devices = self.cache.n_devices();
        if devices == 1 {
            if self.tiered.n_tiers() > 1 {
                // Multi-tier: re-plan in byte currency. plan_bytes solves
                // the same knapsack (budget_bytes / per-expert = T), but
                // its byte ceilings are the planner's output rather than
                // a post-hoc conversion, and apply_tiered_counts installs
                // them without transiently shrinking the count caps.
                let per = self.tiered.base().expert_transfer_bytes((0, 0));
                if !self.sensitivity.is_uniform() {
                    // Tier-priced re-plan (consumer 2): price each
                    // layer's slots at its observed resident-tier byte
                    // mix, so a layer serving degraded copies gets
                    // cheaper slots and the DP shifts budget toward it.
                    let shard = self.cache.shard(0);
                    let bytes_per_expert: Vec<usize> = (0..self.cfg.n_layers)
                        .map(|l| {
                            let resident = shard.resident(l);
                            let total: usize = resident
                                .iter()
                                .filter_map(|&e| shard.resident_meta((l, e)))
                                .map(|m| m.bytes)
                                .sum();
                            if total == 0 {
                                per
                            } else {
                                (total / resident.len()).max(1)
                            }
                        })
                        .collect();
                    let bp = cache_plan::plan_bytes_tiered(&cache_plan::TierPlanInputs {
                        n_experts: inputs.n_experts,
                        budget_bytes: inputs.budget * per,
                        bytes_per_expert,
                        alpha: inputs.alpha.clone(),
                        beta: inputs.beta.clone(),
                    });
                    self.xfer.note_sensitivity_plan();
                    apply_tiered_bytes(self.cache.shard(0), &self.tiered, &bp);
                    return;
                }
                let bp = cache_plan::plan_bytes(&cache_plan::BytePlanInputs {
                    n_experts: inputs.n_experts,
                    budget_bytes: inputs.budget * per,
                    bytes_per_expert: per,
                    alpha: inputs.alpha.clone(),
                    beta: inputs.beta.clone(),
                });
                apply_tiered_counts(self.cache.shard(0), &self.tiered, &bp.allocation);
            } else {
                let plan = cache_plan::plan(&inputs);
                self.cache.set_allocation(&plan.allocation);
            }
            return;
        }
        let allocations = plan_shard_allocations(
            self.cfg.n_layers,
            self.ecfg.cache_budget,
            devices,
            self.ecfg.placement,
            self.cfg.n_experts,
            |budget: usize, layers: &[usize], n_exp: usize| {
                let sub = cache_plan::PlanInputs {
                    n_experts: n_exp,
                    budget,
                    alpha: layers.iter().map(|&l| inputs.alpha[l]).collect(),
                    beta: layers.iter().map(|&l| inputs.beta[l]).collect(),
                };
                cache_plan::plan(&sub).allocation
            },
        );
        for (d, alloc) in allocations.iter().enumerate() {
            if self.tiered.n_tiers() > 1 {
                apply_tiered_counts(self.cache.shard(d), &self.tiered, alloc);
            } else {
                self.cache.shard(d).set_allocation(alloc);
            }
        }
    }

    /// The shared sensitivity map all four resource consumers read.
    pub fn sensitivity_map(&self) -> &Arc<SensitivityMap> {
        &self.sensitivity
    }

    pub fn reset_trace(&mut self) {
        let sim = self.trace.similarity_enabled();
        self.trace = TraceCollector::new(self.cfg.n_layers).with_similarity(sim);
    }
}

/// Build the device-sharded expert cache for a config.
///
/// `devices == 1` reproduces the historical single-pool allocation
/// exactly: a uniform split or the §4.4 DP over the full budget T. With
/// more devices, T is partitioned across the devices that can actually
/// hold experts ([`ShardedCache::partition_budget`], remainder to the
/// earliest) — a device that owns no layers under `layer` placement
/// with more devices than layers gets 0, never a silently-dropped
/// share — and each device's portion is then split per layer: over the
/// device's own layer slice under `layer` placement, or over every
/// layer under `hash`/`load` (each layer's experts spread across all
/// shards, so a shard's per-layer cap is its ~1/D sub-population, not
/// the full expert count).
fn build_sharded_cache(
    cfg: &ModelConfig,
    ecfg: &EngineConfig,
    profile: &Profile,
) -> ShardedCache {
    // no adaptive gating -> no single-expert tokens
    let alpha: Vec<f64> = if matches!(ecfg.gating, GatingPolicy::TopK { .. }) {
        vec![0.0; cfg.n_layers]
    } else {
        profile.alpha.clone()
    };
    // β comes from the *offline* profiling phase even when online
    // prefetching is disabled: with β = 0, eq. 11–15 degenerate to a
    // linear knapsack that dumps the whole budget into a few layers and
    // leaves others at t = 0 — catastrophic under real LRU locality. The
    // profiled β keeps the curvature the paper's allocator relies on.
    let allocate = |budget: usize, layers: &[usize], n_experts: usize| -> Vec<usize> {
        match ecfg.alloc {
            AllocPolicy::Uniform => {
                DeviceCache::uniform_allocation(budget, layers.len(), n_experts)
            }
            AllocPolicy::Planned => {
                let inputs = cache_plan::PlanInputs {
                    n_experts,
                    budget,
                    alpha: layers.iter().map(|&l| alpha[l]).collect(),
                    beta: layers.iter().map(|&l| profile.beta[l]).collect(),
                };
                cache_plan::plan(&inputs).allocation
            }
        }
    };
    let devices = ecfg.devices.max(1);
    if devices == 1 {
        let all_layers: Vec<usize> = (0..cfg.n_layers).collect();
        let allocation = allocate(ecfg.cache_budget, &all_layers, cfg.n_experts);
        return ShardedCache::single(Arc::new(DeviceCache::new(allocation)));
    }
    let allocations = plan_shard_allocations(
        cfg.n_layers,
        ecfg.cache_budget,
        devices,
        ecfg.placement,
        cfg.n_experts,
        allocate,
    );
    ShardedCache::new(allocations, ecfg.placement)
}

/// Shared multi-device budget-split skeleton: partition T over the
/// devices that own at least one layer (a layerless device under
/// `layer` placement with D > L gets 0, never a silently-dropped
/// share), then run `allocate(budget, owned_layers, per_shard_experts)`
/// per device and scatter into full-length layer vectors. Used at
/// construction ([`build_sharded_cache`]) and by the online re-plan
/// ([`Engine::replan_cache`]), so both enforce the same per-device
/// budget shares.
fn plan_shard_allocations(
    n_layers: usize,
    budget: usize,
    devices: usize,
    placement: Placement,
    n_experts: usize,
    mut allocate: impl FnMut(usize, &[usize], usize) -> Vec<usize>,
) -> Vec<Vec<usize>> {
    let all_layers: Vec<usize> = (0..n_layers).collect();
    let owned_per_dev: Vec<Vec<usize>> = (0..devices)
        .map(|dev| match placement {
            Placement::LayerSliced => all_layers
                .iter()
                .copied()
                .filter(|&l| Placement::owner_of_layer(l, n_layers, devices) == dev)
                .collect(),
            _ => all_layers.clone(),
        })
        .collect();
    let active: Vec<usize> =
        (0..devices).filter(|&d| !owned_per_dev[d].is_empty()).collect();
    let shares = ShardedCache::partition_budget(budget, active.len().max(1));
    let mut budgets = vec![0usize; devices];
    for (k, &d) in active.iter().enumerate() {
        budgets[d] = shares[k];
    }
    // Experts of one layer that can actually land on one shard: all of
    // them when the shard owns the whole layer, ~1/D of them when the
    // layer spreads across every shard.
    let per_shard_experts = match placement {
        Placement::LayerSliced => n_experts,
        _ => n_experts.div_ceil(devices),
    };
    (0..devices)
        .map(|dev| {
            let owned = &owned_per_dev[dev];
            let mut full = vec![0usize; n_layers];
            if !owned.is_empty() {
                let local = allocate(budgets[dev], owned, per_shard_experts);
                for (k, &l) in owned.iter().enumerate() {
                    full[l] = local[k];
                }
            }
            full
        })
        .collect()
}

/// Install one shard's *planned* per-layer expert counts in byte
/// currency: each count becomes a byte ceiling at the resident (highest)
/// tier, and the count cap is raised to what those bytes could hold at
/// the *lowest* tier — so degrade-mode residents pack more experts into
/// the same device memory, while a cache full of top-tier copies
/// occupies exactly the planned footprint. Ceilings are installed
/// *before* the counts so a re-plan never transiently shrinks a layer
/// below its final cap (which would mass-evict perfectly-budgeted
/// degraded residents just to re-fetch them).
fn apply_tiered_counts(shard: &DeviceCache, tiered: &TieredStore, counts: &[usize]) {
    let hi = tiered.base().expert_transfer_bytes((0, 0));
    let lo = tiered
        .store(tiered.lowest())
        .expert_transfer_bytes((0, 0))
        .max(1);
    let n_experts = tiered.n_experts();
    let bytes: Vec<usize> = counts.iter().map(|&t| t * hi).collect();
    let raised: Vec<usize> = bytes.iter().map(|&b| (b / lo).min(n_experts)).collect();
    shard.set_byte_budget(Some(bytes));
    shard.set_allocation(&raised);
}

/// Install a tier-priced byte plan on one shard: the planner's own
/// per-layer byte ceilings (already priced at each layer's resident-tier
/// mix) go in directly, and each count cap is raised to what those bytes
/// could hold at the *lowest* tier — the same degrade-mode headroom rule
/// as [`apply_tiered_counts`], ceilings before counts for the same
/// no-transient-shrink reason.
fn apply_tiered_bytes(shard: &DeviceCache, tiered: &TieredStore, bp: &cache_plan::BytePlan) {
    let lo = tiered
        .store(tiered.lowest())
        .expert_transfer_bytes((0, 0))
        .max(1);
    let n_experts = tiered.n_experts();
    let raised: Vec<usize> =
        bp.byte_budgets.iter().map(|&b| (b / lo).min(n_experts)).collect();
    shard.set_byte_budget(Some(bp.byte_budgets.clone()));
    shard.set_allocation(&raised);
}

/// Byte-denominate a freshly built cache: run [`apply_tiered_counts`]
/// over every shard's just-planned allocation. Construction-time only —
/// the counts must be the plan's output, not an already-raised
/// allocation (re-plans go through [`apply_tiered_counts`] directly with
/// the fresh plan).
fn apply_byte_budgets(cache: &ShardedCache, tiered: &TieredStore) {
    for shard in cache.shards() {
        let counts = shard.allocation();
        apply_tiered_counts(shard, tiered, &counts);
    }
}

/// Artifact names needed for a config's batch bucket.
fn manifest_names(ecfg: &EngineConfig) -> Vec<String> {
    let b = ecfg.batch;
    let mut names: Vec<String> = [
        "embed", "attn_step", "gate", "expert_ffn", "expert_ffn_tile", "pre_gate", "unembed",
    ]
    .iter()
    .map(|n| format!("{n}_b{b}"))
    .collect();
    names.dedup();
    names
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::micro_config;

    fn ecfg(
        devices: usize,
        placement: Placement,
        alloc: AllocPolicy,
        budget: usize,
    ) -> EngineConfig {
        EngineConfig {
            batch: 1,
            gating: GatingPolicy::TopK { k: 2 },
            prefetch: PrefetchConfig::disabled(),
            alloc,
            cache_budget: budget,
            schedule: ScheduleMode::ExpertWise,
            quant: QuantKind::F32,
            tiers: Vec::new(),
            precision: PrecisionPolicy::Fixed,
            upgrade_budget: 0,
            tier_mode: TierMode::Degrade,
            platform: Platform::preset("instant").unwrap(),
            n_tiles: 4,
            time_scale: 0.0,
            lanes: LaneConfig::default(),
            devices,
            placement,
            whole_layer: false,
            compute_workers: 0,
            fault_plan: None,
            remote: None,
            sensitivity: SensitivityPolicy::Uniform,
        }
    }

    #[test]
    fn single_device_allocation_matches_historical() {
        let cfg = micro_config();
        let profile = Profile::synthetic(cfg.n_layers);
        let c = build_sharded_cache(
            &cfg,
            &ecfg(1, Placement::LayerSliced, AllocPolicy::Uniform, 10),
            &profile,
        );
        assert_eq!(c.n_devices(), 1);
        assert_eq!(
            c.allocation(),
            DeviceCache::uniform_allocation(10, cfg.n_layers, cfg.n_experts)
        );
    }

    #[test]
    fn layerless_devices_do_not_swallow_budget() {
        // 2-layer model over 4 devices under layer placement: devices 1
        // and 3 own no layers; the whole budget must land on devices 0/2
        // instead of being silently dropped with their shares.
        let cfg = micro_config();
        let profile = Profile::synthetic(cfg.n_layers);
        let c = build_sharded_cache(
            &cfg,
            &ecfg(4, Placement::LayerSliced, AllocPolicy::Uniform, 16),
            &profile,
        );
        assert_eq!(c.n_devices(), 4);
        assert_eq!(c.allocation().iter().sum::<usize>(), 16, "{:?}", c.allocation());
        assert_eq!(c.shard(1).allocation().iter().sum::<usize>(), 0);
        assert_eq!(c.shard(3).allocation().iter().sum::<usize>(), 0);
    }

    #[test]
    fn hash_placement_caps_layers_at_shard_subpopulation() {
        // 8 experts over 4 shards: at most ~2 experts of a layer can ever
        // land on one shard, so per-layer budgets must not exceed that.
        let cfg = micro_config();
        let profile = Profile::synthetic(cfg.n_layers);
        let c = build_sharded_cache(
            &cfg,
            &ecfg(4, Placement::ExpertHash, AllocPolicy::Uniform, 64),
            &profile,
        );
        for d in 0..4 {
            let a = c.shard(d).allocation();
            assert!(a.iter().all(|&t| t <= 2), "device {d}: {a:?}");
        }
        // clamped aggregate: 4 devices x 2 layers x 2 experts
        assert_eq!(c.allocation().iter().sum::<usize>(), 16);
    }

    #[test]
    fn byte_budgets_raise_counts_and_cap_bytes() {
        let cfg = micro_config();
        let w = crate::testutil::synthetic_weights(&cfg, 9);
        let tiered =
            TieredStore::build(&cfg, &w, &[QuantKind::Int2, QuantKind::Int8]).unwrap();
        let profile = Profile::synthetic(cfg.n_layers);
        let cache = build_sharded_cache(
            &cfg,
            &ecfg(1, Placement::LayerSliced, AllocPolicy::Uniform, 8),
            &profile,
        );
        assert_eq!(cache.allocation(), vec![4, 4]);
        apply_byte_budgets(&cache, &tiered);
        let hi = tiered.base().expert_transfer_bytes((0, 0));
        let lo = tiered.store(tiered.lowest()).expert_transfer_bytes((0, 0));
        let counts = cache.shard(0).allocation();
        let bytes = cache.shard(0).byte_budget().expect("byte ceilings set");
        for l in 0..cfg.n_layers {
            // the byte ceiling is the planned footprint at the top tier
            assert_eq!(bytes[l], 4 * hi);
            // counts are raised to the low-tier packing (clamped to N)
            assert_eq!(counts[l], (4 * hi / lo).min(cfg.n_experts));
            assert!(counts[l] >= 4, "raising must never shrink the plan");
        }
    }

    #[test]
    fn planned_allocation_partitions_budget_per_device() {
        let cfg = micro_config();
        let profile = Profile::synthetic(cfg.n_layers);
        let c = build_sharded_cache(
            &cfg,
            &ecfg(2, Placement::LayerSliced, AllocPolicy::Planned, 8),
            &profile,
        );
        // each device DP-plans its own layer slice within its share
        assert!(c.shard(0).allocation().iter().sum::<usize>() <= 4);
        assert!(c.shard(1).allocation().iter().sum::<usize>() <= 4);
        // layer placement: a shard only budgets its owned layers
        assert_eq!(c.shard(0).allocation()[1], 0);
        assert_eq!(c.shard(1).allocation()[0], 0);
    }
}
