//! Adaptive expert prefetching (paper §4.3, Fig. 5).
//!
//! The engine predicts upcoming layers' expert needs by applying layer j's
//! own norm+gate to the *current* activations (valid because successive
//! MoE-block inputs are nearly parallel — Observation 2), and layer 0's
//! needs for the next token via the trained predictive gate. This module
//! holds the pure planning logic: which predicted experts to actually
//! request, in what order, given cache/in-flight state and the gating
//! policy (adaptive gating shrinks the prediction set too — the paper's
//! "incorporating adaptive gating into predictions").

use std::collections::HashSet;

use crate::coordinator::gating::{GateDecision, GatingPolicy};
use crate::memory::device_cache::ExpertCache;
use crate::memory::transfer::TransferEngine;
use crate::model::ExpertId;

#[derive(Clone, Debug)]
pub struct PrefetchConfig {
    pub enabled: bool,
    /// How many layers ahead to predict (paper: next two/three layers).
    pub lookahead: usize,
    /// Use the trained predictive gate for layer 0 (next token).
    pub use_pre_gate: bool,
    /// Max in-flight transfers before the engine stops issuing prefetches.
    /// The link is serial: without a cap, deep lookahead floods the comm
    /// queue faster than the (calibrated, slow) link drains it and the
    /// backlog grows without bound.
    pub max_outstanding: usize,
    /// Per-device in-flight cap on top of the global `max_outstanding`
    /// (`None` = no per-device limit). With sharded backends a hot shard
    /// can otherwise monopolise the global window and starve the other
    /// devices' prefetch budgets (docs/sharded-backends.md follow-on).
    pub max_outstanding_per_device: Option<usize>,
}

impl PrefetchConfig {
    pub fn disabled() -> PrefetchConfig {
        PrefetchConfig {
            enabled: false,
            lookahead: 0,
            use_pre_gate: false,
            max_outstanding: 0,
            max_outstanding_per_device: None,
        }
    }

    pub fn standard() -> PrefetchConfig {
        PrefetchConfig {
            enabled: true,
            lookahead: 3,
            use_pre_gate: true,
            max_outstanding: 4,
            max_outstanding_per_device: None,
        }
    }

    /// Pre-gated MoE baseline: strictly next-layer prediction, no layer-0
    /// predictive gate (it on-demand loads the first layer).
    pub fn next_layer_only() -> PrefetchConfig {
        PrefetchConfig {
            enabled: true,
            lookahead: 1,
            use_pre_gate: false,
            max_outstanding: 4,
            max_outstanding_per_device: None,
        }
    }
}

/// Turn per-row router probabilities for a future layer into per-row
/// predicted expert sets under the gating policy.
pub fn predict_sets<R: AsRef<[f32]>>(
    policy: &GatingPolicy,
    layer: usize,
    probs_rows: &[R],
    active: &[bool],
) -> Vec<HashSet<usize>> {
    probs_rows
        .iter()
        .enumerate()
        .map(|(r, probs)| {
            if !active[r] {
                return HashSet::new();
            }
            let d: GateDecision = policy.decide(layer, probs.as_ref());
            d.experts.iter().map(|&(e, _)| e).collect()
        })
        .collect()
}

/// Experts to request for a predicted layer: union over rows, minus those
/// already resident or in flight. Order: by total predicted probability
/// mass (most-likely first) so partial budget goes to the likeliest.
pub fn plan_requests<R: AsRef<[f32]>>(
    layer: usize,
    predicted: &[HashSet<usize>],
    probs_rows: &[R],
    cache: &dyn ExpertCache,
    xfer: &TransferEngine,
) -> Vec<ExpertId> {
    plan_requests_with_mass(layer, predicted, probs_rows, cache, xfer, None)
        .into_iter()
        .map(|(id, _)| id)
        .collect()
}

/// [`plan_requests`] extended for tiered/sharded engines: each request
/// carries its normalized predicted probability (mass / rows, ∈ [0, 1])
/// so the caller can derive a precision-slack signal, and an optional
/// `per_device_cap` bounds how many transfers may be outstanding per
/// device shard (counting those already in flight). Experts whose
/// `LoadAware` device is not yet bound are never capped — capping them
/// would require binding, which speculative planning must not do.
pub fn plan_requests_with_mass<R: AsRef<[f32]>>(
    layer: usize,
    predicted: &[HashSet<usize>],
    probs_rows: &[R],
    cache: &dyn ExpertCache,
    xfer: &TransferEngine,
    per_device_cap: Option<usize>,
) -> Vec<(ExpertId, f64)> {
    let rows = probs_rows.len().max(1) as f64;
    let mut mass: Vec<(usize, f64)> = Vec::new();
    let mut union: HashSet<usize> = HashSet::new();
    for set in predicted {
        union.extend(set.iter().copied());
    }
    for &e in &union {
        let m: f64 = probs_rows
            .iter()
            .map(|p| p.as_ref().get(e).copied().unwrap_or(0.0) as f64)
            .sum();
        mass.push((e, m));
    }
    mass.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let shards = xfer.sharded_cache();
    let mut device_budget: Option<Vec<usize>> = per_device_cap.map(|cap| {
        (0..shards.n_devices())
            .map(|d| cap.saturating_sub(xfer.pending_for_device(d)))
            .collect()
    });
    mass.into_iter()
        .map(|(e, m)| ((layer, e), (m / rows).clamp(0.0, 1.0)))
        .filter(|&(id, _)| {
            if cache.contains(id)
                || xfer.in_flight(id).is_some()
                || xfer.staging_contains(id)
            {
                return false;
            }
            let Some(budget) = &mut device_budget else { return true };
            match shards.device_of_peek(id) {
                // unbound LoadAware expert: uncapped (see doc above)
                None => true,
                Some(d) => {
                    if budget[d] == 0 {
                        false
                    } else {
                        budget[d] -= 1;
                        true
                    }
                }
            }
        })
        .collect()
}

/// Re-rank a prefetch plan by sensitivity: each request's priority key is
/// `importance(layer) × predicted probability`, sorted descending with a
/// stable sort so equal keys keep their mass order. Under a uniform
/// [`SensitivityMap`] every key equals the probability the list is
/// already sorted by, so the plan comes back bit-for-bit unchanged —
/// the determinism guarantee of docs/sensitivity.md.
pub fn prioritize(
    reqs: Vec<(ExpertId, f64)>,
    map: &crate::coordinator::sensitivity::SensitivityMap,
) -> Vec<(ExpertId, f64)> {
    if map.is_uniform() {
        return reqs;
    }
    let mut keyed: Vec<((ExpertId, f64), f64)> = reqs
        .into_iter()
        .map(|(id, p)| ((id, p), map.importance(id.0) * p))
        .collect();
    keyed.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
    keyed.into_iter().map(|(r, _)| r).collect()
}

/// True when every predicted expert for `layer` is resident or staged —
/// the paper's condition for extending the prefetch horizon to the layer
/// after ("if the experts needed by the next layer are already cached,
/// preemptively fetch for subsequent layers"). In-flight transfers do NOT
/// count: extending past a still-loading layer floods the serial link.
pub fn layer_satisfied(
    layer: usize,
    predicted: &[HashSet<usize>],
    cache: &dyn ExpertCache,
    xfer: &TransferEngine,
) -> bool {
    predicted.iter().flat_map(|s| s.iter()).all(|&e| {
        let id = (layer, e);
        cache.contains(id) || xfer.staging_contains(id)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::memory::device_cache::DeviceCache;
    use crate::memory::host_store::HostStore;
    use crate::memory::platform::Platform;
    use crate::memory::quant::QuantKind;
    use crate::testutil::{micro_config, synthetic_weights};

    fn fixture() -> (Arc<DeviceCache>, TransferEngine) {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 11);
        let store = Arc::new(HostStore::build(&cfg, &w, QuantKind::F32).unwrap());
        let cache = Arc::new(DeviceCache::new(vec![4; cfg.n_layers]));
        let xfer = TransferEngine::new(
            Arc::clone(&store),
            Arc::clone(&cache),
            Platform::preset("instant").unwrap(),
            4,
            0.0,
        );
        (cache, xfer)
    }

    #[test]
    fn predict_sets_respects_active_mask() {
        let pol = GatingPolicy::TopK { k: 2 };
        let probs = vec![vec![0.5, 0.3, 0.2], vec![0.1, 0.1, 0.8]];
        let sets = predict_sets(&pol, 0, &probs, &[true, false]);
        assert_eq!(sets[0], HashSet::from([0, 1]));
        assert!(sets[1].is_empty());
    }

    #[test]
    fn plan_orders_by_mass_and_filters() {
        let (cache, xfer) = fixture();
        let probs = vec![vec![0.05, 0.6, 0.35], vec![0.05, 0.55, 0.40]];
        let predicted = vec![HashSet::from([1, 2]), HashSet::from([1, 2])];
        let reqs = plan_requests(1, &predicted, &probs, &cache, &xfer);
        assert_eq!(reqs, vec![(1, 1), (1, 2)]); // expert 1 has more mass

        // cached experts are filtered out
        cache.insert((1, 1), Arc::new(crate::memory::host_store::ExpertF32 {
            w1: crate::tensor::Tensor::zeros(vec![1, 1]),
            w3: crate::tensor::Tensor::zeros(vec![1, 1]),
            w2: crate::tensor::Tensor::zeros(vec![1, 1]),
        }));
        let reqs = plan_requests(1, &predicted, &probs, &cache, &xfer);
        assert_eq!(reqs, vec![(1, 2)]);
    }

    #[test]
    fn in_flight_not_requested_twice() {
        let (cache, xfer) = fixture();
        let h = xfer.request((0, 3), crate::memory::transfer::Priority::Prefetch);
        let probs = vec![vec![0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0]];
        let predicted = vec![HashSet::from([3])];
        // depending on timing the transfer may already have completed; both
        // outcomes (filtered by in-flight or by cache) yield an empty plan.
        h.wait_full();
        let reqs = plan_requests(0, &predicted, &probs, &cache, &xfer);
        assert!(reqs.is_empty());
    }

    #[test]
    fn per_device_cap_bounds_requests_and_counts_in_flight() {
        use crate::memory::sharded_cache::{Placement, ShardedCache};
        use crate::memory::transfer::LaneConfig;

        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 12);
        let store = Arc::new(HostStore::build(&cfg, &w, QuantKind::Int4).unwrap());
        // 2 devices, layer-sliced over the 2-layer micro config: layer 0
        // lives on device 0, layer 1 on device 1.
        let cache = Arc::new(ShardedCache::new(
            vec![vec![4, 4]; 2],
            Placement::LayerSliced,
        ));
        // slow calibrated link so issued prefetches stay in flight
        let xfer = crate::memory::transfer::TransferEngine::with_devices(
            Arc::clone(&store),
            Arc::clone(&cache),
            Platform::preset("rtx4090").unwrap(),
            4,
            1.0,
            LaneConfig::default(),
        );
        let probs: Vec<Vec<f32>> =
            vec![(0..8).map(|e| 1.0 / (e as f32 + 1.5)).collect()];
        let predicted = vec![HashSet::from([0usize, 1, 2, 3])];
        // cap 2 per device: layer-0 predictions all land on device 0
        let capped = plan_requests_with_mass(0, &predicted, &probs, &cache, &xfer, Some(2));
        assert_eq!(capped.len(), 2, "cap must bound the plan: {capped:?}");
        // most-likely-first survives the cap
        assert_eq!(capped[0].0, (0, 0));
        assert_eq!(capped[1].0, (0, 1));
        // normalized mass rides along, within [0, 1]
        assert!(capped.iter().all(|&(_, p)| (0.0..=1.0).contains(&p)));
        assert!(capped[0].1 >= capped[1].1);
        // in-flight transfers consume the window: issue 2 on device 1 ...
        for e in 0..2 {
            xfer.request((1, e), crate::memory::transfer::Priority::Prefetch);
        }
        assert_eq!(xfer.pending_for_device(1), 2);
        // ... so a capped plan for layer 1 has no budget left
        let predicted1 = vec![HashSet::from([4usize, 5])];
        let none = plan_requests_with_mass(1, &predicted1, &probs, &cache, &xfer, Some(2));
        assert!(none.is_empty(), "{none:?}");
        // the other device's budget is untouched
        let still = plan_requests_with_mass(0, &predicted, &probs, &cache, &xfer, Some(2));
        assert_eq!(still.len(), 2);
        xfer.quiesce().unwrap();
        // uncapped path unchanged
        let all = plan_requests(0, &predicted, &probs, &cache, &xfer);
        assert_eq!(all.len(), 4);
    }

    #[test]
    fn prioritize_is_identity_for_uniform_and_reorders_by_importance() {
        use crate::coordinator::profile::Profile;
        use crate::coordinator::sensitivity::{SensitivityMap, SensitivityPolicy};
        let reqs = vec![((0usize, 1usize), 0.9), ((1, 2), 0.8), ((2, 3), 0.7)];
        let uni = SensitivityMap::uniform(3);
        assert_eq!(prioritize(reqs.clone(), &uni), reqs);
        let mut prof = Profile::synthetic(3);
        prof.sensitivity = vec![0.1, 0.2, 1.0];
        let m = SensitivityMap::from_profile(&prof, SensitivityPolicy::Profile);
        // keys: 0.09, 0.16, 0.70 — importance dominates raw mass order
        let out = prioritize(reqs, &m);
        assert_eq!(
            out.iter().map(|&(id, _)| id).collect::<Vec<_>>(),
            vec![(2, 3), (1, 2), (0, 1)]
        );
    }

    #[test]
    fn satisfied_detects_full_coverage() {
        let (cache, xfer) = fixture();
        let predicted = vec![HashSet::from([0]), HashSet::from([1])];
        assert!(!layer_satisfied(0, &predicted, &cache, &xfer));
        for e in 0..2 {
            cache.insert((0, e), Arc::new(crate::memory::host_store::ExpertF32 {
                w1: crate::tensor::Tensor::zeros(vec![1, 1]),
                w3: crate::tensor::Tensor::zeros(vec![1, 1]),
                w2: crate::tensor::Tensor::zeros(vec![1, 1]),
            }));
        }
        assert!(layer_satisfied(0, &predicted, &cache, &xfer));
    }
}
