//! Compute/comm overlap scheduling (paper §5, Algorithm 1 + Fig. 6).
//!
//! For one MoE block, [`build_plan`] emits a unified work queue covering
//! everything the layer must touch: `Ready` experts (resident — compute
//! immediately, overlapping the transfers of the rest), `Pending` experts
//! (enqueued as on-demand loads, consumed **in arrival order** by the
//! completion-driven executor) and `ExtraLoad` entries (whole-layer
//! baseline loads that are transferred but never computed). The executor
//! ([`crate::coordinator::executor`] and the engine's kernel path) drains
//! the queue either **expert-wise** (whole expert per kernel call) or
//! **tile-wise** (kernel call per arrived f-tile — Fig. 6(b)).
//!
//! Each on-demand load issued here is assigned to one of the transfer
//! engine's parallel comm lanes by the configured
//! [`crate::memory::transfer::LanePolicy`] (round-robin /
//! least-queued-bytes / pinned); the chosen lane rides on the returned
//! [`TransferHandle`] and queue order is unaffected — the plan's
//! canonical reduction order is what keeps output bits independent of
//! which lane lands first (see docs/transfer-lanes.md).
//!
//! The cache argument is the [`ExpertCache`] surface: against a
//! [`crate::memory::sharded_cache::ShardedCache`], every lookup, staging
//! promotion and on-demand request routes to the *owning device shard*
//! (and, through the transfer engine's lane affinity, rides a lane of
//! that device's group) without any change to the plan's structure —
//! see docs/sharded-backends.md.

use std::sync::Arc;

use crate::memory::device_cache::ExpertCache;
use crate::memory::host_store::ExpertF32;
use crate::memory::transfer::{Priority, TransferEngine, TransferHandle};
use crate::model::ExpertId;

/// How a tiered plan treats a resident copy whose source tier is below
/// the engine's preferred tier (docs/tiered-precision.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TierMode {
    /// Serve the resident low-tier copy instead of stalling on a
    /// higher-tier fetch (degrade-instead-of-miss). The background
    /// upgrade path restores precision when the lanes go idle.
    Degrade,
    /// Treat a below-preferred resident as a miss: issue an on-demand
    /// load at the preferred tier and wait for it.
    Strict,
}

/// How the engine consumes on-demand experts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleMode {
    /// Wait for the full expert, then one kernel call (Fig. 6(a)).
    ExpertWise,
    /// Kernel call per arrived tile, overlapping compute with the rest of
    /// the transfer (Fig. 6(b)).
    TileWise,
}

/// One unit of MoE-layer work.
pub enum WorkItem {
    /// Resident (or staged-prefetch) expert: compute whenever a worker is
    /// free — no transfer to wait for.
    Ready { expert: usize, weights: Arc<ExpertF32> },
    /// Expert in flight on the comm stream: compute on arrival. Per-item
    /// arrival instants live on the [`TransferHandle`] (queue-delay
    /// attribution).
    Pending { expert: usize, handle: Arc<TransferHandle> },
    /// Whole-layer-baseline load: transferred (lands in the cache via the
    /// comm thread) but not computed, and never waited on.
    ExtraLoad { expert: usize, handle: Arc<TransferHandle> },
}

/// Execution plan for one layer's MoE block: a queue the executor drains.
/// Order is ready-first (Algorithm 1 line 11), then pending in expert
/// order, then extra loads — but the completion-driven executor is free to
/// consume pending items in arrival order instead.
pub struct ExecPlan {
    pub layer: usize,
    pub queue: Vec<WorkItem>,
    /// On-demand loads issued by this plan (for trace accounting).
    pub on_demand_issued: u64,
    /// Hits served from a resident copy below the preferred tier
    /// (degrade-instead-of-miss accepted a lower-precision answer to
    /// avoid a stall). Always 0 for single-tier engines.
    pub degraded: u64,
}

impl ExecPlan {
    /// Ready experts, in queue order.
    pub fn ready_items(&self) -> impl Iterator<Item = (usize, &Arc<ExpertF32>)> + '_ {
        self.queue.iter().filter_map(|w| match w {
            WorkItem::Ready { expert, weights } => Some((*expert, weights)),
            _ => None,
        })
    }

    /// Pending (compute-on-arrival) experts, in queue order.
    pub fn pending_items(&self) -> impl Iterator<Item = (usize, &Arc<TransferHandle>)> + '_ {
        self.queue.iter().filter_map(|w| match w {
            WorkItem::Pending { expert, handle } => Some((*expert, handle)),
            _ => None,
        })
    }

    pub fn n_ready(&self) -> usize {
        self.ready_items().count()
    }

    pub fn n_pending(&self) -> usize {
        self.pending_items().count()
    }

    /// Items that produce FFN output (ready + pending).
    pub fn n_compute(&self) -> usize {
        self.n_ready() + self.n_pending()
    }
}

/// Build the plan: look up each compute target in the cache; request
/// on-demand transfers for misses (joining in-flight transfers); request
/// (but do not compute) `extra_loads` — the whole-layer baseline's
/// load-everything behaviour. Any resident copy counts as a hit
/// ([`TierMode::Degrade`]); single-tier engines are unaffected because
/// every resident copy is already at the preferred tier.
pub fn build_plan(
    layer: usize,
    computes: &[usize],
    extra_loads: &[usize],
    cache: &dyn ExpertCache,
    xfer: &TransferEngine,
) -> ExecPlan {
    build_plan_tiered(layer, computes, extra_loads, cache, xfer, TierMode::Degrade)
}

/// [`build_plan`] with an explicit degrade-vs-stall mode for resident
/// copies below the engine's preferred tier. Under [`TierMode::Degrade`]
/// such a hit is served immediately (counted in [`ExecPlan::degraded`]);
/// under [`TierMode::Strict`] it is treated as a miss and re-fetched
/// on-demand at the preferred tier.
pub fn build_plan_tiered(
    layer: usize,
    computes: &[usize],
    extra_loads: &[usize],
    cache: &dyn ExpertCache,
    xfer: &TransferEngine,
    mode: TierMode,
) -> ExecPlan {
    let mut ready = Vec::new();
    // Pending items are recorded first and materialized after the miss
    // batch goes out: joins carry their handle immediately, misses carry
    // an index into the batched request (`None` queue slots below).
    enum Pend {
        Join(Arc<TransferHandle>),
        Miss(usize),
    }
    let mut pending_spec: Vec<(usize, Pend)> = Vec::new();
    let mut misses: Vec<ExpertId> = Vec::new();
    let mut extra = Vec::new();
    let mut issued = 0;
    let mut degraded = 0;
    let preferred = xfer.preferred_tier();
    // Single-tier engines can never hold a below-preferred resident, so
    // the per-expert meta peek (an extra cache-mutex acquisition on the
    // hot path) is skipped entirely.
    let multi_tier = xfer.tiered_store().n_tiers() > 1;

    for &e in computes {
        let id: ExpertId = (layer, e);
        // A resident copy below the preferred tier is a *degraded* hit:
        // served under Degrade (never stalls the executor), re-fetched
        // under Strict. Entries without tier metadata (or at/above the
        // preferred tier) are plain hits. Strict refuses the degraded
        // copy *without touching it* — a get() here would count a cache
        // hit and promote to MRU the very entry the re-fetch is about to
        // replace.
        let below = multi_tier
            && cache
                .resident_meta(id)
                .is_some_and(|m| m.kind.bits() < preferred.bits());
        if !(below && mode == TierMode::Strict) {
            if let Some(w) = cache.get(id) {
                if below {
                    degraded += 1;
                }
                ready.push(WorkItem::Ready { expert: e, weights: w });
                continue;
            }
        }
        if let Some((w, meta)) = (!cache.contains(id)).then(|| xfer.staging.take(id)).flatten()
        {
            // prefetched earlier, parked in the staging buffers (the cache
            // may have had no room for this layer) — consume it now and give
            // the cache another chance to keep it.
            cache.insert_tiered(id, Arc::clone(&w), meta);
            ready.push(WorkItem::Ready { expert: e, weights: w });
        } else if let Some(h) = xfer.in_flight(id) {
            // already being loaded (e.g. by a prefetch): join it
            pending_spec.push((e, Pend::Join(h)));
        } else {
            // Fresh miss: collected now, submitted as one coalesced batch
            // after the loop so the whole plan's misses ride a single
            // multi-expert wire job per device (docs/hot-path.md). A
            // repeated expert maps onto the first occurrence's slot.
            let slot = match misses.iter().position(|&m| m == id) {
                Some(i) => i,
                None => {
                    misses.push(id);
                    issued += 1;
                    misses.len() - 1
                }
            };
            pending_spec.push((e, Pend::Miss(slot)));
        }
    }
    // Strict misses insist on the preferred tier (that is the point of
    // refusing the degraded copy); Degrade misses defer to the engine's
    // precision policy — whose on-demand pick is expert-independent, so
    // one tier covers the whole batch either way.
    let miss_kind = match mode {
        TierMode::Strict => preferred,
        TierMode::Degrade => xfer.on_demand_tier(),
    };
    let miss_handles = if misses.is_empty() {
        Vec::new()
    } else {
        xfer.request_group_at(&misses, Priority::OnDemand, miss_kind)
    };
    let mut pending: Vec<WorkItem> = pending_spec
        .into_iter()
        .map(|(e, p)| WorkItem::Pending {
            expert: e,
            handle: match p {
                Pend::Join(h) => h,
                Pend::Miss(i) => Arc::clone(&miss_handles[i]),
            },
        })
        .collect();
    // Extras batch the same way (the miss tickets above are already
    // registered, so an extra that duplicates a miss joins it via the
    // in-flight check, exactly as it did when requests were serial).
    let mut extra_ids: Vec<ExpertId> = Vec::new();
    let mut extra_experts: Vec<usize> = Vec::new();
    for &e in extra_loads {
        let id: ExpertId = (layer, e);
        if !cache.contains(id) && xfer.in_flight(id).is_none() && !extra_ids.contains(&id) {
            extra_ids.push(id);
            extra_experts.push(e);
            issued += 1;
        }
    }
    if !extra_ids.is_empty() {
        let handles =
            xfer.request_group_at(&extra_ids, Priority::OnDemand, xfer.on_demand_tier());
        for (e, handle) in extra_experts.into_iter().zip(handles) {
            extra.push(WorkItem::ExtraLoad { expert: e, handle });
        }
    }
    let mut queue = ready;
    queue.append(&mut pending);
    queue.append(&mut extra);
    ExecPlan { layer, queue, on_demand_issued: issued, degraded }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::device_cache::DeviceCache;
    use crate::memory::host_store::HostStore;
    use crate::memory::platform::Platform;
    use crate::memory::quant::QuantKind;
    use crate::testutil::{micro_config, synthetic_weights};
    use crate::util::prop;

    fn fixture(alloc: Vec<usize>, platform: &str) -> (Arc<HostStore>, Arc<DeviceCache>, TransferEngine) {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 21);
        let store = Arc::new(HostStore::build(&cfg, &w, QuantKind::F32).unwrap());
        let cache = Arc::new(DeviceCache::new(alloc));
        let xfer = TransferEngine::new(
            Arc::clone(&store),
            Arc::clone(&cache),
            Platform::preset(platform).unwrap(),
            4,
            1.0,
        );
        (store, cache, xfer)
    }

    #[test]
    fn cached_experts_are_ready() {
        let (store, cache, xfer) = fixture(vec![8, 8], "instant");
        cache.insert((0, 2), Arc::new(store.dequantize((0, 2))));
        let plan = build_plan(0, &[2, 5], &[], &cache, &xfer);
        assert_eq!(plan.n_ready(), 1);
        assert_eq!(plan.ready_items().next().unwrap().0, 2);
        assert_eq!(plan.n_pending(), 1);
        let (e, h) = plan.pending_items().next().unwrap();
        assert_eq!(e, 5);
        assert_eq!(plan.on_demand_issued, 1);
        h.wait_full();
    }

    #[test]
    fn queue_orders_ready_before_pending_before_extras() {
        let (store, cache, xfer) = fixture(vec![8, 8], "instant");
        cache.insert((0, 3), Arc::new(store.dequantize((0, 3))));
        let plan = build_plan(0, &[1, 3], &[6], &cache, &xfer);
        let kinds: Vec<&str> = plan
            .queue
            .iter()
            .map(|w| match w {
                WorkItem::Ready { .. } => "ready",
                WorkItem::Pending { .. } => "pending",
                WorkItem::ExtraLoad { .. } => "extra",
            })
            .collect();
        assert_eq!(kinds, vec!["ready", "pending", "extra"]);
        assert_eq!(plan.n_compute(), 2);
        xfer.quiesce().unwrap();
    }

    #[test]
    fn joins_in_flight_without_reissuing() {
        // slow (calibrated) link so the prefetch is still in flight
        let (_store, cache, xfer) = fixture(vec![8, 8], "rtx4090");
        let _pf = xfer.request((0, 3), Priority::Prefetch);
        let plan = build_plan(0, &[3], &[], &cache, &xfer);
        // Either the prefetch already completed (instant platform) and it is
        // a cache hit, or the plan joined the in-flight transfer; in neither
        // case may a *new* on-demand transfer be issued.
        assert_eq!(plan.on_demand_issued, 0);
        for (_, h) in plan.pending_items() {
            h.wait_full();
        }
    }

    #[test]
    fn staged_prefetch_is_consumed_as_ready_and_cached() {
        let (_store, cache, xfer) = fixture(vec![8, 8], "instant");
        xfer.request((0, 6), crate::memory::transfer::Priority::Prefetch)
            .wait_full();
        xfer.quiesce().unwrap();
        assert!(xfer.staging_contains((0, 6)));
        assert!(!cache.contains((0, 6)));
        let plan = build_plan(0, &[6], &[], &cache, &xfer);
        assert_eq!(plan.n_ready(), 1, "staged expert should be ready");
        assert_eq!(plan.on_demand_issued, 0);
        assert!(cache.contains((0, 6)), "use promotes staged expert to cache");
        assert!(!xfer.staging_contains((0, 6)));
    }

    #[test]
    fn staged_promotion_at_capacity_evicts_lru() {
        // Layer 0 holds a single expert. A staged prefetch consumed by
        // build_plan must still promote into the cache — evicting the
        // resident LRU entry — so "use promotes staged" holds under
        // contention, not just with free slots.
        let (store, cache, xfer) = fixture(vec![1, 8], "instant");
        cache.insert((0, 0), Arc::new(store.dequantize((0, 0))));
        xfer.request((0, 5), Priority::Prefetch).wait_full();
        xfer.quiesce().unwrap();
        assert!(xfer.staging_contains((0, 5)));
        let (_, _, ev_before) = cache.stats();
        let plan = build_plan(0, &[5], &[], &cache, &xfer);
        assert_eq!(plan.n_ready(), 1, "staged expert must come back ready");
        assert_eq!(plan.on_demand_issued, 0);
        assert!(cache.contains((0, 5)), "promotion must land despite full layer");
        assert!(!cache.contains((0, 0)), "LRU resident must be evicted");
        let (_, _, ev_after) = cache.stats();
        assert_eq!(ev_after, ev_before + 1, "promotion at capacity is an eviction");
        assert!(!xfer.staging_contains((0, 5)), "staging entry is single-use");
    }

    #[test]
    fn prop_staged_promotion_respects_capacity() {
        // Random layer budgets and staged-prefetch mixes: consuming staged
        // experts never overflows a layer, never issues on-demand loads for
        // staged experts, and always leaves the computed experts resident
        // (capacity permitting the newest insert).
        prop::check("staged-promotion-capacity", 16, |rng| {
            let cap = rng.usize_below(3); // 0..=2 slots in layer 0
            let cfg = micro_config();
            let w = synthetic_weights(&cfg, 21);
            let store = Arc::new(HostStore::build(&cfg, &w, QuantKind::F32).unwrap());
            let cache = Arc::new(DeviceCache::new(vec![cap, 8]));
            let xfer = TransferEngine::new(
                Arc::clone(&store),
                Arc::clone(&cache),
                Platform::preset("instant").unwrap(),
                4,
                0.0,
            );
            let n_staged = 1 + rng.usize_below(4);
            let staged: Vec<usize> = (0..n_staged).collect();
            for &e in &staged {
                xfer.request((0, e), Priority::Prefetch).wait_full();
            }
            xfer.quiesce().unwrap();
            let plan = build_plan(0, &staged, &[], &cache, &xfer);
            crate::prop_assert!(
                plan.on_demand_issued == 0,
                "staged experts must not re-issue loads (cap={cap}, staged={n_staged})"
            );
            crate::prop_assert!(plan.n_ready() == n_staged, "all staged come back ready");
            let resident = cache.resident(0);
            crate::prop_assert!(
                resident.len() <= cap,
                "layer overflow: {resident:?} > cap {cap}"
            );
            if cap > 0 {
                let last = *staged.last().unwrap();
                crate::prop_assert!(
                    cache.contains((0, last)),
                    "most recent promotion must be resident (cap={cap})"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn degrade_vs_strict_on_below_preferred_residents() {
        use crate::memory::sharded_cache::ShardedCache;
        use crate::memory::tiered_store::{PrecisionPolicy, TieredStore};
        use crate::memory::transfer::LaneConfig;

        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 23);
        let tiers = Arc::new(
            TieredStore::build(&cfg, &w, &[QuantKind::Int2, QuantKind::Int8]).unwrap(),
        );
        let cache = Arc::new(DeviceCache::new(vec![8, 8]));
        let xfer = TransferEngine::with_tiers(
            Arc::clone(&tiers),
            PrecisionPolicy::Urgency,
            Arc::new(ShardedCache::single(Arc::clone(&cache))),
            Platform::preset("instant").unwrap(),
            4,
            0.0,
            LaneConfig::default(),
        );
        // land an int2 (below-preferred) copy of expert (0, 2)
        xfer.request((0, 2), Priority::OnDemand).wait_full();
        xfer.quiesce().unwrap();
        assert_eq!(cache.resident_meta((0, 2)).unwrap().kind, QuantKind::Int2);

        // Degrade: the low-tier resident is served ready — no stall, no load
        let plan = build_plan_tiered(0, &[2], &[], &cache, &xfer, TierMode::Degrade);
        assert_eq!(plan.n_ready(), 1);
        assert_eq!(plan.n_pending(), 0);
        assert_eq!(plan.on_demand_issued, 0);
        assert_eq!(plan.degraded, 1);

        // Strict: the same resident is a miss; the re-fetch rides the
        // preferred (int8) tier
        let plan = build_plan_tiered(0, &[2], &[], &cache, &xfer, TierMode::Strict);
        assert_eq!(plan.n_ready(), 0);
        assert_eq!(plan.n_pending(), 1);
        assert_eq!(plan.on_demand_issued, 1);
        assert_eq!(plan.degraded, 0);
        let (_, h) = plan.pending_items().next().unwrap();
        assert_eq!(h.kind, QuantKind::Int8);
        h.wait_full();
        xfer.quiesce().unwrap();
        assert_eq!(cache.resident_meta((0, 2)).unwrap().kind, QuantKind::Int8);
        // at-preferred residents are plain hits in both modes
        let plan = build_plan_tiered(0, &[2], &[], &cache, &xfer, TierMode::Strict);
        assert_eq!(plan.n_ready(), 1);
        assert_eq!(plan.degraded, 0);
    }

    #[test]
    fn plan_misses_coalesce_into_one_wire_job() {
        use std::sync::atomic::Ordering;
        let (_store, cache, xfer) = fixture(vec![8, 8], "instant");
        let plan = build_plan(0, &[1, 2, 3], &[], &cache, &xfer);
        assert_eq!(plan.n_pending(), 3);
        assert_eq!(plan.on_demand_issued, 3);
        for (_, h) in plan.pending_items() {
            h.wait_full();
        }
        xfer.quiesce().unwrap();
        // Three misses, one multi-expert job on the wire — but still one
        // transfer (and one resident copy) per expert.
        assert_eq!(xfer.stats.wire_jobs.load(Ordering::Relaxed), 1);
        assert_eq!(xfer.stats.coalesced_groups.load(Ordering::Relaxed), 1);
        assert_eq!(xfer.stats.coalesced_members.load(Ordering::Relaxed), 3);
        assert_eq!(xfer.stats.transfers.load(Ordering::Relaxed), 3);
        assert!(cache.contains((0, 1)) && cache.contains((0, 2)) && cache.contains((0, 3)));
    }

    #[test]
    fn extra_loads_are_issued_not_computed() {
        let (_store, cache, xfer) = fixture(vec![8, 8], "instant");
        let plan = build_plan(1, &[0], &[1, 2, 3], &cache, &xfer);
        assert_eq!(plan.n_pending(), 1);
        assert_eq!(plan.n_compute(), 1);
        assert_eq!(plan.queue.len(), 4, "extras ride in the unified queue");
        assert_eq!(plan.on_demand_issued, 4);
        xfer.quiesce().unwrap();
        // extra loads landed in cache even though not computed
        assert!(cache.contains((1, 1)) && cache.contains((1, 2)) && cache.contains((1, 3)));
    }
}
