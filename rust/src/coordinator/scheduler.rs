//! Compute/comm overlap scheduling (paper §5, Algorithm 1 + Fig. 6).
//!
//! For one MoE block, partitions the experts to execute into
//! `ready` (resident — compute immediately, overlapping the transfers of
//! the rest) and `pending` (enqueued as on-demand loads). The engine then
//! consumes `pending` either **expert-wise** (wait for the whole expert)
//! or **tile-wise** (consume each f-tile as it arrives — Fig. 6(b)).

use std::sync::Arc;

use crate::memory::device_cache::DeviceCache;
use crate::memory::host_store::ExpertF32;
use crate::memory::transfer::{Priority, TransferEngine, TransferHandle};
use crate::model::ExpertId;

/// How the engine consumes on-demand experts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleMode {
    /// Wait for the full expert, then one kernel call (Fig. 6(a)).
    ExpertWise,
    /// Kernel call per arrived tile, overlapping compute with the rest of
    /// the transfer (Fig. 6(b)).
    TileWise,
}

/// Execution plan for one layer's MoE block.
pub struct ExecPlan {
    /// Experts resident right now (compute first — Algorithm 1 line 11).
    pub ready: Vec<(usize, Arc<ExpertF32>)>,
    /// Experts being loaded on-demand (compute as they arrive — line 12).
    pub pending: Vec<(usize, Arc<TransferHandle>)>,
    /// On-demand loads issued by this plan (for trace accounting).
    pub on_demand_issued: u64,
}

/// Build the plan: look up each compute target in the cache; request
/// on-demand transfers for misses (joining in-flight transfers); request
/// (but do not compute) `extra_loads` — the whole-layer baseline's
/// load-everything behaviour.
pub fn build_plan(
    layer: usize,
    computes: &[usize],
    extra_loads: &[usize],
    cache: &DeviceCache,
    xfer: &TransferEngine,
) -> ExecPlan {
    let mut ready = Vec::new();
    let mut pending = Vec::new();
    let mut issued = 0;

    for &e in computes {
        let id: ExpertId = (layer, e);
        if let Some(w) = cache.get(id) {
            ready.push((e, w));
        } else if let Some(w) = xfer.staging.take(id) {
            // prefetched earlier, parked in the staging buffers (the cache
            // may have had no room for this layer) — consume it now and give
            // the cache another chance to keep it.
            cache.insert(id, Arc::clone(&w));
            ready.push((e, w));
        } else if let Some(h) = xfer.in_flight(id) {
            // already being loaded (e.g. by a prefetch): join it
            pending.push((e, h));
        } else {
            pending.push((e, xfer.request(id, Priority::OnDemand)));
            issued += 1;
        }
    }
    for &e in extra_loads {
        let id: ExpertId = (layer, e);
        if !cache.contains(id) && xfer.in_flight(id).is_none() {
            xfer.request(id, Priority::OnDemand);
            issued += 1;
        }
    }
    ExecPlan { ready, pending, on_demand_issued: issued }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::host_store::HostStore;
    use crate::memory::platform::Platform;
    use crate::memory::quant::QuantKind;
    use crate::testutil::{micro_config, synthetic_weights};

    fn fixture(alloc: Vec<usize>, platform: &str) -> (Arc<HostStore>, Arc<DeviceCache>, TransferEngine) {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 21);
        let store = Arc::new(HostStore::build(&cfg, &w, QuantKind::F32).unwrap());
        let cache = Arc::new(DeviceCache::new(alloc));
        let xfer = TransferEngine::new(
            Arc::clone(&store),
            Arc::clone(&cache),
            Platform::preset(platform).unwrap(),
            4,
            1.0,
        );
        (store, cache, xfer)
    }

    #[test]
    fn cached_experts_are_ready() {
        let (store, cache, xfer) = fixture(vec![8, 8], "instant");
        cache.insert((0, 2), Arc::new(store.dequantize((0, 2))));
        let plan = build_plan(0, &[2, 5], &[], &cache, &xfer);
        assert_eq!(plan.ready.len(), 1);
        assert_eq!(plan.ready[0].0, 2);
        assert_eq!(plan.pending.len(), 1);
        assert_eq!(plan.pending[0].0, 5);
        assert_eq!(plan.on_demand_issued, 1);
        plan.pending[0].1.wait_full();
    }

    #[test]
    fn joins_in_flight_without_reissuing() {
        // slow (calibrated) link so the prefetch is still in flight
        let (_store, cache, xfer) = fixture(vec![8, 8], "rtx4090");
        let _pf = xfer.request((0, 3), Priority::Prefetch);
        let plan = build_plan(0, &[3], &[], &cache, &xfer);
        // Either the prefetch already completed (instant platform) and it is
        // a cache hit, or the plan joined the in-flight transfer; in neither
        // case may a *new* on-demand transfer be issued.
        assert_eq!(plan.on_demand_issued, 0);
        for (_, h) in &plan.pending {
            h.wait_full();
        }
    }

    #[test]
    fn staged_prefetch_is_consumed_as_ready_and_cached() {
        let (_store, cache, xfer) = fixture(vec![8, 8], "instant");
        xfer.request((0, 6), crate::memory::transfer::Priority::Prefetch)
            .wait_full();
        xfer.quiesce();
        assert!(xfer.staging_contains((0, 6)));
        assert!(!cache.contains((0, 6)));
        let plan = build_plan(0, &[6], &[], &cache, &xfer);
        assert_eq!(plan.ready.len(), 1, "staged expert should be ready");
        assert_eq!(plan.on_demand_issued, 0);
        assert!(cache.contains((0, 6)), "use promotes staged expert to cache");
        assert!(!xfer.staging_contains((0, 6)));
    }

    #[test]
    fn extra_loads_are_issued_not_computed() {
        let (_store, cache, xfer) = fixture(vec![8, 8], "instant");
        let plan = build_plan(1, &[0], &[1, 2, 3], &cache, &xfer);
        assert_eq!(plan.pending.len(), 1);
        assert_eq!(plan.on_demand_issued, 4);
        xfer.quiesce();
        // extra loads landed in cache even though not computed
        assert!(cache.contains((1, 1)) && cache.contains((1, 2)) && cache.contains((1, 3)));
    }
}
