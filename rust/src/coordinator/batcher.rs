//! Request batching for the serving front-end (vLLM-router-style continuous
//! batching, scaled to this engine's fixed batch buckets).
//!
//! Requests enter a FIFO admission queue; the decode loop drains them into
//! free engine slots between steps, decodes all active rows together, and
//! retires rows on EOS/length. The batcher is engine-agnostic (pure state
//! machine) so its invariants are property-testable without PJRT.

use std::collections::VecDeque;

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

/// Lifecycle of an admitted request.
#[derive(Debug)]
pub struct ActiveRequest {
    pub req: Request,
    pub row: usize,
    /// Next prompt token index to feed (prompt is consumed step by step).
    pub fed: usize,
    pub generated: Vec<u32>,
}

impl ActiveRequest {
    /// The token to feed this step: next prompt token, or the last
    /// generated one.
    pub fn next_input(&self) -> u32 {
        if self.fed < self.req.prompt.len() {
            self.req.prompt[self.fed]
        } else {
            *self.generated.last().expect("past prompt implies a sample")
        }
    }

    /// Are we still pre-filling the prompt (no sampling yet)?
    pub fn prefilling(&self) -> bool {
        self.fed < self.req.prompt.len()
    }

    pub fn done(&self) -> bool {
        self.generated.len() >= self.req.max_new
    }
}

/// FIFO admission + active set management.
#[derive(Default)]
pub struct Batcher {
    queue: VecDeque<Request>,
    pub active: Vec<ActiveRequest>,
    next_id: u64,
}

impl Batcher {
    pub fn new() -> Batcher {
        Batcher::default()
    }

    /// Enqueue a request; returns its id.
    pub fn submit(&mut self, prompt: Vec<u32>, max_new: usize) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request { id, prompt, max_new });
        id
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Admit queued requests into the given free rows (in order).
    pub fn admit(&mut self, free_rows: &[usize]) -> usize {
        let mut admitted = 0;
        for &row in free_rows {
            let Some(req) = self.queue.pop_front() else { break };
            self.active.push(ActiveRequest { req, row, fed: 0, generated: Vec::new() });
            admitted += 1;
        }
        admitted
    }

    /// (row, token) pairs to feed this step.
    pub fn step_inputs(&self) -> Vec<(usize, u32)> {
        self.active.iter().map(|a| (a.row, a.next_input())).collect()
    }

    /// Apply one step's sampled tokens (row -> sampled token). During
    /// prefill the sample is discarded (teacher forcing over the prompt).
    pub fn apply_step(&mut self, sampled: &[(usize, u32)]) {
        for a in self.active.iter_mut() {
            let Some(&(_, tok)) = sampled.iter().find(|(r, _)| *r == a.row) else {
                continue;
            };
            if a.prefilling() {
                a.fed += 1;
                if !a.prefilling() {
                    // prompt consumed: this step's sample is the first output
                    a.generated.push(tok);
                }
            } else {
                a.generated.push(tok);
            }
        }
    }

    /// Remove finished requests; returns them.
    pub fn retire(&mut self) -> Vec<ActiveRequest> {
        let mut done = Vec::new();
        let mut keep = Vec::new();
        for a in self.active.drain(..) {
            if a.done() {
                done.push(a);
            } else {
                keep.push(a);
            }
        }
        self.active = keep;
        done
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn fifo_admission() {
        let mut b = Batcher::new();
        let i1 = b.submit(vec![1, 2], 3);
        let i2 = b.submit(vec![3], 2);
        assert_eq!(b.admit(&[0]), 1);
        assert_eq!(b.active[0].req.id, i1);
        assert_eq!(b.admit(&[1]), 1);
        assert_eq!(b.active[1].req.id, i2);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn prefill_then_generate() {
        let mut b = Batcher::new();
        b.submit(vec![10, 11], 2);
        b.admit(&[0]);
        assert_eq!(b.step_inputs(), vec![(0, 10)]);
        b.apply_step(&[(0, 99)]); // sample during prefill: discarded
        assert_eq!(b.step_inputs(), vec![(0, 11)]);
        b.apply_step(&[(0, 42)]); // prompt consumed: first real token
        assert_eq!(b.active[0].generated, vec![42]);
        assert_eq!(b.step_inputs(), vec![(0, 42)]);
        b.apply_step(&[(0, 43)]);
        assert!(b.active[0].done());
        let done = b.retire();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].generated, vec![42, 43]);
        assert!(b.idle());
    }

    #[test]
    fn prop_batcher_invariants() {
        prop::check("batcher-invariants", 100, |rng| {
            let mut b = Batcher::new();
            let slots = 1 + rng.usize_below(8);
            let mut free: Vec<usize> = (0..slots).collect();
            let n_req = 1 + rng.usize_below(12);
            for _ in 0..n_req {
                let plen = 1 + rng.usize_below(4);
                let prompt = (0..plen).map(|_| rng.below(64) as u32).collect();
                b.submit(prompt, 1 + rng.usize_below(4));
            }
            let mut produced = 0;
            let mut steps = 0;
            while !b.idle() && steps < 10_000 {
                steps += 1;
                let admitted = b.admit(&free);
                free.drain(..admitted.min(free.len()));
                for a in &b.active {
                    crate::prop_assert!(a.row < slots, "row out of range");
                }
                // rows must be unique among active requests
                let mut rows: Vec<usize> = b.active.iter().map(|a| a.row).collect();
                rows.sort_unstable();
                rows.dedup();
                crate::prop_assert!(rows.len() == b.active.len(), "duplicate rows");
                let inputs = b.step_inputs();
                let sampled: Vec<(usize, u32)> =
                    inputs.iter().map(|&(r, _)| (r, rng.below(64) as u32)).collect();
                b.apply_step(&sampled);
                for a in b.retire() {
                    crate::prop_assert!(a.generated.len() == a.req.max_new);
                    produced += 1;
                    free.push(a.row);
                }
            }
            crate::prop_assert!(produced == n_req, "finished {produced}/{n_req}");
            Ok(())
        });
    }
}
