//! Request batching for the serving front-end (vLLM-router-style continuous
//! batching, scaled to this engine's fixed batch buckets).
//!
//! Requests enter a priority-aware admission queue (higher [`Request::priority`]
//! first, FIFO within a priority); the decode loop drains them into free
//! engine slots between steps, decodes all active rows together, samples each
//! row with its own [`SamplingParams`], and retires rows on stop-token (EOS),
//! length, or cancellation. The batcher is engine-agnostic (pure state
//! machine) so its invariants are property-testable without PJRT.

use std::collections::VecDeque;

use crate::model::sampling;
use crate::util::rng::Rng;

/// Per-request sampling knobs, threaded from the API surface down to
/// [`crate::model::sampling::sample_params`]. The all-zero default means
/// greedy decoding over the full vocabulary.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; `<= 0` is greedy argmax.
    pub temperature: f64,
    /// Restrict sampling to the k highest logits; 0 = unrestricted.
    pub top_k: usize,
    /// Seed for this request's private sampling stream; `None` derives an
    /// uncorrelated one from the request id at admission.
    pub seed: Option<u64>,
}

/// Why a request left the active set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated `max_new` tokens (or the KV cache filled up).
    Length,
    /// Sampled one of the request's stop tokens (not included in output).
    Stop,
    /// Cancelled by id mid-flight.
    Cancelled,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub params: SamplingParams,
    /// Tokens that terminate generation when sampled (the byte LM has no
    /// trained EOS; stop tokens play that role per request).
    pub stop: Vec<u32>,
    /// Higher admits first; ties break FIFO.
    pub priority: i32,
}

/// Lifecycle of an admitted request.
#[derive(Debug)]
pub struct ActiveRequest {
    pub req: Request,
    pub row: usize,
    /// Next prompt token index to feed (prompt is consumed step by step).
    pub fed: usize,
    pub generated: Vec<u32>,
    /// Sampled a stop token (the token itself is not kept).
    pub stopped: bool,
    /// Cancelled mid-flight; retired on the next retire() sweep.
    pub cancelled: bool,
    /// Private sampling stream (seeded from `req.params.seed`).
    rng: Rng,
}

impl ActiveRequest {
    fn new(req: Request, row: usize) -> ActiveRequest {
        let rng = Rng::new(req.params.seed.unwrap_or(0x5eed_0000 ^ req.id));
        ActiveRequest {
            req,
            row,
            fed: 0,
            generated: Vec::new(),
            stopped: false,
            cancelled: false,
            rng,
        }
    }

    /// The token to feed this step: next prompt token, or the last
    /// generated one.
    pub fn next_input(&self) -> u32 {
        if self.fed < self.req.prompt.len() {
            self.req.prompt[self.fed]
        } else {
            *self.generated.last().expect("past prompt implies a sample")
        }
    }

    /// Are we still pre-filling the prompt (no sampling yet)?
    pub fn prefilling(&self) -> bool {
        self.fed < self.req.prompt.len()
    }

    pub fn done(&self) -> bool {
        self.cancelled || self.stopped || self.generated.len() >= self.req.max_new
    }

    /// Valid once `done()`; reflects why the request retired.
    pub fn finish(&self) -> FinishReason {
        if self.cancelled {
            FinishReason::Cancelled
        } else if self.stopped {
            FinishReason::Stop
        } else {
            FinishReason::Length
        }
    }
}

/// Outcome of [`Batcher::cancel`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CancelOutcome {
    /// Removed from the admission queue before it ever ran.
    Queued,
    /// Marked for retirement at the next retire() sweep.
    Active,
    /// No queued or active request with that id.
    Unknown,
}

/// Priority admission + active set management.
#[derive(Default)]
pub struct Batcher {
    queue: VecDeque<Request>,
    pub active: Vec<ActiveRequest>,
    next_id: u64,
}

impl Batcher {
    pub fn new() -> Batcher {
        Batcher::default()
    }

    /// Enqueue a request with default sampling params; returns its id.
    pub fn submit(&mut self, prompt: Vec<u32>, max_new: usize) -> u64 {
        self.submit_request(prompt, max_new, SamplingParams::default(), Vec::new(), 0)
    }

    /// Enqueue a fully-parameterized request; returns its id.
    pub fn submit_request(
        &mut self,
        prompt: Vec<u32>,
        max_new: usize,
        params: SamplingParams,
        stop: Vec<u32>,
        priority: i32,
    ) -> u64 {
        let id = self.reserve_id();
        self.queue.push_back(Request { id, prompt, max_new, params, stop, priority });
        id
    }

    /// Consume and return the next request id without enqueuing anything —
    /// for requests rejected before admission, so their ids stay unique.
    pub fn reserve_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Cancel by id, wherever the request currently lives.
    pub fn cancel(&mut self, id: u64) -> CancelOutcome {
        if let Some(i) = self.queue.iter().position(|r| r.id == id) {
            let _ = self.queue.remove(i);
            return CancelOutcome::Queued;
        }
        if let Some(a) = self.active.iter_mut().find(|a| a.req.id == id) {
            a.cancelled = true;
            return CancelOutcome::Active;
        }
        CancelOutcome::Unknown
    }

    /// Highest-priority queued request (FIFO within a priority), if any.
    fn pop_next(&mut self) -> Option<Request> {
        let mut best: Option<(usize, i32)> = None;
        for (i, r) in self.queue.iter().enumerate() {
            match best {
                // strict > keeps the earliest submission among equals
                Some((_, bp)) if r.priority <= bp => {}
                _ => best = Some((i, r.priority)),
            }
        }
        best.and_then(|(i, _)| self.queue.remove(i))
    }

    /// Admit queued requests into the given free rows (in priority order).
    pub fn admit(&mut self, free_rows: &[usize]) -> usize {
        let mut admitted = 0;
        for &row in free_rows {
            let Some(req) = self.pop_next() else { break };
            self.active.push(ActiveRequest::new(req, row));
            admitted += 1;
        }
        admitted
    }

    /// (row, token) pairs to feed this step.
    pub fn step_inputs(&self) -> Vec<(usize, u32)> {
        self.active.iter().map(|a| (a.row, a.next_input())).collect()
    }

    /// Sample one token per logits row using that row's request params and
    /// private rng stream. Rows without an active request are skipped.
    pub fn sample_step(&mut self, logits: &[(usize, Vec<f32>)]) -> Vec<(usize, u32)> {
        let idx = self.index_by_row();
        let mut out = Vec::with_capacity(logits.len());
        for (row, l) in logits {
            let Some(&Some(i)) = idx.get(*row) else { continue };
            let a = &mut self.active[i];
            out.push((*row, sampling::sample_params(l, &a.req.params, &mut a.rng)));
        }
        out
    }

    /// Apply one step's sampled tokens (row -> sampled token). During
    /// prefill the sample is discarded (teacher forcing over the prompt);
    /// sampling a stop token sets `stopped` without keeping the token.
    /// Returns the (id, token, index) tuples actually emitted this step.
    pub fn apply_step(&mut self, sampled: &[(usize, u32)]) -> Vec<(u64, u32, usize)> {
        let max_row = sampled.iter().map(|&(r, _)| r).max().unwrap_or(0);
        let mut tok_of_row: Vec<Option<u32>> = vec![None; max_row + 1];
        for &(r, t) in sampled {
            tok_of_row[r] = Some(t);
        }
        let mut emitted = Vec::new();
        for a in self.active.iter_mut() {
            if a.cancelled || a.stopped {
                continue;
            }
            let Some(tok) = tok_of_row.get(a.row).copied().flatten() else {
                continue;
            };
            let sample_live = if a.prefilling() {
                a.fed += 1;
                // prompt consumed: this step's sample is the first output
                !a.prefilling()
            } else {
                true
            };
            // the bound can already be met at the prefill boundary
            // (max_new = 0): such requests take nothing from the sample
            if sample_live && a.generated.len() < a.req.max_new {
                if a.req.stop.contains(&tok) {
                    a.stopped = true;
                } else {
                    a.generated.push(tok);
                    emitted.push((a.req.id, tok, a.generated.len() - 1));
                }
            }
        }
        emitted
    }

    /// Remove finished requests; returns them.
    pub fn retire(&mut self) -> Vec<ActiveRequest> {
        let mut done = Vec::new();
        let mut keep = Vec::new();
        for a in self.active.drain(..) {
            if a.done() {
                done.push(a);
            } else {
                keep.push(a);
            }
        }
        self.active = keep;
        done
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// active index per row (rows are small, dense engine slot numbers).
    fn index_by_row(&self) -> Vec<Option<usize>> {
        let max_row = self.active.iter().map(|a| a.row).max().unwrap_or(0);
        let mut idx: Vec<Option<usize>> = vec![None; max_row + 1];
        for (i, a) in self.active.iter().enumerate() {
            idx[a.row] = Some(i);
        }
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn fifo_admission() {
        let mut b = Batcher::new();
        let i1 = b.submit(vec![1, 2], 3);
        let i2 = b.submit(vec![3], 2);
        assert_eq!(b.admit(&[0]), 1);
        assert_eq!(b.active[0].req.id, i1);
        assert_eq!(b.admit(&[1]), 1);
        assert_eq!(b.active[1].req.id, i2);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn priority_admission_order() {
        let mut b = Batcher::new();
        let low = b.submit_request(vec![1], 1, SamplingParams::default(), vec![], 0);
        let high = b.submit_request(vec![1], 1, SamplingParams::default(), vec![], 5);
        let mid1 = b.submit_request(vec![1], 1, SamplingParams::default(), vec![], 2);
        let mid2 = b.submit_request(vec![1], 1, SamplingParams::default(), vec![], 2);
        b.admit(&[0, 1, 2, 3]);
        let order: Vec<u64> = b.active.iter().map(|a| a.req.id).collect();
        assert_eq!(order, vec![high, mid1, mid2, low], "priority desc, FIFO within");
    }

    #[test]
    fn prefill_then_generate() {
        let mut b = Batcher::new();
        b.submit(vec![10, 11], 2);
        b.admit(&[0]);
        assert_eq!(b.step_inputs(), vec![(0, 10)]);
        assert!(b.apply_step(&[(0, 99)]).is_empty()); // prefill sample: discarded
        assert_eq!(b.step_inputs(), vec![(0, 11)]);
        // prompt consumed: first real token, emitted with index 0
        assert_eq!(b.apply_step(&[(0, 42)]), vec![(0, 42, 0)]);
        assert_eq!(b.active[0].generated, vec![42]);
        assert_eq!(b.step_inputs(), vec![(0, 42)]);
        assert_eq!(b.apply_step(&[(0, 43)]), vec![(0, 43, 1)]);
        assert!(b.active[0].done());
        let done = b.retire();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].generated, vec![42, 43]);
        assert_eq!(done[0].finish(), FinishReason::Length);
        assert!(b.idle());
    }

    #[test]
    fn stop_token_retires_without_keeping_it() {
        let mut b = Batcher::new();
        b.submit_request(vec![7], 100, SamplingParams::default(), vec![13], 0);
        b.admit(&[0]);
        assert_eq!(b.apply_step(&[(0, 40)]), vec![(0, 40, 0)]); // boundary emit
        assert!(b.apply_step(&[(0, 13)]).is_empty()); // stop token: swallowed
        let done = b.retire();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish(), FinishReason::Stop);
        assert_eq!(done[0].generated, vec![40]);
    }

    #[test]
    fn cancel_queued_and_active() {
        let mut b = Batcher::new();
        let q1 = b.submit(vec![1], 4);
        let q2 = b.submit(vec![2], 4);
        assert_eq!(b.cancel(q1), CancelOutcome::Queued);
        assert_eq!(b.queued(), 1);
        b.admit(&[0]);
        assert_eq!(b.active[0].req.id, q2);
        assert_eq!(b.cancel(q2), CancelOutcome::Active);
        assert_eq!(b.cancel(999), CancelOutcome::Unknown);
        let done = b.retire();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish(), FinishReason::Cancelled);
        assert!(b.idle());
    }

    #[test]
    fn per_request_sampling_is_deterministic_per_seed() {
        let run = |seed| {
            let mut b = Batcher::new();
            let p = SamplingParams { temperature: 1.0, top_k: 3, seed: Some(seed) };
            b.submit_request(vec![1], 4, p, vec![], 0);
            b.admit(&[0]);
            let logits: Vec<f32> = (0..16).map(|i| (i as f32) * 0.1).collect();
            let mut toks = Vec::new();
            for _ in 0..4 {
                let s = b.sample_step(&[(0, logits.clone())]);
                b.apply_step(&s);
                toks.extend(s.into_iter().map(|(_, t)| t));
            }
            toks
        };
        assert_eq!(run(7), run(7));
        // top_k = 3 restricts to the three largest logits (indices 13..16)
        for t in run(3) {
            assert!((13..16).contains(&t), "token {t} escaped top-k window");
        }
    }

    #[test]
    fn prop_batcher_invariants() {
        prop::check("batcher-invariants", 100, |rng| {
            let mut b = Batcher::new();
            let slots = 1 + rng.usize_below(8);
            let mut free: Vec<usize> = (0..slots).collect();
            let n_req = 1 + rng.usize_below(12);
            let mut ids = Vec::new();
            for _ in 0..n_req {
                let plen = 1 + rng.usize_below(4);
                let prompt = (0..plen).map(|_| rng.below(64) as u32).collect();
                // a third of requests carry a stop token from the sample
                // alphabet, so stop-retirement actually fires
                let stop = if rng.chance(0.33) { vec![rng.below(8) as u32] } else { vec![] };
                let prio = rng.below(4) as i32 - 2;
                // max_new 0 is legal: the request prefills and retires empty
                ids.push(b.submit_request(
                    prompt,
                    rng.usize_below(5),
                    SamplingParams { temperature: 0.0, top_k: 0, seed: Some(rng.next_u64()) },
                    stop,
                    prio,
                ));
            }
            // cancel a random queued request up front
            let mut cancelled = 0;
            if rng.chance(0.3) {
                if b.cancel(*rng.choose(&ids)) == CancelOutcome::Queued {
                    cancelled += 1;
                }
            }
            let mut produced = 0;
            let mut steps = 0;
            while !b.idle() && steps < 10_000 {
                steps += 1;
                let admitted = b.admit(&free);
                free.drain(..admitted.min(free.len()));
                for a in &b.active {
                    crate::prop_assert!(a.row < slots, "row out of range");
                }
                // rows must be unique among active requests
                let mut rows: Vec<usize> = b.active.iter().map(|a| a.row).collect();
                rows.sort_unstable();
                rows.dedup();
                crate::prop_assert!(rows.len() == b.active.len(), "duplicate rows");
                // occasionally cancel a random in-flight request
                if rng.chance(0.05) && !b.active.is_empty() {
                    let i = rng.usize_below(b.active.len());
                    let id = b.active[i].req.id;
                    crate::prop_assert!(b.cancel(id) == CancelOutcome::Active);
                }
                let inputs = b.step_inputs();
                let sampled: Vec<(usize, u32)> =
                    inputs.iter().map(|&(r, _)| (r, rng.below(64) as u32)).collect();
                b.apply_step(&sampled);
                for a in b.retire() {
                    match a.finish() {
                        FinishReason::Length => {
                            crate::prop_assert!(a.generated.len() == a.req.max_new);
                        }
                        FinishReason::Stop => {
                            crate::prop_assert!(a.generated.len() < a.req.max_new);
                            for t in &a.generated {
                                crate::prop_assert!(
                                    !a.req.stop.contains(t),
                                    "stop token kept in output"
                                );
                            }
                        }
                        FinishReason::Cancelled => {
                            cancelled += 1;
                        }
                    }
                    if a.finish() != FinishReason::Cancelled {
                        produced += 1;
                    }
                    free.push(a.row);
                }
            }
            crate::prop_assert!(
                produced + cancelled == n_req,
                "finished {produced}+{cancelled}/{n_req}"
            );
            Ok(())
        });
    }
}
