//! Method presets: every serving method evaluated in §6 as an
//! [`EngineConfig`] factory, so benches and the CLI talk in paper terms.
//!
//! | preset | gating | prefetch | cache alloc | schedule | layer load |
//! |---|---|---|---|---|---|
//! | `baseline` (DeepSpeed/FlexGen-style) | top-k | off | uniform | expert-wise | whole layer |
//! | `mixtral-offloading` | top-k | off | uniform LRU | expert-wise | needed only |
//! | `pre-gated` | top-k | next layer, no pre-gate | uniform | expert-wise | needed only |
//! | `adapmoe-nogate` | top-k | 3-layer + pre-gate | DP | tile-wise | needed only |
//! | `adapmoe` | sensitivity | 3-layer + pre-gate | DP | tile-wise | needed only |
//!
//! Ablation rows of Table 2 are built with [`ablation`].

use crate::coordinator::engine::{AllocPolicy, EngineConfig};
use crate::coordinator::gating::GatingPolicy;
use crate::coordinator::prefetch::PrefetchConfig;
use crate::coordinator::profile::Profile;
use crate::coordinator::scheduler::{ScheduleMode, TierMode};
use crate::coordinator::sensitivity::SensitivityPolicy;
use crate::memory::faults::FaultPlan;
use crate::memory::platform::Platform;
use crate::memory::quant::QuantKind;
use crate::memory::sharded_cache::Placement;
use crate::memory::tiered_store::PrecisionPolicy;
use crate::memory::transfer::{LaneConfig, LanePolicy};

/// Shared knobs independent of the serving method.
#[derive(Clone, Debug)]
pub struct RunSettings {
    pub batch: usize,
    pub cache_budget: usize,
    pub quant: QuantKind,
    pub platform: Platform,
    pub n_tiles: usize,
    pub time_scale: f64,
    pub top_k: usize,
    /// Host-FFN worker threads (0 = engine-thread kernel path; see
    /// [`crate::coordinator::executor`]).
    pub compute_workers: usize,
    /// Parallel comm lanes feeding the CompletionBoard (`--lanes`).
    pub n_lanes: usize,
    /// How transfers are assigned to lanes (`--lane-policy`).
    pub lane_policy: LanePolicy,
    /// Device backends sharding the expert cache (`--devices`).
    pub n_devices: usize,
    /// ExpertId → device mapping when sharded (`--placement`).
    pub placement: Placement,
    /// Precision tiers of the expert store (`--tiers`; empty = the
    /// single `quant` tier, the historical shape).
    pub tiers: Vec<QuantKind>,
    /// Per-transfer bit-width selection (`--precision-policy`).
    pub precision: PrecisionPolicy,
    /// Background upgrade transfers per idle moment (`--upgrade-budget`).
    pub upgrade_budget: usize,
    /// Per-device in-flight prefetch cap (`--prefetch-device-cap`;
    /// `None` = global window only).
    pub prefetch_per_device: Option<usize>,
    /// Scripted fault injection (`--fault-plan`; `None` = fault-free).
    pub fault_plan: Option<FaultPlan>,
    /// Artifact-server address (`--remote`; `None` = local store).
    pub remote: Option<String>,
    /// Sensitivity map driving the resource consumers
    /// (`--sensitivity-policy`; `Uniform` = historical behavior).
    pub sensitivity: SensitivityPolicy,
}

impl RunSettings {
    pub fn new(batch: usize, cache_budget: usize, quant: QuantKind, platform: Platform) -> Self {
        RunSettings {
            batch,
            cache_budget,
            quant,
            platform,
            n_tiles: 4,
            time_scale: 1.0,
            top_k: 2,
            compute_workers: 0,
            n_lanes: 1,
            lane_policy: LanePolicy::RoundRobin,
            n_devices: 1,
            placement: Placement::LayerSliced,
            tiers: Vec::new(),
            precision: PrecisionPolicy::Fixed,
            upgrade_budget: 0,
            prefetch_per_device: None,
            fault_plan: None,
            remote: None,
            sensitivity: SensitivityPolicy::Uniform,
        }
    }
}

pub const METHODS: &[&str] = &[
    "baseline",
    "mixtral-offloading",
    "pre-gated",
    "adapmoe-nogate",
    "adapmoe",
];

/// Build the EngineConfig for a named method.
pub fn method(name: &str, s: &RunSettings, profile: &Profile) -> Option<EngineConfig> {
    let topk = GatingPolicy::TopK { k: s.top_k };
    let sens = GatingPolicy::Sensitivity {
        k: s.top_k,
        threshold: profile.threshold,
        sensitivity: profile.sensitivity.clone(),
    };
    let base = EngineConfig {
        batch: s.batch,
        gating: topk.clone(),
        prefetch: PrefetchConfig::disabled(),
        alloc: AllocPolicy::Uniform,
        cache_budget: s.cache_budget,
        schedule: ScheduleMode::ExpertWise,
        quant: s.quant,
        tiers: s.tiers.clone(),
        precision: s.precision,
        upgrade_budget: s.upgrade_budget,
        tier_mode: TierMode::Degrade,
        platform: s.platform.clone(),
        n_tiles: s.n_tiles,
        time_scale: s.time_scale,
        whole_layer: false,
        compute_workers: s.compute_workers,
        lanes: LaneConfig::new(s.n_lanes, s.lane_policy),
        devices: s.n_devices,
        placement: s.placement,
        fault_plan: s.fault_plan.clone(),
        remote: s.remote.clone(),
        sensitivity: s.sensitivity,
    };
    let mut cfg = match name {
        // DeepSpeed/FlexGen-style dense offloading: loads every expert of
        // every layer on demand.
        "baseline" => EngineConfig { whole_layer: true, ..base },
        // Eliseev & Mazur: LRU expert cache, on-demand needed experts only,
        // fixed (uniform) per-layer cache split, no prefetch, no gating.
        "mixtral-offloading" => base,
        // Hwang et al.: previous-layer activations select + prefetch the
        // next layer's experts; first layer stays on-demand.
        "pre-gated" => EngineConfig {
            prefetch: PrefetchConfig::next_layer_only(),
            ..base
        },
        // AdapMoE without adaptive gating (output-identical to top-k).
        "adapmoe-nogate" => EngineConfig {
            prefetch: PrefetchConfig::standard(),
            alloc: AllocPolicy::Planned,
            schedule: ScheduleMode::TileWise,
            ..base
        },
        // Full AdapMoE.
        "adapmoe" => EngineConfig {
            gating: sens,
            prefetch: PrefetchConfig::standard(),
            alloc: AllocPolicy::Planned,
            schedule: ScheduleMode::TileWise,
            ..base
        },
        _ => return None,
    };
    // Shared knob, orthogonal to the method's prefetch shape: the
    // per-device window rides whatever prefetch config the preset chose.
    cfg.prefetch.max_outstanding_per_device = s.prefetch_per_device;
    Some(cfg)
}

/// Table 2 ablation row: toggle gating / prefetch / DP-cache independently
/// on top of the tuned Mixtral-offloading baseline (tile-wise scheduling is
/// part of the system implementation, kept on for all rows as in §6.4).
pub fn ablation(
    gating: bool,
    prefetching: bool,
    dp_cache: bool,
    s: &RunSettings,
    profile: &Profile,
) -> EngineConfig {
    let mut cfg = method("mixtral-offloading", s, profile).unwrap();
    cfg.schedule = ScheduleMode::TileWise;
    if gating {
        cfg.gating = GatingPolicy::Sensitivity {
            k: s.top_k,
            threshold: profile.threshold,
            sensitivity: profile.sensitivity.clone(),
        };
    }
    if prefetching {
        cfg.prefetch = PrefetchConfig::standard();
    }
    if dp_cache {
        cfg.alloc = AllocPolicy::Planned;
    }
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    fn settings() -> RunSettings {
        RunSettings::new(1, 8, QuantKind::Int4, Platform::preset("instant").unwrap())
    }

    #[test]
    fn all_methods_resolve() {
        let p = Profile::synthetic(4);
        for m in METHODS {
            assert!(method(m, &settings(), &p).is_some(), "{m}");
        }
        assert!(method("nope", &settings(), &p).is_none());
    }

    #[test]
    fn baseline_loads_whole_layers() {
        let p = Profile::synthetic(4);
        assert!(method("baseline", &settings(), &p).unwrap().whole_layer);
        assert!(!method("mixtral-offloading", &settings(), &p).unwrap().whole_layer);
    }

    #[test]
    fn pregated_has_no_first_layer_prediction() {
        let p = Profile::synthetic(4);
        let cfg = method("pre-gated", &settings(), &p).unwrap();
        assert!(cfg.prefetch.enabled);
        assert_eq!(cfg.prefetch.lookahead, 1);
        assert!(!cfg.prefetch.use_pre_gate);
    }

    #[test]
    fn adapmoe_uses_sensitivity_and_dp() {
        let p = Profile::synthetic(4);
        let cfg = method("adapmoe", &settings(), &p).unwrap();
        assert_eq!(cfg.gating.name(), "sensitivity");
        assert_eq!(cfg.alloc, AllocPolicy::Planned);
        assert_eq!(cfg.schedule, ScheduleMode::TileWise);
        let ng = method("adapmoe-nogate", &settings(), &p).unwrap();
        assert_eq!(ng.gating.name(), "topk");
    }

    #[test]
    fn lane_settings_propagate_to_config() {
        let p = Profile::synthetic(4);
        let mut s = settings();
        s.n_lanes = 4;
        s.lane_policy = LanePolicy::Pinned;
        let cfg = method("adapmoe", &s, &p).unwrap();
        assert_eq!(cfg.lanes.count, 4);
        assert_eq!(cfg.lanes.policy, LanePolicy::Pinned);
        // defaults stay single-lane round-robin
        let d = method("adapmoe", &settings(), &p).unwrap();
        assert_eq!(d.lanes.count, 1);
        assert_eq!(d.lanes.policy, LanePolicy::RoundRobin);
    }

    #[test]
    fn device_settings_propagate_to_config() {
        let p = Profile::synthetic(4);
        let mut s = settings();
        s.n_devices = 4;
        s.placement = Placement::ExpertHash;
        let cfg = method("adapmoe", &s, &p).unwrap();
        assert_eq!(cfg.devices, 4);
        assert_eq!(cfg.placement, Placement::ExpertHash);
        // defaults stay single-device layer-sliced
        let d = method("adapmoe", &settings(), &p).unwrap();
        assert_eq!(d.devices, 1);
        assert_eq!(d.placement, Placement::LayerSliced);
    }

    #[test]
    fn tier_settings_propagate_to_config() {
        let p = Profile::synthetic(4);
        let mut s = settings();
        s.tiers = vec![QuantKind::Int2, QuantKind::Int4];
        s.precision = PrecisionPolicy::Urgency;
        s.upgrade_budget = 2;
        s.prefetch_per_device = Some(3);
        let cfg = method("adapmoe", &s, &p).unwrap();
        assert_eq!(cfg.tiers, vec![QuantKind::Int2, QuantKind::Int4]);
        assert_eq!(cfg.precision, PrecisionPolicy::Urgency);
        assert_eq!(cfg.upgrade_budget, 2);
        assert_eq!(cfg.prefetch.max_outstanding_per_device, Some(3));
        assert_eq!(cfg.tier_mode, TierMode::Degrade);
        // defaults stay single-tier fixed, no upgrades, uncapped devices
        let d = method("adapmoe", &settings(), &p).unwrap();
        assert!(d.tiers.is_empty());
        assert_eq!(d.precision, PrecisionPolicy::Fixed);
        assert_eq!(d.upgrade_budget, 0);
        assert_eq!(d.prefetch.max_outstanding_per_device, None);
    }

    #[test]
    fn fault_plan_propagates_to_config() {
        let p = Profile::synthetic(4);
        let mut s = settings();
        s.fault_plan = Some(FaultPlan::parse("2:halt:0").unwrap());
        let cfg = method("adapmoe", &s, &p).unwrap();
        assert_eq!(cfg.fault_plan, s.fault_plan);
        // default stays fault-free
        assert!(method("adapmoe", &settings(), &p).unwrap().fault_plan.is_none());
    }

    #[test]
    fn remote_store_propagates_to_config() {
        let p = Profile::synthetic(4);
        let mut s = settings();
        s.remote = Some("127.0.0.1:9099".into());
        let cfg = method("adapmoe", &s, &p).unwrap();
        assert_eq!(cfg.remote.as_deref(), Some("127.0.0.1:9099"));
        // default stays local
        assert!(method("adapmoe", &settings(), &p).unwrap().remote.is_none());
    }

    #[test]
    fn sensitivity_policy_propagates_to_config() {
        let p = Profile::synthetic(4);
        let mut s = settings();
        s.sensitivity = SensitivityPolicy::Profile;
        let cfg = method("adapmoe", &s, &p).unwrap();
        assert_eq!(cfg.sensitivity, SensitivityPolicy::Profile);
        // every preset defaults to the uniform (identity) map
        for m in METHODS {
            let d = method(m, &settings(), &p).unwrap();
            assert_eq!(d.sensitivity, SensitivityPolicy::Uniform, "{m}");
        }
    }

    #[test]
    fn ablation_combos() {
        let p = Profile::synthetic(4);
        let all = ablation(true, true, true, &settings(), &p);
        assert_eq!(all.gating.name(), "sensitivity");
        assert!(all.prefetch.enabled);
        assert_eq!(all.alloc, AllocPolicy::Planned);
        let none = ablation(false, false, false, &settings(), &p);
        assert_eq!(none.gating.name(), "topk");
        assert!(!none.prefetch.enabled);
        assert_eq!(none.alloc, AllocPolicy::Uniform);
    }
}
