//! Expert gating policies (paper §4.2).
//!
//! Given a token's router probabilities over N experts, decide which experts
//! to activate and with what mixing weights:
//!
//! * [`GatingPolicy::TopK`] — fixed Mixtral top-k routing (the accuracy
//!   reference; every baseline in §6 uses it).
//! * [`GatingPolicy::Score`] — the Adap-gating baseline (Li et al. 2023):
//!   drop to a single expert whenever the top-1's normalized score α exceeds
//!   a score threshold, regardless of which layer it is.
//! * [`GatingPolicy::Sensitivity`] — AdapMoE's contribution: drop to a
//!   single expert when the *loss perturbation* bound
//!   `(1-α)² · Σdiag(F_i) ≤ T` (eq. 8) holds, where `Σdiag(F_i)` is the
//!   offline Fisher sensitivity of layer i. Early (sensitive) layers keep
//!   two experts; late layers shed them aggressively — same mean activation
//!   ratio, better accuracy (Fig. 7).

use crate::model::sampling::top_k_indices;

/// One token-row's routing decision: (expert index, mixing weight) pairs,
/// weights renormalized over the selected set.
#[derive(Clone, Debug, PartialEq)]
pub struct GateDecision {
    pub experts: Vec<(usize, f32)>,
}

impl GateDecision {
    pub fn single(&self) -> bool {
        self.experts.len() == 1
    }

    pub fn contains(&self, e: usize) -> bool {
        self.experts.iter().any(|&(x, _)| x == e)
    }
}

#[derive(Clone, Debug)]
pub enum GatingPolicy {
    /// Always the top `k` experts (weights renormalized over the k).
    TopK { k: usize },
    /// Score-based adaptive gating: single expert iff α ≥ `alpha_min`.
    Score { k: usize, alpha_min: f64 },
    /// Sensitivity-based adaptive gating (eq. 8): single expert iff
    /// (1-α)² · sensitivity\[layer\] ≤ threshold.
    Sensitivity {
        k: usize,
        threshold: f64,
        sensitivity: Vec<f64>,
    },
}

impl GatingPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            GatingPolicy::TopK { .. } => "topk",
            GatingPolicy::Score { .. } => "score",
            GatingPolicy::Sensitivity { .. } => "sensitivity",
        }
    }

    pub fn k(&self) -> usize {
        match self {
            GatingPolicy::TopK { k }
            | GatingPolicy::Score { k, .. }
            | GatingPolicy::Sensitivity { k, .. } => *k,
        }
    }

    /// Decide routing for one token row of router probabilities at `layer`.
    pub fn decide(&self, layer: usize, probs: &[f32]) -> GateDecision {
        let k = self.k().min(probs.len());
        let top = top_k_indices(probs, k);
        let p1 = probs[top[0]];
        let p2 = if k > 1 { probs[top[1]] } else { 0.0 };
        // α: top-1 share of the top-2 mass (paper eq. 3 normalization).
        let alpha = (p1 / (p1 + p2 + 1e-12)) as f64;

        let single = match self {
            GatingPolicy::TopK { .. } => false,
            GatingPolicy::Score { alpha_min, .. } => alpha >= *alpha_min,
            GatingPolicy::Sensitivity { threshold, sensitivity, .. } => {
                let s = sensitivity.get(layer).copied().unwrap_or(f64::INFINITY);
                (1.0 - alpha).powi(2) * s <= *threshold
            }
        };

        if single || k == 1 {
            GateDecision { experts: vec![(top[0], 1.0)] }
        } else {
            let mass: f32 = top.iter().map(|&i| probs[i]).sum();
            GateDecision {
                experts: top.iter().map(|&i| (i, probs[i] / mass)).collect(),
            }
        }
    }

    /// Average single-expert ratio this policy yields on a probability
    /// trace (rows of router probs per layer) — the x-axis of Fig. 7.
    pub fn single_ratio(&self, trace: &[(usize, Vec<f32>)]) -> f64 {
        if trace.is_empty() {
            return 0.0;
        }
        let singles = trace
            .iter()
            .filter(|(layer, probs)| self.decide(*layer, probs).single())
            .count();
        singles as f64 / trace.len() as f64
    }
}

/// Calibrate a sensitivity threshold T that achieves `target_ratio` mean
/// single-expert activations on a trace (paper: binary search on the
/// validation set; 24% is the deployed setting).
pub fn calibrate_threshold(
    sensitivity: &[f64],
    trace: &[(usize, Vec<f32>)],
    k: usize,
    target_ratio: f64,
) -> f64 {
    let mut lo = 0.0f64;
    let mut hi = sensitivity.iter().cloned().fold(0.0, f64::max).max(1e-30) + 1e-30;
    for _ in 0..64 {
        let mid = 0.5 * (lo + hi);
        let pol = GatingPolicy::Sensitivity {
            k,
            threshold: mid,
            sensitivity: sensitivity.to_vec(),
        };
        if pol.single_ratio(trace) < target_ratio {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Calibrate the score-based baseline's α threshold for the same ratio.
pub fn calibrate_score_threshold(
    trace: &[(usize, Vec<f32>)],
    k: usize,
    target_ratio: f64,
) -> f64 {
    let mut lo = 0.5f64;
    let mut hi = 1.0f64;
    for _ in 0..64 {
        let mid = 0.5 * (lo + hi);
        let pol = GatingPolicy::Score { k, alpha_min: mid };
        if pol.single_ratio(trace) > target_ratio {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn topk_always_k_and_normalized() {
        let pol = GatingPolicy::TopK { k: 2 };
        let d = pol.decide(0, &[0.1, 0.6, 0.2, 0.1]);
        assert_eq!(d.experts.len(), 2);
        assert_eq!(d.experts[0].0, 1);
        assert_eq!(d.experts[1].0, 2);
        let w: f32 = d.experts.iter().map(|&(_, w)| w).sum();
        assert!((w - 1.0).abs() < 1e-6);
        assert!((d.experts[0].1 - 0.75).abs() < 1e-6);
    }

    #[test]
    fn score_gate_drops_to_single_on_skew() {
        let pol = GatingPolicy::Score { k: 2, alpha_min: 0.8 };
        // α = 0.9/(0.9+0.05) ≈ 0.947 -> single
        assert!(pol.decide(0, &[0.9, 0.05, 0.03, 0.02]).single());
        // α = 0.5 -> keep both
        assert!(!pol.decide(0, &[0.4, 0.4, 0.1, 0.1]).single());
    }

    #[test]
    fn sensitivity_gate_is_layer_aware() {
        // same probs, different layers: sensitive layer keeps 2 experts
        let pol = GatingPolicy::Sensitivity {
            k: 2,
            threshold: 1e-2,
            sensitivity: vec![10.0, 0.01],
        };
        let probs = [0.7f32, 0.2, 0.05, 0.05];
        assert!(!pol.decide(0, &probs).single(), "sensitive layer must keep 2");
        assert!(pol.decide(1, &probs).single(), "insensitive layer can drop");
    }

    #[test]
    fn sensitivity_reduces_to_topk_at_zero_threshold() {
        let pol = GatingPolicy::Sensitivity {
            k: 2,
            threshold: 0.0,
            sensitivity: vec![1.0; 4],
        };
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            let probs = prop::simplex(&mut rng, 8);
            let d = pol.decide(rng.usize_below(4), &probs);
            // α<1 strictly (ties aside) so (1-α)²·S > 0 ≥ T fails -> top-2
            assert_eq!(d.experts.len(), 2);
        }
    }

    #[test]
    fn calibration_hits_target_ratio() {
        let mut rng = Rng::new(42);
        let sens: Vec<f64> = (0..8).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let trace: Vec<(usize, Vec<f32>)> = (0..4000)
            .map(|_| (rng.usize_below(8), prop::simplex(&mut rng, 8)))
            .collect();
        let t = calibrate_threshold(&sens, &trace, 2, 0.24);
        let pol = GatingPolicy::Sensitivity { k: 2, threshold: t, sensitivity: sens };
        let r = pol.single_ratio(&trace);
        assert!((r - 0.24).abs() < 0.03, "ratio={r}");
    }

    #[test]
    fn score_calibration_hits_target_ratio() {
        let mut rng = Rng::new(43);
        let trace: Vec<(usize, Vec<f32>)> = (0..4000)
            .map(|_| (rng.usize_below(8), prop::simplex(&mut rng, 8)))
            .collect();
        let t = calibrate_score_threshold(&trace, 2, 0.3);
        let pol = GatingPolicy::Score { k: 2, alpha_min: t };
        let r = pol.single_ratio(&trace);
        assert!((r - 0.3).abs() < 0.03, "ratio={r}");
    }

    #[test]
    fn prop_decisions_are_valid() {
        prop::check("gate-decision-valid", 200, |rng| {
            let n = 4 + rng.usize_below(8);
            let probs = prop::simplex(rng, n);
            let layer = rng.usize_below(8);
            let sens: Vec<f64> = (0..8).map(|_| rng.f64()).collect();
            let pol = match rng.usize_below(3) {
                0 => GatingPolicy::TopK { k: 2 },
                1 => GatingPolicy::Score { k: 2, alpha_min: 0.5 + rng.f64() / 2.0 },
                _ => GatingPolicy::Sensitivity {
                    k: 2,
                    threshold: rng.f64() * 0.5,
                    sensitivity: sens,
                },
            };
            let d = pol.decide(layer, &probs);
            crate::prop_assert!(!d.experts.is_empty() && d.experts.len() <= 2);
            let w: f32 = d.experts.iter().map(|&(_, w)| w).sum();
            crate::prop_assert!((w - 1.0).abs() < 1e-5, "weights sum {w}");
            // experts must be distinct and in range
            let mut seen = std::collections::HashSet::new();
            for &(e, _) in &d.experts {
                crate::prop_assert!(e < n, "expert {e} out of range {n}");
                crate::prop_assert!(seen.insert(e), "duplicate expert {e}");
            }
            // top-1 is always included
            let top1 = top_k_indices(&probs, 1)[0];
            crate::prop_assert!(d.contains(top1), "top-1 missing");
            Ok(())
        });
    }

    #[test]
    fn prop_sensitivity_monotone_in_threshold() {
        prop::check("sensitivity-monotone", 100, |rng| {
            let probs = prop::simplex(rng, 8);
            let layer = rng.usize_below(4);
            let sens: Vec<f64> = (0..4).map(|_| rng.f64() + 0.1).collect();
            let t1 = rng.f64() * 0.2;
            let t2 = t1 + rng.f64() * 0.5;
            let d1 = GatingPolicy::Sensitivity { k: 2, threshold: t1, sensitivity: sens.clone() }
                .decide(layer, &probs);
            let d2 = GatingPolicy::Sensitivity { k: 2, threshold: t2, sensitivity: sens }
                .decide(layer, &probs);
            // a higher threshold can only shed experts, never add
            crate::prop_assert!(
                d2.experts.len() <= d1.experts.len(),
                "t1={t1} kept {}, t2={t2} kept {}",
                d1.experts.len(),
                d2.experts.len()
            );
            Ok(())
        });
    }
}
