//! Completion-driven MoE layer execution (the compute half of Algorithm 1,
//! restructured to kill head-of-line blocking).
//!
//! The core primitive is [`drain_arrival_order`]: consume a layer's
//! pending transfers — whole experts, or individual f-tiles in
//! [`ScheduleMode::TileWise`] — in **arrival order** as announced on the
//! [`CompletionBoard`], promoting completed experts into the cache and
//! attributing arrived-but-unconsumed time (queue delay) separately from
//! true idle waits (stall). Queue delay is additionally split by the comm
//! **lane** that carried each expert/tile (`TransferHandle::lane`), so a
//! multi-lane engine's fig9 breakdown shows which lane the head-of-line
//! cost came from. Both MoE execution paths share it, so the fig9
//! attribution means the same thing everywhere:
//!
//! * the engine's kernel path (engine.rs) passes a consume callback that
//!   runs the XLA expert kernel on the engine thread (PJRT handles are
//!   not `Send`);
//! * [`run_layer_parallel`] passes a callback that fans host-side SwiGLU
//!   FFNs ([`expert_ffn_host`]) out across the [`ThreadPool`], computing
//!   cached (ready) experts in parallel while pending transfers stream in.
//!
//! [`run_layer_serial`] is the historical baseline kept for benches and
//! tests: ready experts first, then pending transfers **in plan order**,
//! blocking on each — if expert *i+1* lands before expert *i*, its data
//! sits idle while the compute stream stalls on *i*, the head-of-line
//! term HOBBIT / EdgeMoE identify as the dominant decode-latency cost.
//!
//! Worker results in the parallel drain are reduced in **canonical queue
//! order** (per expert, per tile index) at the end of the layer, so the
//! accumulated residual is bit-for-bit identical to the serial drain no
//! matter which worker computed what or in which order transfers arrived.
//! The same property merges arrivals **across device backends**: with a
//! sharded cache ([`crate::memory::sharded_cache::ShardedCache`]) the
//! drain consumes whichever device's lane lands first and promotes each
//! expert into its owning shard, while the canonical reduction keeps the
//! output bits independent of which device won the race
//! (rust/tests/devices.rs locks this down).
//!
//! When the transfer engine's fault pump gives up on a transfer
//! ([`TransferHandle::is_failed`]), the drain walks the **degradation
//! ladder** (docs/fault-tolerance.md) instead of wedging: serve a
//! resident copy of any tier, else a replica from a non-owning shard,
//! else drop the expert from the plan the way AdapMoE's adaptive gating
//! drops low-sensitivity experts — the token always completes.

use std::cell::Cell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::scheduler::{ExecPlan, ScheduleMode, WorkItem};
use crate::memory::device_cache::{ExpertCache, ResidentMeta};
use crate::memory::host_store::ExpertF32;
use crate::memory::transfer::{TransferEngine, TransferHandle};
use crate::tensor::Tensor;
use crate::util::threadpool::{RowBufferPool, ThreadPool};

/// How long the executor parks on the completion board per wait. A timeout
/// (not pure blocking) makes the drain robust to dropped/stale events.
const WAIT_SLICE: Duration = Duration::from_millis(2);

/// Result of draining one layer's MoE work queue.
pub struct LayerOutcome {
    /// Accumulated expert outputs, [batch, d_model].
    pub acc: Tensor,
    /// Time the compute stream truly idled waiting for transfers (ns).
    pub stall_ns: u64,
    /// Time transferred data sat ready before compute consumed it (ns),
    /// summed per expert/tile — the head-of-line-blocking cost.
    pub queue_delay_ns: u64,
    /// Queue delay split by the comm lane that carried the data, so the
    /// fig9 breakdown can attribute head-of-line cost per lane.
    pub queue_delay_by_lane: HashMap<usize, u64>,
    /// Queue delay split by the precision tier whose bytes arrived
    /// (keyed by [`crate::memory::quant::QuantKind::tier_index`]) — the
    /// fig9 per-tier attribution of the tiered store.
    pub queue_delay_by_tier: HashMap<usize, u64>,
    /// Pending experts in the order they were consumed (completion order
    /// for the arrival-order drain, plan order for the serial one).
    pub consumed: Vec<usize>,
    /// Experts whose transfer failed but were served from the degradation
    /// ladder (resident copy of any tier, or a replica shard).
    pub recovered: u64,
    /// Experts dropped from the layer entirely: transfer failed and no
    /// fallback copy existed. Tiles that landed before the failure still
    /// contribute (they are exact partial sums); the missing remainder is
    /// treated as zero, AdapMoE-gating-style.
    pub dropped: Vec<usize>,
}

/// Wait accounting from [`drain_arrival_order`].
pub struct DrainStats {
    pub stall_ns: u64,
    pub queue_delay_ns: u64,
    /// Queue delay attributed to the lane each expert/tile arrived on.
    pub queue_delay_by_lane: HashMap<usize, u64>,
    /// Queue delay attributed to the precision tier each expert/tile was
    /// encoded at (key = `QuantKind::tier_index`).
    pub queue_delay_by_tier: HashMap<usize, u64>,
    /// Pending experts in consumption (arrival) order. Dropped experts
    /// are *not* listed here; `consumed.len() + dropped.len()` equals the
    /// pending count.
    pub consumed: Vec<usize>,
    /// Failed transfers served from the degradation ladder.
    pub recovered: u64,
    /// Failed transfers with no fallback copy — skipped, in failure order.
    pub dropped: Vec<usize>,
}

/// A unit of pending work handed to the consume callback, in arrival order.
pub enum Arrived<'a> {
    Full { expert: usize, weights: &'a Arc<ExpertF32> },
    Tile {
        expert: usize,
        index: usize,
        tile: &'a Arc<ExpertF32>,
    },
}

/// Host-side expert FFN: `y[r] = coef[r] * (silu(x[r]·w1) ⊙ (x[r]·w3)) · w2`.
///
/// Works for full experts (`w1 [d,f]`, `w2 [f,d]`) and f-tiles
/// (`w1 [d,w]`, `w2 [w,d]`): tile outputs sum to the full output because
/// the second matmul is linear over the f dimension. Rows with a zero
/// coefficient are skipped (their output is exactly zero).
pub fn expert_ffn_host(x: &Tensor, w: &ExpertF32, coef: &[f32]) -> Tensor {
    let (b, d) = (x.dims[0], x.dims[1]);
    let f = w.w1.dims[1];
    let d_out = w.w2.dims[1];
    debug_assert_eq!(w.w1.dims[0], d);
    debug_assert_eq!(w.w2.dims[0], f);
    let mut y = Tensor::zeros(vec![b, d_out]);
    let mut h = vec![0f32; f];
    for r in 0..b {
        let c = coef[r];
        if c == 0.0 {
            continue;
        }
        let xr = x.row(r);
        for (j, hj) in h.iter_mut().enumerate() {
            let (mut a, mut g) = (0f32, 0f32);
            for (i, &xi) in xr.iter().enumerate() {
                a += xi * w.w1.data[i * f + j];
                g += xi * w.w3.data[i * f + j];
            }
            let silu = a / (1.0 + (-a).exp());
            *hj = silu * g;
        }
        let yr = &mut y.data[r * d_out..(r + 1) * d_out];
        for (j, &hj) in h.iter().enumerate() {
            let w2_row = &w.w2.data[j * d_out..(j + 1) * d_out];
            for (yk, &wk) in yr.iter_mut().zip(w2_row) {
                *yk += hj * wk;
            }
        }
        for yk in yr.iter_mut() {
            *yk *= c;
        }
    }
    y
}

/// Expert-major batched twin of [`expert_ffn_host`]: gather the routed
/// rows (non-zero coefficient) into one packed matrix, run the SwiGLU
/// with the `f` dimension as the **outer** loop, and scatter the scaled
/// packed outputs back to their batch slots.
///
/// Why it is faster: `w1`/`w3` are `[d, f]`, so column `j` is strided by
/// `f`. The row-major nest in [`expert_ffn_host`] re-walks that strided
/// column once per routed row — `b × f` cold column walks per expert at
/// decode batch `b`. Here each column (and the contiguous `w2` row `j`)
/// is walked once and reused across every packed row while cache-hot, so
/// the weight traffic is independent of the batch size. Scratch comes
/// from the shared [`RowBufferPool`], so steady-state decode performs no
/// compute-side heap allocation.
///
/// Why the bits match: per `(row, j)` the two dot products accumulate
/// over `i` in the same ascending order, and each output element takes
/// its `h_j * w2[j][k]` contributions in the same ascending-`j` order
/// before the final per-row scale — the float-op sequence per output
/// element is identical to the row-major nest, so the result is
/// bit-for-bit equal (rust/tests/hotpath.rs locks this down).
pub fn expert_ffn_host_grouped(
    x: &Tensor,
    w: &ExpertF32,
    coef: &[f32],
    pool: &RowBufferPool,
) -> Tensor {
    let (b, d) = (x.dims[0], x.dims[1]);
    let f = w.w1.dims[1];
    let d_out = w.w2.dims[1];
    debug_assert_eq!(w.w1.dims[0], d);
    debug_assert_eq!(w.w2.dims[0], f);
    let mut y = Tensor::zeros(vec![b, d_out]);
    let rows: Vec<usize> = (0..b).filter(|&r| coef[r] != 0.0).collect();
    let m = rows.len();
    if m == 0 {
        return y;
    }
    // Gather once: pack the routed rows contiguously.
    let mut xp = pool.take(m * d);
    for (k, &r) in rows.iter().enumerate() {
        xp[k * d..(k + 1) * d].copy_from_slice(x.row(r));
    }
    let mut yp = pool.take(m * d_out);
    for j in 0..f {
        let w2_row = &w.w2.data[j * d_out..(j + 1) * d_out];
        for k in 0..m {
            let xr = &xp[k * d..(k + 1) * d];
            let (mut a, mut g) = (0f32, 0f32);
            for (i, &xi) in xr.iter().enumerate() {
                a += xi * w.w1.data[i * f + j];
                g += xi * w.w3.data[i * f + j];
            }
            let silu = a / (1.0 + (-a).exp());
            let hj = silu * g;
            let yr = &mut yp[k * d_out..(k + 1) * d_out];
            for (yk, &wk) in yr.iter_mut().zip(w2_row) {
                *yk += hj * wk;
            }
        }
    }
    // Scatter once: scale each packed row by its coefficient into place.
    for (k, &r) in rows.iter().enumerate() {
        let yr = &mut y.data[r * d_out..(r + 1) * d_out];
        for (yk, &vp) in yr.iter_mut().zip(&yp[k * d_out..(k + 1) * d_out]) {
            *yk = vp * coef[r];
        }
    }
    pool.put(xp);
    pool.put(yp);
    y
}

fn since(at: Instant) -> u64 {
    Instant::now().saturating_duration_since(at).as_nanos() as u64
}

/// Slice the f-range `[f_lo, f_hi)` out of a full expert — the layout
/// twin of `HostStore::dequantize_tile`, used by the degradation ladder
/// to re-create the missing tiles of a failed transfer from a recovered
/// resident/replica copy (w1/w3 are `[d, f]` so the tile gathers columns;
/// w2 is `[f, d]` so its rows are contiguous).
fn slice_tile(w: &ExpertF32, f_lo: usize, f_hi: usize) -> ExpertF32 {
    let d = w.w1.dims[0];
    let f = w.w1.dims[1];
    let width = f_hi - f_lo;
    let mut t1 = Vec::with_capacity(d * width);
    let mut t3 = Vec::with_capacity(d * width);
    for r in 0..d {
        t1.extend_from_slice(&w.w1.data[r * f + f_lo..r * f + f_hi]);
        t3.extend_from_slice(&w.w3.data[r * f + f_lo..r * f + f_hi]);
    }
    let d_out = w.w2.dims[1];
    let t2 = w.w2.data[f_lo * d_out..f_hi * d_out].to_vec();
    ExpertF32 {
        w1: Tensor { dims: vec![d, width], data: t1 },
        w3: Tensor { dims: vec![d, width], data: t3 },
        w2: Tensor { dims: vec![width, d_out], data: t2 },
    }
}

/// Consume `pending` transfers in arrival order: sweep the handles for
/// newly landed experts/tiles, feed each to `consume` on the calling
/// thread, promote completed experts into `cache`, and park on the
/// engine's completion board when nothing is consumable. A wait only
/// counts toward `stall_ns` when `count_wait()` is true at its start —
/// the parallel path passes a pool-idle check there so waits that
/// overlap worker compute are not misattributed as stalls. Transfers the
/// fault pump abandons are served through the degradation ladder (module
/// doc), so the drain terminates for every fault pattern.
#[allow(clippy::too_many_arguments)]
pub fn drain_arrival_order(
    layer: usize,
    pending: &[(usize, Arc<TransferHandle>)],
    mode: ScheduleMode,
    n_tiles: usize,
    cache: &dyn ExpertCache,
    xfer: &TransferEngine,
    mut consume: impl FnMut(Arrived<'_>) -> Result<()>,
    mut count_wait: impl FnMut() -> bool,
) -> Result<DrainStats> {
    let board = &xfer.completions;
    // Anything already landed is found by the first sweep; queued stale
    // events would only cause harmless extra sweeps, so drop them.
    board.clear();

    struct Pend {
        expert: usize,
        handle: Arc<TransferHandle>,
        tiles: usize,
        done: bool,
    }
    let mut pend: Vec<Pend> = pending
        .iter()
        .map(|(e, h)| Pend { expert: *e, handle: Arc::clone(h), tiles: 0, done: false })
        .collect();

    let mut stats = DrainStats {
        stall_ns: 0,
        queue_delay_ns: 0,
        queue_delay_by_lane: HashMap::new(),
        queue_delay_by_tier: HashMap::new(),
        consumed: Vec::new(),
        recovered: 0,
        dropped: Vec::new(),
    };
    // Degradation ladder, step 1 and 2: a resident copy of any tier
    // (TierMode::Degrade leaves those behind), else a replica on a
    // non-owning shard — promoted into `cache` so the next layer hits.
    let fallback_copy = |cache: &dyn ExpertCache, expert: usize| {
        let id = (layer, expert);
        cache.get(id).or_else(|| {
            xfer.sharded_cache().find_replica(id).map(|(w, m)| {
                cache.insert_tiered(id, Arc::clone(&w), m);
                w
            })
        })
    };
    let mut remaining = pend.len();
    while remaining > 0 {
        let mut progress = false;
        for p in pend.iter_mut().filter(|p| !p.done) {
            let meta = ResidentMeta { kind: p.handle.kind, bytes: p.handle.bytes };
            let tier = p.handle.kind.tier_index();
            match mode {
                ScheduleMode::ExpertWise => {
                    if let Some((wts, at)) = p.handle.try_full() {
                        let d = since(at);
                        stats.queue_delay_ns += d;
                        *stats.queue_delay_by_lane.entry(p.handle.lane).or_insert(0) += d;
                        *stats.queue_delay_by_tier.entry(tier).or_insert(0) += d;
                        consume(Arrived::Full { expert: p.expert, weights: &wts })?;
                        cache.insert_tiered((layer, p.expert), wts, meta);
                        stats.consumed.push(p.expert);
                        p.done = true;
                        remaining -= 1;
                        progress = true;
                    } else if p.handle.is_failed() {
                        let corr = crate::obs::expert_corr((layer, p.expert));
                        if let Some(wts) = fallback_copy(cache, p.expert) {
                            consume(Arrived::Full { expert: p.expert, weights: &wts })?;
                            stats.recovered += 1;
                            stats.consumed.push(p.expert);
                            crate::obs::instant(
                                crate::obs::Track::Decode,
                                crate::obs::Name::CacheDegrade,
                                corr,
                                0,
                            );
                        } else {
                            stats.dropped.push(p.expert);
                            crate::obs::instant(
                                crate::obs::Track::Decode,
                                crate::obs::Name::Fault,
                                corr,
                                0,
                            );
                        }
                        p.done = true;
                        remaining -= 1;
                        progress = true;
                    }
                }
                ScheduleMode::TileWise => {
                    while p.tiles < n_tiles {
                        let Some((tile, at)) = p.handle.try_tile(p.tiles) else {
                            break;
                        };
                        let d = since(at);
                        stats.queue_delay_ns += d;
                        *stats.queue_delay_by_lane.entry(p.handle.lane).or_insert(0) += d;
                        *stats.queue_delay_by_tier.entry(tier).or_insert(0) += d;
                        consume(Arrived::Tile {
                            expert: p.expert,
                            index: p.tiles,
                            tile: &tile,
                        })?;
                        p.tiles += 1;
                        progress = true;
                    }
                    if p.tiles == n_tiles {
                        // assemble+publish of the full expert trails the
                        // last tile by microseconds — but the fault pump
                        // can abandon the ticket in that window, so poll
                        // instead of blocking. A failure here costs only
                        // the cache promotion; every tile was consumed.
                        if let Some((wts, _)) = p.handle.try_full() {
                            cache.insert_tiered((layer, p.expert), wts, meta);
                            stats.consumed.push(p.expert);
                            p.done = true;
                            remaining -= 1;
                        } else if p.handle.is_failed() {
                            stats.consumed.push(p.expert);
                            p.done = true;
                            remaining -= 1;
                            progress = true;
                        }
                    } else if p.handle.is_failed() {
                        // Mid-expert failure: re-create the missing tiles
                        // from a fallback copy so the partial sums already
                        // dispatched stay valid, else drop the remainder.
                        let corr = crate::obs::expert_corr((layer, p.expert));
                        if let Some(full) = fallback_copy(cache, p.expert) {
                            let step = full.w1.dims[1] / n_tiles;
                            while p.tiles < n_tiles {
                                let t = p.tiles;
                                let tile =
                                    Arc::new(slice_tile(&full, t * step, (t + 1) * step));
                                consume(Arrived::Tile {
                                    expert: p.expert,
                                    index: t,
                                    tile: &tile,
                                })?;
                                p.tiles += 1;
                            }
                            stats.recovered += 1;
                            stats.consumed.push(p.expert);
                            crate::obs::instant(
                                crate::obs::Track::Decode,
                                crate::obs::Name::CacheDegrade,
                                corr,
                                0,
                            );
                        } else {
                            stats.dropped.push(p.expert);
                            crate::obs::instant(
                                crate::obs::Track::Decode,
                                crate::obs::Name::Fault,
                                corr,
                                0,
                            );
                        }
                        p.done = true;
                        remaining -= 1;
                        progress = true;
                    }
                }
            }
        }
        if remaining > 0 && !progress {
            // Drive the engine's fault machinery from the consumer side:
            // deadline timeouts, retries and failover all fire from here,
            // so a drain stuck on a dead lane unsticks itself.
            xfer.pump_faults();
            let counts = count_wait();
            let t_wait = Instant::now();
            let _ = board.wait_pop(WAIT_SLICE);
            if counts {
                stats.stall_ns += t_wait.elapsed().as_nanos() as u64;
            }
        }
    }
    Ok(stats)
}

/// Plan-order drain (the head-of-line-blocking baseline): compute ready
/// experts serially, then block on each pending transfer in queue order.
pub fn run_layer_serial(
    plan: &ExecPlan,
    x: &Tensor,
    coef: &[Vec<f32>],
    mode: ScheduleMode,
    n_tiles: usize,
    cache: &dyn ExpertCache,
) -> LayerOutcome {
    let mut acc = Tensor::zeros(x.dims.clone());
    let mut stall_ns = 0u64;
    let mut queue_delay_ns = 0u64;
    let mut queue_delay_by_lane: HashMap<usize, u64> = HashMap::new();
    let mut queue_delay_by_tier: HashMap<usize, u64> = HashMap::new();
    let mut consumed = Vec::new();

    for (e, wts) in plan.ready_items() {
        acc.add_assign(&expert_ffn_host(x, wts, &coef[e]));
    }
    for (e, handle) in plan.pending_items() {
        let meta = ResidentMeta { kind: handle.kind, bytes: handle.bytes };
        let tier = handle.kind.tier_index();
        match mode {
            ScheduleMode::ExpertWise => {
                let t_wait = Instant::now();
                let wts = handle.wait_full();
                stall_ns += t_wait.elapsed().as_nanos() as u64;
                let (_, at) = handle.try_full().expect("full just landed");
                let d = since(at);
                queue_delay_ns += d;
                *queue_delay_by_lane.entry(handle.lane).or_insert(0) += d;
                *queue_delay_by_tier.entry(tier).or_insert(0) += d;
                acc.add_assign(&expert_ffn_host(x, &wts, &coef[e]));
                cache.insert_tiered((plan.layer, e), wts, meta);
            }
            ScheduleMode::TileWise => {
                for t in 0..n_tiles {
                    let t_wait = Instant::now();
                    let tile = handle.wait_tile(t);
                    stall_ns += t_wait.elapsed().as_nanos() as u64;
                    let (_, at) = handle.try_tile(t).expect("tile just landed");
                    let d = since(at);
                    queue_delay_ns += d;
                    *queue_delay_by_lane.entry(handle.lane).or_insert(0) += d;
                    *queue_delay_by_tier.entry(tier).or_insert(0) += d;
                    acc.add_assign(&expert_ffn_host(x, &tile, &coef[e]));
                }
                let wts = handle.wait_full(); // already complete
                cache.insert_tiered((plan.layer, e), wts, meta);
            }
        }
        consumed.push(e);
    }
    LayerOutcome {
        acc,
        stall_ns,
        queue_delay_ns,
        queue_delay_by_lane,
        queue_delay_by_tier,
        consumed,
        recovered: 0,
        dropped: Vec::new(),
    }
}

/// Completion-driven drain: ready experts fan out across the pool at once;
/// pending experts/tiles are dispatched in arrival order via
/// [`drain_arrival_order`]. Returns the same bits as [`run_layer_serial`]
/// thanks to canonical-order reduction.
#[allow(clippy::too_many_arguments)]
pub fn run_layer_parallel(
    plan: &ExecPlan,
    x: &Tensor,
    coef: &[Vec<f32>],
    mode: ScheduleMode,
    n_tiles: usize,
    cache: &dyn ExpertCache,
    xfer: &TransferEngine,
    pool: &ThreadPool,
) -> LayerOutcome {
    let x = Arc::new(x.clone());

    // One result slot per compute item, in queue order; tile-wise pending
    // slots hold one sub-result per tile. Reduction walks slots (then subs)
    // in order, which is what makes the output independent of scheduling.
    let (tx, rx) = channel::<(usize, usize, Tensor)>();
    let mut slot_subs: Vec<usize> = Vec::new();
    let mut expert_slot: HashMap<usize, usize> = HashMap::new();
    let mut pending: Vec<(usize, Arc<TransferHandle>)> = Vec::new();
    // Dispatched/finished job counts: board waits while workers still
    // crunch are *overlap*, not stall — only waits with a drained pool
    // count (see count_wait below). Cell, because both the consume and
    // count_wait closures need it.
    let jobs = Cell::new(0usize);
    let done = Arc::new(AtomicUsize::new(0));

    let dispatch = |slot: usize, sub: usize, wts: Arc<ExpertF32>, c: Vec<f32>| {
        let x = Arc::clone(&x);
        let tx = tx.clone();
        let done = Arc::clone(&done);
        let bufs = Arc::clone(pool.buffers());
        pool.submit(move || {
            // Expert-major hot path: one packed gather/compute/scatter per
            // (expert, tile) job, scratch recycled through the pool's
            // shared row buffers. Bit-identical to expert_ffn_host, so the
            // canonical reduction below still matches the serial baseline.
            let y = expert_ffn_host_grouped(&x, &wts, &c, &bufs);
            let _ = tx.send((slot, sub, y));
            done.fetch_add(1, Ordering::SeqCst);
        });
        jobs.set(jobs.get() + 1);
    };

    for item in &plan.queue {
        match item {
            WorkItem::Ready { expert, weights } => {
                let slot = slot_subs.len();
                slot_subs.push(1);
                dispatch(slot, 0, Arc::clone(weights), coef[*expert].clone());
            }
            WorkItem::Pending { expert, handle } => {
                let slot = slot_subs.len();
                slot_subs.push(match mode {
                    ScheduleMode::ExpertWise => 1,
                    ScheduleMode::TileWise => n_tiles,
                });
                expert_slot.insert(*expert, slot);
                pending.push((*expert, Arc::clone(handle)));
            }
            // Extra loads are the comm stream's business: they land in the
            // cache when they land; the layer never waits on them.
            WorkItem::ExtraLoad { .. } => {}
        }
    }

    let stats = drain_arrival_order(
        plan.layer,
        &pending,
        mode,
        n_tiles,
        cache,
        xfer,
        |arrived| {
            match arrived {
                Arrived::Full { expert, weights } => {
                    dispatch(expert_slot[&expert], 0, Arc::clone(weights), coef[expert].clone());
                }
                Arrived::Tile { expert, index, tile } => {
                    dispatch(expert_slot[&expert], index, Arc::clone(tile), coef[expert].clone());
                }
            }
            Ok(())
        },
        || done.load(Ordering::SeqCst) >= jobs.get(),
    )
    .expect("dispatch consume cannot fail");

    // Gather worker results and reduce in canonical (queue, tile) order.
    drop(tx);
    let mut slots: Vec<Vec<Option<Tensor>>> =
        slot_subs.iter().map(|&n| (0..n).map(|_| None).collect()).collect();
    for _ in 0..jobs.get() {
        let (slot, sub, y) = rx.recv().expect("ffn worker died");
        slots[slot][sub] = Some(y);
    }
    let mut acc = Tensor::zeros(x.dims.clone());
    for subs in slots {
        for y in subs {
            // A None sub belongs to a dropped expert (degradation ladder
            // exhausted): its contribution is zero by construction.
            if let Some(y) = y {
                acc.add_assign(&y);
            }
        }
    }
    LayerOutcome {
        acc,
        stall_ns: stats.stall_ns,
        queue_delay_ns: stats.queue_delay_ns,
        queue_delay_by_lane: stats.queue_delay_by_lane,
        queue_delay_by_tier: stats.queue_delay_by_tier,
        consumed: stats.consumed,
        recovered: stats.recovered,
        dropped: stats.dropped,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::build_plan;
    use crate::memory::device_cache::DeviceCache;
    use crate::memory::host_store::HostStore;
    use crate::memory::platform::Platform;
    use crate::memory::quant::QuantKind;
    use crate::memory::transfer::Priority;
    use crate::testutil::{micro_config, synthetic_weights};
    use crate::util::rng::Rng;

    fn fixture(
        quant: QuantKind,
        platform: &str,
        scale: f64,
    ) -> (Arc<HostStore>, Arc<DeviceCache>, TransferEngine) {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 11);
        let store = Arc::new(HostStore::build(&cfg, &w, quant).unwrap());
        let cache = Arc::new(DeviceCache::new(vec![8, 8]));
        let xfer = TransferEngine::new(
            Arc::clone(&store),
            Arc::clone(&cache),
            Platform::preset(platform).unwrap(),
            4,
            scale,
        );
        (store, cache, xfer)
    }

    fn inputs(b: usize, n_experts: usize, seed: u64) -> (Tensor, Vec<Vec<f32>>) {
        let cfg = micro_config();
        let mut rng = Rng::new(seed);
        let x = Tensor::new(
            vec![b, cfg.d_model],
            (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
        )
        .unwrap();
        let coef: Vec<Vec<f32>> = (0..n_experts)
            .map(|_| (0..b).map(|_| rng.f32()).collect())
            .collect();
        (x, coef)
    }

    #[test]
    fn host_ffn_matches_scalar_oracle() {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 3);
        let store = HostStore::build(&cfg, &w, QuantKind::F32).unwrap();
        let e = store.dequantize((0, 0));
        let (x, _) = inputs(2, 1, 5);
        let coef = vec![0.75f32, 0.0];
        let y = expert_ffn_host(&x, &e, &coef);
        // row 1 has zero coef -> exactly zero
        assert!(y.row(1).iter().all(|&v| v == 0.0));
        // row 0: scalar oracle
        let (d, f) = (cfg.d_model, cfg.d_ff);
        let xr = x.row(0);
        let mut want = vec![0f32; d];
        let mut h = vec![0f32; f];
        for j in 0..f {
            let (mut a, mut g) = (0f32, 0f32);
            for i in 0..d {
                a += xr[i] * e.w1.data[i * f + j];
                g += xr[i] * e.w3.data[i * f + j];
            }
            h[j] = (a / (1.0 + (-a).exp())) * g;
        }
        for (j, &hj) in h.iter().enumerate() {
            for k in 0..d {
                want[k] += hj * e.w2.data[j * d + k];
            }
        }
        for (k, &got) in y.row(0).iter().enumerate() {
            let exp = 0.75 * want[k];
            assert!((got - exp).abs() < 1e-5, "k={k}: {got} vs {exp}");
        }
    }

    #[test]
    fn grouped_ffn_matches_row_major_bits() {
        // The expert-major packed nest must reproduce the row-major
        // baseline bit-for-bit — including zero-coefficient rows (exactly
        // zero) and the all-skipped case — while returning its scratch to
        // the pool.
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 3);
        let store = HostStore::build(&cfg, &w, QuantKind::F32).unwrap();
        let e = store.dequantize((0, 1));
        let (x, _) = inputs(4, 1, 19);
        let pool = crate::util::threadpool::RowBufferPool::new();
        for coef in [
            vec![0.75f32, 0.0, 1.25, 0.5],
            vec![0.0f32, 0.0, 0.0, 0.0],
            vec![1.0f32, 1.0, 1.0, 1.0],
        ] {
            let want = expert_ffn_host(&x, &e, &coef);
            let got = expert_ffn_host_grouped(&x, &e, &coef, &pool);
            assert_eq!(want.data, got.data, "coef={coef:?}");
        }
        // gather + accumulate buffers parked for reuse
        assert!(pool.parked() >= 2);
    }

    #[test]
    fn tile_outputs_sum_close_to_full() {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 4);
        let store = HostStore::build(&cfg, &w, QuantKind::F32).unwrap();
        let full = store.dequantize((1, 2));
        let (x, _) = inputs(2, 1, 6);
        let coef = vec![1.0f32, 0.5];
        let want = expert_ffn_host(&x, &full, &coef);
        let step = cfg.d_ff / 4;
        let mut got = Tensor::zeros(x.dims.clone());
        for t in 0..4 {
            let tile = store.dequantize_tile((1, 2), t * step, (t + 1) * step);
            got.add_assign(&expert_ffn_host(&x, &tile, &coef));
        }
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn parallel_reduction_matches_serial_bit_for_bit() {
        // All-ready layer: fan-out across the pool must reproduce the
        // serial accumulation exactly (canonical-order reduction).
        let (store, cache, xfer) = fixture(QuantKind::F32, "instant", 0.0);
        for e in 0..6 {
            cache.insert((0, e), Arc::new(store.dequantize((0, e))));
        }
        let computes: Vec<usize> = (0..6).collect();
        let (x, coef) = inputs(4, 8, 7);
        let pool = ThreadPool::new(4);

        let plan_a = build_plan(0, &computes, &[], &cache, &xfer);
        let serial = run_layer_serial(&plan_a, &x, &coef, ScheduleMode::ExpertWise, 4, &cache);
        let plan_b = build_plan(0, &computes, &[], &cache, &xfer);
        let par = run_layer_parallel(
            &plan_b,
            &x,
            &coef,
            ScheduleMode::ExpertWise,
            4,
            &cache,
            &xfer,
            &pool,
        );
        assert_eq!(serial.acc.data, par.acc.data, "partial-sum reduction must be exact");
        assert_eq!(serial.stall_ns, 0);
        assert_eq!(par.stall_ns, 0);
    }

    #[test]
    fn out_of_order_completion_is_consumed_in_arrival_order() {
        // Transfers are enqueued so they ARRIVE in the order 2, 1, 0 while
        // the plan lists them 0, 1, 2. The serial drain head-of-line blocks
        // on expert 0 (the last to arrive) and accrues large queue delay on
        // 1 and 2; the completion-driven drain consumes 2, 1, 0 as they
        // land with (near-)zero queue delay.
        let serial_out = {
            let (_store, cache, xfer) = fixture(QuantKind::Int4, "rtx4090", 1.0);
            for e in [2usize, 1, 0] {
                xfer.request((0, e), Priority::Prefetch);
            }
            let plan = build_plan(0, &[0, 1, 2], &[], &cache, &xfer);
            assert_eq!(plan.n_pending(), 3, "prefetches still in flight must be joined");
            let (x, coef) = inputs(4, 8, 9);
            run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &cache)
        };
        let par_out = {
            let (_store, cache, xfer) = fixture(QuantKind::Int4, "rtx4090", 1.0);
            for e in [2usize, 1, 0] {
                xfer.request((0, e), Priority::Prefetch);
            }
            let plan = build_plan(0, &[0, 1, 2], &[], &cache, &xfer);
            assert_eq!(plan.n_pending(), 3);
            let (x, coef) = inputs(4, 8, 9);
            let pool = ThreadPool::new(3);
            run_layer_parallel(
                &plan,
                &x,
                &coef,
                ScheduleMode::ExpertWise,
                4,
                &cache,
                &xfer,
                &pool,
            )
        };

        assert_eq!(serial_out.consumed, vec![0, 1, 2], "serial drains in plan order");
        assert_eq!(par_out.consumed, vec![2, 1, 0], "executor must follow arrival order");
        // Same bits despite opposite consumption order.
        assert_eq!(serial_out.acc.data, par_out.acc.data);
        // Serial leaves experts 1 and 2 parked behind expert 0 (several ms
        // of simulated wire time each); arrival-order consumption adds no
        // such queueing.
        assert!(
            par_out.queue_delay_ns < serial_out.queue_delay_ns / 2,
            "arrival-order queue delay {} should be far below serial {}",
            par_out.queue_delay_ns,
            serial_out.queue_delay_ns
        );
    }

    #[test]
    fn tile_wise_parallel_matches_serial_bits() {
        let serial_out = {
            let (_store, cache, xfer) = fixture(QuantKind::F32, "instant", 0.0);
            let plan = build_plan(1, &[3, 4], &[], &cache, &xfer);
            let (x, coef) = inputs(2, 8, 13);
            run_layer_serial(&plan, &x, &coef, ScheduleMode::TileWise, 4, &cache)
        };
        let par_out = {
            let (_store, cache, xfer) = fixture(QuantKind::F32, "instant", 0.0);
            let plan = build_plan(1, &[3, 4], &[], &cache, &xfer);
            let (x, coef) = inputs(2, 8, 13);
            let pool = ThreadPool::new(2);
            run_layer_parallel(
                &plan,
                &x,
                &coef,
                ScheduleMode::TileWise,
                4,
                &cache,
                &xfer,
                &pool,
            )
        };
        assert_eq!(serial_out.acc.data, par_out.acc.data);
        // both drains promote consumed experts into the cache
        assert_eq!(serial_out.consumed.len(), 2);
        assert_eq!(par_out.consumed.len(), 2);
    }

    #[test]
    fn shared_drain_reports_kernel_style_consume() {
        // drain_arrival_order with an inline (engine-style) consume
        // callback: accumulate per-expert partials, reduce in plan order.
        let (_store, cache, xfer) = fixture(QuantKind::F32, "instant", 0.0);
        let plan = build_plan(0, &[1, 2], &[], &cache, &xfer);
        let (x, coef) = inputs(2, 8, 17);
        let pending: Vec<(usize, Arc<TransferHandle>)> = plan
            .pending_items()
            .map(|(e, h)| (e, Arc::clone(h)))
            .collect();
        let mut parts: HashMap<usize, Tensor> = pending
            .iter()
            .map(|(e, _)| (*e, Tensor::zeros(x.dims.clone())))
            .collect();
        let stats = drain_arrival_order(
            0,
            &pending,
            ScheduleMode::ExpertWise,
            4,
            &cache,
            &xfer,
            |arrived| {
                if let Arrived::Full { expert, weights } = arrived {
                    let y = expert_ffn_host(&x, weights, &coef[expert]);
                    parts.get_mut(&expert).unwrap().add_assign(&y);
                }
                Ok(())
            },
            || true,
        )
        .unwrap();
        assert_eq!(stats.consumed.len(), 2);
        assert!(cache.contains((0, 1)) && cache.contains((0, 2)));
        let mut acc = Tensor::zeros(x.dims.clone());
        for (e, _) in &pending {
            acc.add_assign(&parts[e]);
        }
        // must equal the serial plan-order result bit-for-bit
        let (_store2, cache2, xfer2) = fixture(QuantKind::F32, "instant", 0.0);
        let plan2 = build_plan(0, &[1, 2], &[], &cache2, &xfer2);
        let serial = run_layer_serial(&plan2, &x, &coef, ScheduleMode::ExpertWise, 4, &cache2);
        assert_eq!(acc.data, serial.acc.data);
    }
}
