//! The paper's core signal — per-layer Fisher sensitivity — as one shared
//! data structure every resource decision reads (docs/sensitivity.md).
//!
//! AdapMoE derives sensitivity offline (eq. 6–7) and uses it to gate
//! expert *count*. ROADMAP's "sensitivity-driven resource unification"
//! extends it to the other three resource axes, EdgeMoE-style
//! (importance → bit width, PAPERS.md):
//!
//! 1. **Tier assignment** — per-layer importance floors the precision
//!    tier a non-urgent transfer rides
//!    ([`SensitivityMap::tier_for`], consumed by
//!    `crate::memory::transfer::TransferEngine::request_with_slack`).
//! 2. **Cache planning** — importance prices each layer's DP slots at
//!    its observed resident-tier byte mix
//!    (`crate::coordinator::cache_plan::plan_bytes_tiered`).
//! 3. **Eviction / prefetch priority** — importance weights LRU victim
//!    selection ([`SensitivityMap::eviction_weights`], consumed by
//!    `crate::memory::device_cache::DeviceCache`) and re-ranks prefetch
//!    request order (`crate::coordinator::prefetch::prioritize`).
//! 4. **Upgrade scheduling** — a per-lane EWMA of inter-completion gaps
//!    ([`LaneIdlePredictor`]) replaces the `pending == 0` heuristic for
//!    background precision upgrades, and importance orders which layers
//!    upgrade first ([`SensitivityMap::upgrade_order`]).
//!
//! **Determinism contract:** the [`SensitivityPolicy::Uniform`] map is
//! the identity everywhere — every consumer reproduces the historical
//! decision bit-for-bit (rust/tests/sensitivity.rs locks this down), so
//! the default engine shape is unchanged.

use std::time::Instant;

use crate::coordinator::profile::Profile;
use crate::memory::quant::QuantKind;
use crate::memory::transfer::LaneSnapshot;

/// Which sensitivity signal the map carries (`--sensitivity-policy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SensitivityPolicy {
    /// Every layer equally important — the historical behaviour, bit-for-
    /// bit (the map is the identity for all four consumers).
    Uniform,
    /// Per-layer importance from the offline profile's Fisher
    /// sensitivities (paper eq. 6–7), normalized to (0, 1].
    Profile,
}

impl SensitivityPolicy {
    pub fn from_name(name: &str) -> Option<SensitivityPolicy> {
        match name {
            "uniform" => Some(SensitivityPolicy::Uniform),
            "profile" => Some(SensitivityPolicy::Profile),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SensitivityPolicy::Uniform => "uniform",
            SensitivityPolicy::Profile => "profile",
        }
    }

    pub fn names() -> &'static [&'static str] {
        &["uniform", "profile"]
    }
}

/// Per-layer importance in (0, 1], shared (behind one `Arc`) by the tier
/// selector, the cache planner, the eviction/prefetch paths and the
/// upgrade scheduler — the "one profile, four consumers" refactor.
#[derive(Clone, Debug, PartialEq)]
pub struct SensitivityMap {
    policy: SensitivityPolicy,
    /// Normalized per-layer importance; empty for the uniform map (every
    /// accessor then degenerates to the identity).
    importance: Vec<f64>,
}

impl SensitivityMap {
    /// The identity map: every consumer behaves exactly as before.
    pub fn uniform(n_layers: usize) -> SensitivityMap {
        SensitivityMap {
            policy: SensitivityPolicy::Uniform,
            importance: vec![1.0; n_layers],
        }
    }

    /// Build from the offline profile. `Uniform` ignores the profile;
    /// `Profile` normalizes the Fisher sensitivities by their max so the
    /// most sensitive layer has importance exactly 1.0. A degenerate
    /// profile (empty or non-positive sensitivities) falls back to the
    /// uniform map rather than inventing a signal.
    pub fn from_profile(profile: &Profile, policy: SensitivityPolicy) -> SensitivityMap {
        let n = profile.sensitivity.len();
        if policy == SensitivityPolicy::Uniform {
            return Self::uniform(n);
        }
        let max = profile.sensitivity.iter().copied().fold(0.0f64, f64::max);
        if n == 0 || !max.is_finite() || max <= 0.0 {
            return Self::uniform(n);
        }
        let importance = profile
            .sensitivity
            .iter()
            .map(|&s| (s / max).clamp(0.0, 1.0))
            .collect();
        SensitivityMap { policy: SensitivityPolicy::Profile, importance }
    }

    pub fn policy(&self) -> SensitivityPolicy {
        self.policy
    }

    pub fn n_layers(&self) -> usize {
        self.importance.len()
    }

    /// True for the identity map — consumers take their historical,
    /// bit-for-bit-unchanged path.
    pub fn is_uniform(&self) -> bool {
        self.policy == SensitivityPolicy::Uniform
    }

    /// Normalized importance of one layer (1.0 for the uniform map and
    /// for layers beyond the profile, so unknown layers are treated as
    /// maximally sensitive — the conservative default).
    pub fn importance(&self, layer: usize) -> f64 {
        if self.is_uniform() {
            return 1.0;
        }
        self.importance.get(layer).copied().unwrap_or(1.0)
    }

    /// Offline importance → bit-width assignment (EdgeMoE, PAPERS.md):
    /// the precision tier a layer's experts should at least ride.
    /// Monotone in importance: a more important layer never maps to a
    /// lower tier (property-tested in rust/tests/sensitivity.rs). The
    /// uniform map pins the top tier, which as a *floor* is inert — the
    /// engine only consults it under the `Profile` policy.
    pub fn tier_for(&self, layer: usize, tiers: &[QuantKind]) -> QuantKind {
        let hi = tiers.len() - 1;
        let w = self.importance(layer).clamp(0.0, 1.0);
        tiers[((w * hi as f64).round() as usize).min(hi)]
    }

    /// Per-layer tier assignment table (offline store construction and
    /// the docs' worked examples).
    pub fn tier_assignments(&self, tiers: &[QuantKind]) -> Vec<QuantKind> {
        (0..self.n_layers().max(1)).map(|l| self.tier_for(l, tiers)).collect()
    }

    /// Prefetch slack for an expert with normalized predicted probability
    /// `p`. Uniform: exactly the historical `1.0 - p`. Profile: floored
    /// at the layer's importance, so a sensitive layer's prefetches keep
    /// riding a high-precision tier even when the router is near-certain.
    pub fn prefetch_slack(&self, layer: usize, p: f64) -> f64 {
        let base = 1.0 - p;
        if self.is_uniform() {
            return base;
        }
        base.max(self.importance(layer))
    }

    /// Per-layer eviction weights for the caches, or `None` for the
    /// uniform map (caches then keep exact LRU).
    pub fn eviction_weights(&self) -> Option<Vec<f64>> {
        if self.is_uniform() {
            None
        } else {
            Some(self.importance.clone())
        }
    }

    /// Layer visit order for background upgrades: uniform keeps the
    /// historical `0..n` sweep; profile visits the most sensitive layers
    /// first (stable on ties, so equal-importance layers keep index
    /// order and the schedule stays deterministic).
    pub fn upgrade_order(&self, n_layers: usize) -> Vec<usize> {
        let mut order: Vec<usize> = (0..n_layers).collect();
        if !self.is_uniform() {
            order.sort_by(|&a, &b| {
                self.importance(b)
                    .partial_cmp(&self.importance(a))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
        }
        order
    }
}

/// Per-lane EWMA of inter-completion gaps — the upgrade scheduler's
/// idle-time predictor. The historical heuristic (`pending == 0`) fires
/// the moment the queues drain, even mid-burst between two waves of
/// on-demand loads; the predictor instead waits until a lane has been
/// quiet for at least its *typical* completion gap, so upgrades land in
/// genuinely idle windows (consumer 4, docs/sensitivity.md).
#[derive(Debug, Default)]
pub struct LaneIdlePredictor {
    lanes: Vec<LaneTrack>,
    /// EWMA smoothing factor for the gap estimate.
    alpha: f64,
}

#[derive(Debug, Clone, Copy)]
struct LaneTrack {
    /// Cumulative transfer count at the last observation.
    transfers: u64,
    /// When the last completion delta was observed.
    last_completion: Option<Instant>,
    /// Smoothed inter-completion gap (seconds); 0 until two deltas seen.
    ewma_gap: f64,
}

impl LaneIdlePredictor {
    pub fn new() -> LaneIdlePredictor {
        LaneIdlePredictor { lanes: Vec::new(), alpha: 0.3 }
    }

    /// Feed one per-lane snapshot set; call once per engine step.
    pub fn observe(&mut self, snaps: &[LaneSnapshot]) {
        self.observe_at(snaps, Instant::now());
    }

    fn observe_at(&mut self, snaps: &[LaneSnapshot], now: Instant) {
        if self.lanes.len() < snaps.len() {
            self.lanes.resize(
                snaps.len(),
                LaneTrack { transfers: 0, last_completion: None, ewma_gap: 0.0 },
            );
        }
        for s in snaps {
            let t = &mut self.lanes[s.lane];
            if s.transfers > t.transfers {
                if let Some(prev) = t.last_completion {
                    let gap = now.duration_since(prev).as_secs_f64();
                    t.ewma_gap = if t.ewma_gap == 0.0 {
                        gap
                    } else {
                        self.alpha * gap + (1.0 - self.alpha) * t.ewma_gap
                    };
                }
                t.last_completion = Some(now);
            }
            t.transfers = s.transfers;
        }
    }

    /// True when every lane looks idle *and likely to stay idle*: no
    /// queued jobs, and quiet for at least its smoothed completion gap.
    /// A lane that has never completed anything (or has no gap estimate
    /// yet) counts as idle when its queue is empty — the predictor must
    /// not wedge upgrades shut on a cold start.
    pub fn predicted_idle(&self, snaps: &[LaneSnapshot]) -> bool {
        self.predicted_idle_at(snaps, Instant::now())
    }

    fn predicted_idle_at(&self, snaps: &[LaneSnapshot], now: Instant) -> bool {
        snaps.iter().all(|s| {
            if s.queued_jobs > 0 {
                return false;
            }
            match self.lanes.get(s.lane) {
                Some(t) if t.ewma_gap > 0.0 => match t.last_completion {
                    Some(prev) => {
                        now.duration_since(prev).as_secs_f64() >= t.ewma_gap
                    }
                    None => true,
                },
                _ => true,
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    use crate::memory::transfer::LaneHealth;

    fn snap(lane: usize, transfers: u64, queued_jobs: u64) -> LaneSnapshot {
        LaneSnapshot {
            lane,
            transfers,
            bytes: 0,
            on_demand: 0,
            prefetch: 0,
            upgrades: 0,
            busy_ms: 0.0,
            queued_bytes: 0,
            queued_jobs,
            health: LaneHealth::Healthy,
            retries: 0,
            timeouts: 0,
            failovers: 0,
        }
    }

    #[test]
    fn uniform_map_is_the_identity_everywhere() {
        let m = SensitivityMap::uniform(4);
        assert!(m.is_uniform());
        let tiers = [QuantKind::Int2, QuantKind::Int4, QuantKind::Int8];
        for l in 0..6 {
            assert_eq!(m.importance(l), 1.0);
            assert_eq!(m.tier_for(l, &tiers), QuantKind::Int8);
        }
        // prefetch slack is exactly the historical 1 - p
        for p in [0.0, 0.25, 0.9, 1.0] {
            assert_eq!(m.prefetch_slack(2, p), 1.0 - p);
        }
        assert!(m.eviction_weights().is_none());
        assert_eq!(m.upgrade_order(4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn profile_map_normalizes_and_orders_by_importance() {
        let p = Profile::synthetic(4); // strictly decreasing sensitivity
        let m = SensitivityMap::from_profile(&p, SensitivityPolicy::Profile);
        assert!(!m.is_uniform());
        assert_eq!(m.importance(0), 1.0, "max-sensitivity layer normalizes to 1");
        for l in 1..4 {
            assert!(m.importance(l) < m.importance(l - 1));
        }
        // out-of-profile layers default conservative
        assert_eq!(m.importance(99), 1.0);
        assert_eq!(m.upgrade_order(4), vec![0, 1, 2, 3]); // already descending
        // an inverted profile reverses the order
        let inv = Profile {
            sensitivity: vec![0.1, 0.2, 0.4, 0.8],
            ..Profile::synthetic(4)
        };
        let mi = SensitivityMap::from_profile(&inv, SensitivityPolicy::Profile);
        assert_eq!(mi.upgrade_order(4), vec![3, 2, 1, 0]);
        assert_eq!(mi.eviction_weights().unwrap().len(), 4);
    }

    #[test]
    fn degenerate_profiles_fall_back_to_uniform() {
        let empty = Profile { sensitivity: vec![], ..Profile::synthetic(0) };
        assert!(SensitivityMap::from_profile(&empty, SensitivityPolicy::Profile)
            .is_uniform());
        let zeros = Profile { sensitivity: vec![0.0; 3], ..Profile::synthetic(3) };
        assert!(SensitivityMap::from_profile(&zeros, SensitivityPolicy::Profile)
            .is_uniform());
    }

    #[test]
    fn tier_for_is_monotone_and_slack_floors_at_importance() {
        let p = Profile::synthetic(6);
        let m = SensitivityMap::from_profile(&p, SensitivityPolicy::Profile);
        let tiers = [QuantKind::Int2, QuantKind::Int4, QuantKind::Int8];
        for l in 1..6 {
            assert!(
                m.tier_for(l, &tiers).bits() <= m.tier_for(l - 1, &tiers).bits(),
                "layer {l} outranks the more sensitive layer {}",
                l - 1
            );
        }
        // near-certain prefetch on the most sensitive layer keeps full slack
        assert_eq!(m.prefetch_slack(0, 0.99), 1.0);
        // on a low-importance layer the historical signal dominates
        let w5 = m.importance(5);
        assert_eq!(m.prefetch_slack(5, 0.1), (1.0f64 - 0.1).max(w5));
    }

    #[test]
    fn policy_names_roundtrip() {
        for name in SensitivityPolicy::names() {
            assert_eq!(SensitivityPolicy::from_name(name).unwrap().name(), *name);
        }
        assert!(SensitivityPolicy::from_name("psychic").is_none());
    }

    #[test]
    fn idle_predictor_learns_gaps_and_gates_on_them() {
        let mut p = LaneIdlePredictor::new();
        let t0 = Instant::now();
        // cold start: empty queues predict idle
        assert!(p.predicted_idle_at(&[snap(0, 0, 0)], t0));
        // a queued job is never idle
        assert!(!p.predicted_idle_at(&[snap(0, 0, 3)], t0));
        // two completions 100ms apart establish a gap estimate
        p.observe_at(&[snap(0, 1, 0)], t0);
        p.observe_at(&[snap(0, 2, 0)], t0 + Duration::from_millis(100));
        // 10ms after the last completion: too soon to call it idle
        assert!(!p.predicted_idle_at(
            &[snap(0, 2, 0)],
            t0 + Duration::from_millis(110)
        ));
        // 150ms after: quiet past the learned gap — idle
        assert!(p.predicted_idle_at(
            &[snap(0, 2, 0)],
            t0 + Duration::from_millis(250)
        ));
        // a second lane with queued work blocks the verdict
        p.observe_at(&[snap(0, 2, 0), snap(1, 1, 0)], t0 + Duration::from_millis(300));
        assert!(!p.predicted_idle_at(
            &[snap(0, 2, 0), snap(1, 1, 2)],
            t0 + Duration::from_secs(10)
        ));
    }

    #[test]
    fn ewma_smooths_toward_recent_gaps() {
        let mut p = LaneIdlePredictor::new();
        let t0 = Instant::now();
        p.observe_at(&[snap(0, 1, 0)], t0);
        p.observe_at(&[snap(0, 2, 0)], t0 + Duration::from_millis(100));
        let g1 = p.lanes[0].ewma_gap;
        assert!((g1 - 0.1).abs() < 1e-9, "first gap seeds the estimate: {g1}");
        p.observe_at(&[snap(0, 3, 0)], t0 + Duration::from_millis(400));
        let g2 = p.lanes[0].ewma_gap;
        // alpha 0.3 over (0.3s, 0.1s) → 0.16s
        assert!((g2 - 0.16).abs() < 1e-9, "{g2}");
    }
}
