//! Offline profile (artifacts/profile.json) — the output of the paper's
//! offline phase: Fisher sensitivities, calibrated gating threshold, and
//! the α/β priors that seed the DP cache planner before online traces
//! accumulate.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct Profile {
    /// Σ diag(F_i) per layer (paper eq. 6–7).
    pub sensitivity: Vec<f64>,
    /// Calibrated threshold T for the target single-expert ratio.
    pub threshold: f64,
    pub target_single_ratio: f64,
    /// Offline single-expert probability per layer (α_i prior).
    pub alpha: Vec<f64>,
    /// Offline prefetch accuracy per layer (β_i prior).
    pub beta: Vec<f64>,
    /// Cross-layer activation similarity (Fig. 3 reference series).
    pub similarity: Vec<f64>,
}

impl Profile {
    pub fn load(dir: &Path) -> Result<Profile> {
        let text = std::fs::read_to_string(dir.join("profile.json"))
            .with_context(|| format!("reading profile.json in {}", dir.display()))?;
        Self::from_json(&Json::parse(&text).context("parsing profile.json")?)
    }

    pub fn from_json(j: &Json) -> Result<Profile> {
        let vec = |k: &str| -> Result<Vec<f64>> {
            j.get(k)
                .and_then(|v| v.as_f64_vec())
                .with_context(|| format!("profile missing '{k}'"))
        };
        Ok(Profile {
            sensitivity: vec("sensitivity")?,
            threshold: j
                .get("threshold")
                .and_then(|v| v.as_f64())
                .context("profile missing 'threshold'")?,
            target_single_ratio: j
                .get("target_single_ratio")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.24),
            alpha: vec("alpha")?,
            beta: vec("beta")?,
            similarity: vec("similarity").unwrap_or_default(),
        })
    }

    /// Flat profile for tests / runs without artifacts.
    pub fn synthetic(n_layers: usize) -> Profile {
        Profile {
            sensitivity: (0..n_layers).map(|i| 1.0 / (1.0 + i as f64)).collect(),
            threshold: 0.05,
            target_single_ratio: 0.24,
            alpha: vec![0.24; n_layers],
            beta: vec![0.7; n_layers],
            similarity: vec![0.9; n_layers.saturating_sub(1)],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_profile_json() {
        let j = Json::parse(
            r#"{"sensitivity":[2.0,1.0],"threshold":0.1,
                "target_single_ratio":0.24,
                "alpha":[0.2,0.3],"beta":[0.6,0.8],"similarity":[0.9]}"#,
        )
        .unwrap();
        let p = Profile::from_json(&j).unwrap();
        assert_eq!(p.sensitivity, vec![2.0, 1.0]);
        assert_eq!(p.beta[1], 0.8);
        assert_eq!(p.similarity, vec![0.9]);
    }

    #[test]
    fn missing_fields_error() {
        let j = Json::parse(r#"{"threshold": 0.1}"#).unwrap();
        assert!(Profile::from_json(&j).is_err());
    }
}
