//! Tiny CLI argument parser — first-party stand-in for `clap`.
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments. Typed getters with defaults keep call sites short.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.bools.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key) || self.flags.contains_key(key)
    }

    /// Comma-separated list: `--sizes 8,16,32`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            Some(v) => v
                .split(',')
                .filter_map(|p| p.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn flag_value_pairs() {
        let a = parse(&["--name", "x", "--n=5", "pos1"]);
        assert_eq!(a.get("name"), Some("x"));
        assert_eq!(a.usize_or("n", 0), 5);
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn boolean_flags() {
        let a = parse(&["--verbose", "--x", "1"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("x"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse(&["--a", "1", "--fast"]);
        assert!(a.flag("fast"));
        assert_eq!(a.usize_or("a", 0), 1);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.str_or("m", "d"), "d");
        assert_eq!(a.f64_or("f", 2.5), 2.5);
        assert_eq!(a.usize_list_or("l", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn lists() {
        let a = parse(&["--sizes", "8,16,32"]);
        assert_eq!(a.usize_list_or("sizes", &[]), vec![8, 16, 32]);
    }
}
