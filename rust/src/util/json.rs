//! Minimal JSON parser/serializer — first-party stand-in for `serde_json`.
//!
//! Parses the build-time artifacts (`manifest.json`, `profile.json`) and
//! serializes metrics / server responses. Supports the full JSON grammar
//! except `\u` surrogate pairs beyond the BMP (not needed by our files).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- constructors -------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // -- accessors -----------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `a.b.c` path access.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|j| j.as_f64()).collect()
    }

    // -- parse ---------------------------------------------------------------
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let bytes = s.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- serialize -----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path("a").unwrap().as_arr().unwrap()[2].path("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"x":true,"y":null},"s":"v"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn f64_vec_helper() {
        let j = Json::parse("[0.5, 1, 2]").unwrap();
        assert_eq!(j.as_f64_vec().unwrap(), vec![0.5, 1.0, 2.0]);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
