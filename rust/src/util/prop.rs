//! Mini property-testing harness — first-party stand-in for `proptest`.
//!
//! `check(name, cases, |rng| ...)` runs the closure against `cases`
//! independently-seeded RNGs; on failure it reports the failing seed so the
//! case can be replayed deterministically with `replay(seed, f)`.
//! Coordinator invariants (routing, batching, cache state) are tested with
//! this throughout `coordinator/`.
//!
//! Setting `TEST_SEED` (decimal or `0x`-hex) pins every property to that
//! single seed — paste the seed from a failure report to replay it under
//! the normal `cargo test` invocation. Ad-hoc randomized tests should draw
//! their RNG from [`rng_for`] so they honor the same variable and print
//! their seed when they fail.

use crate::util::rng::Rng;

/// Result of a single property case.
pub type CaseResult = Result<(), String>;

/// Parse a seed string: decimal or `0x`-prefixed hex.
fn parse_seed(raw: &str) -> Option<u64> {
    let raw = raw.trim();
    if let Some(hex) = raw.strip_prefix("0x").or_else(|| raw.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        raw.parse().ok()
    }
}

/// Read the `TEST_SEED` env var (decimal or `0x`-prefixed hex), if set.
pub fn env_seed() -> Option<u64> {
    let raw = std::env::var("TEST_SEED").ok()?;
    match parse_seed(&raw) {
        Some(s) => Some(s),
        None => panic!("TEST_SEED={raw:?} is not a decimal or 0x-hex u64"),
    }
}

/// RNG for ad-hoc randomized tests: uses `TEST_SEED` when set (else
/// `default_seed`) and prints the choice so a failing test's log always
/// carries the seed needed to reproduce it.
pub fn rng_for(name: &str, default_seed: u64) -> Rng {
    let (seed, src) = match env_seed() {
        Some(s) => (s, "TEST_SEED"),
        None => (default_seed, "default"),
    };
    println!("test '{name}' rng seed {seed:#x} ({src}); replay with TEST_SEED={seed:#x}");
    Rng::new(seed)
}

/// Run `f` against `cases` seeds; panic with the first failing seed + message.
/// With `TEST_SEED` set, runs only that seed (single replay case).
pub fn check<F: FnMut(&mut Rng) -> CaseResult>(name: &str, cases: u64, mut f: F) {
    if let Some(seed) = env_seed() {
        println!("property '{name}': TEST_SEED set, replaying single seed {seed:#x}");
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed on replay (seed {seed:#x}): {msg}");
        }
        return;
    }
    for case in 0..cases {
        let seed = 0x5EED_0000u64 ^ (case.wrapping_mul(0x9E37_79B9)) ^ case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 replay with TEST_SEED={seed:#x} or util::prop::replay({seed:#x}, f)"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: FnMut(&mut Rng) -> CaseResult>(seed: u64, mut f: F) -> CaseResult {
    let mut rng = Rng::new(seed);
    f(&mut rng)
}

/// Assert helper producing `CaseResult`s inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Random f32 vector with entries in [-scale, scale).
pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
}

/// Random probability simplex of dimension n (Dirichlet-ish via exp).
pub fn simplex(rng: &mut Rng, n: usize) -> Vec<f32> {
    let raw: Vec<f32> = (0..n).map(|_| rng.exp(1.0) as f32 + 1e-6).collect();
    let sum: f32 = raw.iter().sum();
    raw.iter().map(|x| x / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("trivial", 50, |rng| {
            let v = rng.f64();
            prop_assert!((0.0..1.0).contains(&v), "out of range: {v}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn replay_reproduces() {
        // replay() with the reported seed must see the same stream the
        // failing case saw.
        let seed = 0x5EED_0000u64 ^ 0x9E37_79B9 ^ 1; // case 1's derived seed
        let mut seen = 0u64;
        let _ = replay(seed, |rng| {
            seen = rng.next_u64();
            Ok(())
        });
        let mut again = 0u64;
        let _ = replay(seed, |rng| {
            again = rng.next_u64();
            Ok(())
        });
        assert_eq!(seen, again);
    }

    #[test]
    fn seed_strings_parse_decimal_and_hex() {
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed(" 0x5eed0000 "), Some(0x5EED_0000));
        assert_eq!(parse_seed("0XFF"), Some(255));
        assert_eq!(parse_seed("nope"), None);
        assert_eq!(parse_seed("0x"), None);
    }

    #[test]
    fn rng_for_default_seed_is_deterministic() {
        // without TEST_SEED both draws must match; with it set (a manual
        // replay run) they still match each other, just on that seed.
        let a = rng_for("determinism-check", 99).next_u64();
        let b = rng_for("determinism-check", 99).next_u64();
        assert_eq!(a, b);
    }

    #[test]
    fn simplex_sums_to_one() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let s = simplex(&mut rng, 8);
            let sum: f32 = s.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(s.iter().all(|&p| p > 0.0));
        }
    }
}
