//! Mini property-testing harness — first-party stand-in for `proptest`.
//!
//! `check(name, cases, |rng| ...)` runs the closure against `cases`
//! independently-seeded RNGs; on failure it reports the failing seed so the
//! case can be replayed deterministically with `replay(seed, f)`.
//! Coordinator invariants (routing, batching, cache state) are tested with
//! this throughout `coordinator/`.

use crate::util::rng::Rng;

/// Result of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `f` against `cases` seeds; panic with the first failing seed + message.
pub fn check<F: FnMut(&mut Rng) -> CaseResult>(name: &str, cases: u64, mut f: F) {
    for case in 0..cases {
        let seed = 0x5EED_0000u64 ^ (case.wrapping_mul(0x9E37_79B9)) ^ case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 replay with util::prop::replay({seed:#x}, f)"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: FnMut(&mut Rng) -> CaseResult>(seed: u64, mut f: F) -> CaseResult {
    let mut rng = Rng::new(seed);
    f(&mut rng)
}

/// Assert helper producing `CaseResult`s inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Random f32 vector with entries in [-scale, scale).
pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
}

/// Random probability simplex of dimension n (Dirichlet-ish via exp).
pub fn simplex(rng: &mut Rng, n: usize) -> Vec<f32> {
    let raw: Vec<f32> = (0..n).map(|_| rng.exp(1.0) as f32 + 1e-6).collect();
    let sum: f32 = raw.iter().sum();
    raw.iter().map(|x| x / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("trivial", 50, |rng| {
            let v = rng.f64();
            prop_assert!((0.0..1.0).contains(&v), "out of range: {v}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn replay_reproduces() {
        // replay() with the reported seed must see the same stream the
        // failing case saw.
        let seed = 0x5EED_0000u64 ^ 0x9E37_79B9 ^ 1; // case 1's derived seed
        let mut seen = 0u64;
        let _ = replay(seed, |rng| {
            seen = rng.next_u64();
            Ok(())
        });
        let mut again = 0u64;
        let _ = replay(seed, |rng| {
            again = rng.next_u64();
            Ok(())
        });
        assert_eq!(seen, again);
    }

    #[test]
    fn simplex_sums_to_one() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let s = simplex(&mut rng, 8);
            let sum: f32 = s.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(s.iter().all(|&p| p > 0.0));
        }
    }
}
