//! Deterministic PRNG (xoshiro256**) — first-party stand-in for `rand`.
//!
//! Everything stochastic in the system (workload generation, sampling,
//! property tests, cache simulations) goes through this so runs are
//! reproducible from a seed.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }

    /// Exponentially-distributed inter-arrival gap with the given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * self.f64().max(1e-12).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.02, "frac={frac}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let m = (0..n).map(|_| r.exp(3.0)).sum::<f64>() / n as f64;
        assert!((m - 3.0).abs() < 0.1, "m={m}");
    }
}
