//! First-party substrate utilities.
//!
//! The offline build image vendors only the `xla` crate's dependency
//! closure, so the usual ecosystem crates are re-implemented here at the
//! size this project needs: [`json`] (serde_json), [`cli`] (clap),
//! [`rng`] (rand), [`timer`] (criterion), [`prop`] (proptest),
//! [`threadpool`] + OS threads (tokio), [`stats`] (hdrhistogram).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timer;
