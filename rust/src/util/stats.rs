//! Summary statistics + histogram helpers used by benches and metrics.

/// Online summary of a stream of f64 samples (latencies are the main user).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { samples: Vec::new() }
    }

    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn extend(&mut self, vs: &[f64]) {
        self.samples.extend_from_slice(vs);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile by linear interpolation between closest ranks, p in [0,100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            let frac = rank - lo as f64;
            s[lo] * (1.0 - frac) + s[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }
}

/// Bounded sliding window of samples for lifetime-of-a-server percentiles:
/// keeps the most recent `cap` values in a ring, so memory stays fixed and
/// a percentile query sorts at most `cap` elements. Use instead of
/// [`Summary`] wherever samples accrue without bound (e.g. per-request
/// latencies in the serving stats).
#[derive(Clone, Debug)]
pub struct LatencyWindow {
    buf: Vec<f64>,
    next: usize,
    cap: usize,
    total: u64,
}

impl LatencyWindow {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "window capacity must be positive");
        LatencyWindow { buf: Vec::new(), next: 0, cap, total: 0 }
    }

    pub fn add(&mut self, v: f64) {
        self.total += 1;
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Samples ever added (not just the retained window).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Percentile over the retained window, same convention as
    /// [`Summary::percentile`] (linear interpolation, p in [0,100]).
    pub fn percentile(&self, p: f64) -> f64 {
        let mut s = Summary::new();
        s.extend(&self.buf);
        s.percentile(p)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Fixed-range histogram (used for Fig. 2 score distributions).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins] }
    }

    pub fn add(&mut self, v: f64) {
        let bins = self.counts.len();
        let idx = ((v - self.lo) / (self.hi - self.lo) * bins as f64)
            .clamp(0.0, bins as f64 - 1.0) as usize;
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Render a unicode sparkline of the bins, e.g. for terminal reports.
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        self.counts
            .iter()
            .map(|&c| BARS[(c as f64 / max as f64 * 7.0).round() as usize])
            .collect()
    }
}

/// Log-bucketed latency histogram with interior-mutable (atomic) counters,
/// so hot paths can record through a shared reference without a lock.
///
/// Geometric buckets, 4 per octave (ratio 2^(1/4) ≈ 1.19): bucket `i`
/// covers `(bound(i-1), bound(i)]` seconds with `bound(i) = 1µs · 2^(i/4)`.
/// 96 buckets span 1µs .. ~14s; values outside clamp to the end buckets.
/// Quantiles return the upper bound of the covering bucket, so the
/// relative error is at most the bucket ratio (~19%). Merging adds
/// counts bucket-wise and is exact (and associative) on integers.
pub struct LogHistogram {
    counts: Vec<std::sync::atomic::AtomicU64>,
    count: std::sync::atomic::AtomicU64,
    sum_ns: std::sync::atomic::AtomicU64,
}

impl LogHistogram {
    pub const BUCKETS: usize = 96;
    const BASE: f64 = 1e-6; // bucket 0 upper bound, seconds

    pub fn new() -> Self {
        LogHistogram {
            counts: (0..Self::BUCKETS).map(|_| std::sync::atomic::AtomicU64::new(0)).collect(),
            count: std::sync::atomic::AtomicU64::new(0),
            sum_ns: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Upper bound of bucket `i` in seconds.
    pub fn bucket_bound(i: usize) -> f64 {
        Self::BASE * 2f64.powf(i as f64 / 4.0)
    }

    fn bucket_of(seconds: f64) -> usize {
        if !seconds.is_finite() || seconds <= Self::BASE {
            return 0;
        }
        let idx = (4.0 * (seconds / Self::BASE).log2()).ceil() as i64;
        idx.clamp(0, Self::BUCKETS as i64 - 1) as usize
    }

    /// Record one sample (seconds). Lock-free; relaxed ordering is fine
    /// because readers only ever see a point-in-time snapshot.
    pub fn record(&self, seconds: f64) {
        use std::sync::atomic::Ordering::Relaxed;
        let s = if seconds.is_finite() { seconds.max(0.0) } else { 0.0 };
        self.counts[Self::bucket_of(s)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum_ns.fetch_add((s * 1e9) as u64, Relaxed);
    }

    /// Add `other`'s counts into `self` (bucket-wise integer addition —
    /// exact and associative).
    pub fn merge(&self, other: &LogHistogram) {
        use std::sync::atomic::Ordering::Relaxed;
        for (a, b) in self.counts.iter().zip(&other.counts) {
            a.fetch_add(b.load(Relaxed), Relaxed);
        }
        self.count.fetch_add(other.count.load(Relaxed), Relaxed);
        self.sum_ns.fetch_add(other.sum_ns.load(Relaxed), Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    pub fn sum_seconds(&self) -> f64 {
        self.sum_ns.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e9
    }

    /// Cumulative bucket counts as (upper-bound seconds, count ≤ bound)
    /// pairs for nonzero buckets — the Prometheus `_bucket{le=...}` series.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        use std::sync::atomic::Ordering::Relaxed;
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let n = c.load(Relaxed);
            if n > 0 {
                cum += n;
                out.push((Self::bucket_bound(i), cum));
            }
        }
        out
    }

    /// Quantile estimate, p in [0,1]: upper bound of the bucket holding
    /// the ⌈p·n⌉-th smallest sample (0.0 when empty). Overestimates the
    /// true quantile by at most one bucket ratio (2^(1/4)) for in-range
    /// samples.
    pub fn quantile(&self, p: f64) -> f64 {
        use std::sync::atomic::Ordering::Relaxed;
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = ((p.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Relaxed);
            if cum >= target {
                return Self::bucket_bound(i);
            }
        }
        Self::bucket_bound(Self::BUCKETS - 1)
    }

    /// Wire form: `{"buckets":[[index,count],...],"count":N,"sum_ns":N}`
    /// with only nonzero buckets listed.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        use std::sync::atomic::Ordering::Relaxed;
        let buckets = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, c)| c.load(Relaxed) > 0)
            .map(|(i, c)| {
                Json::Arr(vec![Json::Num(i as f64), Json::Num(c.load(Relaxed) as f64)])
            })
            .collect();
        Json::obj(vec![
            ("buckets", Json::Arr(buckets)),
            ("count", Json::Num(self.count() as f64)),
            ("sum_ns", Json::Num(self.sum_ns.load(Relaxed) as f64)),
        ])
    }

    /// Parse the wire form; out-of-range bucket indices are clamped into
    /// the last bucket so a newer peer can't crash an older one.
    pub fn from_json(j: &crate::util::json::Json) -> LogHistogram {
        use std::sync::atomic::Ordering::Relaxed;
        let h = LogHistogram::new();
        if let Some(buckets) = j.get("buckets").and_then(|b| b.as_arr()) {
            for pair in buckets {
                if let Some(p) = pair.as_arr() {
                    if p.len() == 2 {
                        let i = (p[0].as_f64().unwrap_or(0.0) as usize).min(Self::BUCKETS - 1);
                        let n = p[1].as_f64().unwrap_or(0.0) as u64;
                        h.counts[i].fetch_add(n, Relaxed);
                    }
                }
            }
        }
        if let Some(n) = j.get("count").and_then(|v| v.as_f64()) {
            h.count.store(n as u64, Relaxed);
        }
        if let Some(n) = j.get("sum_ns").and_then(|v| v.as_f64()) {
            h.sum_ns.store(n as u64, Relaxed);
        }
        h
    }
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

impl Clone for LogHistogram {
    fn clone(&self) -> Self {
        let h = LogHistogram::new();
        h.merge(self);
        h
    }
}

impl std::fmt::Debug for LogHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "LogHistogram {{ count: {}, sum_s: {:.6}, p50: {:.6}, p99: {:.6} }}",
            self.count(),
            self.sum_seconds(),
            self.quantile(0.5),
            self.quantile(0.99)
        )
    }
}

/// Cosine similarity between two equal-length vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for i in 0..a.len() {
        dot += a[i] as f64 * b[i] as f64;
        na += (a[i] as f64).powi(2);
        nb += (b[i] as f64).powi(2);
    }
    dot / (na.sqrt() * nb.sqrt() + 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        s.extend(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.p50(), 3.0);
        assert!((s.std() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        s.extend(&[0.0, 10.0]);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
    }

    #[test]
    fn latency_window_bounds_memory_and_slides() {
        let mut w = LatencyWindow::new(4);
        assert_eq!(w.percentile(50.0), 0.0); // empty is safe
        for v in [1.0, 2.0, 3.0, 4.0] {
            w.add(v);
        }
        assert_eq!(w.p50(), 2.5);
        // overflow evicts the oldest samples (1.0, 2.0)
        w.add(10.0);
        w.add(20.0);
        assert_eq!(w.total(), 6);
        assert_eq!(w.percentile(100.0), 20.0);
        assert_eq!(w.percentile(0.0), 3.0);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        for v in [0.1, 0.3, 0.3, 0.9, 1.5, -0.5] {
            h.add(v);
        }
        assert_eq!(h.counts, vec![2, 2, 0, 2]); // clamps out-of-range
        assert_eq!(h.total(), 6);
    }

    #[test]
    fn cosine_known_values() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-9);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-9);
        assert!((cosine(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn log_histogram_basics() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), 0.0); // empty is safe
        h.record(0.001); // 1ms
        h.record(0.001);
        h.record(0.1); // 100ms
        assert_eq!(h.count(), 3);
        assert!((h.sum_seconds() - 0.102).abs() < 1e-6);
        // p50 bucket covers 1ms: bound within one ratio above
        let p50 = h.quantile(0.5);
        assert!(p50 >= 0.001 && p50 <= 0.001 * 2f64.powf(0.25) * 1.0001, "p50={p50}");
        // p99 lands in the 100ms bucket
        let p99 = h.quantile(0.99);
        assert!(p99 >= 0.1 && p99 <= 0.1 * 2f64.powf(0.25) * 1.0001, "p99={p99}");
        // sub-microsecond and degenerate samples clamp to bucket 0
        h.record(1e-9);
        h.record(-1.0);
        h.record(f64::NAN);
        assert_eq!(h.count(), 6);
        assert!((h.quantile(0.0) - LogHistogram::bucket_bound(0)).abs() < 1e-12);
        // cumulative series is monotone and ends at the total count
        let cum = h.cumulative();
        assert!(cum.windows(2).all(|w| w[0].1 <= w[1].1 && w[0].0 < w[1].0));
        assert_eq!(cum.last().unwrap().1, 6);
    }

    #[test]
    fn log_histogram_json_roundtrip() {
        let h = LogHistogram::new();
        for v in [2e-6, 5e-4, 0.02, 3.0, 100.0] {
            h.record(v);
        }
        let j = h.to_json();
        let back = LogHistogram::from_json(&j);
        assert_eq!(back.count(), h.count());
        assert!((back.sum_seconds() - h.sum_seconds()).abs() < 1e-9);
        assert_eq!(back.to_json().to_string(), j.to_string());
        // an empty histogram round-trips too
        let e = LogHistogram::from_json(&LogHistogram::new().to_json());
        assert!(e.is_empty());
    }

    #[test]
    fn log_histogram_merge_is_associative() {
        use crate::util::prop;
        prop::check("log-hist-merge-assoc", 50, |rng| {
            let hists: Vec<LogHistogram> = (0..3)
                .map(|_| {
                    let h = LogHistogram::new();
                    let n = 1 + (rng.next_u64() % 40) as usize;
                    for _ in 0..n {
                        // span the full range, 1µs .. ~10s, log-uniform
                        h.record(1e-6 * 2f64.powf(rng.f64() * 23.0));
                    }
                    h
                })
                .collect();
            // (a ⊕ b) ⊕ c
            let left = hists[0].clone();
            left.merge(&hists[1]);
            left.merge(&hists[2]);
            // a ⊕ (b ⊕ c)
            let bc = hists[1].clone();
            bc.merge(&hists[2]);
            let right = hists[0].clone();
            right.merge(&bc);
            crate::prop_assert!(
                left.to_json().to_string() == right.to_json().to_string(),
                "merge not associative: {left:?} vs {right:?}"
            );
            crate::prop_assert!(
                left.count() == hists.iter().map(|h| h.count()).sum::<u64>(),
                "merged count mismatch"
            );
            Ok(())
        });
    }

    #[test]
    fn log_histogram_quantile_brackets_true_quantile() {
        use crate::util::prop;
        let ratio = 2f64.powf(0.25);
        prop::check("log-hist-quantile-bound", 50, |rng| {
            let h = LogHistogram::new();
            let n = 1 + (rng.next_u64() % 200) as usize;
            let mut vals: Vec<f64> = (0..n)
                // strictly inside the histogram range: (1µs, ~0.5s)
                .map(|_| 1e-6 * 2f64.powf(0.1 + rng.f64() * 18.0))
                .collect();
            for &v in &vals {
                h.record(v);
            }
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for &p in &[0.5, 0.95, 0.99] {
                let rank = ((p * n as f64).ceil() as usize).max(1);
                let true_q = vals[rank - 1];
                let est = h.quantile(p);
                crate::prop_assert!(
                    est >= true_q * (1.0 - 1e-9),
                    "p{p}: estimate {est} below true quantile {true_q}"
                );
                crate::prop_assert!(
                    est <= true_q * ratio * (1.0 + 1e-9),
                    "p{p}: estimate {est} above true quantile {true_q} by more than one bucket"
                );
            }
            Ok(())
        });
    }
}
