//! Summary statistics + histogram helpers used by benches and metrics.

/// Online summary of a stream of f64 samples (latencies are the main user).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { samples: Vec::new() }
    }

    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn extend(&mut self, vs: &[f64]) {
        self.samples.extend_from_slice(vs);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile by linear interpolation between closest ranks, p in [0,100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            let frac = rank - lo as f64;
            s[lo] * (1.0 - frac) + s[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }
}

/// Bounded sliding window of samples for lifetime-of-a-server percentiles:
/// keeps the most recent `cap` values in a ring, so memory stays fixed and
/// a percentile query sorts at most `cap` elements. Use instead of
/// [`Summary`] wherever samples accrue without bound (e.g. per-request
/// latencies in the serving stats).
#[derive(Clone, Debug)]
pub struct LatencyWindow {
    buf: Vec<f64>,
    next: usize,
    cap: usize,
    total: u64,
}

impl LatencyWindow {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "window capacity must be positive");
        LatencyWindow { buf: Vec::new(), next: 0, cap, total: 0 }
    }

    pub fn add(&mut self, v: f64) {
        self.total += 1;
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Samples ever added (not just the retained window).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Percentile over the retained window, same convention as
    /// [`Summary::percentile`] (linear interpolation, p in [0,100]).
    pub fn percentile(&self, p: f64) -> f64 {
        let mut s = Summary::new();
        s.extend(&self.buf);
        s.percentile(p)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Fixed-range histogram (used for Fig. 2 score distributions).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins] }
    }

    pub fn add(&mut self, v: f64) {
        let bins = self.counts.len();
        let idx = ((v - self.lo) / (self.hi - self.lo) * bins as f64)
            .clamp(0.0, bins as f64 - 1.0) as usize;
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Render a unicode sparkline of the bins, e.g. for terminal reports.
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        self.counts
            .iter()
            .map(|&c| BARS[(c as f64 / max as f64 * 7.0).round() as usize])
            .collect()
    }
}

/// Cosine similarity between two equal-length vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for i in 0..a.len() {
        dot += a[i] as f64 * b[i] as f64;
        na += (a[i] as f64).powi(2);
        nb += (b[i] as f64).powi(2);
    }
    dot / (na.sqrt() * nb.sqrt() + 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        s.extend(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.p50(), 3.0);
        assert!((s.std() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        s.extend(&[0.0, 10.0]);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
    }

    #[test]
    fn latency_window_bounds_memory_and_slides() {
        let mut w = LatencyWindow::new(4);
        assert_eq!(w.percentile(50.0), 0.0); // empty is safe
        for v in [1.0, 2.0, 3.0, 4.0] {
            w.add(v);
        }
        assert_eq!(w.p50(), 2.5);
        // overflow evicts the oldest samples (1.0, 2.0)
        w.add(10.0);
        w.add(20.0);
        assert_eq!(w.total(), 6);
        assert_eq!(w.percentile(100.0), 20.0);
        assert_eq!(w.percentile(0.0), 3.0);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        for v in [0.1, 0.3, 0.3, 0.9, 1.5, -0.5] {
            h.add(v);
        }
        assert_eq!(h.counts, vec![2, 2, 0, 2]); // clamps out-of-range
        assert_eq!(h.total(), 6);
    }

    #[test]
    fn cosine_known_values() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-9);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-9);
        assert!((cosine(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs() < 1e-9);
    }
}
