//! Small fixed-size worker pool over std channels.
//!
//! Used for batched expert computation fan-out and the serving front-end.
//! (No tokio on this image; AdapMoE's two-"stream" overlap is modelled with
//! dedicated OS threads — see coordinator::scheduler.)

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Shared pool of reusable `f32` scratch buffers for the decode hot path.
///
/// The expert-major FFN ([`crate::coordinator::executor::expert_ffn_host_grouped`])
/// packs routed rows into a gather buffer and accumulates into a packed
/// output buffer per call; at `b=16` with several experts per layer that
/// is thousands of short-lived heap allocations per decode step. Workers
/// instead `take` a buffer sized to their need (zeroed, retaining the
/// largest capacity seen) and `put` it back when the scatter is done, so
/// steady-state decode performs no compute-side heap allocation.
#[derive(Default)]
pub struct RowBufferPool {
    bufs: Mutex<Vec<Vec<f32>>>,
}

impl RowBufferPool {
    pub fn new() -> Self {
        RowBufferPool::default()
    }

    /// Take a zeroed buffer of exactly `len` elements, reusing a retired
    /// buffer's capacity when one is available.
    pub fn take(&self, len: usize) -> Vec<f32> {
        let mut buf = self.bufs.lock().unwrap().pop().unwrap_or_default();
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer to the pool for reuse.
    pub fn put(&self, buf: Vec<f32>) {
        self.bufs.lock().unwrap().push(buf);
    }

    /// Buffers currently parked in the pool (test/introspection hook).
    pub fn parked(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }
}

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    /// Scratch buffers shared by every worker (and the submitting thread):
    /// the grouped expert FFN draws its gather/accumulate storage here.
    buffers: Arc<RowBufferPool>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("adapmoe-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, buffers: Arc::new(RowBufferPool::new()) }
    }

    /// The pool's shared row-buffer scratch.
    pub fn buffers(&self) -> &Arc<RowBufferPool> {
        &self.buffers
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Run a batch of jobs and wait for all of them.
    pub fn scatter_gather<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        let n = jobs.len();
        let (tx, rx) = channel();
        for (i, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            self.submit(move || {
                let out = job();
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v) = rx.recv().expect("worker panicked");
            slots[i] = Some(v);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scatter_gather_preserves_order() {
        let pool = ThreadPool::new(3);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..20usize)
            .map(|i| Box::new(move || i * i) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let out = pool.scatter_gather(jobs);
        assert_eq!(out, (0..20usize).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn row_buffer_pool_recycles_capacity() {
        let pool = RowBufferPool::new();
        let mut a = pool.take(64);
        assert_eq!(a.len(), 64);
        assert!(a.iter().all(|&v| v == 0.0));
        a[0] = 7.0;
        let cap = a.capacity();
        pool.put(a);
        assert_eq!(pool.parked(), 1);
        // smaller request reuses the retired buffer's capacity, zeroed
        let b = pool.take(16);
        assert_eq!(b.len(), 16);
        assert!(b.capacity() >= cap);
        assert!(b.iter().all(|&v| v == 0.0));
        assert_eq!(pool.parked(), 0);
    }

    #[test]
    fn row_buffer_pool_is_shareable_across_threads() {
        let pool = Arc::new(RowBufferPool::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let p = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let buf = p.take(128);
                    assert_eq!(buf.len(), 128);
                    p.put(buf);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // every taken buffer came back
        assert!(pool.parked() >= 1 && pool.parked() <= 4);
    }
}
