//! Small fixed-size worker pool over std channels.
//!
//! Used for batched expert computation fan-out and the serving front-end.
//! (No tokio on this image; AdapMoE's two-"stream" overlap is modelled with
//! dedicated OS threads — see coordinator::scheduler.)

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("adapmoe-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Run a batch of jobs and wait for all of them.
    pub fn scatter_gather<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        let n = jobs.len();
        let (tx, rx) = channel();
        for (i, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            self.submit(move || {
                let out = job();
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v) = rx.recv().expect("worker panicked");
            slots[i] = Some(v);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scatter_gather_preserves_order() {
        let pool = ThreadPool::new(3);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..20usize)
            .map(|i| Box::new(move || i * i) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let out = pool.scatter_gather(jobs);
        assert_eq!(out, (0..20usize).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }
}
