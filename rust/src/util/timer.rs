//! Bench harness — first-party stand-in for `criterion`.
//!
//! `cargo bench` runs our `harness = false` bench binaries; each uses
//! [`Bench`] to time closures with warmup + repeated measurement and print
//! aligned result tables that mirror the paper's tables/figures.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// Measure a closure: `warmup` unrecorded runs, then `iters` timed runs.
pub fn measure<F: FnMut()>(mut f: F, warmup: usize, iters: usize) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        s.add(t.elapsed().as_secs_f64());
    }
    s
}

/// Measure until `budget` elapses (at least `min_iters` runs).
pub fn measure_for<F: FnMut()>(mut f: F, budget: Duration, min_iters: usize) -> Summary {
    let mut s = Summary::new();
    let start = Instant::now();
    let mut i = 0;
    while i < min_iters || start.elapsed() < budget {
        let t = Instant::now();
        f();
        s.add(t.elapsed().as_secs_f64());
        i += 1;
        if i > 100_000 {
            break;
        }
    }
    s
}

/// Pretty duration: picks ns/µs/ms/s.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

/// Aligned table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let line = |cells: &[String]| {
            let mut out = String::new();
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i] - c.chars().count();
                out.push_str("| ");
                out.push_str(c);
                out.push_str(&" ".repeat(pad + 1));
            }
            out.push('|');
            out
        };
        println!("{}", line(&self.headers));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", line(&sep));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Named bench group: prints a heading, collects rows of (name, Summary).
pub struct Bench {
    name: String,
    results: Vec<(String, Summary)>,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        println!("\n=== bench: {name} ===");
        Bench { name: name.to_string(), results: Vec::new() }
    }

    pub fn run<F: FnMut()>(&mut self, case: &str, f: F) {
        let s = measure(f, 2, 10);
        println!(
            "  {case:40} {:>10} ± {:>8}  (p50 {})",
            fmt_duration(s.mean()),
            fmt_duration(s.std()),
            fmt_duration(s.p50()),
        );
        self.results.push((case.to_string(), s));
    }

    pub fn run_with<F: FnMut()>(&mut self, case: &str, warmup: usize, iters: usize, f: F) {
        let s = measure(f, warmup, iters);
        println!(
            "  {case:40} {:>10} ± {:>8}  (p50 {})",
            fmt_duration(s.mean()),
            fmt_duration(s.std()),
            fmt_duration(s.p50()),
        );
        self.results.push((case.to_string(), s));
    }

    pub fn results(&self) -> &[(String, Summary)] {
        &self.results
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut n = 0;
        let s = measure(|| n += 1, 3, 7);
        assert_eq!(n, 10);
        assert_eq!(s.len(), 7);
    }

    #[test]
    fn measure_for_respects_min() {
        let mut n = 0;
        let s = measure_for(|| n += 1, Duration::from_millis(0), 5);
        assert!(s.len() >= 5);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-6).ends_with("µs"));
        assert!(fmt_duration(5e-3).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with('s'));
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()]);
        }));
        assert!(r.is_err());
    }
}
