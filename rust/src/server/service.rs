//! The inference service: owns the engine loop behind a typed, protocol-
//! agnostic API.
//!
//! [`InferenceService::run`] drives continuous batching (admit → decode →
//! sample → retire) against any [`Backend`] on the caller's thread (PJRT
//! handles are not `Send`, so the engine must stay where it was built).
//! Clonable [`ServiceHandle`]s — safe to share across connection threads —
//! submit typed [`GenerationRequest`]s, receive per-token
//! [`GenerationEvent`]s over a private channel, cancel requests by id, and
//! snapshot [`ServerStats`]. The TCP front-end ([`super::tcp`]) is a thin
//! line-protocol adapter over this; the CLI's `generate` runs the same
//! service in-process via [`InferenceService::run_until_idle`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::batcher::{Batcher, CancelOutcome, FinishReason, SamplingParams};
use crate::coordinator::engine::Engine;
use crate::model::tokenizer::ByteTokenizer;
use crate::server::api::{GenerationEvent, GenerationRequest, ServerStats};
use crate::util::stats::LatencyWindow;

/// Completed-request latency samples retained for stats percentiles.
const LATENCY_WINDOW: usize = 4096;

/// Default admission cap: submits beyond this queue depth are shed with a
/// terminal [`GenerationEvent::Overloaded`] instead of queued — bounded
/// queues are the service half of degraded serving
/// (docs/fault-tolerance.md).
const DEFAULT_QUEUE_CAP: usize = 256;

/// Poison-proof lock: a thread that panicked while holding the state
/// lock must not take the submit/cancel/stats surface down with it —
/// the counters stay consistent enough to serve and the server keeps
/// answering.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Engine-side performance counters surfaced through `stats`.
#[derive(Clone, Debug, Default)]
pub struct PerfSnapshot {
    pub tokens_per_sec: f64,
    pub token_p50_ms: f64,
    pub token_p99_ms: f64,
    /// Log-bucketed latency distributions (docs/observability.md):
    /// per-decode-step latency, per-arrival lane queue delay, and remote
    /// fetch round-trips (empty for backends without them).
    pub token_hist: crate::util::stats::LogHistogram,
    pub lane_queue_hist: crate::util::stats::LogHistogram,
    pub fetch_hist: crate::util::stats::LogHistogram,
    /// Per-comm-lane transfer counters (empty for backends without a
    /// transfer engine, e.g. the mock).
    pub lanes: Vec<crate::memory::transfer::LaneSnapshot>,
    /// Per-device expert-cache shard counters (empty for backends
    /// without a cache, e.g. the mock).
    pub devices: Vec<crate::memory::sharded_cache::DeviceSnapshot>,
    /// Per-precision-tier transfer volumes (empty for backends without
    /// a transfer engine, e.g. the mock).
    pub tiers: Vec<crate::memory::transfer::TierSnapshot>,
    /// Local-vs-remote byte attribution and remote-fetch health (all
    /// zeros for local stores and backends without a transfer engine).
    pub source: crate::memory::transfer::SourceSnapshot,
    /// Per-consumer sensitivity decision counters (all zeros under the
    /// uniform map — docs/sensitivity.md).
    pub sensitivity: crate::memory::transfer::SensitivitySnapshot,
}

/// What the service needs from a decode engine. [`Engine`] is the real
/// implementation; tests drive the full service + TCP stack through
/// [`crate::testutil::MockBackend`] without PJRT artifacts.
pub trait Backend {
    fn acquire_slot(&mut self) -> Option<usize>;
    fn release_slot(&mut self, row: usize);
    /// Row's KV cache is exhausted — the request must retire now.
    fn slot_full(&self, row: usize) -> bool;
    /// One decode step over the given (row, token) pairs; returns per-row
    /// next-token logits.
    fn decode_step(&mut self, inputs: &[(usize, u32)]) -> Result<Vec<(usize, Vec<f32>)>>;
    fn perf(&self) -> PerfSnapshot {
        PerfSnapshot::default()
    }
}

impl Backend for Engine {
    fn acquire_slot(&mut self) -> Option<usize> {
        Engine::acquire_slot(self)
    }

    fn release_slot(&mut self, row: usize) {
        Engine::release_slot(self, row)
    }

    fn slot_full(&self, row: usize) -> bool {
        Engine::slot_full(self, row)
    }

    fn decode_step(&mut self, inputs: &[(usize, u32)]) -> Result<Vec<(usize, Vec<f32>)>> {
        Engine::decode_step(self, inputs)
    }

    fn perf(&self) -> PerfSnapshot {
        PerfSnapshot {
            tokens_per_sec: self.trace.tokens_per_sec(),
            token_p50_ms: self.trace.token_latency.p50() * 1e3,
            token_p99_ms: self.trace.token_latency.p99() * 1e3,
            token_hist: self.trace.token_hist.clone(),
            lane_queue_hist: self.trace.lane_queue_hist.clone(),
            fetch_hist: self
                .tiered
                .remote_counters()
                .map(|c| c.fetch_hist.clone())
                .unwrap_or_default(),
            lanes: self.xfer.lane_snapshots(),
            devices: self.xfer.device_snapshots(),
            tiers: self.xfer.tier_snapshots(),
            source: self.xfer.source_snapshot(),
            sensitivity: self.xfer.sensitivity_snapshot(),
        }
    }
}

struct State {
    batcher: Batcher,
    /// Per-request event channels; removed when the terminal event is sent.
    subs: HashMap<u64, Sender<GenerationEvent>>,
    submit_times: HashMap<u64, Instant>,
    start_times: HashMap<u64, Instant>,
    served: u64,
    cancelled: u64,
    tokens_out: u64,
    /// Completed-request latency distributions (ms) over a bounded recent
    /// window — stats percentiles must stay O(window) under the lock no
    /// matter how long the server has been up.
    queue_wait_ms: LatencyWindow,
    total_ms: LatencyWindow,
    /// Published by the engine loop on completions and periodically (the
    /// backend itself is not reachable from handles).
    perf: PerfSnapshot,
    /// Decode steps driven so far (throttles perf refreshes).
    steps: u64,
    /// Requests shed at admission because the queue was at `queue_cap`.
    shed: u64,
    /// Admission cap enforced by [`ServiceHandle::submit`].
    queue_cap: usize,
    started_at: Instant,
}

/// Owner side: runs the engine loop. Created with a paired [`ServiceHandle`].
pub struct InferenceService {
    shared: Arc<Mutex<State>>,
}

/// Submit/cancel/stats side — `Clone + Send`, one per connection thread.
#[derive(Clone)]
pub struct ServiceHandle {
    shared: Arc<Mutex<State>>,
}

impl InferenceService {
    pub fn new() -> (InferenceService, ServiceHandle) {
        let shared = Arc::new(Mutex::new(State {
            batcher: Batcher::new(),
            subs: HashMap::new(),
            submit_times: HashMap::new(),
            start_times: HashMap::new(),
            served: 0,
            cancelled: 0,
            tokens_out: 0,
            queue_wait_ms: LatencyWindow::new(LATENCY_WINDOW),
            total_ms: LatencyWindow::new(LATENCY_WINDOW),
            perf: PerfSnapshot::default(),
            steps: 0,
            shed: 0,
            queue_cap: DEFAULT_QUEUE_CAP,
            started_at: Instant::now(),
        }));
        (InferenceService { shared: Arc::clone(&shared) }, ServiceHandle { shared })
    }

    /// Drive the loop until `shutdown` flips; returns completions served.
    pub fn run<B: Backend>(&self, backend: &mut B, shutdown: &AtomicBool) -> Result<u64> {
        while !shutdown.load(Ordering::SeqCst) {
            if !self.step(backend)? {
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        Ok(lock_unpoisoned(&self.shared).served)
    }

    /// Drive the loop until every submitted request has retired (in-process
    /// use: CLI generate, tests). Returns completions served so far.
    pub fn run_until_idle<B: Backend>(&self, backend: &mut B) -> Result<u64> {
        loop {
            if lock_unpoisoned(&self.shared).batcher.idle() {
                return Ok(lock_unpoisoned(&self.shared).served);
            }
            self.step(backend)?;
        }
    }

    /// One admit → decode → sample → retire cycle. Returns false when there
    /// was nothing to do. The decode itself runs without the state lock so
    /// submits/cancels/stats never wait on the model.
    fn step<B: Backend>(&self, backend: &mut B) -> Result<bool> {
        let inputs = {
            let mut g = lock_unpoisoned(&self.shared);
            // admit new work into free slots, highest priority first
            while g.batcher.queued() > 0 {
                let Some(row) = backend.acquire_slot() else { break };
                if g.batcher.admit(&[row]) == 0 {
                    backend.release_slot(row);
                    break;
                }
                // admit==1 guarantees a push, but a panic beats a poisoned
                // lock if the batcher ever breaks that contract
                let Some(a) = g.batcher.active.last() else {
                    backend.release_slot(row);
                    break;
                };
                let id = a.req.id;
                g.start_times.insert(id, Instant::now());
                if let Some(tx) = g.subs.get(&id) {
                    let _ = tx.send(GenerationEvent::Started { id });
                }
            }
            if g.batcher.active.is_empty() {
                return Ok(false);
            }
            g.batcher.step_inputs()
        };

        let outs = match backend.decode_step(&inputs) {
            Ok(o) => o,
            Err(e) => {
                // the engine is wedged: fail every request loudly
                let mut g = lock_unpoisoned(&self.shared);
                for (id, tx) in g.subs.drain() {
                    let _ = tx.send(GenerationEvent::Error {
                        id,
                        message: format!("{e:#}"),
                    });
                }
                return Err(e);
            }
        };

        let mut g = lock_unpoisoned(&self.shared);
        let sampled = g.batcher.sample_step(&outs);
        for (id, token, index) in g.batcher.apply_step(&sampled) {
            g.tokens_out += 1;
            if let Some(tx) = g.subs.get(&id) {
                let _ = tx.send(GenerationEvent::Token { id, token, index });
            }
        }
        // rows whose KV is exhausted must retire regardless of max_new
        for a in g.batcher.active.iter_mut() {
            if backend.slot_full(a.row) {
                a.req.max_new = a.generated.len();
            }
        }
        let now = Instant::now();
        let retired = g.batcher.retire();
        let retired_any = !retired.is_empty();
        for done in retired {
            backend.release_slot(done.row);
            let id = done.req.id;
            let queued_at = g.submit_times.remove(&id).unwrap_or(now);
            let started_at = g.start_times.remove(&id).unwrap_or(queued_at);
            let queue_ms = started_at.duration_since(queued_at).as_secs_f64() * 1e3;
            let total_ms = now.duration_since(queued_at).as_secs_f64() * 1e3;
            let tx = g.subs.remove(&id);
            match done.finish() {
                FinishReason::Cancelled => {
                    g.cancelled += 1;
                    if let Some(tx) = tx {
                        let _ = tx.send(GenerationEvent::Cancelled { id });
                    }
                }
                finish => {
                    g.served += 1;
                    g.queue_wait_ms.add(queue_ms);
                    g.total_ms.add(total_ms);
                    if let Some(tx) = tx {
                        let _ = tx.send(GenerationEvent::Done {
                            id,
                            tokens: done.generated,
                            finish,
                            queue_ms,
                            total_ms,
                        });
                    }
                }
            }
        }
        // Refresh the published perf snapshot on completions and every 32nd
        // step (not every step: Engine::perf sorts the full latency history,
        // so an unthrottled refresh would cost O(n log n) per token under
        // the service lock).
        g.steps += 1;
        if retired_any || g.steps % 32 == 0 {
            g.perf = backend.perf();
        }
        Ok(true)
    }
}

impl ServiceHandle {
    fn lock(&self) -> MutexGuard<'_, State> {
        lock_unpoisoned(&self.shared)
    }

    /// Override the admission cap (default [`DEFAULT_QUEUE_CAP`]); load
    /// experiments and tests shrink it to exercise shedding.
    pub fn set_queue_cap(&self, cap: usize) {
        self.lock().queue_cap = cap;
    }

    /// Submit a request. Returns its id and the private event stream
    /// (Queued is already in the channel when this returns). An empty
    /// prompt fails immediately with a terminal Error event — it can never
    /// decode (there is no first input token), and rejecting it here keeps
    /// the engine loop panic-free. The wire layer rejects it even earlier.
    pub fn submit(&self, req: GenerationRequest) -> (u64, Receiver<GenerationEvent>) {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut g = self.lock();
        let prompt = ByteTokenizer::encode(&req.prompt);
        if prompt.is_empty() {
            // consume the id so the failed request never aliases a live one
            let id = g.batcher.reserve_id();
            let _ = tx.send(GenerationEvent::Queued { id });
            let _ = tx.send(GenerationEvent::Error {
                id,
                message: "empty prompt".into(),
            });
            return (id, rx);
        }
        if g.batcher.queued() >= g.queue_cap {
            // shed at admission: a terminal Overloaded the client can back
            // off from beats an unbounded queue that melts tail latency
            let id = g.batcher.reserve_id();
            g.shed += 1;
            let _ = tx.send(GenerationEvent::Overloaded { id });
            return (id, rx);
        }
        let params = SamplingParams {
            temperature: req.temperature,
            top_k: req.top_k,
            seed: req.seed,
        };
        let id = g.batcher.submit_request(prompt, req.max_new, params, req.stop, req.priority);
        g.submit_times.insert(id, Instant::now());
        let _ = tx.send(GenerationEvent::Queued { id });
        g.subs.insert(id, tx);
        (id, rx)
    }

    /// Cancel by id. Queued requests retire immediately (Cancelled event
    /// sent here); in-flight ones retire at the next engine step. Returns
    /// whether the id was known.
    pub fn cancel(&self, id: u64) -> bool {
        let mut g = self.lock();
        match g.batcher.cancel(id) {
            CancelOutcome::Queued => {
                g.cancelled += 1;
                g.submit_times.remove(&id);
                if let Some(tx) = g.subs.remove(&id) {
                    let _ = tx.send(GenerationEvent::Cancelled { id });
                }
                true
            }
            CancelOutcome::Active => true,
            CancelOutcome::Unknown => false,
        }
    }

    /// Point-in-time stats: queue/active depth, lifetime counters, engine
    /// throughput and latency percentiles.
    pub fn stats(&self) -> ServerStats {
        let g = self.lock();
        ServerStats {
            queued: g.batcher.queued(),
            active: g.batcher.active.len(),
            served: g.served,
            cancelled: g.cancelled,
            shed: g.shed,
            tokens_generated: g.tokens_out,
            request_p50_ms: g.total_ms.p50(),
            request_p99_ms: g.total_ms.p99(),
            queue_p50_ms: g.queue_wait_ms.p50(),
            uptime_s: g.started_at.elapsed().as_secs_f64(),
            ..stats_from_perf(&g.perf)
        }
    }

    /// Prometheus-style text exposition of every counter family in
    /// [`ServerStats`], including the log-bucketed latency histograms.
    pub fn metrics(&self) -> String {
        crate::obs::metrics::MetricsRegistry::from_server_stats(&self.stats()).render()
    }

    pub fn served(&self) -> u64 {
        self.lock().served
    }
}

/// Engine-only stats snapshot: every perf-derived field of [`ServerStats`]
/// (throughput, latency quantiles, counter families, histograms) with the
/// serving-layer request counters left at zero. Used by `ServiceHandle::stats`
/// and by CLI `--metrics-out` dumps where no service loop is running.
pub fn stats_from_perf(perf: &PerfSnapshot) -> ServerStats {
    ServerStats {
        tokens_per_sec: perf.tokens_per_sec,
        token_p50_ms: perf.token_p50_ms,
        token_p95_ms: perf.token_hist.quantile(0.95) * 1e3,
        token_p99_ms: perf.token_p99_ms,
        lane_queue_p50_ms: perf.lane_queue_hist.quantile(0.50) * 1e3,
        lane_queue_p95_ms: perf.lane_queue_hist.quantile(0.95) * 1e3,
        lane_queue_p99_ms: perf.lane_queue_hist.quantile(0.99) * 1e3,
        fetch_p50_ms: perf.fetch_hist.quantile(0.50) * 1e3,
        fetch_p95_ms: perf.fetch_hist.quantile(0.95) * 1e3,
        fetch_p99_ms: perf.fetch_hist.quantile(0.99) * 1e3,
        lanes: perf.lanes.clone(),
        devices: perf.devices.clone(),
        tiers: perf.tiers.clone(),
        source: perf.source,
        sensitivity: perf.sensitivity,
        token_hist: perf.token_hist.clone(),
        lane_queue_hist: perf.lane_queue_hist.clone(),
        fetch_hist: perf.fetch_hist.clone(),
        ..ServerStats::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::MockBackend;

    fn drain(rx: &Receiver<GenerationEvent>) -> Vec<GenerationEvent> {
        let mut evs = Vec::new();
        while let Ok(ev) = rx.recv_timeout(Duration::from_secs(5)) {
            let terminal = ev.is_terminal();
            evs.push(ev);
            if terminal {
                break;
            }
        }
        evs
    }

    #[test]
    fn event_ordering_queued_started_tokens_done() {
        let mut be = MockBackend::new(2, 64);
        let (svc, h) = InferenceService::new();
        let (id, rx) = h.submit(GenerationRequest { max_new: 3, ..GenerationRequest::new("ab") });
        svc.run_until_idle(&mut be).unwrap();
        let evs = drain(&rx);
        let kinds: Vec<&str> = evs
            .iter()
            .map(|e| match e {
                GenerationEvent::Queued { .. } => "queued",
                GenerationEvent::Started { .. } => "started",
                GenerationEvent::Token { .. } => "token",
                GenerationEvent::Done { .. } => "done",
                _ => "other",
            })
            .collect();
        assert_eq!(kinds, vec!["queued", "started", "token", "token", "token", "done"]);
        assert!(evs.iter().all(|e| e.id() == id));
        let GenerationEvent::Done { tokens, finish, .. } = evs.last().unwrap() else {
            panic!("missing done");
        };
        // mock emits input+1: prompt "ab" (97,98) -> 99,100,101
        assert_eq!(tokens, &vec![99, 100, 101]);
        assert_eq!(*finish, FinishReason::Length);
        // token indices count up from 0
        let idxs: Vec<usize> = evs
            .iter()
            .filter_map(|e| match e {
                GenerationEvent::Token { index, .. } => Some(*index),
                _ => None,
            })
            .collect();
        assert_eq!(idxs, vec![0, 1, 2]);
    }

    #[test]
    fn stop_token_finishes_early() {
        let mut be = MockBackend::new(1, 64);
        let (svc, h) = InferenceService::new();
        // generation runs 99,100,101,... — stop at 101
        let req = GenerationRequest {
            max_new: 50,
            stop: vec![101],
            ..GenerationRequest::new("ab")
        };
        let (_id, rx) = h.submit(req);
        svc.run_until_idle(&mut be).unwrap();
        let evs = drain(&rx);
        let GenerationEvent::Done { tokens, finish, .. } = evs.last().unwrap() else {
            panic!("missing done");
        };
        assert_eq!(tokens, &vec![99, 100], "stop token must not be kept");
        assert_eq!(*finish, FinishReason::Stop);
    }

    #[test]
    fn cancel_queued_request_never_starts() {
        let mut be = MockBackend::new(1, 64);
        let (svc, h) = InferenceService::new();
        // one slot: second request waits in the queue
        let (_id1, rx1) =
            h.submit(GenerationRequest { max_new: 2, ..GenerationRequest::new("a") });
        let (id2, rx2) =
            h.submit(GenerationRequest { max_new: 2, ..GenerationRequest::new("b") });
        assert!(h.cancel(id2));
        assert!(!h.cancel(999));
        svc.run_until_idle(&mut be).unwrap();
        let evs2 = drain(&rx2);
        assert_eq!(evs2.len(), 2, "queued then cancelled: {evs2:?}");
        assert!(matches!(evs2[1], GenerationEvent::Cancelled { .. }));
        assert!(matches!(drain(&rx1).last(), Some(GenerationEvent::Done { .. })));
        let s = h.stats();
        assert_eq!(s.served, 1);
        assert_eq!(s.cancelled, 1);
    }

    #[test]
    fn cancel_in_flight_request_mid_decode() {
        let mut be = MockBackend::new(1, 4096);
        let (svc, h) = InferenceService::new();
        let (id, rx) =
            h.submit(GenerationRequest { max_new: 100_000, ..GenerationRequest::new("a") });
        // drive a few steps by hand, then cancel mid-flight
        for _ in 0..5 {
            svc.step(&mut be).unwrap();
        }
        assert!(h.cancel(id));
        svc.run_until_idle(&mut be).unwrap();
        let evs = drain(&rx);
        assert!(matches!(evs.last(), Some(GenerationEvent::Cancelled { .. })), "{evs:?}");
        let n_tokens = evs
            .iter()
            .filter(|e| matches!(e, GenerationEvent::Token { .. }))
            .count();
        assert!(n_tokens >= 1 && n_tokens < 100, "cancel landed mid-stream: {n_tokens}");
        assert_eq!(h.stats().cancelled, 1);
        // the slot was released: a new request can run
        let (_id2, rx2) =
            h.submit(GenerationRequest { max_new: 1, ..GenerationRequest::new("z") });
        svc.run_until_idle(&mut be).unwrap();
        assert!(matches!(drain(&rx2).last(), Some(GenerationEvent::Done { .. })));
    }

    #[test]
    fn priority_orders_admission_under_contention() {
        let mut be = MockBackend::new(1, 64);
        // make each decode step dominate the submit-time skew so the
        // queue-wait comparison below is unambiguous
        be.step_delay = Duration::from_millis(5);
        let (svc, h) = InferenceService::new();
        let mk = |prio| GenerationRequest {
            max_new: 1,
            priority: prio,
            ..GenerationRequest::new("a")
        };
        let (_a, rx_a) = h.submit(mk(0));
        let (_b, rx_b) = h.submit(mk(5));
        let (_c, rx_c) = h.submit(mk(1));
        svc.run_until_idle(&mut be).unwrap();
        // queue-wait ordering proves admission order: b (prio 5) waited
        // least, then c (prio 1), then a (prio 0, submitted first but lowest)
        let w = |rx: &Receiver<GenerationEvent>| {
            drain(rx)
                .iter()
                .find_map(|e| match e {
                    GenerationEvent::Done { queue_ms, .. } => Some(*queue_ms),
                    _ => None,
                })
                .unwrap()
        };
        let (wa, wb, wc) = (w(&rx_a), w(&rx_b), w(&rx_c));
        assert!(wb <= wc && wc <= wa, "queue waits a={wa} b={wb} c={wc}");
    }

    #[test]
    fn stats_track_counts_and_depth() {
        let mut be = MockBackend::new(2, 64);
        let (svc, h) = InferenceService::new();
        let s0 = h.stats();
        assert_eq!((s0.queued, s0.active, s0.served), (0, 0, 0));
        let (_i1, _rx1) =
            h.submit(GenerationRequest { max_new: 2, ..GenerationRequest::new("a") });
        let (_i2, _rx2) =
            h.submit(GenerationRequest { max_new: 2, ..GenerationRequest::new("b") });
        let (_i3, _rx3) =
            h.submit(GenerationRequest { max_new: 2, ..GenerationRequest::new("c") });
        assert_eq!(h.stats().queued, 3);
        svc.run_until_idle(&mut be).unwrap();
        let s = h.stats();
        assert_eq!(s.served, 3);
        assert_eq!(s.queued, 0);
        assert_eq!(s.active, 0);
        assert_eq!(s.tokens_generated, 6);
        assert!(s.uptime_s >= 0.0);
    }

    #[test]
    fn empty_prompt_and_zero_max_new_are_safe() {
        let mut be = MockBackend::new(1, 64);
        let (svc, h) = InferenceService::new();
        // empty prompt: rejected with a terminal Error, engine never runs
        let (_id, rx) = h.submit(GenerationRequest::new(""));
        let evs = drain(&rx);
        assert!(matches!(evs.last(), Some(GenerationEvent::Error { .. })), "{evs:?}");
        // max_new 0: retires cleanly with zero tokens
        let (_id2, rx2) =
            h.submit(GenerationRequest { max_new: 0, ..GenerationRequest::new("ab") });
        svc.run_until_idle(&mut be).unwrap();
        let evs = drain(&rx2);
        let Some(GenerationEvent::Done { tokens, .. }) = evs.last() else {
            panic!("expected done: {evs:?}");
        };
        assert!(tokens.is_empty());
        assert!(!evs.iter().any(|e| matches!(e, GenerationEvent::Token { .. })));
    }

    #[test]
    fn overload_sheds_at_admission_cap() {
        let mut be = MockBackend::new(1, 64);
        let (svc, h) = InferenceService::new();
        h.set_queue_cap(2);
        let (_a, rx_a) =
            h.submit(GenerationRequest { max_new: 1, ..GenerationRequest::new("a") });
        let (_b, rx_b) =
            h.submit(GenerationRequest { max_new: 1, ..GenerationRequest::new("b") });
        // queue is at the cap: the third submit is shed with a single
        // terminal event and never enters the queue
        let (id_c, rx_c) =
            h.submit(GenerationRequest { max_new: 1, ..GenerationRequest::new("c") });
        let evs = drain(&rx_c);
        assert_eq!(evs.len(), 1, "{evs:?}");
        assert!(matches!(evs[0], GenerationEvent::Overloaded { id } if id == id_c));
        assert_eq!(h.stats().shed, 1);
        assert_eq!(h.stats().queued, 2, "shed request must not occupy the queue");
        // the admitted requests still complete normally
        svc.run_until_idle(&mut be).unwrap();
        assert!(matches!(drain(&rx_a).last(), Some(GenerationEvent::Done { .. })));
        assert!(matches!(drain(&rx_b).last(), Some(GenerationEvent::Done { .. })));
        let s = h.stats();
        assert_eq!(s.served, 2);
        assert_eq!(s.shed, 1);
    }

    #[test]
    fn decode_error_fails_requests_with_error_event() {
        let mut be = MockBackend::new(1, 64);
        be.fail_after = Some(2);
        let (svc, h) = InferenceService::new();
        let (_id, rx) =
            h.submit(GenerationRequest { max_new: 50, ..GenerationRequest::new("abc") });
        assert!(svc.run_until_idle(&mut be).is_err());
        let evs = drain(&rx);
        assert!(
            matches!(evs.last(), Some(GenerationEvent::Error { .. })),
            "expected error event, got {evs:?}"
        );
    }
}
