//! Typed request/response surface of the serving stack.
//!
//! Everything a front-end protocol (TCP today, HTTP/sharded lanes later)
//! needs to talk to the [`super::service::InferenceService`] lives here:
//! [`GenerationRequest`] in, a stream of [`GenerationEvent`]s out, plus the
//! [`ServerStats`] snapshot. The JSON encode/decode for the line protocol is
//! also defined here so the wire format has a single source of truth and
//! protocol adapters stay thin.

use anyhow::{bail, Result};

pub use crate::coordinator::batcher::{FinishReason, SamplingParams};
pub use crate::memory::sharded_cache::DeviceSnapshot;
pub use crate::memory::transfer::{LaneSnapshot, SensitivitySnapshot, SourceSnapshot, TierSnapshot};
use crate::model::tokenizer::ByteTokenizer;
use crate::util::json::Json;
use crate::util::stats::LogHistogram;

/// A fully-parameterized generation request.
#[derive(Clone, Debug)]
pub struct GenerationRequest {
    pub prompt: String,
    /// Upper bound on generated tokens (stop tokens can end it earlier).
    pub max_new: usize,
    /// Softmax temperature; 0 (the default) is greedy decoding.
    pub temperature: f64,
    /// Restrict sampling to the k highest logits; 0 = unrestricted.
    pub top_k: usize,
    /// Tokens that terminate generation when sampled (excluded from output).
    pub stop: Vec<u32>,
    /// Higher runs first when slots are contended; ties are FIFO.
    pub priority: i32,
    /// Stream per-token events instead of one final completion.
    pub stream: bool,
    /// Sampling seed; `None` derives one from the request id.
    pub seed: Option<u64>,
}

impl Default for GenerationRequest {
    fn default() -> GenerationRequest {
        GenerationRequest {
            prompt: String::new(),
            max_new: 32,
            temperature: 0.0,
            top_k: 0,
            stop: Vec::new(),
            priority: 0,
            stream: false,
            seed: None,
        }
    }
}

impl GenerationRequest {
    pub fn new(prompt: &str) -> GenerationRequest {
        GenerationRequest { prompt: prompt.to_string(), ..Default::default() }
    }

    /// Parse the wire form. `stop` accepts a string (each byte-token of it
    /// stops generation) or an array of token numbers.
    pub fn from_json(j: &Json) -> Result<GenerationRequest> {
        let Some(prompt) = j.get("prompt").and_then(|p| p.as_str()) else {
            bail!("request missing 'prompt'");
        };
        if prompt.is_empty() {
            bail!("'prompt' must be non-empty");
        }
        let mut req = GenerationRequest::new(prompt);
        if let Some(v) = j.get("max_new").and_then(|v| v.as_usize()) {
            req.max_new = v;
        }
        if let Some(v) = j.get("temperature").and_then(|v| v.as_f64()) {
            req.temperature = v;
        }
        if let Some(v) = j.get("top_k").and_then(|v| v.as_usize()) {
            req.top_k = v;
        }
        match j.get("stop") {
            None | Some(Json::Null) => {}
            Some(Json::Str(s)) => req.stop = ByteTokenizer::encode(s),
            Some(Json::Arr(a)) => {
                for v in a {
                    let Some(t) = v.as_f64() else { bail!("'stop' array must be numeric") };
                    req.stop.push(t as u32);
                }
            }
            Some(_) => bail!("'stop' must be a string or token array"),
        }
        if let Some(v) = j.get("priority").and_then(|v| v.as_f64()) {
            req.priority = v as i32;
        }
        if let Some(v) = j.get("stream").and_then(|v| v.as_bool()) {
            req.stream = v;
        }
        if let Some(v) = j.get("seed").and_then(|v| v.as_f64()) {
            req.seed = Some(v as u64);
        }
        Ok(req)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("prompt", Json::Str(self.prompt.clone())),
            ("max_new", Json::Num(self.max_new as f64)),
            ("temperature", Json::Num(self.temperature)),
            ("top_k", Json::Num(self.top_k as f64)),
            ("priority", Json::Num(self.priority as f64)),
            ("stream", Json::Bool(self.stream)),
        ];
        if !self.stop.is_empty() {
            pairs.push((
                "stop",
                Json::Arr(self.stop.iter().map(|&t| Json::Num(t as f64)).collect()),
            ));
        }
        if let Some(s) = self.seed {
            pairs.push(("seed", Json::Num(s as f64)));
        }
        Json::obj(pairs)
    }
}

/// Lifecycle events of one request, emitted in order:
/// Queued → Started → Token* → (Done | Cancelled | Error), or the single
/// terminal Overloaded when admission shed the request at submit time.
#[derive(Clone, Debug)]
pub enum GenerationEvent {
    Queued { id: u64 },
    Started { id: u64 },
    Token { id: u64, token: u32, index: usize },
    Done { id: u64, tokens: Vec<u32>, finish: FinishReason, queue_ms: f64, total_ms: f64 },
    Cancelled { id: u64 },
    Error { id: u64, message: String },
    /// The admission queue is at capacity; the request was shed without
    /// ever being queued (degraded serving — docs/fault-tolerance.md).
    /// Clients should back off and retry.
    Overloaded { id: u64 },
}

impl GenerationEvent {
    pub fn id(&self) -> u64 {
        match self {
            GenerationEvent::Queued { id }
            | GenerationEvent::Started { id }
            | GenerationEvent::Token { id, .. }
            | GenerationEvent::Done { id, .. }
            | GenerationEvent::Cancelled { id }
            | GenerationEvent::Error { id, .. }
            | GenerationEvent::Overloaded { id } => *id,
        }
    }

    /// Terminal events end a request's stream.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            GenerationEvent::Done { .. }
                | GenerationEvent::Cancelled { .. }
                | GenerationEvent::Error { .. }
                | GenerationEvent::Overloaded { .. }
        )
    }

    /// One wire line: `{"event": "...", "id": N, ...}`.
    pub fn to_json(&self) -> Json {
        match self {
            GenerationEvent::Queued { id } => Json::obj(vec![
                ("event", Json::Str("queued".into())),
                ("id", Json::Num(*id as f64)),
            ]),
            GenerationEvent::Started { id } => Json::obj(vec![
                ("event", Json::Str("started".into())),
                ("id", Json::Num(*id as f64)),
            ]),
            GenerationEvent::Token { id, token, index } => Json::obj(vec![
                ("event", Json::Str("token".into())),
                ("id", Json::Num(*id as f64)),
                ("token", Json::Num(*token as f64)),
                ("index", Json::Num(*index as f64)),
                ("text", Json::Str(ByteTokenizer::decode(&[*token]))),
            ]),
            GenerationEvent::Done { id, tokens, finish, queue_ms, total_ms } => Json::obj(vec![
                ("event", Json::Str("done".into())),
                ("id", Json::Num(*id as f64)),
                ("text", Json::Str(ByteTokenizer::decode(tokens))),
                (
                    "tokens",
                    Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                ),
                ("finish", Json::Str(finish.as_str().into())),
                ("queue_ms", Json::Num(*queue_ms)),
                ("total_ms", Json::Num(*total_ms)),
            ]),
            GenerationEvent::Cancelled { id } => Json::obj(vec![
                ("event", Json::Str("cancelled".into())),
                ("id", Json::Num(*id as f64)),
            ]),
            GenerationEvent::Error { id, message } => Json::obj(vec![
                ("event", Json::Str("error".into())),
                ("id", Json::Num(*id as f64)),
                ("error", Json::Str(message.clone())),
            ]),
            GenerationEvent::Overloaded { id } => Json::obj(vec![
                ("event", Json::Str("overloaded".into())),
                ("id", Json::Num(*id as f64)),
            ]),
        }
    }
}

/// Point-in-time service statistics (`{"cmd":"stats"}` on the wire).
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// Requests waiting for a free engine slot.
    pub queued: usize,
    /// Requests currently decoding.
    pub active: usize,
    /// Completions delivered (Done events).
    pub served: u64,
    /// Requests cancelled (queued or in-flight).
    pub cancelled: u64,
    /// Requests shed at admission because the queue was at capacity
    /// (each one got a terminal Overloaded event).
    pub shed: u64,
    /// Tokens emitted across all requests.
    pub tokens_generated: u64,
    /// Engine decode throughput (rows × steps / second).
    pub tokens_per_sec: f64,
    /// Engine per-step latency percentiles (ms).
    pub token_p50_ms: f64,
    pub token_p95_ms: f64,
    pub token_p99_ms: f64,
    /// Completed-request latency percentiles (ms, submit→finish).
    pub request_p50_ms: f64,
    pub request_p99_ms: f64,
    /// Completed-request queue wait p50 (ms, submit→start).
    pub queue_p50_ms: f64,
    /// Per-arrival lane queue-delay percentiles (ms), from the
    /// log-bucketed histogram below.
    pub lane_queue_p50_ms: f64,
    pub lane_queue_p95_ms: f64,
    pub lane_queue_p99_ms: f64,
    /// Remote fetch round-trip percentiles (ms); zeros for local stores.
    pub fetch_p50_ms: f64,
    pub fetch_p95_ms: f64,
    pub fetch_p99_ms: f64,
    pub uptime_s: f64,
    /// Log-bucketed latency distributions behind the percentile fields
    /// (docs/observability.md): per-decode-step latency, per-arrival lane
    /// queue delay, and remote fetch round-trips.
    pub token_hist: LogHistogram,
    pub lane_queue_hist: LogHistogram,
    pub fetch_hist: LogHistogram,
    /// Per-comm-lane transfer counters (one entry per lane, in lane
    /// order); empty when the backend has no transfer engine (mock).
    pub lanes: Vec<LaneSnapshot>,
    /// Per-device expert-cache shard counters (one entry per device, in
    /// device order; a single entry for the historical one-device
    /// engine); empty when the backend has no cache (mock).
    pub devices: Vec<DeviceSnapshot>,
    /// Per-precision-tier transfer volumes (one entry per configured
    /// tier, ascending bits; a single entry for single-tier engines);
    /// empty when the backend has no transfer engine (mock).
    pub tiers: Vec<TierSnapshot>,
    /// Local-vs-remote byte attribution and remote-fetch health
    /// (docs/remote-store.md); all zeros for local stores.
    pub source: SourceSnapshot,
    /// Per-consumer sensitivity-map decision counters
    /// (docs/sensitivity.md); all zeros under the uniform policy.
    pub sensitivity: SensitivitySnapshot,
}

impl ServerStats {
    pub fn to_json(&self) -> Json {
        let devices = Json::Arr(
            self.devices
                .iter()
                .map(|d| {
                    Json::obj(vec![
                        ("device", Json::Num(d.device as f64)),
                        ("hits", Json::Num(d.hits as f64)),
                        ("misses", Json::Num(d.misses as f64)),
                        ("evictions", Json::Num(d.evictions as f64)),
                        ("resident", Json::Num(d.resident as f64)),
                        ("capacity", Json::Num(d.capacity as f64)),
                        ("queued_bytes", Json::Num(d.queued_bytes as f64)),
                        ("resident_bytes", Json::Num(d.resident_bytes as f64)),
                        ("capacity_bytes", Json::Num(d.capacity_bytes as f64)),
                    ])
                })
                .collect(),
        );
        let lanes = Json::Arr(
            self.lanes
                .iter()
                .map(|l| {
                    Json::obj(vec![
                        ("lane", Json::Num(l.lane as f64)),
                        ("transfers", Json::Num(l.transfers as f64)),
                        ("bytes", Json::Num(l.bytes as f64)),
                        ("on_demand", Json::Num(l.on_demand as f64)),
                        ("prefetch", Json::Num(l.prefetch as f64)),
                        ("upgrades", Json::Num(l.upgrades as f64)),
                        ("busy_ms", Json::Num(l.busy_ms)),
                        ("queued_bytes", Json::Num(l.queued_bytes as f64)),
                        ("queued_jobs", Json::Num(l.queued_jobs as f64)),
                        ("health", Json::Str(l.health.name().into())),
                        ("retries", Json::Num(l.retries as f64)),
                        ("timeouts", Json::Num(l.timeouts as f64)),
                        ("failovers", Json::Num(l.failovers as f64)),
                    ])
                })
                .collect(),
        );
        let tiers = Json::Arr(
            self.tiers
                .iter()
                .map(|t| {
                    Json::obj(vec![
                        ("tier", Json::Str(t.kind.name().into())),
                        ("transfers", Json::Num(t.transfers as f64)),
                        ("bytes", Json::Num(t.bytes as f64)),
                        ("upgrades", Json::Num(t.upgrades as f64)),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("queued", Json::Num(self.queued as f64)),
            ("active", Json::Num(self.active as f64)),
            ("served", Json::Num(self.served as f64)),
            ("cancelled", Json::Num(self.cancelled as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("tokens_generated", Json::Num(self.tokens_generated as f64)),
            ("tokens_per_sec", Json::Num(self.tokens_per_sec)),
            ("token_p50_ms", Json::Num(self.token_p50_ms)),
            ("token_p95_ms", Json::Num(self.token_p95_ms)),
            ("token_p99_ms", Json::Num(self.token_p99_ms)),
            ("request_p50_ms", Json::Num(self.request_p50_ms)),
            ("request_p99_ms", Json::Num(self.request_p99_ms)),
            ("queue_p50_ms", Json::Num(self.queue_p50_ms)),
            ("lane_queue_p50_ms", Json::Num(self.lane_queue_p50_ms)),
            ("lane_queue_p95_ms", Json::Num(self.lane_queue_p95_ms)),
            ("lane_queue_p99_ms", Json::Num(self.lane_queue_p99_ms)),
            ("fetch_p50_ms", Json::Num(self.fetch_p50_ms)),
            ("fetch_p95_ms", Json::Num(self.fetch_p95_ms)),
            ("fetch_p99_ms", Json::Num(self.fetch_p99_ms)),
            ("uptime_s", Json::Num(self.uptime_s)),
            ("token_hist", self.token_hist.to_json()),
            ("lane_queue_hist", self.lane_queue_hist.to_json()),
            ("fetch_hist", self.fetch_hist.to_json()),
            ("lanes", lanes),
            ("devices", devices),
            ("tiers", tiers),
            (
                "source",
                Json::obj(vec![
                    ("local_bytes", Json::Num(self.source.local_bytes as f64)),
                    ("remote_bytes", Json::Num(self.source.remote_bytes as f64)),
                    ("remote_faults", Json::Num(self.source.remote_faults as f64)),
                    ("fetches", Json::Num(self.source.fetches as f64)),
                    ("fetched_bytes", Json::Num(self.source.fetched_bytes as f64)),
                    (
                        "batched_fetches",
                        Json::Num(self.source.batched_fetches as f64),
                    ),
                    ("fetch_ms", Json::Num(self.source.fetch_ms)),
                    ("retries", Json::Num(self.source.retries as f64)),
                    (
                        "checksum_failures",
                        Json::Num(self.source.checksum_failures as f64),
                    ),
                    ("reconnects", Json::Num(self.source.reconnects as f64)),
                ]),
            ),
            (
                "sensitivity",
                Json::obj(vec![
                    (
                        "tier_assigns",
                        Json::Num(self.sensitivity.tier_assigns as f64),
                    ),
                    ("plans", Json::Num(self.sensitivity.plans as f64)),
                    ("evictions", Json::Num(self.sensitivity.evictions as f64)),
                    ("prefetches", Json::Num(self.sensitivity.prefetches as f64)),
                    ("upgrades", Json::Num(self.sensitivity.upgrades as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_wire_roundtrip() {
        let j = Json::parse(
            r#"{"prompt":"hi","max_new":8,"temperature":0.7,"top_k":4,
                "stop":".","priority":2,"stream":true,"seed":9}"#,
        )
        .unwrap();
        let r = GenerationRequest::from_json(&j).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.max_new, 8);
        assert!((r.temperature - 0.7).abs() < 1e-12);
        assert_eq!(r.top_k, 4);
        assert_eq!(r.stop, vec![b'.' as u32]);
        assert_eq!(r.priority, 2);
        assert!(r.stream);
        assert_eq!(r.seed, Some(9));
        // serialize → parse → same fields
        let r2 = GenerationRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(r2.stop, r.stop);
        assert_eq!(r2.max_new, r.max_new);
    }

    #[test]
    fn request_defaults_and_stop_array() {
        let j = Json::parse(r#"{"prompt":"x","stop":[10,13]}"#).unwrap();
        let r = GenerationRequest::from_json(&j).unwrap();
        assert_eq!(r.max_new, 32);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.stop, vec![10, 13]);
        assert!(!r.stream);
        assert!(GenerationRequest::from_json(&Json::parse(r#"{"x":1}"#).unwrap()).is_err());
        assert!(
            GenerationRequest::from_json(&Json::parse(r#"{"prompt":"x","stop":5}"#).unwrap())
                .is_err()
        );
    }

    #[test]
    fn event_lines_carry_ids_and_terminality() {
        let ev = GenerationEvent::Token { id: 3, token: b'a' as u32, index: 0 };
        let j = ev.to_json();
        assert_eq!(j.get("event").and_then(|e| e.as_str()), Some("token"));
        assert_eq!(j.get("text").and_then(|t| t.as_str()), Some("a"));
        assert!(!ev.is_terminal());
        let done = GenerationEvent::Done {
            id: 3,
            tokens: vec![b'a' as u32, b'b' as u32],
            finish: FinishReason::Stop,
            queue_ms: 1.0,
            total_ms: 2.0,
        };
        assert!(done.is_terminal());
        let j = done.to_json();
        assert_eq!(j.get("text").and_then(|t| t.as_str()), Some("ab"));
        assert_eq!(j.get("finish").and_then(|f| f.as_str()), Some("stop"));
        assert_eq!(done.id(), 3);
    }

    #[test]
    fn stats_serialize_nonempty() {
        let s = ServerStats { served: 2, queued: 1, ..Default::default() };
        let j = s.to_json();
        assert_eq!(j.get("served").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("queued").and_then(|v| v.as_usize()), Some(1));
        assert!(j.get("tokens_per_sec").is_some());
        // lanes/devices/tiers always present, empty without a transfer engine
        assert_eq!(j.get("lanes").and_then(|l| l.as_arr()).map(|a| a.len()), Some(0));
        assert_eq!(j.get("devices").and_then(|d| d.as_arr()).map(|a| a.len()), Some(0));
        assert_eq!(j.get("tiers").and_then(|t| t.as_arr()).map(|a| a.len()), Some(0));
    }

    #[test]
    fn stats_serialize_per_device_entries() {
        let s = ServerStats {
            devices: vec![
                DeviceSnapshot {
                    device: 0,
                    hits: 7,
                    misses: 2,
                    evictions: 1,
                    resident: 5,
                    capacity: 8,
                    queued_bytes: 4096,
                    resident_bytes: 2048,
                    capacity_bytes: 65536,
                },
                DeviceSnapshot { device: 1, misses: 3, ..Default::default() },
            ],
            ..Default::default()
        };
        let j = s.to_json();
        let devices = j.get("devices").and_then(|d| d.as_arr()).expect("devices array");
        assert_eq!(devices.len(), 2);
        assert_eq!(devices[0].get("device").and_then(|v| v.as_usize()), Some(0));
        assert_eq!(devices[0].get("hits").and_then(|v| v.as_usize()), Some(7));
        assert_eq!(devices[0].get("misses").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(devices[0].get("evictions").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(devices[0].get("resident").and_then(|v| v.as_usize()), Some(5));
        assert_eq!(devices[0].get("capacity").and_then(|v| v.as_usize()), Some(8));
        assert_eq!(devices[0].get("queued_bytes").and_then(|v| v.as_usize()), Some(4096));
        assert_eq!(
            devices[0].get("resident_bytes").and_then(|v| v.as_usize()),
            Some(2048)
        );
        assert_eq!(
            devices[0].get("capacity_bytes").and_then(|v| v.as_usize()),
            Some(65536)
        );
        assert_eq!(devices[1].get("device").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(devices[1].get("misses").and_then(|v| v.as_usize()), Some(3));
    }

    #[test]
    fn stats_serialize_per_tier_entries() {
        use crate::memory::quant::QuantKind;
        let s = ServerStats {
            tiers: vec![
                TierSnapshot {
                    kind: QuantKind::Int2,
                    transfers: 5,
                    bytes: 1000,
                    upgrades: 0,
                },
                TierSnapshot {
                    kind: QuantKind::Int8,
                    transfers: 2,
                    bytes: 1600,
                    upgrades: 2,
                },
            ],
            ..Default::default()
        };
        let j = s.to_json();
        let tiers = j.get("tiers").and_then(|t| t.as_arr()).expect("tiers array");
        assert_eq!(tiers.len(), 2);
        assert_eq!(tiers[0].get("tier").and_then(|v| v.as_str()), Some("int2"));
        assert_eq!(tiers[0].get("transfers").and_then(|v| v.as_usize()), Some(5));
        assert_eq!(tiers[0].get("bytes").and_then(|v| v.as_usize()), Some(1000));
        assert_eq!(tiers[0].get("upgrades").and_then(|v| v.as_usize()), Some(0));
        assert_eq!(tiers[1].get("tier").and_then(|v| v.as_str()), Some("int8"));
        assert_eq!(tiers[1].get("upgrades").and_then(|v| v.as_usize()), Some(2));
    }

    #[test]
    fn stats_serialize_per_lane_entries() {
        use crate::memory::transfer::LaneHealth;
        let s = ServerStats {
            lanes: vec![
                LaneSnapshot { lane: 0, transfers: 3, bytes: 1024, ..Default::default() },
                LaneSnapshot {
                    lane: 1,
                    on_demand: 2,
                    queued_jobs: 1,
                    health: LaneHealth::Suspect,
                    retries: 4,
                    timeouts: 2,
                    failovers: 1,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        let j = s.to_json();
        let lanes = j.get("lanes").and_then(|l| l.as_arr()).expect("lanes array");
        assert_eq!(lanes.len(), 2);
        assert_eq!(lanes[0].get("transfers").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(lanes[0].get("bytes").and_then(|v| v.as_usize()), Some(1024));
        assert_eq!(lanes[0].get("health").and_then(|v| v.as_str()), Some("healthy"));
        assert_eq!(lanes[0].get("retries").and_then(|v| v.as_usize()), Some(0));
        assert_eq!(lanes[1].get("lane").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(lanes[1].get("on_demand").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(lanes[1].get("queued_jobs").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(lanes[1].get("health").and_then(|v| v.as_str()), Some("suspect"));
        assert_eq!(lanes[1].get("retries").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(lanes[1].get("timeouts").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(lanes[1].get("failovers").and_then(|v| v.as_usize()), Some(1));
    }

    #[test]
    fn stats_serialize_source_attribution() {
        let s = ServerStats {
            source: SourceSnapshot {
                local_bytes: 100,
                remote_bytes: 900,
                remote_faults: 1,
                fetches: 9,
                fetched_bytes: 450,
                batched_fetches: 3,
                fetch_ms: 12.5,
                retries: 2,
                checksum_failures: 1,
                reconnects: 1,
            },
            ..Default::default()
        };
        let j = s.to_json();
        let src = j.get("source").expect("source object");
        assert_eq!(src.get("local_bytes").and_then(|v| v.as_usize()), Some(100));
        assert_eq!(src.get("remote_bytes").and_then(|v| v.as_usize()), Some(900));
        assert_eq!(src.get("remote_faults").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(src.get("fetches").and_then(|v| v.as_usize()), Some(9));
        assert_eq!(src.get("fetched_bytes").and_then(|v| v.as_usize()), Some(450));
        assert_eq!(src.get("batched_fetches").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(src.get("fetch_ms").and_then(|v| v.as_f64()), Some(12.5));
        assert_eq!(src.get("retries").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(
            src.get("checksum_failures").and_then(|v| v.as_usize()),
            Some(1)
        );
        assert_eq!(src.get("reconnects").and_then(|v| v.as_usize()), Some(1));
        // a default (all-local) stats object reports a zeroed source block
        let d = ServerStats::default().to_json();
        let dsrc = d.get("source").expect("source object");
        assert_eq!(dsrc.get("remote_bytes").and_then(|v| v.as_usize()), Some(0));
    }

    #[test]
    fn stats_serialize_sensitivity_counters() {
        let s = ServerStats {
            sensitivity: SensitivitySnapshot {
                tier_assigns: 4,
                plans: 3,
                evictions: 2,
                prefetches: 7,
                upgrades: 1,
            },
            ..Default::default()
        };
        let j = s.to_json();
        let sj = j.get("sensitivity").expect("sensitivity object");
        assert_eq!(sj.get("tier_assigns").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(sj.get("plans").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(sj.get("evictions").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(sj.get("prefetches").and_then(|v| v.as_usize()), Some(7));
        assert_eq!(sj.get("upgrades").and_then(|v| v.as_usize()), Some(1));
        // a default (uniform-policy) stats object reports an all-zero block
        let d = ServerStats::default().to_json();
        let dj = d.get("sensitivity").expect("sensitivity object");
        assert_eq!(dj.get("tier_assigns").and_then(|v| v.as_usize()), Some(0));
    }

    #[test]
    fn stats_serialize_histograms_and_quantiles() {
        let mut s = ServerStats { token_p95_ms: 2.5, ..Default::default() };
        s.token_hist.record(0.002);
        s.lane_queue_hist.record(0.0005);
        let j = s.to_json();
        assert_eq!(j.get("token_p95_ms").and_then(|v| v.as_f64()), Some(2.5));
        for k in [
            "lane_queue_p50_ms",
            "lane_queue_p95_ms",
            "lane_queue_p99_ms",
            "fetch_p50_ms",
            "fetch_p95_ms",
            "fetch_p99_ms",
        ] {
            assert!(j.get(k).is_some(), "missing {k}");
        }
        // histograms round-trip through the wire form
        let th = j.get("token_hist").expect("token_hist");
        let back = LogHistogram::from_json(th);
        assert_eq!(back.count(), 1);
        assert!((back.quantile(0.5) - s.token_hist.quantile(0.5)).abs() < 1e-12);
        let lq = j.get("lane_queue_hist").expect("lane_queue_hist");
        assert_eq!(LogHistogram::from_json(lq).count(), 1);
        assert_eq!(
            j.get("fetch_hist").and_then(|h| h.get("count")).and_then(|v| v.as_usize()),
            Some(0)
        );
    }

    #[test]
    fn overloaded_event_is_terminal_on_the_wire() {
        let ev = GenerationEvent::Overloaded { id: 9 };
        assert!(ev.is_terminal());
        assert_eq!(ev.id(), 9);
        let j = ev.to_json();
        assert_eq!(j.get("event").and_then(|e| e.as_str()), Some("overloaded"));
        assert_eq!(j.get("id").and_then(|v| v.as_usize()), Some(9));
        // shed counter rides the stats object
        let s = ServerStats { shed: 3, ..Default::default() };
        assert_eq!(s.to_json().get("shed").and_then(|v| v.as_usize()), Some(3));
    }
}
