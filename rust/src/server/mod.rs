//! TCP serving front-end (wired up after the engine: see server::tcp).

pub mod tcp;
