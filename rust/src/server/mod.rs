//! Serving front-end: typed API ([`api`]), the engine-owning service loop
//! ([`service`]), and the line-protocol TCP adapter ([`tcp`]).

pub mod api;
pub mod service;
pub mod tcp;
