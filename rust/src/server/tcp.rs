//! Line-delimited-JSON TCP adapter over the [`super::service::InferenceService`].
//!
//! Protocol v2 (one JSON object per line; see docs/protocol.md):
//!
//!   -> {"prompt": "...", "max_new": 32, "temperature": 0.7, "top_k": 4,
//!       "stop": ".", "priority": 1, "seed": 42}
//!   <- {"id": 0, "text": "...", "tokens": [..], "finish": "length|stop",
//!       "queue_ms": .., "total_ms": ..}
//!
//!   -> {"prompt": "...", "stream": true, ...}
//!   <- {"event": "queued",  "id": 0}
//!   <- {"event": "started", "id": 0}
//!   <- {"event": "token",   "id": 0, "token": 104, "index": 0, "text": "h"}
//!      ... one line per token ...
//!   <- {"event": "done", "id": 0, "text": "...", "tokens": [..],
//!       "finish": "...", "queue_ms": .., "total_ms": ..}
//!      (or a terminal {"event": "cancelled"} / {"event": "error"} /
//!       {"event": "overloaded"} line — the last means the submit was shed
//!       at admission because the queue was full; back off and retry)
//!
//!   -> {"cmd": "cancel", "id": 0}
//!   <- {"id": 0, "cancelled": true}          // false: id unknown/finished
//!
//!   -> {"cmd": "stats"}
//!   <- {"queued": .., "active": .., "served": .., "cancelled": ..,
//!       "shed": .., "tokens_generated": .., "tokens_per_sec": ..,
//!       "token_p50_ms": .., "token_p99_ms": .., "request_p50_ms": ..,
//!       "request_p99_ms": .., "queue_p50_ms": .., "uptime_s": ..,
//!       "lanes": [..per comm lane, incl. health/retries/timeouts/
//!       failovers..], "devices": [..per cache shard..]}
//!
//!   -> {"cmd": "metrics"}
//!   <- {"exposition": "# HELP adapmoe_requests_queued ...\n..."}
//!      (Prometheus-style text exposition of every ServerStats counter
//!       family plus the log-bucketed latency histograms; see
//!       docs/observability.md)
//!
//!   -> {"cmd": "ping"}
//!   <- {"pong": true}
//!
//! One engine thread drives the service loop (admit → decode → retire);
//! connection threads only parse lines, talk to a [`ServiceHandle`], and
//! write responses — cancellation is id-addressed, so any connection can
//! cancel any request. This is the E2E serving path used by
//! `examples/serve_demo.rs`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::server::api::{GenerationEvent, GenerationRequest};
use crate::server::service::{Backend, InferenceService, ServiceHandle};
use crate::util::json::Json;

/// How long a connection waits on a generation before giving up on it.
const EVENT_TIMEOUT: Duration = Duration::from_secs(600);

/// While waiting on generation events, probe the client socket this often
/// so a disconnected client cancels its request instead of decoding into
/// the void for up to [`EVENT_TIMEOUT`].
const PROBE_INTERVAL: Duration = Duration::from_millis(100);

/// Serve `backend` on `addr` until `shutdown` flips. Blocks the caller
/// (spawn a thread if needed; PJRT-backed engines must stay on the thread
/// that built them). Returns total completions served.
pub fn serve<B: Backend>(mut backend: B, addr: &str, shutdown: Arc<AtomicBool>) -> Result<u64> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let (service, handle) = InferenceService::new();

    // acceptor thread: hand each connection its own service handle
    let acceptor = {
        let shutdown = Arc::clone(&shutdown);
        std::thread::Builder::new()
            .name("adapmoe-accept".into())
            .spawn(move || {
                while !shutdown.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let handle = handle.clone();
                            let _ = std::thread::Builder::new()
                                .name("adapmoe-conn".into())
                                .spawn(move || handle_conn(stream, handle));
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn acceptor")
    };

    // engine loop (this thread). On an engine error, still flip shutdown
    // and join — otherwise the acceptor keeps taking connections that a
    // dead service will never answer.
    let served = service.run(&mut backend, &shutdown);
    shutdown.store(true, Ordering::SeqCst);
    let _ = acceptor.join();
    served
}

/// Liveness probe over a connection's read half. `peek` answering 0 bytes
/// means the peer closed its socket; generations the connection is waiting
/// on should then be cancelled rather than decoded for nobody.
struct ConnProbe {
    stream: Option<TcpStream>,
}

impl ConnProbe {
    fn new(stream: &TcpStream) -> ConnProbe {
        ConnProbe { stream: stream.try_clone().ok() }
    }

    /// Probe-less stand-in for in-memory callers (tests drive
    /// `handle_line` against a `Vec<u8>` writer with no socket).
    fn none() -> ConnProbe {
        ConnProbe { stream: None }
    }

    /// True when the peer has closed (or broken) the connection. Only
    /// called from the connection's own thread between line reads, so the
    /// temporary read timeout never races the `BufReader`.
    fn client_gone(&self) -> bool {
        let Some(s) = &self.stream else { return false };
        if s.set_read_timeout(Some(Duration::from_millis(1))).is_err() {
            return true;
        }
        let mut byte = [0u8; 1];
        let gone = match s.peek(&mut byte) {
            Ok(0) => true, // orderly shutdown
            Ok(_) => false,
            Err(e) => !matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ),
        };
        let _ = s.set_read_timeout(None);
        gone
    }
}

fn handle_conn(stream: TcpStream, handle: ServiceHandle) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let probe = ConnProbe::new(&stream);
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let ok = match handle_line(&line, &handle, &mut writer, &probe) {
            Ok(()) => true,
            Err(e) => {
                let err = Json::obj(vec![("error", Json::Str(format!("{e:#}")))]);
                writeln!(writer, "{}", err.to_string()).is_ok()
            }
        };
        if !ok {
            break;
        }
    }
}

/// Dispatch one request line, writing one line (commands, non-streamed
/// generations) or a line per event (streamed generations).
fn handle_line(
    line: &str,
    handle: &ServiceHandle,
    writer: &mut impl Write,
    probe: &ConnProbe,
) -> Result<()> {
    let req = Json::parse(line).context("bad request json")?;
    if req.get("prompt").is_some() {
        let greq = GenerationRequest::from_json(&req)?;
        let stream_mode = greq.stream;
        let (id, rx) = handle.submit(greq);
        let result = if stream_mode {
            stream_events(&rx, writer, probe)
        } else {
            collect_completion(&rx, writer, probe)
        };
        if result.is_err() {
            // client gone or timed out: release the request's slot instead
            // of decoding tokens nobody will read (no-op if already done)
            let _ = handle.cancel(id);
        }
        return result;
    }
    let reply = match req.get("cmd").and_then(|c| c.as_str()) {
        Some("stats") => handle.stats().to_json(),
        Some("metrics") => Json::obj(vec![("exposition", Json::Str(handle.metrics()))]),
        Some("cancel") => {
            let id = req
                .get("id")
                .and_then(|v| v.as_f64())
                .context("cancel needs a numeric 'id'")? as u64;
            Json::obj(vec![
                ("id", Json::Num(id as f64)),
                ("cancelled", Json::Bool(handle.cancel(id))),
            ])
        }
        Some("ping") => Json::obj(vec![("pong", Json::Bool(true))]),
        Some(other) => bail!("unknown cmd '{other}'"),
        None => bail!("unknown request: expected 'prompt' or 'cmd'"),
    };
    writeln!(writer, "{}", reply.to_string())?;
    Ok(())
}

/// Wait for the next generation event, probing the client socket between
/// short receive slices: a disconnect surfaces as an error here, which the
/// caller turns into a cancel — without it, a vanished client would hold
/// its decode slot until [`EVENT_TIMEOUT`].
fn next_event(rx: &Receiver<GenerationEvent>, probe: &ConnProbe) -> Result<GenerationEvent> {
    let mut waited = Duration::ZERO;
    loop {
        match rx.recv_timeout(PROBE_INTERVAL) {
            Ok(ev) => return Ok(ev),
            Err(RecvTimeoutError::Timeout) => {
                if probe.client_gone() {
                    bail!("client disconnected mid-generation");
                }
                waited += PROBE_INTERVAL;
                if waited >= EVENT_TIMEOUT {
                    bail!("generation timed out");
                }
            }
            Err(RecvTimeoutError::Disconnected) => bail!("service dropped the event stream"),
        }
    }
}

/// Streamed generation: forward every event as its own line.
fn stream_events(
    rx: &Receiver<GenerationEvent>,
    writer: &mut impl Write,
    probe: &ConnProbe,
) -> Result<()> {
    loop {
        let ev = next_event(rx, probe)?;
        writeln!(writer, "{}", ev.to_json().to_string())?;
        if ev.is_terminal() {
            return Ok(());
        }
    }
}

/// Non-streamed generation: wait for the terminal event, answer one line.
/// Done lines keep the v1 shape (id/text/tokens/queue_ms/total_ms) plus
/// the "finish" reason.
fn collect_completion(
    rx: &Receiver<GenerationEvent>,
    writer: &mut impl Write,
    probe: &ConnProbe,
) -> Result<()> {
    loop {
        let ev = next_event(rx, probe)?;
        if !ev.is_terminal() {
            continue;
        }
        let mut j = ev.to_json();
        if let (Json::Obj(m), GenerationEvent::Done { .. }) = (&mut j, &ev) {
            m.remove("event"); // v1 completion shape
        }
        writeln!(writer, "{}", j.to_string())?;
        return Ok(());
    }
}

// -- clients (examples / benches / tests) ------------------------------------

/// One finished generation as seen by a client.
#[derive(Clone, Debug, Default)]
pub struct ClientCompletion {
    pub id: u64,
    pub text: String,
    pub tokens: Vec<u32>,
    pub finish: String,
    pub queue_ms: f64,
    pub total_ms: f64,
    /// Token-event lines observed before the terminal line (streaming only).
    pub token_lines: usize,
}

/// Blocking client: one request, one completion. With `req.stream` it
/// consumes the event stream (counting token lines) until the terminal
/// line; otherwise it reads the single completion line.
pub fn client_generate(addr: &str, req: &GenerationRequest) -> Result<ClientCompletion> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    writeln!(stream, "{}", req.to_json().to_string())?;
    let mut reader = BufReader::new(stream);
    let mut out = ClientCompletion::default();
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            bail!("server closed connection mid-generation");
        }
        let j = Json::parse(&line).context("bad response json")?;
        if let Some(err) = j.get("error").and_then(|e| e.as_str()) {
            bail!("server error: {err}");
        }
        match j.get("event").and_then(|e| e.as_str()) {
            Some("token") => out.token_lines += 1,
            Some("queued") | Some("started") => {}
            Some("cancelled") => {
                out.id = j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                out.finish = "cancelled".into();
                return Ok(out);
            }
            // admission shed: terminal, no tokens — callers back off/retry
            Some("overloaded") => {
                out.id = j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                out.finish = "overloaded".into();
                return Ok(out);
            }
            // "done" event line (streaming) or the bare completion object
            _ => {
                out.id = j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                out.text = j
                    .get("text")
                    .and_then(|t| t.as_str())
                    .unwrap_or_default()
                    .to_string();
                if let Some(toks) = j.get("tokens").and_then(|t| t.as_arr()) {
                    out.tokens = toks.iter().filter_map(|t| t.as_f64()).map(|t| t as u32).collect();
                }
                out.finish = j
                    .get("finish")
                    .and_then(|f| f.as_str())
                    .unwrap_or("length")
                    .to_string();
                out.queue_ms = j.get("queue_ms").and_then(|v| v.as_f64()).unwrap_or(0.0);
                out.total_ms = j.get("total_ms").and_then(|v| v.as_f64()).unwrap_or(0.0);
                return Ok(out);
            }
        }
    }
}

/// v1-compatible convenience: greedy, non-streamed; returns
/// (text, queue_ms, total_ms).
pub fn client_request(addr: &str, prompt: &str, max_new: usize) -> Result<(String, f64, f64)> {
    let req = GenerationRequest { max_new, ..GenerationRequest::new(prompt) };
    let c = client_generate(addr, &req)?;
    Ok((c.text, c.queue_ms, c.total_ms))
}

/// Cancel request `id`; returns whether the server knew the id.
pub fn client_cancel(addr: &str, id: u64) -> Result<bool> {
    let j = client_cmd(addr, Json::obj(vec![
        ("cmd", Json::Str("cancel".into())),
        ("id", Json::Num(id as f64)),
    ]))?;
    Ok(j.get("cancelled").and_then(|b| b.as_bool()).unwrap_or(false))
}

/// Fetch the server's stats object.
pub fn client_stats(addr: &str) -> Result<Json> {
    client_cmd(addr, Json::obj(vec![("cmd", Json::Str("stats".into()))]))
}

/// Fetch the server's Prometheus-style metrics exposition text.
pub fn client_metrics(addr: &str) -> Result<String> {
    let j = client_cmd(addr, Json::obj(vec![("cmd", Json::Str("metrics".into()))]))?;
    j.get("exposition")
        .and_then(|e| e.as_str())
        .map(str::to_string)
        .context("metrics reply missing 'exposition'")
}

fn client_cmd(addr: &str, cmd: Json) -> Result<Json> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    writeln!(stream, "{}", cmd.to_string())?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let j = Json::parse(&line).context("bad response json")?;
    if let Some(err) = j.get("error").and_then(|e| e.as_str()) {
        bail!("server error: {err}");
    }
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_protocol_rejects_garbage_and_answers_commands() {
        let (_service, handle) = InferenceService::new();
        let probe = ConnProbe::none();
        let mut out = Vec::new();
        assert!(handle_line("not json", &handle, &mut out, &probe).is_err());
        assert!(handle_line("{\"x\":1}", &handle, &mut out, &probe).is_err());
        assert!(handle_line("{\"cmd\":\"nope\"}", &handle, &mut out, &probe).is_err());
        assert!(handle_line("{\"cmd\":\"cancel\"}", &handle, &mut out, &probe).is_err());

        handle_line("{\"cmd\":\"ping\"}", &handle, &mut out, &probe).unwrap();
        let pong = Json::parse(std::str::from_utf8(&out).unwrap().trim()).unwrap();
        assert_eq!(pong.get("pong").and_then(|b| b.as_bool()), Some(true));

        // stats works with an idle service and is non-empty
        let mut out = Vec::new();
        handle_line("{\"cmd\":\"stats\"}", &handle, &mut out, &probe).unwrap();
        let stats = Json::parse(std::str::from_utf8(&out).unwrap().trim()).unwrap();
        assert_eq!(stats.get("served").and_then(|v| v.as_usize()), Some(0));
        assert!(stats.get("uptime_s").is_some());

        // metrics answers a text exposition wrapped in one JSON line
        let mut out = Vec::new();
        handle_line("{\"cmd\":\"metrics\"}", &handle, &mut out, &probe).unwrap();
        let j = Json::parse(std::str::from_utf8(&out).unwrap().trim()).unwrap();
        let text = j.get("exposition").and_then(|e| e.as_str()).unwrap();
        assert!(text.contains("# TYPE adapmoe_requests_served_total counter"));
        assert!(text.contains("adapmoe_uptime_seconds"));

        // cancel with an unknown id answers false rather than erroring
        let mut out = Vec::new();
        handle_line("{\"cmd\":\"cancel\",\"id\":42}", &handle, &mut out, &probe).unwrap();
        let j = Json::parse(std::str::from_utf8(&out).unwrap().trim()).unwrap();
        assert_eq!(j.get("cancelled").and_then(|b| b.as_bool()), Some(false));
    }

    // Full socket round-trips (streaming, cancellation, stats) run against
    // MockBackend in rust/tests/protocol.rs, and against the real engine +
    // artifacts in rust/tests/integration.rs.
}
