//! Line-delimited-JSON TCP serving front-end.
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "...", "max_new": 32}
//!   <- {"id": 0, "text": "...", "tokens": [..], "queue_ms": .., "total_ms": ..}
//!   -> {"cmd": "stats"}
//!   <- {"tokens_per_sec": .., "p50_ms": .., "p99_ms": .., ...}
//!
//! One engine thread drives continuous batching (admit → decode → retire);
//! connection threads only parse/enqueue/respond. This is the E2E serving
//! path used by `examples/serve_demo.rs`.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::batcher::Batcher;
use crate::coordinator::engine::Engine;
use crate::model::sampling;
use crate::model::tokenizer::ByteTokenizer;
use crate::util::json::Json;

/// Completed generation sent back to the connection thread.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub queued_at: Instant,
    pub started_at: Instant,
    pub finished_at: Instant,
}

struct Shared {
    batcher: Batcher,
    responders: HashMap<u64, Sender<Completion>>,
    submit_times: HashMap<u64, Instant>,
    start_times: HashMap<u64, Instant>,
}

/// Serve `engine` on `addr` until `shutdown` flips. Blocks the caller
/// (spawn a thread if needed). Returns total completions served.
pub fn serve(mut engine: Engine, addr: &str, shutdown: Arc<AtomicBool>) -> Result<u64> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let shared = Arc::new(Mutex::new(Shared {
        batcher: Batcher::new(),
        responders: HashMap::new(),
        submit_times: HashMap::new(),
        start_times: HashMap::new(),
    }));

    // acceptor thread
    let acceptor = {
        let shared = Arc::clone(&shared);
        let shutdown = Arc::clone(&shutdown);
        std::thread::Builder::new()
            .name("adapmoe-accept".into())
            .spawn(move || {
                while !shutdown.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let shared = Arc::clone(&shared);
                            let _ = std::thread::Builder::new()
                                .name("adapmoe-conn".into())
                                .spawn(move || handle_conn(stream, shared));
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn acceptor")
    };

    // engine loop (this thread)
    let mut served = 0u64;
    while !shutdown.load(Ordering::SeqCst) {
        // admit new work into free slots
        {
            let mut g = shared.lock().unwrap();
            while g.batcher.queued() > 0 {
                let Some(row) = engine.acquire_slot() else { break };
                g.batcher.admit(&[row]);
                let started = g.batcher.active.last().map(|a| a.req.id);
                if let Some(id) = started {
                    g.start_times.insert(id, Instant::now());
                }
            }
            if g.batcher.active.is_empty() {
                drop(g);
                std::thread::sleep(Duration::from_millis(2));
                continue;
            }
        }

        // decode one step for all active rows
        let inputs = { shared.lock().unwrap().batcher.step_inputs() };
        let outs = engine.decode_step(&inputs)?;
        let sampled: Vec<(usize, u32)> = outs
            .iter()
            .map(|(row, logits)| (*row, sampling::greedy(logits)))
            .collect();

        let mut g = shared.lock().unwrap();
        g.batcher.apply_step(&sampled);
        // rows whose KV is exhausted must retire regardless of max_new
        for a in g.batcher.active.iter_mut() {
            if engine.slot_full(a.row) {
                a.req.max_new = a.generated.len();
            }
        }
        for done in g.batcher.retire() {
            engine.release_slot(done.row);
            served += 1;
            let id = done.req.id;
            let queued_at = g.submit_times.remove(&id).unwrap_or_else(Instant::now);
            let started_at = g.start_times.remove(&id).unwrap_or(queued_at);
            if let Some(tx) = g.responders.remove(&id) {
                let _ = tx.send(Completion {
                    id,
                    tokens: done.generated,
                    queued_at,
                    started_at,
                    finished_at: Instant::now(),
                });
            }
        }
    }
    drop(shared);
    let _ = acceptor.join();
    Ok(served)
}

fn handle_conn(stream: TcpStream, shared: Arc<Mutex<Shared>>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply = match handle_line(&line, &shared) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![("error", Json::Str(format!("{e:#}")))]),
        };
        if writeln!(writer, "{}", reply.to_string()).is_err() {
            break;
        }
    }
    let _ = peer;
}

fn handle_line(line: &str, shared: &Arc<Mutex<Shared>>) -> Result<Json> {
    let req = Json::parse(line).context("bad request json")?;
    if let Some(prompt) = req.get("prompt").and_then(|p| p.as_str()) {
        let max_new = req.get("max_new").and_then(|v| v.as_usize()).unwrap_or(32);
        let tokens = ByteTokenizer::encode(prompt);
        let (tx, rx) = std::sync::mpsc::channel();
        let id = {
            let mut g = shared.lock().unwrap();
            let id = g.batcher.submit(tokens, max_new);
            g.responders.insert(id, tx);
            g.submit_times.insert(id, Instant::now());
            id
        };
        let done = rx
            .recv_timeout(Duration::from_secs(600))
            .context("generation timed out")?;
        let text = ByteTokenizer::decode(&done.tokens);
        Ok(Json::obj(vec![
            ("id", Json::Num(id as f64)),
            ("text", Json::Str(text)),
            (
                "tokens",
                Json::Arr(done.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            (
                "queue_ms",
                Json::Num(
                    done.started_at.duration_since(done.queued_at).as_secs_f64() * 1e3,
                ),
            ),
            (
                "total_ms",
                Json::Num(
                    done.finished_at.duration_since(done.queued_at).as_secs_f64() * 1e3,
                ),
            ),
        ]))
    } else if req.get("cmd").and_then(|c| c.as_str()) == Some("ping") {
        Ok(Json::obj(vec![("pong", Json::Bool(true))]))
    } else {
        anyhow::bail!("unknown request: expected 'prompt' or 'cmd'")
    }
}

/// Blocking client for examples/benches: one request, one completion.
pub fn client_request(addr: &str, prompt: &str, max_new: usize) -> Result<(String, f64, f64)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let req = Json::obj(vec![
        ("prompt", Json::Str(prompt.to_string())),
        ("max_new", Json::Num(max_new as f64)),
    ]);
    writeln!(stream, "{}", req.to_string())?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let j = Json::parse(&line).context("bad response json")?;
    if let Some(err) = j.get("error").and_then(|e| e.as_str()) {
        anyhow::bail!("server error: {err}");
    }
    Ok((
        j.get("text").and_then(|t| t.as_str()).unwrap_or_default().to_string(),
        j.get("queue_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
        j.get("total_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_protocol_rejects_garbage() {
        let shared = Arc::new(Mutex::new(Shared {
            batcher: Batcher::new(),
            responders: HashMap::new(),
            submit_times: HashMap::new(),
            start_times: HashMap::new(),
        }));
        assert!(handle_line("not json", &shared).is_err());
        assert!(handle_line("{\"x\":1}", &shared).is_err());
        let pong = handle_line("{\"cmd\":\"ping\"}", &shared).unwrap();
        assert_eq!(pong.get("pong").and_then(|b| b.as_bool()), Some(true));
    }

    // Full server round-trips run in rust/tests/integration.rs (need artifacts).
}
