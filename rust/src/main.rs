//! adapmoe — CLI leader for the AdapMoE serving stack.
//!
//! Subcommands:
//!   generate    one prompt through a chosen serving method
//!   serve       TCP serving front-end (line-delimited JSON)
//!   plan-cache  print the DP cache allocation for a budget
//!   profile     decode eval tokens and print the online trace (α/β/…)
//!
//! Common flags: --artifacts DIR --method NAME --platform NAME --quant KIND
//!               --cache N --batch B --time-scale X --seed S

use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use adapmoe::coordinator::cache_plan;
use adapmoe::coordinator::engine::Engine;
use adapmoe::coordinator::policy::{self, RunSettings};
use adapmoe::coordinator::profile::Profile;
use adapmoe::coordinator::sensitivity::SensitivityPolicy;
use adapmoe::memory::faults::FaultPlan;
use adapmoe::memory::platform::Platform;
use adapmoe::memory::quant::QuantKind;
use adapmoe::memory::sharded_cache::Placement;
use adapmoe::memory::tiered_store::{PrecisionPolicy, TieredStore};
use adapmoe::memory::transfer::LanePolicy;
use adapmoe::model::tokenizer::{ByteTokenizer, EvalStream};
use adapmoe::net::{ArtifactImage, StoreServer};
use adapmoe::server::api::{GenerationEvent, GenerationRequest, ServerStats};
use adapmoe::server::service::{stats_from_perf, Backend, InferenceService};
use adapmoe::server::tcp;
use adapmoe::util::cli::Args;
use adapmoe::util::rng::Rng;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
        return;
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv);
    let r = match cmd.as_str() {
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "plan-cache" => cmd_plan_cache(&args),
        "profile" => cmd_profile(&args),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => {
            usage();
            Err(anyhow::anyhow!("unknown subcommand '{other}'"))
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "adapmoe — AdapMoE (ICCAD'24) serving stack\n\
         \n\
         USAGE: adapmoe <generate|serve|plan-cache|profile> [flags]\n\
         \n\
         flags:\n\
           --artifacts DIR   artifacts directory (default: artifacts)\n\
           --method NAME     {} (default: adapmoe)\n\
           --platform NAME   {:?} (default: rtx4090)\n\
           --quant KIND      f32|int8|4bit|4+2bit (default: 4bit)\n\
           --cache N         total cached experts (default: half of all)\n\
           --batch B         batch bucket (default: 1 generate, 4 serve)\n\
           --time-scale X    simulated-link time multiplier (default: 1.0)\n\
           --lanes N         parallel comm lanes feeding the completion board (default: 1)\n\
           --lane-policy P   {} (default: round-robin)\n\
                             lane semantics: docs/transfer-lanes.md\n\
           --devices N       device backends sharding the expert cache (default: 1)\n\
           --placement P     {} (default: layer)\n\
                             device sharding: docs/sharded-backends.md\n\
           --tiers LIST      comma-separated precision tiers, e.g. int2,int4\n\
                             (default: the single --quant tier)\n\
           --precision-policy P  {} (default: fixed; urgency when --tiers\n\
                             names several) — docs/tiered-precision.md\n\
           --upgrade-budget N  background precision upgrades per idle moment\n\
                             (default: 0 = off)\n\
           --sensitivity-policy P  {} (default: uniform) — one map driving\n\
                             tier floors, cache re-plans, eviction and\n\
                             upgrade order (docs/sensitivity.md)\n\
           --prefetch-device-cap N  per-device in-flight prefetch cap\n\
                             (default: 0 = global window only)\n\
           --fault-plan PLAN scripted lane/device faults, ;-separated\n\
                             STEP:KIND:ARG events, e.g. 3:halt:1;5:slow:0:4\n\
                             (kinds: halt|slow|flaky|delay|blackout —\n\
                             docs/fault-tolerance.md)\n\
           --remote ADDR     fetch expert weights from an artifact server\n\
                             instead of local weights (cacheless mode —\n\
                             docs/remote-store.md)\n\
           --serve-store ADDR  (serve) also publish this engine's expert\n\
                             store as an artifact server on ADDR\n\
           --prompt TEXT     (generate) prompt text\n\
           --max-new N       (generate) tokens to generate (default: 64)\n\
           --temperature X   (generate) sampling temperature, 0 = greedy (default: 0)\n\
           --top-k K         (generate) sample among the K best logits, 0 = all (default: 0)\n\
           --stop TEXT       (generate) stop at any byte-token of TEXT (default: none)\n\
           --seed S          (generate) sampling seed (default: derived from id)\n\
           --addr HOST:PORT  (serve) bind address (default: 127.0.0.1:7411)\n\
                             wire format: docs/protocol.md (streaming, cancel, stats)\n\
           --tokens N        (profile) eval tokens to decode (default: 200)\n\
           --budget N        (plan-cache) cache budget in experts\n\
           --trace-out FILE  record a flight-recorder timeline and write it as\n\
                             Chrome trace-event JSON at exit (open in Perfetto;\n\
                             docs/observability.md)\n\
           --metrics-out FILE  (generate|profile) write the Prometheus-style\n\
                             metrics exposition at exit; under serve use the\n\
                             {{\"cmd\":\"metrics\"}} wire op instead",
        policy::METHODS.join("|"),
        Platform::names(),
        LanePolicy::names().join("|"),
        Placement::names().join("|"),
        PrecisionPolicy::names().join("|"),
        SensitivityPolicy::names().join("|"),
    );
}

/// Arm the flight recorder when `--trace-out FILE` is present; returns the
/// output path so [`trace_finish`] can dump the timeline after the run.
fn trace_setup(args: &Args) -> Option<PathBuf> {
    let path = args.get("trace-out").map(PathBuf::from);
    if path.is_some() {
        adapmoe::obs::enable();
        eprintln!("[adapmoe] flight recorder armed");
    }
    path
}

/// Drain the flight recorder and write Chrome trace-event JSON to `path`
/// (no-op when `--trace-out` was absent).
fn trace_finish(args: &Args, path: Option<PathBuf>) -> Result<()> {
    let Some(path) = path else { return Ok(()) };
    let events = adapmoe::obs::drain();
    let dropped = adapmoe::obs::dropped();
    adapmoe::obs::disable();
    let n_lanes = args.usize_or("lanes", 1);
    let n_devices = args.usize_or("devices", 1);
    let j = adapmoe::obs::chrome_trace(&events, n_lanes, n_devices);
    std::fs::write(&path, j.to_string())
        .with_context(|| format!("writing trace to {}", path.display()))?;
    eprintln!(
        "[adapmoe] wrote {} trace events to {} ({} dropped)",
        events.len(),
        path.display(),
        dropped
    );
    Ok(())
}

/// Write the Prometheus-style metrics exposition for `stats` when
/// `--metrics-out FILE` is present.
fn metrics_finish(args: &Args, stats: &ServerStats) -> Result<()> {
    let Some(path) = args.get("metrics-out") else { return Ok(()) };
    let text = adapmoe::obs::metrics::MetricsRegistry::from_server_stats(stats).render();
    std::fs::write(path, text).with_context(|| format!("writing metrics to {path}"))?;
    eprintln!("[adapmoe] wrote metrics exposition to {path}");
    Ok(())
}

/// Build an engine from CLI flags (shared by generate/serve/profile).
fn build_engine(args: &Args, default_batch: usize) -> Result<Engine> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let profile = Profile::load(&dir)?;
    let n_layers = profile.sensitivity.len();
    let platform = Platform::preset(&args.str_or("platform", "rtx4090"))
        .context("unknown platform (see --help)")?;
    let quant = QuantKind::from_name(&args.str_or("quant", "4bit"))
        .context("unknown quant kind (see --help)")?;
    let mut settings = RunSettings::new(
        args.usize_or("batch", default_batch),
        args.usize_or("cache", n_layers * 8 / 2),
        quant,
        platform,
    );
    settings.time_scale = args.f64_or("time-scale", 1.0);
    settings.n_lanes = args.usize_or("lanes", 1);
    if settings.n_lanes == 0 {
        bail!("--lanes must be >= 1");
    }
    settings.lane_policy = LanePolicy::from_name(&args.str_or("lane-policy", "round-robin"))
        .context("unknown lane policy (see --help)")?;
    settings.n_devices = args.usize_or("devices", 1);
    if settings.n_devices == 0 {
        bail!("--devices must be >= 1");
    }
    settings.placement = Placement::from_name(&args.str_or("placement", "layer"))
        .context("unknown placement (see --help)")?;
    if let Some(list) = args.get("tiers") {
        let kinds = TieredStore::parse_tiers(list)
            .context("unknown precision tier in --tiers (see --help)")?;
        if kinds.is_empty() {
            bail!("--tiers must name at least one tier");
        }
        settings.tiers = kinds;
    }
    let default_precision = if settings.tiers.len() > 1 { "urgency" } else { "fixed" };
    settings.precision =
        PrecisionPolicy::from_name(&args.str_or("precision-policy", default_precision))
            .context("unknown precision policy (see --help)")?;
    settings.upgrade_budget = args.usize_or("upgrade-budget", 0);
    if settings.upgrade_budget > 0 && settings.tiers.len() < 2 {
        bail!("--upgrade-budget needs --tiers with at least two tiers");
    }
    settings.sensitivity =
        SensitivityPolicy::from_name(&args.str_or("sensitivity-policy", "uniform"))
            .context("unknown sensitivity policy (see --help)")?;
    let cap = args.usize_or("prefetch-device-cap", 0);
    settings.prefetch_per_device = (cap > 0).then_some(cap);
    if let Some(spec) = args.get("fault-plan") {
        let plan = FaultPlan::parse(spec).context("bad --fault-plan (see --help)")?;
        plan.validate(settings.n_lanes, settings.n_devices)
            .context("bad --fault-plan (see --help)")?;
        if !plan.is_empty() {
            eprintln!("[adapmoe] fault plan armed: {plan}");
            settings.fault_plan = Some(plan);
        }
    }
    if let Some(addr) = args.get("remote") {
        eprintln!("[adapmoe] cacheless mode: expert store at {addr}");
        settings.remote = Some(addr.to_string());
    }
    let method = args.str_or("method", "adapmoe");
    let ecfg = policy::method(&method, &settings, &profile)
        .with_context(|| format!("unknown method '{method}'"))?;
    let tier_names: Vec<&str> = settings.tiers.iter().map(|k| k.name()).collect();
    eprintln!(
        "[adapmoe] method={method} platform={} quant={} cache={} batch={} lanes={}/{} \
         devices={}/{} tiers={}/{}",
        settings.platform.name,
        settings.quant.name(),
        settings.cache_budget,
        settings.batch,
        settings.n_lanes,
        settings.lane_policy.name(),
        settings.n_devices,
        settings.placement.name(),
        if tier_names.is_empty() {
            settings.quant.name().to_string()
        } else {
            tier_names.join(",")
        },
        settings.precision.name(),
    );
    Engine::from_artifacts(&dir, ecfg)
}

fn cmd_generate(args: &Args) -> Result<()> {
    let trace_out = trace_setup(args);
    let mut engine = build_engine(args, 1)?;
    let prompt_text = args.str_or("prompt", "the model expert gate ");
    if prompt_text.is_empty() {
        bail!("--prompt must be non-empty");
    }
    let req = GenerationRequest {
        prompt: prompt_text.clone(),
        max_new: args.usize_or("max-new", 64),
        temperature: args.f64_or("temperature", 0.0),
        top_k: args.usize_or("top-k", 0),
        stop: ByteTokenizer::encode(&args.str_or("stop", "")),
        seed: args.get("seed").and_then(|s| s.parse().ok()),
        stream: true,
        ..Default::default()
    };

    // Same path as serving: the engine loop runs here, a printer thread
    // relays the event stream to stdout as tokens land.
    let (service, handle) = InferenceService::new();
    let (_id, rx) = handle.submit(req);
    {
        use std::io::Write as _;
        print!("{prompt_text}");
        let _ = std::io::stdout().flush();
    }
    let printer = std::thread::spawn(move || {
        use std::io::Write as _;
        let mut summary = None;
        for ev in rx {
            match ev {
                GenerationEvent::Token { token, .. } => {
                    print!("{}", ByteTokenizer::decode(&[token]));
                    let _ = std::io::stdout().flush();
                }
                GenerationEvent::Done { tokens, finish, queue_ms, total_ms, .. } => {
                    summary = Some((tokens.len(), finish, queue_ms, total_ms));
                }
                GenerationEvent::Error { message, .. } => {
                    eprintln!("\n[adapmoe] generation error: {message}");
                }
                _ => {}
            }
        }
        summary
    });
    let t0 = std::time::Instant::now();
    service.run_until_idle(&mut engine)?;
    let dt = t0.elapsed().as_secs_f64();
    let (n_tokens, finish, _queue_ms, _total_ms) = printer
        .join()
        .expect("printer thread")
        .context("generation produced no completion")?;
    println!();
    let (h, m, _) = engine.cache.stats();
    eprintln!(
        "\n[adapmoe] {} tokens in {:.2}s ({:.1} tok/s, finish={}) | per-token p50 {:.1}ms | \
         cache hit {:.0}% | single-expert {:.0}%",
        n_tokens,
        dt,
        n_tokens as f64 / dt,
        finish.as_str(),
        engine.trace.token_latency.p50() * 1e3,
        100.0 * h as f64 / (h + m).max(1) as f64,
        100.0 * engine.trace.mean_single_ratio(),
    );
    metrics_finish(args, &stats_from_perf(&engine.perf()))?;
    trace_finish(args, trace_out)?;
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let trace_out = trace_setup(args);
    let engine = build_engine(args, 4)?;
    // Optionally publish this engine's expert store so cacheless peers
    // (`--remote`) can fetch their experts from us (docs/remote-store.md).
    let _store_server = match args.get("serve-store") {
        Some(store_addr) => {
            let image = Arc::new(ArtifactImage::from_tiered(
                &engine.tiered,
                engine.cfg.d_model,
                engine.cfg.d_ff,
            ));
            let srv = StoreServer::spawn(image, store_addr)
                .with_context(|| format!("binding artifact server on {store_addr}"))?;
            eprintln!("[adapmoe] artifact server on {}", srv.local_addr());
            Some(srv)
        }
        None => None,
    };
    let addr = args.str_or("addr", "127.0.0.1:7411");
    eprintln!("[adapmoe] serving on {addr} (Ctrl-C to stop)");
    let shutdown = Arc::new(AtomicBool::new(false));
    let served = tcp::serve(engine, &addr, shutdown)?;
    eprintln!("[adapmoe] served {served} completions");
    trace_finish(args, trace_out)?;
    Ok(())
}

fn cmd_plan_cache(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let profile = Profile::load(&dir)?;
    let l = profile.sensitivity.len();
    let budget = args.usize_or("budget", l * 8 / 2);
    let inputs = cache_plan::PlanInputs {
        n_experts: args.usize_or("experts", 8),
        budget,
        alpha: profile.alpha.clone(),
        beta: profile.beta.clone(),
    };
    let plan = cache_plan::plan(&inputs);
    println!("layer  alpha  beta   cache");
    for i in 0..l {
        println!(
            "{:5}  {:.3}  {:.3}  {:5}",
            i, profile.alpha[i], profile.beta[i], plan.allocation[i]
        );
    }
    println!(
        "total {budget} experts -> expected on-demand loads/token: {:.4}",
        plan.expected_loads
    );
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let trace_out = trace_setup(args);
    let mut engine = build_engine(args, 1)?;
    engine.trace.enable_similarity(); // Fig. 3 series is part of the profile
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let eval = EvalStream::load(&dir.join("tokens_eval.bin"))?;
    let n = args.usize_or("tokens", 200);
    let mut rng = Rng::new(args.u64_or("seed", 0));
    let window = engine.cfg.max_seq - 1;
    let mut remaining = n;
    while remaining > 0 {
        let take = remaining.min(window);
        let prompt = eval.sample_prompt(&mut rng, take);
        let row = engine.acquire_slot().context("no slot")?;
        for &t in &prompt {
            engine.decode_step(&[(row, t)])?;
        }
        engine.release_slot(row);
        remaining -= take;
    }
    let tr = &engine.trace;
    println!("layer  single%  beta   alpha_mean  on_demand");
    let sr = tr.single_ratio();
    let beta = tr.beta();
    let am = tr.alpha_mean();
    for i in 0..engine.cfg.n_layers {
        println!(
            "{:5}  {:6.1}%  {:.3}  {:9.3}  {:9}",
            i,
            100.0 * sr[i],
            beta[i],
            am[i],
            tr.on_demand[i]
        );
    }
    println!(
        "similarity: {:?}",
        tr.similarity()
            .iter()
            .map(|s| (s * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>()
    );
    println!(
        "tokens/s {:.2} | p50 {:.1}ms | stall {:.1}ms total",
        tr.tokens_per_sec(),
        tr.token_latency.p50() * 1e3,
        tr.stall_ns as f64 / 1e6
    );
    metrics_finish(args, &stats_from_perf(&engine.perf()))?;
    trace_finish(args, trace_out)?;
    Ok(())
}
