//! Transfer engine: the paper's comm CUDA stream(s), as dedicated OS
//! threads — one per **lane**.
//!
//! Implements the COMMSTREAM half of Algorithm 1, generalized from a single
//! simulated PCIe stream to a [`LaneConfig::count`]-wide set of independent
//! lanes. Each lane owns its own urgent/prefetch queues and its own
//! simulated wire clock; a lane-assignment policy ([`LanePolicy`]) decides
//! which lane a new transfer rides. Jobs are transferred **tile by tile**
//! (Fig. 6) with per-tile arrival notification so the compute stream can
//! start consuming an expert before it has fully arrived. On-demand loads
//! travel in a higher-priority queue than prefetches *within* a lane; the
//! `Pinned` policy additionally reserves lane 0 for on-demand loads so a
//! prefetch burst can never delay them (the paper's Fig. 9 stall case).
//!
//! The PCIe link is simulated (DESIGN.md 'Substitutions'): each tile does
//! its *real* work (dequantizing the quantized bytes to f32) and then sleeps
//! out the remainder of the simulated wire time given by the platform's
//! calibrated bandwidth, scaled per lane. Completed experts are published
//! into the [`DeviceCache`] and handed to waiters through
//! [`TransferHandle`], which records the lane that carried it.
//!
//! Every tile/expert arrival is additionally announced on the engine-wide
//! [`CompletionBoard`] (tagged with its lane), which lets the compute
//! stream consume work in **arrival order** (completion-driven execution)
//! rather than blocking on transfers in plan order — see
//! [`crate::coordinator::executor`]. Lane semantics, policies and the
//! determinism guarantees are documented in `docs/transfer-lanes.md`.
//!
//! The engine drains into a [`ShardedCache`]: with more than one device
//! backend, lanes gain a **device affinity** — a transfer for device d
//! rides a lane of d's lane group (lane l serves device `l % devices`),
//! and the configured [`LanePolicy`] picks *within* the group. With one
//! device (the historical shape) assignment falls back to PR 3's
//! policies bit-for-bit. Per-device queued bytes are tracked alongside
//! the per-lane gauges and surfaced through
//! [`TransferEngine::device_snapshots`] (docs/sharded-backends.md).
//!
//! Transfers are fault tolerant (docs/fault-tolerance.md): every lane
//! carries a circuit-breaker health state ([`LaneHealth`]), jobs carry an
//! optional deadline and retry budget ([`FaultConfig`]), and the engine's
//! fault pump re-issues work off dead lanes onto healthy ones in the same
//! device-affinity group. A transfer that exhausts the ladder is *failed*
//! ([`TransferHandle::is_failed`]) rather than stranded, and
//! [`TransferEngine::quiesce`] returns a structured [`FaultReport`]. With
//! no injected faults and no deadline the machinery is inert and the
//! engine's behavior is bit-for-bit the pre-fault-layer one.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::sensitivity::SensitivityMap;
use crate::memory::device_cache::{DeviceCache, ResidentMeta};
use crate::memory::faults::{FaultAction, FaultPlan};
use crate::memory::host_store::{ExpertF32, FetchSource, HostStore};
use crate::memory::platform::Platform;
use crate::memory::quant::QuantKind;
use crate::memory::sharded_cache::{DeviceId, DeviceSnapshot, ShardedCache};
use crate::memory::tiered_store::{PrecisionPolicy, TieredStore};
use crate::model::ExpertId;
use crate::tensor::Tensor;

/// Index of a comm lane (0-based).
pub type LaneId = usize;

/// Lock that shrugs off poisoning: a comm worker that panicked mid-tile
/// must not cascade into lock-poisoning aborts on the engine or serving
/// threads — registries and counters stay readable for the fault report.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Priority class of a transfer job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    /// Needed by the layer currently executing — compute is stalling on it.
    OnDemand,
    /// Speculative load for an upcoming layer.
    Prefetch,
    /// Background re-transfer of a resident low-tier expert at a higher
    /// precision tier (docs/tiered-precision.md). Rides the prefetch
    /// queue — an upgrade must never delay an urgent or speculative load
    /// — and replaces the resident cache entry when it lands.
    Upgrade,
}

// ---------------------------------------------------------------------------
// Lane configuration & policies
// ---------------------------------------------------------------------------

/// How [`TransferEngine::request`] spreads fresh jobs across lanes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LanePolicy {
    /// Cycle lanes in order regardless of load.
    RoundRobin,
    /// Pick the lane with the fewest assigned-but-unfinished bytes
    /// (ties break toward the lowest index).
    LeastQueuedBytes,
    /// Lane 0 is reserved for on-demand loads; prefetches spread over the
    /// remaining lanes by least-queued-bytes, so a prefetch burst can never
    /// sit in front of a load compute is stalling on. Degenerates to a
    /// single shared lane when `count == 1`.
    Pinned,
}

impl LanePolicy {
    /// Parse a CLI/config name.
    pub fn from_name(name: &str) -> Option<LanePolicy> {
        match name {
            "round-robin" => Some(LanePolicy::RoundRobin),
            "least-queued" => Some(LanePolicy::LeastQueuedBytes),
            "pinned" => Some(LanePolicy::Pinned),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LanePolicy::RoundRobin => "round-robin",
            LanePolicy::LeastQueuedBytes => "least-queued",
            LanePolicy::Pinned => "pinned",
        }
    }

    pub fn names() -> &'static [&'static str] {
        &["round-robin", "least-queued", "pinned"]
    }
}

/// Circuit-breaker health of one comm lane. Health only ratchets toward
/// `Dead` in this engine generation: a `Suspect` lane (observed timeouts
/// or drops) keeps serving but is avoided for retries, and a `Dead` lane
/// (halted, or its worker exited) never recovers — its jobs fail over.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum LaneHealth {
    #[default]
    Healthy,
    Suspect,
    Dead,
}

impl LaneHealth {
    fn from_u8(v: u8) -> LaneHealth {
        match v {
            0 => LaneHealth::Healthy,
            1 => LaneHealth::Suspect,
            _ => LaneHealth::Dead,
        }
    }

    /// Wire name (`ServerStats.lanes[].health`).
    pub fn name(&self) -> &'static str {
        match self {
            LaneHealth::Healthy => "healthy",
            LaneHealth::Suspect => "suspect",
            LaneHealth::Dead => "dead",
        }
    }
}

/// Fault-tolerance knobs of a lane set. Inert by default: no `deadline`
/// means the timeout/retry machinery never fires, and `failover` only
/// acts when a lane actually dies — so a zero-fault run is bit-for-bit
/// identical to an engine without the fault layer.
#[derive(Clone, Copy, Debug)]
pub struct FaultConfig {
    /// Per-attempt transfer deadline (queue wait + wire time). `None`
    /// disables timeout detection entirely.
    pub deadline: Option<Duration>,
    /// Re-sends allowed after the first attempt before the transfer is
    /// failed ([`TransferHandle::is_failed`]).
    pub max_retries: u32,
    /// Base backoff before a retry re-send; doubles per retry.
    pub backoff: Duration,
    /// Re-issue the jobs of a dead lane on a live one (same
    /// device-affinity group first). When off, a dead lane strands its
    /// jobs and [`TransferEngine::quiesce_for`] reports it as an error.
    pub failover: bool,
}

impl Default for FaultConfig {
    fn default() -> FaultConfig {
        FaultConfig {
            deadline: None,
            max_retries: 2,
            backoff: Duration::from_millis(10),
            failover: true,
        }
    }
}

impl FaultConfig {
    /// Legacy semantics (pre-fault-layer): no deadlines, no failover.
    pub fn disabled() -> FaultConfig {
        FaultConfig { failover: false, ..FaultConfig::default() }
    }
}

/// Structured fault-layer summary, the success value of
/// [`TransferEngine::quiesce`]. Counters are cumulative over the engine's
/// lifetime; `failed` lists transfers abandoned after exhausting the
/// retry/failover ladder (their handles report
/// [`TransferHandle::is_failed`] and never complete).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultReport {
    pub retries: u64,
    pub timeouts: u64,
    pub failovers: u64,
    pub failed: Vec<ExpertId>,
    pub dead_lanes: Vec<LaneId>,
}

impl FaultReport {
    /// No fault-layer activity at all (the zero-fault fast path).
    pub fn is_clean(&self) -> bool {
        self.retries == 0
            && self.timeouts == 0
            && self.failovers == 0
            && self.failed.is_empty()
            && self.dead_lanes.is_empty()
    }
}

/// Lane-set shape of a [`TransferEngine`].
#[derive(Clone, Debug)]
pub struct LaneConfig {
    /// Number of parallel comm lanes (threads). Must be >= 1.
    pub count: usize,
    pub policy: LanePolicy,
    /// Per-lane multipliers on the engine's `time_scale` (empty = all 1.0).
    /// Tests use asymmetric values to force out-of-order arrivals across
    /// lanes; ops can model an unevenly shared physical link.
    pub time_scales: Vec<f64>,
    /// Fault-tolerance knobs (deadline/retry/failover); inert by default.
    pub faults: FaultConfig,
}

impl Default for LaneConfig {
    fn default() -> LaneConfig {
        LaneConfig {
            count: 1,
            policy: LanePolicy::RoundRobin,
            time_scales: Vec::new(),
            faults: FaultConfig::default(),
        }
    }
}

impl LaneConfig {
    pub fn new(count: usize, policy: LanePolicy) -> LaneConfig {
        LaneConfig { count, policy, ..LaneConfig::default() }
    }

    /// Builder: per-lane wire-clock multipliers (len must equal `count`).
    pub fn with_time_scales(mut self, scales: Vec<f64>) -> LaneConfig {
        self.time_scales = scales;
        self
    }

    /// Builder: deadline/retry/failover behavior.
    pub fn with_faults(mut self, faults: FaultConfig) -> LaneConfig {
        self.faults = faults;
        self
    }
}

/// Per-lane counters (atomics: written by the lane thread, read anywhere).
#[derive(Default)]
pub struct LaneStats {
    pub transfers: AtomicU64,
    pub bytes: AtomicU64,
    pub on_demand: AtomicU64,
    pub prefetch: AtomicU64,
    pub upgrades: AtomicU64,
    pub sim_busy_ns: AtomicU64,
    pub skipped_cached: AtomicU64,
    /// Bytes assigned to this lane and not yet finished/skipped — the
    /// load signal the `LeastQueuedBytes` / `Pinned` policies balance on.
    pub queued_bytes: AtomicU64,
    /// Jobs assigned and not yet finished/skipped.
    pub queued_jobs: AtomicU64,
    /// Re-sends of this lane's timed-out/dropped jobs (fault layer).
    pub retries: AtomicU64,
    /// Per-attempt deadline expiries observed on this lane.
    pub timeouts: AtomicU64,
    /// Jobs re-issued *off* this lane after it died.
    pub failovers: AtomicU64,
    /// Circuit-breaker state, stored as `LaneHealth as u8`.
    health: AtomicU8,
}

/// Point-in-time copy of one lane's counters, for `ServerStats` / benches.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LaneSnapshot {
    pub lane: LaneId,
    pub transfers: u64,
    pub bytes: u64,
    pub on_demand: u64,
    pub prefetch: u64,
    /// Background precision-upgrade transfers carried by this lane.
    pub upgrades: u64,
    /// Simulated wire time this lane has been busy (ms).
    pub busy_ms: f64,
    pub queued_bytes: u64,
    pub queued_jobs: u64,
    /// Circuit-breaker state of the lane's worker.
    pub health: LaneHealth,
    pub retries: u64,
    pub timeouts: u64,
    pub failovers: u64,
}

impl LaneStats {
    fn snapshot(&self, lane: LaneId) -> LaneSnapshot {
        LaneSnapshot {
            lane,
            transfers: self.transfers.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            on_demand: self.on_demand.load(Ordering::Relaxed),
            prefetch: self.prefetch.load(Ordering::Relaxed),
            upgrades: self.upgrades.load(Ordering::Relaxed),
            busy_ms: self.sim_busy_ns.load(Ordering::Relaxed) as f64 / 1e6,
            queued_bytes: self.queued_bytes.load(Ordering::Relaxed),
            queued_jobs: self.queued_jobs.load(Ordering::Relaxed),
            health: self.health(),
            retries: self.retries.load(Ordering::Relaxed),
            timeouts: self.timeouts.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
        }
    }

    fn health(&self) -> LaneHealth {
        LaneHealth::from_u8(self.health.load(Ordering::SeqCst))
    }

    /// Health only ratchets toward `Dead` (no automatic recovery): a
    /// concurrent `Suspect` mark can never mask a death.
    fn set_health(&self, h: LaneHealth) {
        self.health.fetch_max(h as u8, Ordering::SeqCst);
    }

    fn enqueue(&self, bytes: u64) {
        self.queued_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.queued_jobs.fetch_add(1, Ordering::Relaxed);
    }

    fn dequeue(&self, bytes: u64) {
        self.queued_bytes.fetch_sub(bytes, Ordering::Relaxed);
        self.queued_jobs.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Shared state of one in-flight expert transfer.
pub struct TransferHandle {
    state: Mutex<HandleState>,
    cond: Condvar,
    /// Set when the fault pump abandoned the transfer (retry budget or
    /// failover ladder exhausted). A failed handle never publishes `full`.
    failed: AtomicBool,
    pub id: ExpertId,
    pub n_tiles: usize,
    /// The comm lane this transfer was assigned to.
    pub lane: LaneId,
    /// The precision tier whose bytes this transfer moves.
    pub kind: QuantKind,
    /// Wire bytes of the expert at that tier (what the gauges charge).
    pub bytes: usize,
}

struct HandleState {
    tiles: Vec<Option<Arc<ExpertF32>>>,
    /// Arrival instant of each tile (queue-delay attribution).
    tiles_at: Vec<Option<Instant>>,
    full: Option<Arc<ExpertF32>>,
    full_at: Option<Instant>,
    tiles_done: usize,
}

impl TransferHandle {
    fn new(
        id: ExpertId,
        n_tiles: usize,
        lane: LaneId,
        kind: QuantKind,
        bytes: usize,
    ) -> TransferHandle {
        TransferHandle {
            state: Mutex::new(HandleState {
                tiles: vec![None; n_tiles],
                tiles_at: vec![None; n_tiles],
                full: None,
                full_at: None,
                tiles_done: 0,
            }),
            cond: Condvar::new(),
            failed: AtomicBool::new(false),
            id,
            n_tiles,
            lane,
            kind,
            bytes,
        }
    }

    /// Block until tile `t` has arrived; returns its dequantized slice
    /// (w1/w3 column tile + w2 row tile — see HostStore::dequantize_tile).
    /// Blocks forever on a failed transfer — fault-aware consumers poll
    /// [`TransferHandle::try_tile`] + [`TransferHandle::is_failed`].
    pub fn wait_tile(&self, t: usize) -> Arc<ExpertF32> {
        let mut g = lock_unpoisoned(&self.state);
        while g.tiles[t].is_none() {
            g = self.cond.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
        g.tiles[t].clone().unwrap()
    }

    /// Block until the whole expert has arrived. Blocks forever on a
    /// failed transfer — fault-aware consumers poll
    /// [`TransferHandle::try_full`] + [`TransferHandle::is_failed`]
    /// (see `coordinator::executor::drain_arrival_order`).
    pub fn wait_full(&self) -> Arc<ExpertF32> {
        let mut g = lock_unpoisoned(&self.state);
        while g.full.is_none() {
            g = self.cond.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
        g.full.clone().unwrap()
    }

    /// Non-blocking: the whole expert plus its arrival instant, if landed.
    /// The instant lets the consumer attribute queue delay (time the data
    /// sat ready before compute picked it up) separately from true stalls.
    pub fn try_full(&self) -> Option<(Arc<ExpertF32>, Instant)> {
        let g = lock_unpoisoned(&self.state);
        match (&g.full, g.full_at) {
            (Some(w), Some(at)) => Some((Arc::clone(w), at)),
            _ => None,
        }
    }

    /// Non-blocking: tile `t` plus its arrival instant, if landed.
    pub fn try_tile(&self, t: usize) -> Option<(Arc<ExpertF32>, Instant)> {
        let g = lock_unpoisoned(&self.state);
        match (&g.tiles[t], g.tiles_at[t]) {
            (Some(w), Some(at)) => Some((Arc::clone(w), at)),
            _ => None,
        }
    }

    pub fn is_complete(&self) -> bool {
        lock_unpoisoned(&self.state).full.is_some()
    }

    /// Whether the fault pump abandoned this transfer. Terminal: a failed
    /// transfer never completes, and its consumer must take the
    /// degradation ladder (resident lower tier → replica shard → drop).
    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::SeqCst)
    }

    pub fn tiles_done(&self) -> usize {
        lock_unpoisoned(&self.state).tiles_done
    }

    fn publish_tile(&self, t: usize, data: Arc<ExpertF32>) {
        let mut g = lock_unpoisoned(&self.state);
        g.tiles[t] = Some(data);
        g.tiles_at[t] = Some(Instant::now());
        g.tiles_done += 1;
        self.cond.notify_all();
    }

    fn publish_full(&self, data: Arc<ExpertF32>) {
        let mut g = lock_unpoisoned(&self.state);
        g.full = Some(data);
        g.full_at = Some(Instant::now());
        self.cond.notify_all();
    }

    /// Mark the transfer abandoned and wake blocking waiters so they can
    /// re-check state (fault-aware ones poll `is_failed`).
    fn fail(&self) {
        self.failed.store(true, Ordering::SeqCst);
        drop(lock_unpoisoned(&self.state));
        self.cond.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Completion notification
// ---------------------------------------------------------------------------

/// What arrived: one tile of an expert, or the whole expert.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompletionKind {
    Tile(usize),
    Full,
}

/// One arrival notification published by a comm lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompletionEvent {
    pub id: ExpertId,
    pub kind: CompletionKind,
    /// Which lane carried the data (per-lane queue-delay attribution).
    pub lane: LaneId,
    /// Which precision tier's bytes arrived (per-tier attribution).
    pub tier: QuantKind,
}

/// Bounded arrival-order queue of completion events, the compute stream's
/// wait target. Instead of blocking on expert *i* while expert *i+1* has
/// already landed (head-of-line blocking), the executor parks here and is
/// woken by whichever transfer — on whichever lane — finishes first. Events
/// are hints: consumers must re-check [`TransferHandle`] state after waking,
/// so the bounded drop of old events (and a timeout on waits) can never
/// lose work.
pub struct CompletionBoard {
    q: Mutex<std::collections::VecDeque<CompletionEvent>>,
    cv: Condvar,
}

/// Backstop bound; far above any realistic in-flight event count.
const BOARD_CAP: usize = 4096;

impl CompletionBoard {
    fn new() -> CompletionBoard {
        CompletionBoard {
            q: Mutex::new(std::collections::VecDeque::new()),
            cv: Condvar::new(),
        }
    }

    fn push(&self, ev: CompletionEvent) {
        let mut g = lock_unpoisoned(&self.q);
        if g.len() >= BOARD_CAP {
            g.pop_front();
        }
        g.push_back(ev);
        self.cv.notify_all();
    }

    /// Pop the oldest event without blocking.
    pub fn try_pop(&self) -> Option<CompletionEvent> {
        lock_unpoisoned(&self.q).pop_front()
    }

    /// Pop the oldest event, blocking up to `timeout` for one to arrive.
    pub fn wait_pop(&self, timeout: Duration) -> Option<CompletionEvent> {
        let mut g = lock_unpoisoned(&self.q);
        if let Some(ev) = g.pop_front() {
            return Some(ev);
        }
        let (mut g, _) = self
            .cv
            .wait_timeout(g, timeout)
            .unwrap_or_else(PoisonError::into_inner);
        g.pop_front()
    }

    /// Drop queued events (start-of-layer hygiene: anything already landed
    /// is found by the executor's initial handle sweep).
    pub fn clear(&self) {
        lock_unpoisoned(&self.q).clear();
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.q).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

struct Job {
    id: ExpertId,
    /// Owning device shard (resolved once at request time).
    device: DeviceId,
    /// Precision tier this job moves (chosen at request time).
    kind: QuantKind,
    /// Wire bytes of the expert at that tier (enqueue/dequeue symmetric).
    /// For a coalesced group this is the *summed* member bytes.
    bytes: usize,
    handle: Arc<TransferHandle>,
    priority: Priority,
    /// Coalesced multi-expert job ([`TransferEngine::request_group_at`]):
    /// every expert of one plan bound for the same `(device, tier)`,
    /// moved under a single wire-clock charge with per-member completion
    /// publication. Empty for the ordinary one-expert job — `Vec::new()`
    /// does not allocate, so the singleton hot path stays free.
    members: Vec<GroupMember>,
}

/// One expert of a coalesced transfer group (docs/hot-path.md).
struct GroupMember {
    id: ExpertId,
    /// This member's own wire bytes (its share of the group charge).
    bytes: usize,
    handle: Arc<TransferHandle>,
}

/// Recycled member-vec storage for coalesced group jobs: `take` on the
/// request path, `put` once the lane has expanded the group at admit —
/// steady-state decode allocates no transfer-side member lists.
#[derive(Default)]
struct GroupSlab {
    slabs: Mutex<Vec<Vec<GroupMember>>>,
}

impl GroupSlab {
    fn take(&self) -> Vec<GroupMember> {
        lock_unpoisoned(&self.slabs).pop().unwrap_or_default()
    }

    fn put(&self, mut v: Vec<GroupMember>) {
        v.clear();
        lock_unpoisoned(&self.slabs).push(v);
    }
}

/// Engine-wide counters (aggregate across lanes) exported to benches/metrics.
#[derive(Default)]
pub struct TransferStats {
    pub transfers: AtomicU64,
    pub bytes: AtomicU64,
    pub on_demand: AtomicU64,
    pub prefetch: AtomicU64,
    /// Completed background precision upgrades.
    pub upgrades: AtomicU64,
    pub sim_busy_ns: AtomicU64,
    pub skipped_cached: AtomicU64,
    /// Re-sends of timed-out or dropped jobs (fault layer).
    pub retries: AtomicU64,
    /// Per-attempt deadline expiries observed.
    pub timeouts: AtomicU64,
    /// Jobs re-issued off a dead lane onto a live one.
    pub failovers: AtomicU64,
    /// Transfers abandoned after exhausting the retry/failover ladder.
    pub failed: AtomicU64,
    /// Per-tier transfer counts, indexed by [`QuantKind::tier_index`].
    pub tier_transfers: [AtomicU64; QuantKind::COUNT],
    /// Per-tier wire bytes moved, indexed by [`QuantKind::tier_index`].
    pub tier_bytes: [AtomicU64; QuantKind::COUNT],
    /// Per-tier completed upgrades (by *target* tier).
    pub tier_upgrades: [AtomicU64; QuantKind::COUNT],
    /// Wire bytes whose source copy was already host-resident when the
    /// transfer was admitted. `local_bytes + remote_bytes == bytes`.
    pub local_bytes: AtomicU64,
    /// Wire bytes whose source copy the admitting lane first pulled from
    /// a remote artifact store (docs/remote-store.md).
    pub remote_bytes: AtomicU64,
    /// Admits dropped because a remote fetch failed after its transport
    /// retries — each one re-enters through the engine's fault pump
    /// exactly like a flaky-lane drop.
    pub remote_faults: AtomicU64,
    /// Transfers whose tier was *raised* by the sensitivity map's
    /// importance floor (consumer 1, docs/sensitivity.md). Zero for the
    /// uniform map.
    pub sens_tier_assigns: AtomicU64,
    /// Tier-priced cache re-plans driven by the sensitivity map
    /// (consumer 2; bumped by the engine's replan path).
    pub sens_plans: AtomicU64,
    /// Prefetch requests whose slack or rank was shaped by the map
    /// (consumer 3; bumped by the engine's prefetch path).
    pub sens_prefetches: AtomicU64,
    /// Upgrade batches released by the lane idle-time predictor instead
    /// of the `pending == 0` heuristic (consumer 4).
    pub sens_upgrades: AtomicU64,
    /// Jobs handed to a lane queue (request, group request, or fault-pump
    /// re-send). A coalesced group counts once however many experts it
    /// carries — `wire_jobs < transfers` is the coalescing win made
    /// observable (docs/hot-path.md).
    pub wire_jobs: AtomicU64,
    /// Multi-expert jobs issued by [`TransferEngine::request_group_at`].
    pub coalesced_groups: AtomicU64,
    /// Experts that rode inside those coalesced jobs.
    pub coalesced_members: AtomicU64,
}

/// Point-in-time per-tier transfer volumes, one entry per configured
/// tier (`ServerStats.tiers`, micro/fig9 tables).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TierSnapshot {
    pub kind: QuantKind,
    pub transfers: u64,
    pub bytes: u64,
    pub upgrades: u64,
}

/// Point-in-time local-vs-remote sourcing counters (`ServerStats.source`,
/// `BENCH_remote.json`). The first three live on [`TransferStats`] (wire
/// bytes attributed by where the admitting lane found the source copy);
/// the rest come from the remote store's shared
/// [`crate::memory::host_store::FetchCounters`] and stay zero for an
/// all-local engine.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SourceSnapshot {
    /// Simulated-wire bytes sourced from an already host-resident copy.
    pub local_bytes: u64,
    /// Simulated-wire bytes whose source was fetched remotely at admit.
    pub remote_bytes: u64,
    /// Admits dropped into the fault pump by a failed remote fetch.
    pub remote_faults: u64,
    /// Successful artifact fetches over the wire.
    pub fetches: u64,
    /// Encoded artifact bytes those fetches moved (real network bytes,
    /// not simulated-link bytes).
    pub fetched_bytes: u64,
    /// Wall-clock milliseconds spent inside artifact fetches.
    pub fetch_ms: f64,
    /// Transport-level retry attempts (below the engine's fault ladder).
    pub retries: u64,
    /// Responses rejected by chunk/manifest checksum verification.
    pub checksum_failures: u64,
    /// Connections re-established after a loss.
    pub reconnects: u64,
    /// Multi-expert `GET_RANGES` round trips that replaced per-expert
    /// fetches (coalesced-group warm-ups, docs/hot-path.md).
    pub batched_fetches: u64,
}

/// Point-in-time per-consumer sensitivity decision counters
/// (`ServerStats.sensitivity`, docs/sensitivity.md). All zeros under the
/// uniform map — the determinism contract made observable.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SensitivitySnapshot {
    /// Transfers whose tier the importance floor raised (consumer 1).
    pub tier_assigns: u64,
    /// Tier-priced DP cache re-plans (consumer 2).
    pub plans: u64,
    /// Evictions where importance weighting overrode plain LRU
    /// (consumer 3, summed across cache shards).
    pub evictions: u64,
    /// Prefetch requests shaped by the map (consumer 3).
    pub prefetches: u64,
    /// Upgrade batches released by the idle predictor (consumer 4).
    pub upgrades: u64,
}

/// Completed prefetches parked until the target layer consumes them —
/// the paper's transient GPU-side landing buffers, distinct from the
/// managed cache (so a layer with a zero cache allocation still benefits
/// from prefetching). Bounded FIFO.
pub struct Staging {
    map: Mutex<(HashMap<ExpertId, (Arc<ExpertF32>, ResidentMeta)>, Vec<ExpertId>)>,
    cap: usize,
}

impl Staging {
    fn new(cap: usize) -> Staging {
        Staging { map: Mutex::new((HashMap::new(), Vec::new())), cap }
    }

    fn put(&self, id: ExpertId, v: Arc<ExpertF32>, meta: ResidentMeta) {
        let mut g = lock_unpoisoned(&self.map);
        if g.0.insert(id, (v, meta)).is_none() {
            g.1.push(id);
        }
        while g.1.len() > self.cap {
            let victim = g.1.remove(0);
            g.0.remove(&victim);
        }
    }

    /// Consume a staged expert and its source-tier metadata (single use —
    /// it moves to the cache or dies; the consumer forwards the meta so
    /// the cache's byte gauges stay honest).
    pub fn take(&self, id: ExpertId) -> Option<(Arc<ExpertF32>, ResidentMeta)> {
        let mut g = lock_unpoisoned(&self.map);
        let v = g.0.remove(&id);
        if v.is_some() {
            if let Some(pos) = g.1.iter().position(|&e| e == id) {
                g.1.remove(pos);
            }
        }
        v
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.map).0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One in-flight transfer's registry entry. `lane`/`device`/`bytes`
/// mirror the gauge charge taken at request time (failover migrates the
/// lane part); the retry/claim fields drive
/// [`TransferEngine::pump_faults`].
struct Ticket {
    lane: LaneId,
    handle: Arc<TransferHandle>,
    priority: Priority,
    kind: QuantKind,
    device: DeviceId,
    bytes: usize,
    /// Re-sends so far (bounded by [`FaultConfig::max_retries`]).
    retries: u32,
    /// When the current attempt was (re-)sent; deadlines measure from here.
    issued_at: Instant,
    /// Backoff gate: a staged retry is not re-sent before this instant.
    not_before: Option<Instant>,
    /// A timed-out/dropped attempt waiting out its backoff re-send.
    needs_reissue: bool,
    /// Completion claim: set by whichever finisher (lane worker or the
    /// fault pump's failure path) got there first; everyone else must
    /// treat the job as already retired.
    claimed: bool,
}

/// The gauge charge a claim winner must release exactly once.
#[derive(Clone, Copy)]
struct ClaimInfo {
    lane: LaneId,
    device: DeviceId,
    bytes: usize,
}

/// In-flight transfer registry shared by the compute thread and every comm
/// lane: id → [`Ticket`]. The Condvar signals every removal so
/// [`TransferEngine::quiesce`] can sleep instead of poll.
struct InFlight {
    map: Mutex<HashMap<ExpertId, Ticket>>,
    drained: Condvar,
}

impl InFlight {
    fn new() -> InFlight {
        InFlight { map: Mutex::new(HashMap::new()), drained: Condvar::new() }
    }

    fn get(&self, id: ExpertId) -> Option<Arc<TransferHandle>> {
        lock_unpoisoned(&self.map).get(&id).map(|t| Arc::clone(&t.handle))
    }

    /// First-finisher election for `id`: returns the gauge charge to
    /// release exactly once, or `None` when the job was already claimed
    /// or retired — the caller then drops its result. Duplicate copies
    /// (failover/retry races) decode identical bits, so losing the claim
    /// is always benign.
    fn claim(&self, id: ExpertId) -> Option<ClaimInfo> {
        let mut g = lock_unpoisoned(&self.map);
        match g.get_mut(&id) {
            Some(t) if !t.claimed => {
                t.claimed = true;
                Some(ClaimInfo { lane: t.lane, device: t.device, bytes: t.bytes })
            }
            _ => None,
        }
    }

    fn remove(&self, id: ExpertId) {
        lock_unpoisoned(&self.map).remove(&id);
        self.drained.notify_all();
    }

    fn len(&self) -> usize {
        lock_unpoisoned(&self.map).len()
    }
}

/// Dynamic per-lane fault knobs (chaos harness, docs/fault-tolerance.md).
/// All atomics so the engine thread can flip them while the lane worker
/// runs; shared between [`Lane`] and its worker's [`CommCtx`].
struct LaneFaults {
    /// f64 bits of a wire-time multiplier (`slow` fault; 1.0 = nominal).
    scale_bits: AtomicU64,
    /// Extra simulated wire time per tile, in ns (`delay` fault).
    delay_ns: AtomicU64,
    /// Drop every k-th admitted job (`flaky` fault; 0 = off).
    drop_period: AtomicU64,
    /// Admission counter driving `drop_period`'s phase.
    admitted: AtomicU64,
}

impl LaneFaults {
    fn new() -> LaneFaults {
        LaneFaults {
            scale_bits: AtomicU64::new(1.0f64.to_bits()),
            delay_ns: AtomicU64::new(0),
            drop_period: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
        }
    }
}

/// Engine-side endpoints of one comm lane.
struct Lane {
    urgent_tx: Sender<Job>,
    prefetch_tx: Sender<Job>,
    wake_tx: Sender<()>,
    worker: Option<JoinHandle<()>>,
    /// Prefetch jobs the compute stream is now blocked on — this lane's
    /// comm loop lifts them to its urgent queue (CUDA-stream-priority
    /// analogue). Promotion cannot move a job across lanes.
    promotions: Arc<Mutex<std::collections::HashSet<ExpertId>>>,
    /// Fault injection: stop this lane's worker without draining (tests /
    /// ops drills; with failover enabled the fault pump re-issues the
    /// lane's jobs, otherwise [`TransferEngine::quiesce_for`] reports it).
    halt: Arc<AtomicBool>,
    /// Scripted slow/flaky/delay fault knobs shared with the worker.
    faults: Arc<LaneFaults>,
    stats: Arc<LaneStats>,
}

/// Default backstop for [`TransferEngine::quiesce`]: far above any sane
/// in-flight drain, so hitting it means a lane is wedged, not slow.
const QUIESCE_BACKSTOP: Duration = Duration::from_secs(30);

pub struct TransferEngine {
    lanes: Vec<Lane>,
    policy: LanePolicy,
    /// Round-robin cursor (single-device assignment).
    rr: AtomicU64,
    /// Tiered expert store: one encoding per configured precision tier
    /// (a single tier for the historical one-kind engine).
    tiers: Arc<TieredStore>,
    /// Which tier a fresh transfer rides (`--precision-policy`).
    precision: PrecisionPolicy,
    /// Shared per-layer importance (`--sensitivity-policy`): a non-uniform
    /// map floors the tier of non-urgent transfers at the layer's
    /// assignment (consumer 1, docs/sensitivity.md). Settable after
    /// construction (the engine builds the map from the profile once the
    /// store shape is known); defaults to the uniform identity.
    sensitivity: Mutex<Arc<SensitivityMap>>,
    /// The device-sharded cache set every lane drains into (a single
    /// shard for the historical one-device engine). Placement drives the
    /// lane affinity of [`TransferEngine::request`].
    cache: Arc<ShardedCache>,
    /// Lane group of each device (lane l serves device `l % devices`;
    /// a device whose group would be empty falls back to the single
    /// lane `device % lanes`). Fixed at construction.
    lane_groups: Vec<Vec<LaneId>>,
    /// Per-device round-robin cursors: each device cycles its *own*
    /// group, so periodic cross-device request patterns cannot alias
    /// onto a fixed lane per device and starve the rest of the group.
    rr_dev: Vec<AtomicU64>,
    /// Bytes assigned to each device's transfers and not yet
    /// landed/skipped (mirrors the per-lane `queued_bytes` gauge).
    device_queued: Arc<Vec<AtomicU64>>,
    /// Deadline/retry/failover behavior ([`LaneConfig::faults`]).
    faults_cfg: FaultConfig,
    /// Jobs a flaky lane dropped at admit, reported to the fault pump.
    fault_dropped: Arc<Mutex<Vec<ExpertId>>>,
    /// Transfers abandoned by the fault pump ([`FaultReport::failed`]).
    fault_failed: Mutex<Vec<ExpertId>>,
    in_flight: Arc<InFlight>,
    /// Member-vec slab shared with every lane: group requests draw their
    /// member lists here; admit returns them once expanded.
    group_slab: Arc<GroupSlab>,
    /// Aggregate counters across lanes.
    pub stats: Arc<TransferStats>,
    pub staging: Arc<Staging>,
    /// Arrival notifications from every lane, consumed by the
    /// completion-driven executor.
    pub completions: Arc<CompletionBoard>,
    pub n_tiles: usize,
    shutdown: Arc<AtomicBool>,
}

impl TransferEngine {
    /// Spawn a single-lane engine (the historical shape; most tests and
    /// baselines). `time_scale` multiplies simulated wire time (1.0 =
    /// calibrated; tests use 0.0 for logic-only runs).
    pub fn new(
        store: Arc<HostStore>,
        cache: Arc<DeviceCache>,
        platform: Platform,
        n_tiles: usize,
        time_scale: f64,
    ) -> TransferEngine {
        Self::with_lanes(store, cache, platform, n_tiles, time_scale, LaneConfig::default())
    }

    /// Spawn `lanes.count` comm threads over a single device cache, each
    /// with its own queues and wire clock, all publishing to one shared
    /// board/staging/cache.
    pub fn with_lanes(
        store: Arc<HostStore>,
        cache: Arc<DeviceCache>,
        platform: Platform,
        n_tiles: usize,
        time_scale: f64,
        lanes: LaneConfig,
    ) -> TransferEngine {
        Self::with_devices(
            store,
            Arc::new(ShardedCache::single(cache)),
            platform,
            n_tiles,
            time_scale,
            lanes,
        )
    }

    /// Spawn the engine over a sharded device-cache set: every lane still
    /// publishes to the shared board/staging, but completed transfers land
    /// on the *owning* shard, and lane assignment gains device affinity
    /// when `cache.n_devices() > 1` (see [`TransferEngine::request`]).
    pub fn with_devices(
        store: Arc<HostStore>,
        cache: Arc<ShardedCache>,
        platform: Platform,
        n_tiles: usize,
        time_scale: f64,
        lanes: LaneConfig,
    ) -> TransferEngine {
        Self::with_tiers(
            Arc::new(TieredStore::single(store)),
            PrecisionPolicy::Fixed,
            cache,
            platform,
            n_tiles,
            time_scale,
            lanes,
        )
    }

    /// Spawn the engine over a tiered mixed-precision store: every fresh
    /// transfer is assigned a [`QuantKind`] tier by `precision` (or
    /// explicitly via [`TransferEngine::request_at`]) and charges that
    /// tier's wire bytes. A single-tier store with
    /// [`PrecisionPolicy::Fixed`] reproduces [`TransferEngine::with_devices`]
    /// bit-for-bit (docs/tiered-precision.md).
    #[allow(clippy::too_many_arguments)]
    pub fn with_tiers(
        tiers: Arc<TieredStore>,
        precision: PrecisionPolicy,
        cache: Arc<ShardedCache>,
        platform: Platform,
        n_tiles: usize,
        time_scale: f64,
        lanes: LaneConfig,
    ) -> TransferEngine {
        assert!(n_tiles >= 1);
        assert!(lanes.count >= 1, "need at least one comm lane");
        assert!(
            lanes.time_scales.is_empty() || lanes.time_scales.len() == lanes.count,
            "lane time_scales must be empty or match lane count"
        );
        let in_flight = Arc::new(InFlight::new());
        let stats = Arc::new(TransferStats::default());
        let staging = Arc::new(Staging::new(4 * tiers.n_experts()));
        let completions = Arc::new(CompletionBoard::new());
        let shutdown = Arc::new(AtomicBool::new(false));
        let n_devices = cache.n_devices();
        let device_queued: Arc<Vec<AtomicU64>> =
            Arc::new((0..n_devices).map(|_| AtomicU64::new(0)).collect());
        let lane_groups: Vec<Vec<LaneId>> = (0..n_devices)
            .map(|dev| {
                let group: Vec<LaneId> =
                    (0..lanes.count).filter(|l| l % n_devices == dev).collect();
                if group.is_empty() {
                    vec![dev % lanes.count]
                } else {
                    group
                }
            })
            .collect();
        let rr_dev: Vec<AtomicU64> = (0..n_devices).map(|_| AtomicU64::new(0)).collect();
        let fault_dropped: Arc<Mutex<Vec<ExpertId>>> = Arc::new(Mutex::new(Vec::new()));
        let group_slab = Arc::new(GroupSlab::default());
        // Lane stats are pre-built as a shared vector: after a failover
        // migrates a job's gauge charge, the *finishing* lane must be able
        // to release the charge on the lane that currently holds it.
        let all_stats: Arc<Vec<Arc<LaneStats>>> = Arc::new(
            (0..lanes.count).map(|_| Arc::new(LaneStats::default())).collect(),
        );
        let all_faults: Vec<Arc<LaneFaults>> =
            (0..lanes.count).map(|_| Arc::new(LaneFaults::new())).collect();

        let lane_set: Vec<Lane> = (0..lanes.count)
            .map(|lane_id| {
                let (urgent_tx, urgent_rx) = channel::<Job>();
                let (prefetch_tx, prefetch_rx) = channel::<Job>();
                let (wake_tx, wake_rx) = channel::<()>();
                let promotions = Arc::new(Mutex::new(std::collections::HashSet::new()));
                let halt = Arc::new(AtomicBool::new(false));
                let lane_stats = Arc::clone(&all_stats[lane_id]);
                let lane_faults = Arc::clone(&all_faults[lane_id]);
                let scale =
                    time_scale * lanes.time_scales.get(lane_id).copied().unwrap_or(1.0);
                let worker = {
                    let ctx = CommCtx {
                        lane: lane_id,
                        tiers: Arc::clone(&tiers),
                        cache: Arc::clone(&cache),
                        platform: platform.clone(),
                        n_tiles,
                        time_scale: scale,
                        urgent_rx,
                        prefetch_rx,
                        wake_rx,
                        in_flight: Arc::clone(&in_flight),
                        stats: Arc::clone(&stats),
                        lane_stats: Arc::clone(&lane_stats),
                        all_lane_stats: Arc::clone(&all_stats),
                        device_queued: Arc::clone(&device_queued),
                        staging: Arc::clone(&staging),
                        promotions: Arc::clone(&promotions),
                        completions: Arc::clone(&completions),
                        shutdown: Arc::clone(&shutdown),
                        halt: Arc::clone(&halt),
                        faults: Arc::clone(&lane_faults),
                        dropped: Arc::clone(&fault_dropped),
                        group_slab: Arc::clone(&group_slab),
                    };
                    std::thread::Builder::new()
                        .name(format!("adapmoe-comm-{lane_id}"))
                        .spawn(move || comm_loop(ctx))
                        .expect("spawn comm lane thread")
                };
                Lane {
                    urgent_tx,
                    prefetch_tx,
                    wake_tx,
                    worker: Some(worker),
                    promotions,
                    halt,
                    faults: lane_faults,
                    stats: lane_stats,
                }
            })
            .collect();

        let n_layers = tiers.n_layers();
        TransferEngine {
            lanes: lane_set,
            policy: lanes.policy,
            rr: AtomicU64::new(0),
            tiers,
            precision,
            sensitivity: Mutex::new(Arc::new(SensitivityMap::uniform(n_layers))),
            cache,
            lane_groups,
            rr_dev,
            device_queued,
            faults_cfg: lanes.faults,
            fault_dropped,
            fault_failed: Mutex::new(Vec::new()),
            in_flight,
            group_slab,
            stats,
            staging,
            completions,
            n_tiles,
            shutdown,
        }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn lane_policy(&self) -> LanePolicy {
        self.policy
    }

    /// Device backends this engine drains into (1 = historical shape).
    pub fn n_devices(&self) -> usize {
        self.cache.n_devices()
    }

    /// The sharded cache set the lanes publish into.
    pub fn sharded_cache(&self) -> &Arc<ShardedCache> {
        &self.cache
    }

    /// The tiered expert store the lanes read from (single-tier for the
    /// historical engine shape).
    pub fn tiered_store(&self) -> &Arc<TieredStore> {
        &self.tiers
    }

    pub fn precision(&self) -> PrecisionPolicy {
        self.precision
    }

    /// Install the shared sensitivity map (consumer 1). The default —
    /// and the `Uniform` policy — is the identity map, under which
    /// [`TransferEngine::request_with_slack`] is bit-for-bit the
    /// historical tier selection.
    pub fn set_sensitivity(&self, map: Arc<SensitivityMap>) {
        *lock_unpoisoned(&self.sensitivity) = map;
    }

    /// The sensitivity map currently floor-ing tier selection.
    pub fn sensitivity(&self) -> Arc<SensitivityMap> {
        Arc::clone(&lock_unpoisoned(&self.sensitivity))
    }

    /// Per-consumer sensitivity decision counters
    /// (`ServerStats.sensitivity`; all zeros under the uniform map).
    /// Eviction decisions live on the cache shards and are merged here.
    pub fn sensitivity_snapshot(&self) -> SensitivitySnapshot {
        SensitivitySnapshot {
            tier_assigns: self.stats.sens_tier_assigns.load(Ordering::Relaxed),
            plans: self.stats.sens_plans.load(Ordering::Relaxed),
            evictions: self.cache.bias_evictions(),
            prefetches: self.stats.sens_prefetches.load(Ordering::Relaxed),
            upgrades: self.stats.sens_upgrades.load(Ordering::Relaxed),
        }
    }

    /// Record one sensitivity-shaped cache re-plan (consumer 2; the
    /// engine's tier-priced DP branch).
    pub fn note_sensitivity_plan(&self) {
        self.stats.sens_plans.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one sensitivity-shaped prefetch decision (consumer 3; a
    /// request whose slack or rank the map changed).
    pub fn note_sensitivity_prefetch(&self) {
        self.stats.sens_prefetches.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one upgrade issued under the predicted-idle gate
    /// (consumer 4).
    pub fn note_sensitivity_upgrade(&self) {
        self.stats.sens_upgrades.fetch_add(1, Ordering::Relaxed);
    }

    /// Highest configured tier — the encoding lookups prefer resident
    /// and the upgrade path promotes toward.
    pub fn preferred_tier(&self) -> QuantKind {
        self.tiers.highest()
    }

    /// The tier [`TransferEngine::request`] assigns an on-demand load:
    /// the precision policy's pick at full slack. The sensitivity floor
    /// never applies to on-demand loads (nothing may add bytes to the
    /// critical path), so this is the same for every expert — which is
    /// what lets a plan batch its misses through
    /// [`TransferEngine::request_group_at`] without changing any tier
    /// decision.
    pub fn on_demand_tier(&self) -> QuantKind {
        self.precision.select(self.tiers.tiers(), Priority::OnDemand, 1.0)
    }

    /// Per-tier transfer volumes, one entry per configured tier
    /// (`ServerStats.tiers`, micro/fig9 tables).
    pub fn tier_snapshots(&self) -> Vec<TierSnapshot> {
        self.tiers
            .tiers()
            .iter()
            .map(|&k| {
                let ti = k.tier_index();
                TierSnapshot {
                    kind: k,
                    transfers: self.stats.tier_transfers[ti].load(Ordering::Relaxed),
                    bytes: self.stats.tier_bytes[ti].load(Ordering::Relaxed),
                    upgrades: self.stats.tier_upgrades[ti].load(Ordering::Relaxed),
                }
            })
            .collect()
    }

    /// Local-vs-remote sourcing counters (`ServerStats.source`,
    /// `BENCH_remote.json`): wire-byte attribution from [`TransferStats`]
    /// merged with the remote store's fetch counters (zeros when every
    /// tier is local).
    pub fn source_snapshot(&self) -> SourceSnapshot {
        let mut s = SourceSnapshot {
            local_bytes: self.stats.local_bytes.load(Ordering::Relaxed),
            remote_bytes: self.stats.remote_bytes.load(Ordering::Relaxed),
            remote_faults: self.stats.remote_faults.load(Ordering::Relaxed),
            ..SourceSnapshot::default()
        };
        if let Some(c) = self.tiers.remote_counters() {
            s.fetches = c.fetches.load(Ordering::Relaxed);
            s.fetched_bytes = c.fetched_bytes.load(Ordering::Relaxed);
            s.fetch_ms = c.fetch_ns.load(Ordering::Relaxed) as f64 / 1e6;
            s.retries = c.retries.load(Ordering::Relaxed);
            s.checksum_failures = c.checksum_failures.load(Ordering::Relaxed);
            s.reconnects = c.reconnects.load(Ordering::Relaxed);
            s.batched_fetches = c.batched_fetches.load(Ordering::Relaxed);
        }
        s
    }

    /// In-flight transfers bound to one device shard (the per-device
    /// prefetch window's occupancy signal). A `LoadAware` expert that is
    /// in flight is always bound, so the peek resolves every entry.
    pub fn pending_for_device(&self, device: DeviceId) -> usize {
        let g = lock_unpoisoned(&self.in_flight.map);
        g.keys()
            .filter(|&&id| self.cache.device_of_peek(id) == Some(device))
            .count()
    }

    /// Lanes with affinity to `device`: lane l serves device
    /// `l % n_devices`. When there are fewer lanes than devices the
    /// group would be empty, so the device falls back to the single lane
    /// `device % n_lanes` (several devices then share a lane). Groups
    /// are precomputed at construction.
    pub fn lanes_for_device(&self, device: DeviceId) -> &[LaneId] {
        &self.lane_groups[device]
    }

    /// Per-device cache counters overlaid with the in-flight queued-bytes
    /// gauge (`ServerStats.devices`, fig9 tables).
    pub fn device_snapshots(&self) -> Vec<DeviceSnapshot> {
        let mut snaps = self.cache.device_snapshots();
        for snap in snaps.iter_mut() {
            snap.queued_bytes = self.device_queued[snap.device].load(Ordering::Relaxed);
        }
        snaps
    }

    /// Point-in-time per-lane counters (stable lane order).
    pub fn lane_snapshots(&self) -> Vec<LaneSnapshot> {
        self.lanes
            .iter()
            .enumerate()
            .map(|(i, l)| l.stats.snapshot(i))
            .collect()
    }

    /// Which lane an in-flight transfer rides, if any.
    pub fn lane_of(&self, id: ExpertId) -> Option<LaneId> {
        lock_unpoisoned(&self.in_flight.map).get(&id).map(|t| t.lane)
    }

    /// Circuit-breaker state of one lane.
    pub fn lane_health(&self, lane: LaneId) -> LaneHealth {
        self.lanes[lane].stats.health()
    }

    /// Lane with the fewest assigned-but-unfinished bytes among
    /// `candidates` (ties toward the lowest index).
    fn least_queued(&self, candidates: impl Iterator<Item = LaneId>) -> LaneId {
        candidates
            .min_by_key(|&i| {
                (self.lanes[i].stats.queued_bytes.load(Ordering::Relaxed), i)
            })
            .expect("non-empty lane group")
    }

    /// Filter `candidates` down to non-dead lanes. Only active when
    /// failover is enabled — a `FaultConfig::disabled()` engine keeps
    /// the historical assignment even when lanes die. Falls back to the
    /// unfiltered candidates when none are live (the caller then strands
    /// the job and quiesce reports the dead lanes).
    fn live_lanes(&self, candidates: &[LaneId]) -> Vec<LaneId> {
        if !self.faults_cfg.failover {
            return candidates.to_vec();
        }
        let live: Vec<LaneId> = candidates
            .iter()
            .copied()
            .filter(|&l| self.lanes[l].stats.health() != LaneHealth::Dead)
            .collect();
        if live.is_empty() {
            candidates.to_vec()
        } else {
            live
        }
    }

    /// Assign a fresh job for `device` to a lane. With one device this
    /// is PR 3's policy logic unchanged; with several, the job is
    /// confined to the owning device's lane group and the policy picks
    /// *within* it (`Pinned` reserves the group's first lane for
    /// on-demand when the group has more than one lane). Dead lanes are
    /// excluded when failover is on; with every lane healthy the pick is
    /// bit-for-bit the historical one.
    fn assign_lane(&self, device: DeviceId, priority: Priority) -> LaneId {
        let n = self.lanes.len();
        if n == 1 {
            return 0;
        }
        if self.cache.n_devices() > 1 {
            let group = self.live_lanes(&self.lane_groups[device]);
            if group.len() == 1 {
                return group[0];
            }
            return match self.policy {
                LanePolicy::RoundRobin => {
                    // per-device cursor: each device cycles its own group
                    let k = self.rr_dev[device].fetch_add(1, Ordering::Relaxed) as usize;
                    group[k % group.len()]
                }
                LanePolicy::LeastQueuedBytes => self.least_queued(group.iter().copied()),
                LanePolicy::Pinned => match priority {
                    Priority::OnDemand => group[0],
                    // prefetches AND upgrades stay off the reserved lane
                    _ => self.least_queued(group[1..].iter().copied()),
                },
            };
        }
        let all: Vec<LaneId> = (0..n).collect();
        let live = self.live_lanes(&all);
        if live.len() == 1 {
            return live[0];
        }
        match self.policy {
            LanePolicy::RoundRobin => {
                let k = self.rr.fetch_add(1, Ordering::Relaxed) as usize;
                live[k % live.len()]
            }
            LanePolicy::LeastQueuedBytes => self.least_queued(live.iter().copied()),
            LanePolicy::Pinned => match priority {
                Priority::OnDemand => live[0],
                _ => self.least_queued(live[1..].iter().copied()),
            },
        }
    }

    /// Enqueue a load (idempotent: joins an in-flight transfer if any; an
    /// on-demand request for an in-flight *prefetch* promotes it to the
    /// urgent queue of the lane that owns it). The precision tier is
    /// chosen by the engine's [`PrecisionPolicy`] at full slack.
    pub fn request(&self, id: ExpertId, priority: Priority) -> Arc<TransferHandle> {
        self.request_with_slack(id, priority, 1.0)
    }

    /// [`TransferEngine::request`] with an explicit slack signal ∈ [0, 1]
    /// — the caller's estimate of how much schedule headroom the load has
    /// (1.0 = pure speculation, 0.0 = needed imminently). Only the
    /// `Urgency` policy reads it (docs/tiered-precision.md).
    pub fn request_with_slack(
        &self,
        id: ExpertId,
        priority: Priority,
        slack: f64,
    ) -> Arc<TransferHandle> {
        let mut kind = self.precision.select(self.tiers.tiers(), priority, slack);
        // Consumer 1 (docs/sensitivity.md): a non-uniform map floors the
        // tier at the layer's importance assignment. On-demand loads are
        // exempt — nothing may add bytes to the critical path — and the
        // uniform map leaves the historical selection untouched.
        if priority != Priority::OnDemand {
            let map = self.sensitivity();
            if !map.is_uniform() {
                let floor = map.tier_for(id.0, self.tiers.tiers());
                if floor.bits() > kind.bits() {
                    kind = floor;
                    self.stats.sens_tier_assigns.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        self.request_at(id, priority, kind)
    }

    /// Enqueue a load at an explicit precision tier (the upgrade path
    /// names its target directly). Joining an in-flight transfer returns
    /// that transfer's handle — and its tier — whatever was asked for.
    pub fn request_at(
        &self,
        id: ExpertId,
        priority: Priority,
        kind: QuantKind,
    ) -> Arc<TransferHandle> {
        assert!(self.tiers.has(kind), "{} is not a configured tier", kind.name());
        let mut g = lock_unpoisoned(&self.in_flight.map);
        if let Some(t) = g.get(&id) {
            let (lane, h) = (t.lane, Arc::clone(&t.handle));
            drop(g);
            if priority == Priority::OnDemand {
                lock_unpoisoned(&self.lanes[lane].promotions).insert(id);
                let _ = self.lanes[lane].wake_tx.send(());
            }
            return h;
        }
        let device = self.cache.device_of(id);
        let lane = self.assign_lane(device, priority);
        // Queued-load accounting uses the same byte figure the lane thread
        // will subtract on completion, so both the lane and device gauges
        // drain back to exactly zero.
        let bytes = self.tiers.expert_transfer_bytes(id, kind);
        let handle = Arc::new(TransferHandle::new(id, self.n_tiles, lane, kind, bytes));
        g.insert(
            id,
            Ticket {
                lane,
                handle: Arc::clone(&handle),
                priority,
                kind,
                device,
                bytes,
                retries: 0,
                issued_at: Instant::now(),
                not_before: None,
                needs_reissue: false,
                claimed: false,
            },
        );
        drop(g);
        self.lanes[lane].stats.enqueue(bytes as u64);
        self.device_queued[device].fetch_add(bytes as u64, Ordering::Relaxed);
        crate::obs::instant(
            crate::obs::Track::Lane(lane),
            crate::obs::Name::Enqueue,
            crate::obs::expert_corr(id),
            bytes as u64,
        );
        let job = Job {
            id,
            device,
            kind,
            bytes,
            handle: Arc::clone(&handle),
            priority,
            members: Vec::new(),
        };
        self.stats.wire_jobs.fetch_add(1, Ordering::Relaxed);
        let l = &self.lanes[lane];
        // A dead lane (halt_lane fault injection, or a crashed worker) has
        // dropped its receivers, so the send fails. Don't panic the
        // requester: leave the job in the in-flight registry as a stranded
        // transfer — waiters block on the handle and quiesce_for() reports
        // the lane per its dead-lane diagnostics.
        let _ = match priority {
            Priority::OnDemand => l.urgent_tx.send(job),
            _ => l.prefetch_tx.send(job),
        };
        let _ = l.wake_tx.send(());
        handle
    }

    /// Enqueue one plan's worth of loads at a shared precision tier,
    /// coalescing the experts bound for the same device into a single
    /// multi-expert wire job per device (docs/hot-path.md). Semantics per
    /// expert are identical to [`TransferEngine::request_at`] — duplicate
    /// and in-flight ids join the existing transfer (with the same
    /// on-demand promotion), every expert gets its own handle, ticket and
    /// completion events, and the returned handles are positional with
    /// `ids`. What changes is the wire accounting: the group's members
    /// move under one summed wire-clock charge split pro-rata by bytes,
    /// and the lane sees one job instead of `ids.len()`.
    pub fn request_group_at(
        &self,
        ids: &[ExpertId],
        priority: Priority,
        kind: QuantKind,
    ) -> Vec<Arc<TransferHandle>> {
        assert!(self.tiers.has(kind), "{} is not a configured tier", kind.name());
        let mut handles = Vec::with_capacity(ids.len());
        let mut promote: Vec<(LaneId, ExpertId)> = Vec::new();
        // One fresh-member group per device, built under a single registry
        // lock so the whole plan's misses coalesce atomically (a duplicate
        // id later in the slice hits the joiner path like any in-flight
        // transfer).
        let mut groups: Vec<Option<(LaneId, Vec<GroupMember>)>> =
            (0..self.cache.n_devices()).map(|_| None).collect();
        {
            let mut g = lock_unpoisoned(&self.in_flight.map);
            for &id in ids {
                if let Some(t) = g.get(&id) {
                    handles.push(Arc::clone(&t.handle));
                    if priority == Priority::OnDemand {
                        promote.push((t.lane, id));
                    }
                    continue;
                }
                let device = self.cache.device_of(id);
                let lane = match &groups[device] {
                    Some((lane, _)) => *lane,
                    None => {
                        let lane = self.assign_lane(device, priority);
                        groups[device] = Some((lane, self.group_slab.take()));
                        lane
                    }
                };
                let bytes = self.tiers.expert_transfer_bytes(id, kind);
                let handle =
                    Arc::new(TransferHandle::new(id, self.n_tiles, lane, kind, bytes));
                g.insert(
                    id,
                    Ticket {
                        lane,
                        handle: Arc::clone(&handle),
                        priority,
                        kind,
                        device,
                        bytes,
                        retries: 0,
                        issued_at: Instant::now(),
                        not_before: None,
                        needs_reissue: false,
                        claimed: false,
                    },
                );
                if let Some((_, members)) = groups[device].as_mut() {
                    members.push(GroupMember { id, bytes, handle: Arc::clone(&handle) });
                }
                handles.push(handle);
            }
        }
        for (lane, id) in promote {
            lock_unpoisoned(&self.lanes[lane].promotions).insert(id);
            let _ = self.lanes[lane].wake_tx.send(());
        }
        for (device, slot) in groups.into_iter().enumerate() {
            let Some((lane, mut members)) = slot else { continue };
            // Gauge charges are per member — exactly what each finisher
            // (or fault-pump failure) releases.
            for m in &members {
                self.lanes[lane].stats.enqueue(m.bytes as u64);
                self.device_queued[device].fetch_add(m.bytes as u64, Ordering::Relaxed);
                crate::obs::instant(
                    crate::obs::Track::Lane(lane),
                    crate::obs::Name::Enqueue,
                    crate::obs::expert_corr(m.id),
                    m.bytes as u64,
                );
            }
            self.stats.wire_jobs.fetch_add(1, Ordering::Relaxed);
            let job = if members.len() == 1 {
                // A lone miss rides the historical singleton path
                // bit-for-bit; its member vec goes straight back to the
                // slab.
                let m = members.pop().expect("one member");
                self.group_slab.put(members);
                Job {
                    id: m.id,
                    device,
                    kind,
                    bytes: m.bytes,
                    handle: m.handle,
                    priority,
                    members: Vec::new(),
                }
            } else {
                self.stats.coalesced_groups.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .coalesced_members
                    .fetch_add(members.len() as u64, Ordering::Relaxed);
                Job {
                    id: members[0].id,
                    device,
                    kind,
                    bytes: members.iter().map(|m| m.bytes).sum(),
                    handle: Arc::clone(&members[0].handle),
                    priority,
                    members,
                }
            };
            let l = &self.lanes[lane];
            // Dead-lane send failures are tolerated exactly as in
            // request_at: tickets stay registered as stranded transfers.
            let _ = match priority {
                Priority::OnDemand => l.urgent_tx.send(job),
                _ => l.prefetch_tx.send(job),
            };
            let _ = l.wake_tx.send(());
        }
        handles
    }

    /// Handle for an in-flight transfer, if any.
    pub fn in_flight(&self, id: ExpertId) -> Option<Arc<TransferHandle>> {
        self.in_flight.get(id)
    }

    /// Whether a completed prefetch is parked in staging for `id`.
    pub fn staging_contains(&self, id: ExpertId) -> bool {
        // peek without consuming
        let g = lock_unpoisoned(&self.staging.map);
        g.0.contains_key(&id)
    }

    pub fn pending(&self) -> usize {
        self.in_flight.len()
    }

    /// Fault injection: stop one lane's worker thread without draining its
    /// queue. In-flight jobs on that lane are stranded — exactly the
    /// condition [`TransferEngine::quiesce_for`] must report per lane.
    pub fn halt_lane(&self, lane: LaneId) {
        assert!(lane < self.lanes.len(), "lane {lane} out of range");
        self.lanes[lane].halt.store(true, Ordering::SeqCst);
        self.lanes[lane].stats.set_health(LaneHealth::Dead);
        let _ = self.lanes[lane].wake_tx.send(());
    }

    /// Block until every lane drains (tests / end-of-run barrier). Sleeps
    /// on the in-flight map's Condvar; woken by every completed transfer.
    /// Drives the fault pump while waiting, so dead-lane failover, retry
    /// backoff and flaky-drop re-issue all make progress here. Returns the
    /// cumulative [`FaultReport`] on success; errors with the per-lane
    /// diagnostic if a lane wedges past the backstop (or dies with
    /// failover disabled) — a silent hang would hide which lane wedged.
    pub fn quiesce(&self) -> Result<FaultReport> {
        self.quiesce_for(QUIESCE_BACKSTOP)
    }

    /// [`TransferEngine::quiesce`] with an explicit backstop. Fails fast —
    /// without waiting out the backstop — when failover is disabled and a
    /// lane's worker has exited while transfers assigned to it are still
    /// in flight, and names every lane with pending work (count +
    /// liveness) in the error, so a single dead lane surfaces as a
    /// per-lane report instead of a global timeout. With failover enabled
    /// a dead lane is not an error: the fault pump re-homes its jobs (or
    /// fails them terminally) and the drain completes.
    pub fn quiesce_for(&self, backstop: Duration) -> Result<FaultReport> {
        let deadline = Instant::now() + backstop;
        loop {
            self.pump_faults();
            let g = lock_unpoisoned(&self.in_flight.map);
            if g.is_empty() {
                drop(g);
                return Ok(self.fault_report());
            }
            let mut pending = vec![0usize; self.lanes.len()];
            for t in g.values() {
                pending[t.lane] += 1;
            }
            let report: Vec<(LaneId, usize, bool)> = self
                .lanes
                .iter()
                .enumerate()
                .filter(|(i, _)| pending[*i] > 0)
                .map(|(i, l)| {
                    let alive =
                        l.worker.as_ref().map(|w| !w.is_finished()).unwrap_or(false);
                    (i, pending[i], alive)
                })
                .collect();
            let dead = report.iter().any(|(_, _, alive)| !alive);
            if (dead && !self.faults_cfg.failover) || Instant::now() >= deadline {
                let detail: Vec<String> = report
                    .iter()
                    .map(|(i, n, alive)| {
                        format!(
                            "lane {i}: {n} in-flight, worker {}",
                            if *alive { "alive" } else { "DEAD" }
                        )
                    })
                    .collect();
                bail!(
                    "transfer quiesce failed ({}): {}",
                    if dead { "dead lane" } else { "backstop elapsed" },
                    detail.join("; ")
                );
            }
            // Timeout only as a backstop so dead lanes, expired deadlines
            // and elapsed backoffs are re-checked by the pump.
            drop(
                self.in_flight
                    .drained
                    .wait_timeout(g, Duration::from_millis(50))
                    .unwrap_or_else(PoisonError::into_inner),
            );
        }
    }

    /// One pass of the fault pump: ratchet lane health from worker
    /// liveness, re-home the jobs of dead lanes, time out attempts past
    /// their deadline, re-send staged retries whose backoff elapsed, and
    /// terminally fail transfers whose retry/failover ladder is
    /// exhausted. Idempotent and cheap when nothing is wrong; called from
    /// [`TransferEngine::quiesce_for`]'s wait loop and after every
    /// scripted fault injection.
    pub fn pump_faults(&self) {
        // Worker liveness → health ratchet (a panicked/halted worker is
        // indistinguishable from a dead link to its queued jobs).
        for lane in &self.lanes {
            let dead = lane.halt.load(Ordering::SeqCst)
                || lane.worker.as_ref().map(|w| w.is_finished()).unwrap_or(true);
            if dead {
                lane.stats.set_health(LaneHealth::Dead);
            }
        }
        let dropped = std::mem::take(&mut *lock_unpoisoned(&self.fault_dropped));
        let cfg = self.faults_cfg;
        let now = Instant::now();
        enum Act {
            Reissue { id: ExpertId, to: LaneId, from: LaneId, failover: bool },
            Fail { id: ExpertId },
        }
        let mut acts: Vec<Act> = Vec::new();
        {
            let mut g = lock_unpoisoned(&self.in_flight.map);
            for (&id, t) in g.iter_mut() {
                if t.claimed {
                    continue;
                }
                if self.lanes[t.lane].stats.health() == LaneHealth::Dead {
                    if !cfg.failover {
                        continue; // legacy semantics: strand; quiesce reports
                    }
                    match self.failover_target(t.device, t.lane) {
                        Some(to) => {
                            // Migrate the gauge charge lane→lane inside the
                            // map lock so exactly one charge is ever alive.
                            self.lanes[t.lane].stats.dequeue(t.bytes as u64);
                            self.lanes[to].stats.enqueue(t.bytes as u64);
                            let from = t.lane;
                            t.lane = to;
                            t.issued_at = now;
                            t.not_before = None;
                            t.needs_reissue = false;
                            acts.push(Act::Reissue { id, to, from, failover: true });
                        }
                        None => {
                            t.claimed = true;
                            acts.push(Act::Fail { id });
                        }
                    }
                    continue;
                }
                let timed_out = !t.needs_reissue
                    && cfg.deadline.is_some_and(|d| {
                        now.checked_duration_since(t.issued_at)
                            .is_some_and(|el| el >= d)
                    });
                let was_dropped = !t.needs_reissue && dropped.contains(&id);
                if timed_out || was_dropped {
                    if timed_out {
                        self.stats.timeouts.fetch_add(1, Ordering::Relaxed);
                        self.lanes[t.lane]
                            .stats
                            .timeouts
                            .fetch_add(1, Ordering::Relaxed);
                    }
                    self.lanes[t.lane].stats.set_health(LaneHealth::Suspect);
                    if t.retries >= cfg.max_retries {
                        t.claimed = true;
                        acts.push(Act::Fail { id });
                        continue;
                    }
                    t.retries += 1;
                    t.not_before =
                        Some(now + cfg.backoff * 2u32.saturating_pow(t.retries - 1));
                    t.needs_reissue = true;
                }
                let due = match t.not_before {
                    Some(nb) => now >= nb,
                    None => true,
                };
                if t.needs_reissue && due {
                    // Retry: same lane if it is still fully healthy, else
                    // the best live lane in the device's affinity group.
                    let to = if self.lanes[t.lane].stats.health() == LaneHealth::Healthy
                    {
                        t.lane
                    } else {
                        self.failover_target(t.device, t.lane).unwrap_or(t.lane)
                    };
                    if to != t.lane {
                        self.lanes[t.lane].stats.dequeue(t.bytes as u64);
                        self.lanes[to].stats.enqueue(t.bytes as u64);
                    }
                    let from = t.lane;
                    t.lane = to;
                    t.issued_at = now;
                    t.not_before = None;
                    t.needs_reissue = false;
                    acts.push(Act::Reissue { id, to, from, failover: false });
                }
            }
        }
        for act in acts {
            match act {
                Act::Reissue { id, to, from, failover } => {
                    // Re-read under the lock: the original copy may have
                    // completed (claimed the ticket) since we staged this.
                    let job = {
                        let g = lock_unpoisoned(&self.in_flight.map);
                        match g.get(&id) {
                            // Re-sends are always singletons: a dropped
                            // group member retries on its own ticket.
                            Some(t) if !t.claimed => Some(Job {
                                id,
                                device: t.device,
                                kind: t.kind,
                                bytes: t.bytes,
                                handle: Arc::clone(&t.handle),
                                priority: t.priority,
                                members: Vec::new(),
                            }),
                            _ => None,
                        }
                    };
                    let Some(job) = job else { continue };
                    if failover {
                        self.stats.failovers.fetch_add(1, Ordering::Relaxed);
                        self.lanes[from]
                            .stats
                            .failovers
                            .fetch_add(1, Ordering::Relaxed);
                        crate::obs::instant(
                            crate::obs::Track::Lane(from),
                            crate::obs::Name::Failover,
                            crate::obs::expert_corr(id),
                            to as u64,
                        );
                    } else {
                        self.stats.retries.fetch_add(1, Ordering::Relaxed);
                        self.lanes[to].stats.retries.fetch_add(1, Ordering::Relaxed);
                        crate::obs::instant(
                            crate::obs::Track::Lane(to),
                            crate::obs::Name::Retry,
                            crate::obs::expert_corr(id),
                            0,
                        );
                    }
                    // Priority escalation: every re-send rides the urgent
                    // queue — a retried prefetch is (or soon will be)
                    // blocking compute. The job keeps its original
                    // priority so landing semantics are unchanged.
                    self.stats.wire_jobs.fetch_add(1, Ordering::Relaxed);
                    let _ = self.lanes[to].urgent_tx.send(job);
                    let _ = self.lanes[to].wake_tx.send(());
                }
                Act::Fail { id } => {
                    let info = {
                        let g = lock_unpoisoned(&self.in_flight.map);
                        g.get(&id)
                            .map(|t| (Arc::clone(&t.handle), t.lane, t.device, t.bytes))
                    };
                    let Some((handle, lane, device, bytes)) = info else { continue };
                    handle.fail();
                    self.lanes[lane].stats.dequeue(bytes as u64);
                    self.device_queued[device].fetch_sub(bytes as u64, Ordering::Relaxed);
                    self.stats.failed.fetch_add(1, Ordering::Relaxed);
                    lock_unpoisoned(&self.fault_failed).push(id);
                    crate::obs::instant(
                        crate::obs::Track::Lane(lane),
                        crate::obs::Name::Fault,
                        crate::obs::expert_corr(id),
                        bytes as u64,
                    );
                    // registry removal last (same ordering as finish/admit):
                    // quiesce returning implies the counters are published
                    self.in_flight.remove(id);
                }
            }
        }
    }

    /// Best live lane to re-home a job bound to `device`, excluding
    /// `exclude`: the least-loaded live lane of the device's affinity
    /// group, falling back to any live lane.
    fn failover_target(&self, device: DeviceId, exclude: LaneId) -> Option<LaneId> {
        self.pick_live(self.lane_groups[device].iter().copied(), exclude)
            .or_else(|| self.pick_live(0..self.lanes.len(), exclude))
    }

    fn pick_live(
        &self,
        candidates: impl Iterator<Item = LaneId>,
        exclude: LaneId,
    ) -> Option<LaneId> {
        candidates
            .filter(|&l| l != exclude && self.lanes[l].stats.health() != LaneHealth::Dead)
            .min_by_key(|&l| {
                (self.lanes[l].stats.queued_bytes.load(Ordering::Relaxed), l)
            })
    }

    /// Cumulative fault-layer summary (the success value of
    /// [`TransferEngine::quiesce`]).
    pub fn fault_report(&self) -> FaultReport {
        FaultReport {
            retries: self.stats.retries.load(Ordering::Relaxed),
            timeouts: self.stats.timeouts.load(Ordering::Relaxed),
            failovers: self.stats.failovers.load(Ordering::Relaxed),
            failed: lock_unpoisoned(&self.fault_failed).clone(),
            dead_lanes: self
                .lanes
                .iter()
                .enumerate()
                .filter(|(_, l)| l.stats.health() == LaneHealth::Dead)
                .map(|(i, _)| i)
                .collect(),
        }
    }

    /// Apply one scripted fault (chaos harness, docs/fault-tolerance.md).
    /// Lane/device indices are validated here against the live engine.
    pub fn inject(&self, action: &FaultAction) {
        match *action {
            FaultAction::HaltLane(l) => self.halt_lane(l),
            FaultAction::SlowLane(l, x) => {
                assert!(l < self.lanes.len(), "lane {l} out of range");
                self.lanes[l].faults.scale_bits.store(x.to_bits(), Ordering::SeqCst);
            }
            FaultAction::FlakyLane(l, k) => {
                assert!(l < self.lanes.len(), "lane {l} out of range");
                self.lanes[l].faults.drop_period.store(k, Ordering::SeqCst);
            }
            FaultAction::DelayLane(l, ms) => {
                assert!(l < self.lanes.len(), "lane {l} out of range");
                self.lanes[l]
                    .faults
                    .delay_ns
                    .store(ms.saturating_mul(1_000_000), Ordering::SeqCst);
            }
            FaultAction::Blackout(d) => {
                assert!(d < self.lane_groups.len(), "device {d} out of range");
                for &l in &self.lane_groups[d] {
                    self.halt_lane(l);
                }
            }
        }
    }

    /// Apply every event of `plan` scheduled for `step`, then pump the
    /// fault machinery once so the effects act immediately.
    pub fn apply_fault_plan(&self, plan: &FaultPlan, step: usize) {
        let mut any = false;
        for action in plan.at(step) {
            self.inject(action);
            any = true;
        }
        if any {
            self.pump_faults();
        }
    }
}

impl Drop for TransferEngine {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for lane in &mut self.lanes {
            let _ = lane.wake_tx.send(());
        }
        for lane in &mut self.lanes {
            if let Some(w) = lane.worker.take() {
                let _ = w.join();
            }
        }
    }
}

struct CommCtx {
    lane: LaneId,
    /// Tiered store: each job decodes from its own tier's encodings.
    tiers: Arc<TieredStore>,
    /// Device-routed cache set: inserts land on the owning shard.
    cache: Arc<ShardedCache>,
    platform: Platform,
    n_tiles: usize,
    /// Engine time_scale × this lane's multiplier.
    time_scale: f64,
    urgent_rx: std::sync::mpsc::Receiver<Job>,
    prefetch_rx: std::sync::mpsc::Receiver<Job>,
    wake_rx: std::sync::mpsc::Receiver<()>,
    in_flight: Arc<InFlight>,
    stats: Arc<TransferStats>,
    lane_stats: Arc<LaneStats>,
    /// All lanes' stats: a finisher releases the gauge charge on the lane
    /// the ticket is *charged* to, which failover may have migrated away
    /// from the executing lane.
    all_lane_stats: Arc<Vec<Arc<LaneStats>>>,
    device_queued: Arc<Vec<AtomicU64>>,
    staging: Arc<Staging>,
    promotions: Arc<Mutex<std::collections::HashSet<ExpertId>>>,
    completions: Arc<CompletionBoard>,
    shutdown: Arc<AtomicBool>,
    halt: Arc<AtomicBool>,
    /// This lane's scripted slow/flaky/delay fault knobs.
    faults: Arc<LaneFaults>,
    /// Shared drop report: ids this lane dropped at admit (flaky fault),
    /// consumed by the engine's fault pump.
    dropped: Arc<Mutex<Vec<ExpertId>>>,
    /// Member-vec slab shared with the engine: expanded group jobs return
    /// their member lists here for the next plan to reuse.
    group_slab: Arc<GroupSlab>,
}

/// An in-progress transfer (tiles published so far).
struct Active {
    job: Job,
    next_tile: usize,
    tiles: Vec<Arc<ExpertF32>>,
    tile_time: f64,
    bytes: usize,
    /// Where the source copy came from when this transfer was admitted
    /// (remote = the admitting lane pulled it over the wire just now).
    source: FetchSource,
}

/// One comm lane. The unit of work is one *tile*: after every tile the
/// loop re-checks the urgent queue, so an on-demand load preempts an
/// in-progress prefetch within one tile's wire time (the tile-wise
/// scheduling of §5 applied to the link itself, like CUDA stream priority
/// at copy-chunk granularity). Preempted prefetches resume afterwards.
/// Preemption is per lane: lanes never steal each other's jobs.
fn comm_loop(ctx: CommCtx) {
    let mut urgent: Vec<Active> = Vec::new();
    let mut background: Vec<Active> = Vec::new();

    loop {
        if ctx.shutdown.load(Ordering::SeqCst) || ctx.halt.load(Ordering::SeqCst) {
            break;
        }
        // Drain newly arrived jobs (a coalesced group admits as one
        // Active per member, all sharing the group's wire-clock charge).
        while let Ok(job) = ctx.urgent_rx.try_recv() {
            admit(&ctx, job, &mut urgent);
        }
        while let Ok(job) = ctx.prefetch_rx.try_recv() {
            admit(&ctx, job, &mut background);
        }
        // Lift prefetches the compute stream is now blocked on.
        {
            let mut promoted = lock_unpoisoned(&ctx.promotions);
            if !promoted.is_empty() {
                let mut i = 0;
                while i < background.len() {
                    if promoted.remove(&background[i].job.id) {
                        let a = background.remove(i);
                        urgent.push(a);
                    } else {
                        i += 1;
                    }
                }
                promoted.clear(); // ids not found were already done/urgent
            }
        }

        // Pick the next tile of work: urgent FIFO first, else background.
        let (queue_is_urgent, slot) = if !urgent.is_empty() {
            (true, &mut urgent)
        } else if !background.is_empty() {
            (false, &mut background)
        } else {
            match ctx.wake_rx.recv_timeout(Duration::from_millis(50)) {
                Ok(()) | Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(_) => break, // engine dropped
            }
        };
        let _ = queue_is_urgent;

        let done = transfer_tile(&ctx, &mut slot[0]);
        if done {
            let a = slot.remove(0);
            finish(&ctx, a);
        }
    }
}

/// Admit one arrived job, pushing zero or more [`Active`] transfers onto
/// `out`. A singleton admits exactly as it always has; a coalesced group
/// expands into one Active per member, all priced off a *single*
/// wire-clock charge over the summed bytes (split pro-rata), with one
/// batched source warm-up for remote-backed stores. Members retired
/// early — flaky-drop, satisfied-by-cache, failed fetch — simply do not
/// consume their share of the charge.
fn admit(ctx: &CommCtx, mut job: Job, out: &mut Vec<Active>) {
    if job.members.is_empty() {
        if let Some(a) = admit_one(ctx, job, None) {
            out.push(a);
        }
        return;
    }
    let members = std::mem::take(&mut job.members);
    let store = ctx.tiers.store(job.kind);
    // One batched source resolve for the whole group: a remote-backed
    // store pulls every missing member in a single GET_RANGES round trip
    // (docs/remote-store.md), so the per-member try_fetch below is a
    // host-local pin read. Best-effort — a failed batch leaves each
    // member to fetch (and fault-retry) individually.
    if store.is_remote() {
        let ids: Vec<ExpertId> = members.iter().map(|m| m.id).collect();
        store.prefetch(&ids);
    }
    let total_bytes: usize = members.iter().map(|m| m.bytes).sum();
    let total_time =
        ctx.platform.transfer_time(total_bytes, store.expert_bytes_f32) * ctx.time_scale;
    for m in &members {
        let share = total_time * (m.bytes as f64 / total_bytes as f64);
        let single = Job {
            id: m.id,
            device: job.device,
            kind: job.kind,
            bytes: m.bytes,
            handle: Arc::clone(&m.handle),
            priority: job.priority,
            members: Vec::new(),
        };
        if let Some(a) = admit_one(ctx, single, Some(share)) {
            out.push(a);
        }
    }
    ctx.group_slab.put(members);
}

/// Set up an Active transfer, or complete it immediately from the cache
/// (prefetch/upgrade no-op path). `time_override` is a coalesced group
/// member's pro-rata share of its group's single wire-clock charge; a
/// singleton prices its own bytes.
fn admit_one(ctx: &CommCtx, job: Job, time_override: Option<f64>) -> Option<Active> {
    // Flaky-lane fault: drop every k-th admitted job on the floor. The
    // registry entry and gauge charge stay alive — the engine's fault
    // pump observes the drop report and re-issues (or fails) the job.
    let period = ctx.faults.drop_period.load(Ordering::Relaxed);
    if period > 0 {
        let n = ctx.faults.admitted.fetch_add(1, Ordering::Relaxed) + 1;
        if n % period == 0 {
            lock_unpoisoned(&ctx.dropped).push(job.id);
            return None;
        }
    }
    // A prefetch is satisfied by any resident copy; an upgrade only by a
    // copy at (or above) its target tier — re-moving equal-or-higher
    // precision bytes would waste the link.
    let satisfied = match job.priority {
        Priority::OnDemand => false,
        Priority::Prefetch => ctx.cache.contains(job.id),
        Priority::Upgrade => ctx
            .cache
            .resident_meta(job.id)
            .is_some_and(|m| m.kind.bits() >= job.kind.bits()),
    };
    if satisfied {
        // Resolve the full copy *before* claiming the ticket: with a
        // remote-backed store the fallback dequantize may need a wire
        // fetch, and a failed fetch must leave the ticket unclaimed so
        // the fault pump can retry the job like any other drop.
        let full = match ctx.cache.get(job.id) {
            Some(f) => f,
            None => {
                let store = ctx.tiers.store(job.kind);
                if store.try_fetch(job.id).is_err() {
                    ctx.stats.remote_faults.fetch_add(1, Ordering::Relaxed);
                    lock_unpoisoned(&ctx.dropped).push(job.id);
                    return None;
                }
                Arc::new(store.dequantize(job.id))
            }
        };
        // First-finisher claim: a failover/retry duplicate of a job whose
        // original copy already retired the ticket must no-op entirely.
        let Some(ci) = ctx.in_flight.claim(job.id) else {
            return None;
        };
        for t in 0..ctx.n_tiles {
            job.handle.publish_tile(t, Arc::clone(&full));
            ctx.completions.push(CompletionEvent {
                id: job.id,
                kind: CompletionKind::Tile(t),
                lane: ctx.lane,
                tier: job.kind,
            });
        }
        job.handle.publish_full(full);
        // event before the in-flight removal: quiesce() returning must imply
        // every completion event is already on the board
        ctx.completions.push(CompletionEvent {
            id: job.id,
            kind: CompletionKind::Full,
            lane: ctx.lane,
            tier: job.kind,
        });
        // Release the gauge charge where the ticket holds it (failover may
        // have migrated it off this lane).
        ctx.all_lane_stats[ci.lane].dequeue(ci.bytes as u64);
        ctx.device_queued[ci.device].fetch_sub(ci.bytes as u64, Ordering::Relaxed);
        ctx.stats.skipped_cached.fetch_add(1, Ordering::Relaxed);
        ctx.lane_stats.skipped_cached.fetch_add(1, Ordering::Relaxed);
        crate::obs::instant(
            crate::obs::Track::Lane(ctx.lane),
            crate::obs::Name::Complete,
            crate::obs::expert_corr(job.id),
            0,
        );
        // registry removal last: quiesce() returning implies the counters
        // above are already published
        ctx.in_flight.remove(job.id);
        return None;
    }
    let store = ctx.tiers.store(job.kind);
    // Resolve the source copy. A local store always answers; a remote
    // store may have to pull the artifact over the wire right here — its
    // latency lands on this lane's clock, which is exactly where a
    // cacheless node pays it. A fetch that fails (after the transport's
    // own bounded retries) is reported like a flaky-lane drop: the ticket
    // stays alive and the fault pump re-issues or fails the job through
    // the ordinary retry → failover → degradation ladder.
    let (bytes, source) = match store.try_fetch(job.id) {
        Ok((q, source)) => (q.size_bytes(), source),
        Err(_) => {
            ctx.stats.remote_faults.fetch_add(1, Ordering::Relaxed);
            lock_unpoisoned(&ctx.dropped).push(job.id);
            return None;
        }
    };
    debug_assert_eq!(bytes, job.bytes, "request-time and admit-time bytes must agree");
    let total_time = match time_override {
        Some(t) => t,
        None => ctx.platform.transfer_time(bytes, store.expert_bytes_f32) * ctx.time_scale,
    };
    crate::obs::instant(
        crate::obs::Track::Lane(ctx.lane),
        crate::obs::Name::Admit,
        crate::obs::expert_corr(job.id),
        bytes as u64,
    );
    Some(Active {
        job,
        next_tile: 0,
        tiles: Vec::with_capacity(ctx.n_tiles),
        tile_time: total_time / ctx.n_tiles as f64,
        bytes,
        source,
    })
}

/// Move one tile of `a` across the simulated link. Returns completion.
fn transfer_tile(ctx: &CommCtx, a: &mut Active) -> bool {
    let store = ctx.tiers.store(a.job.kind);
    let f = store.get(a.job.id).f;
    let f_step = f / ctx.n_tiles;
    let t = a.next_tile;
    let t_start = Instant::now();
    let f_lo = t * f_step;
    let f_hi = if t + 1 == ctx.n_tiles { f } else { (t + 1) * f_step };
    // Real work: decode this tile's bytes at the job's tier.
    let tile = Arc::new(store.dequantize_tile(a.job.id, f_lo, f_hi));
    // Simulated wire time for the remainder of the tile, degraded by any
    // injected slow/delay fault (read per tile so a mid-transfer
    // injection takes effect on the next tile).
    let scale = f64::from_bits(ctx.faults.scale_bits.load(Ordering::Relaxed));
    let extra = ctx.faults.delay_ns.load(Ordering::Relaxed) as f64 / 1e9;
    let tile_time = a.tile_time * scale + extra;
    let elapsed = t_start.elapsed().as_secs_f64();
    if tile_time > elapsed {
        std::thread::sleep(Duration::from_secs_f64(tile_time - elapsed));
    }
    let busy = (tile_time.max(elapsed) * 1e9) as u64;
    ctx.stats.sim_busy_ns.fetch_add(busy, Ordering::Relaxed);
    ctx.lane_stats.sim_busy_ns.fetch_add(busy, Ordering::Relaxed);
    crate::obs::span(
        crate::obs::Track::Lane(ctx.lane),
        crate::obs::Name::Wire,
        crate::obs::expert_corr(a.job.id),
        t_start,
    );
    a.job.handle.publish_tile(t, Arc::clone(&tile));
    ctx.completions.push(CompletionEvent {
        id: a.job.id,
        kind: CompletionKind::Tile(t),
        lane: ctx.lane,
        tier: a.job.kind,
    });
    a.tiles.push(tile);
    a.next_tile += 1;
    a.next_tile == ctx.n_tiles
}

/// Assemble + publish a completed transfer.
fn finish(ctx: &CommCtx, a: Active) {
    // First-finisher claim: when a failover/retry duplicate raced the
    // original, only the winner publishes, counts, and releases the gauge
    // charge; the loser's bytes are dropped (identical decode either way).
    let Some(ci) = ctx.in_flight.claim(a.job.id) else {
        return;
    };
    let q = ctx.tiers.store(a.job.kind).get(a.job.id);
    let (d, f) = (q.d, q.f);
    let full = Arc::new(assemble(d, f, f / ctx.n_tiles, &a.tiles));
    let meta = ResidentMeta { kind: a.job.kind, bytes: a.bytes };
    let corr = crate::obs::expert_corr(a.job.id);
    match a.job.priority {
        // On-demand loads were needed *now*: straight into the LRU cache,
        // with the source tier + wire bytes on the entry.
        Priority::OnDemand => {
            ctx.cache.insert_tiered(a.job.id, Arc::clone(&full), meta);
            crate::obs::instant(
                crate::obs::Track::Device(ci.device),
                crate::obs::Name::CacheInsert,
                corr,
                a.bytes as u64,
            );
        }
        // An upgrade only ever *replaces* the resident copy it improves
        // (atomic check-and-replace). If the target was evicted while
        // the re-transfer was on the wire, the bytes are dropped — the
        // copy is still published on the handle for any joined waiter.
        Priority::Upgrade => {
            ctx.cache.replace_if_resident(a.job.id, Arc::clone(&full), meta);
            crate::obs::instant(
                crate::obs::Track::Tier(a.job.kind.tier_index()),
                crate::obs::Name::Upgrade,
                corr,
                a.bytes as u64,
            );
        }
        // Prefetches are speculative: park them in staging only. They are
        // promoted into the LRU cache at first use (scheduler::build_plan);
        // inserting them eagerly would evict known-recently-useful experts
        // for predicted ones — measurable cache pollution.
        Priority::Prefetch => {
            ctx.staging.put(a.job.id, Arc::clone(&full), meta);
        }
    }
    a.job.handle.publish_full(full);
    // event before the in-flight removal (see admit): quiesce() implies all
    // completion events are published
    ctx.completions.push(CompletionEvent {
        id: a.job.id,
        kind: CompletionKind::Full,
        lane: ctx.lane,
        tier: a.job.kind,
    });
    // Release the gauge charge where the ticket holds it (failover may
    // have migrated it off this lane).
    ctx.all_lane_stats[ci.lane].dequeue(ci.bytes as u64);
    ctx.device_queued[ci.device].fetch_sub(ci.bytes as u64, Ordering::Relaxed);

    let ti = a.job.kind.tier_index();
    ctx.stats.transfers.fetch_add(1, Ordering::Relaxed);
    ctx.stats.bytes.fetch_add(a.bytes as u64, Ordering::Relaxed);
    // Byte-source attribution rides the claim win, so local_bytes +
    // remote_bytes == bytes holds even when failover duplicates race.
    let source_bytes = match a.source {
        FetchSource::Local => &ctx.stats.local_bytes,
        FetchSource::Remote => &ctx.stats.remote_bytes,
    };
    source_bytes.fetch_add(a.bytes as u64, Ordering::Relaxed);
    ctx.stats.tier_transfers[ti].fetch_add(1, Ordering::Relaxed);
    ctx.stats.tier_bytes[ti].fetch_add(a.bytes as u64, Ordering::Relaxed);
    ctx.lane_stats.transfers.fetch_add(1, Ordering::Relaxed);
    ctx.lane_stats.bytes.fetch_add(a.bytes as u64, Ordering::Relaxed);
    match a.job.priority {
        Priority::OnDemand => {
            ctx.stats.on_demand.fetch_add(1, Ordering::Relaxed);
            ctx.lane_stats.on_demand.fetch_add(1, Ordering::Relaxed);
        }
        Priority::Prefetch => {
            ctx.stats.prefetch.fetch_add(1, Ordering::Relaxed);
            ctx.lane_stats.prefetch.fetch_add(1, Ordering::Relaxed);
        }
        Priority::Upgrade => {
            ctx.stats.upgrades.fetch_add(1, Ordering::Relaxed);
            ctx.stats.tier_upgrades[ti].fetch_add(1, Ordering::Relaxed);
            ctx.lane_stats.upgrades.fetch_add(1, Ordering::Relaxed);
        }
    };
    crate::obs::instant(
        crate::obs::Track::Lane(ctx.lane),
        crate::obs::Name::Complete,
        corr,
        a.bytes as u64,
    );
    // registry removal last: quiesce() returning implies every counter
    // above is already published
    ctx.in_flight.remove(a.job.id);
}

/// Stitch f-tiles back into full [d,f]/[f,d] matrices.
fn assemble(d: usize, f: usize, f_step: usize, tiles: &[Arc<ExpertF32>]) -> ExpertF32 {
    let mut w1 = vec![0f32; d * f];
    let mut w3 = vec![0f32; d * f];
    let mut w2 = vec![0f32; f * d];
    for (t, tile) in tiles.iter().enumerate() {
        let f_lo = t * f_step;
        let w = tile.w1.dims[1];
        for r in 0..d {
            w1[r * f + f_lo..r * f + f_lo + w]
                .copy_from_slice(&tile.w1.data[r * w..(r + 1) * w]);
            w3[r * f + f_lo..r * f + f_lo + w]
                .copy_from_slice(&tile.w3.data[r * w..(r + 1) * w]);
        }
        w2[f_lo * d..(f_lo + w) * d].copy_from_slice(&tile.w2.data);
    }
    ExpertF32 {
        w1: Tensor { dims: vec![d, f], data: w1 },
        w3: Tensor { dims: vec![d, f], data: w3 },
        w2: Tensor { dims: vec![f, d], data: w2 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::quant::QuantKind;
    use crate::testutil::{micro_config as test_config, synthetic_weights as fake_weights};

    fn setup(kind: QuantKind, alloc: Vec<usize>, platform: &str, scale: f64)
        -> (Arc<HostStore>, Arc<DeviceCache>, TransferEngine) {
        setup_lanes(kind, alloc, platform, scale, LaneConfig::default())
    }

    fn setup_lanes(
        kind: QuantKind,
        alloc: Vec<usize>,
        platform: &str,
        scale: f64,
        lanes: LaneConfig,
    ) -> (Arc<HostStore>, Arc<DeviceCache>, TransferEngine) {
        let cfg = test_config();
        let w = fake_weights(&cfg, 7);
        let store = Arc::new(HostStore::build(&cfg, &w, kind).unwrap());
        let cache = Arc::new(DeviceCache::new(alloc));
        let engine = TransferEngine::with_lanes(
            Arc::clone(&store),
            Arc::clone(&cache),
            Platform::preset(platform).unwrap(),
            4,
            scale,
            lanes,
        );
        (store, cache, engine)
    }

    #[test]
    fn transfer_lands_in_cache_and_handle() {
        let (store, cache, engine) = setup(QuantKind::F32, vec![4, 4], "instant", 0.0);
        let h = engine.request((0, 3), Priority::OnDemand);
        let full = h.wait_full();
        assert!(cache.contains((0, 3)));
        // F32 roundtrip must match the store exactly
        let direct = store.dequantize((0, 3));
        assert_eq!(full.w1.data, direct.w1.data);
        assert_eq!(full.w2.data, direct.w2.data);
    }

    #[test]
    fn tiles_arrive_incrementally_and_match() {
        let (store, _cache, engine) = setup(QuantKind::Int8, vec![4, 4], "instant", 0.0);
        let h = engine.request((1, 2), Priority::OnDemand);
        let cfg = test_config();
        let step = cfg.d_ff / 4;
        for t in 0..4 {
            let tile = h.wait_tile(t);
            let want = store.dequantize_tile((1, 2), t * step, (t + 1) * step);
            assert_eq!(tile.w1.data, want.w1.data);
            assert_eq!(tile.w2.data, want.w2.data);
        }
        assert_eq!(h.wait_full().w1.data, store.dequantize((1, 2)).w1.data);
    }

    #[test]
    fn duplicate_requests_share_handle() {
        let (_store, _cache, engine) = setup(QuantKind::Int4, vec![8, 8], "rtx4090", 1.0);
        let h1 = engine.request((0, 0), Priority::OnDemand);
        let h2 = engine.request((0, 0), Priority::Prefetch);
        assert!(Arc::ptr_eq(&h1, &h2));
        h1.wait_full();
    }

    #[test]
    fn group_request_coalesces_to_one_wire_job() {
        let (store, cache, engine) = setup(QuantKind::Int4, vec![8, 8], "instant", 0.0);
        let ids = [(0, 0), (0, 1), (0, 2)];
        let handles = engine.request_group_at(&ids, Priority::OnDemand, QuantKind::Int4);
        assert_eq!(handles.len(), 3);
        for h in &handles {
            h.wait_full();
        }
        engine.quiesce().unwrap();
        // One job on the wire, three transfers published — every member
        // got its own completion, residency and bit-exact weights.
        assert_eq!(engine.stats.wire_jobs.load(Ordering::Relaxed), 1);
        assert_eq!(engine.stats.coalesced_groups.load(Ordering::Relaxed), 1);
        assert_eq!(engine.stats.coalesced_members.load(Ordering::Relaxed), 3);
        assert_eq!(engine.stats.transfers.load(Ordering::Relaxed), 3);
        for &id in &ids {
            assert!(cache.contains(id), "member {id:?} not resident");
            let got = cache.get(id).unwrap();
            assert_eq!(got.w1.data, store.dequantize(id).w1.data);
        }
        // The expanded member vec went back to the slab for the next plan.
        assert_eq!(lock_unpoisoned(&engine.group_slab.slabs).len(), 1);
    }

    #[test]
    fn group_request_joins_in_flight_and_singles_out_lone_miss() {
        let (_store, cache, engine) = setup(QuantKind::Int4, vec![8, 8], "instant", 0.0);
        let h0 = engine.request((0, 1), Priority::Prefetch);
        let handles = engine.request_group_at(&[(0, 0), (0, 1)], Priority::OnDemand, QuantKind::Int4);
        // The in-flight expert joined the existing transfer (and was
        // promoted); the lone fresh miss rode a singleton job, so nothing
        // was counted as a coalesced group.
        assert!(Arc::ptr_eq(&h0, &handles[1]));
        for h in &handles {
            h.wait_full();
        }
        engine.quiesce().unwrap();
        assert_eq!(engine.stats.wire_jobs.load(Ordering::Relaxed), 2);
        assert_eq!(engine.stats.coalesced_groups.load(Ordering::Relaxed), 0);
        assert!(cache.contains((0, 0)));
    }

    #[test]
    fn group_request_conserves_gauges_and_counters() {
        let (_store, _cache, engine) = setup(QuantKind::Int4, vec![8, 8], "instant", 0.0);
        let ids = [(1, 0), (1, 1), (1, 2), (1, 3)];
        let handles = engine.request_group_at(&ids, Priority::Prefetch, QuantKind::Int4);
        for h in &handles {
            h.wait_full();
        }
        engine.quiesce().unwrap();
        // Per-member gauge charges all drained back to zero.
        assert_eq!(engine.lanes[0].stats.queued_bytes.load(Ordering::Relaxed), 0);
        assert_eq!(engine.device_queued[0].load(Ordering::Relaxed), 0);
        assert_eq!(engine.stats.prefetch.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn simulated_time_is_enforced() {
        let (store, _cache, engine) = setup(QuantKind::Int4, vec![8, 8], "rtx4090", 1.0);
        let bytes = store.expert_transfer_bytes((0, 0));
        let expect = Platform::preset("rtx4090")
            .unwrap()
            .transfer_time(bytes, store.expert_bytes_f32);
        let t0 = Instant::now();
        engine.request((0, 0), Priority::OnDemand).wait_full();
        let elapsed = t0.elapsed().as_secs_f64();
        assert!(
            elapsed >= expect * 0.8,
            "transfer finished too fast: {elapsed}s < {expect}s"
        );
    }

    #[test]
    fn prefetch_skipped_when_already_cached() {
        let (store, cache, engine) = setup(QuantKind::F32, vec![8, 8], "instant", 0.0);
        cache.insert((0, 1), Arc::new(store.dequantize((0, 1))));
        let h = engine.request((0, 1), Priority::Prefetch);
        h.wait_full();
        engine.quiesce().unwrap();
        assert_eq!(engine.stats.skipped_cached.load(Ordering::Relaxed), 1);
        assert_eq!(engine.stats.transfers.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn stats_track_priorities() {
        let (_store, _cache, engine) = setup(QuantKind::F32, vec![8, 8], "instant", 0.0);
        engine.request((0, 0), Priority::OnDemand).wait_full();
        engine.request((1, 1), Priority::Prefetch).wait_full();
        engine.quiesce().unwrap();
        assert_eq!(engine.stats.on_demand.load(Ordering::Relaxed), 1);
        assert_eq!(engine.stats.prefetch.load(Ordering::Relaxed), 1);
        assert!(engine.stats.bytes.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn prefetch_parks_in_staging_not_cache() {
        let (_store, cache, engine) = setup(QuantKind::F32, vec![8, 8], "instant", 0.0);
        engine.request((0, 4), Priority::Prefetch).wait_full();
        engine.quiesce().unwrap();
        assert!(!cache.contains((0, 4)), "speculative load must not pollute LRU");
        assert!(engine.staging_contains((0, 4)));
        // consuming it removes it from staging
        let w = engine.staging.take((0, 4));
        assert!(w.is_some());
        assert!(!engine.staging_contains((0, 4)));
        assert!(engine.staging.take((0, 4)).is_none(), "single-use");
    }

    #[test]
    fn on_demand_lands_in_cache_directly() {
        let (_store, cache, engine) = setup(QuantKind::F32, vec![8, 8], "instant", 0.0);
        engine.request((1, 5), Priority::OnDemand).wait_full();
        engine.quiesce().unwrap();
        assert!(cache.contains((1, 5)));
    }

    #[test]
    fn staging_capacity_bounded_fifo() {
        let staging = Staging::new(2);
        let dummy = |_: usize| {
            Arc::new(ExpertF32 {
                w1: Tensor::zeros(vec![1]),
                w3: Tensor::zeros(vec![1]),
                w2: Tensor::zeros(vec![1]),
            })
        };
        let meta = ResidentMeta { kind: QuantKind::Int4, bytes: 16 };
        staging.put((0, 0), dummy(0), meta);
        staging.put((0, 1), dummy(1), meta);
        staging.put((0, 2), dummy(2), meta); // evicts (0,0)
        assert_eq!(staging.len(), 2);
        assert!(staging.take((0, 0)).is_none());
        let (_, m) = staging.take((0, 1)).expect("staged");
        assert_eq!(m, meta, "staging must preserve the source-tier meta");
        assert!(staging.take((0, 2)).is_some());
    }

    #[test]
    fn on_demand_promotes_joined_prefetch() {
        // Slow link: queue prefetch A then B; A starts transferring. An
        // on-demand request for B must lift it over A's remaining tiles.
        let (_store, _cache, engine) = setup(QuantKind::Int4, vec![8, 8], "rtx4090", 1.0);
        let a = engine.request((0, 0), Priority::Prefetch);
        std::thread::sleep(Duration::from_millis(1)); // let A become active
        let b = engine.request((0, 1), Priority::Prefetch);
        let b2 = engine.request((0, 1), Priority::OnDemand); // promote B
        assert!(Arc::ptr_eq(&b, &b2));
        b.wait_full();
        assert!(
            !a.is_complete(),
            "promoted on-demand should finish before the preempted prefetch"
        );
        a.wait_full();
    }

    #[test]
    fn completion_events_follow_arrival_order() {
        let (_store, _cache, engine) = setup(QuantKind::F32, vec![8, 8], "instant", 0.0);
        engine.completions.clear();
        let a = engine.request((0, 2), Priority::OnDemand);
        a.wait_full();
        let b = engine.request((0, 5), Priority::OnDemand);
        b.wait_full();
        engine.quiesce().unwrap();
        // 4 tiles + 1 full per expert, expert (0,2) strictly before (0,5)
        let mut seen = Vec::new();
        while let Some(ev) = engine.completions.try_pop() {
            seen.push(ev);
        }
        assert_eq!(seen.len(), 10, "4 tiles + full per expert: {seen:?}");
        assert!(seen[..5].iter().all(|e| e.id == (0, 2)));
        assert!(seen[5..].iter().all(|e| e.id == (0, 5)));
        assert_eq!(seen[4].kind, CompletionKind::Full);
        assert_eq!(seen[9].kind, CompletionKind::Full);
        // single-lane engine: every event carries lane 0
        assert!(seen.iter().all(|e| e.lane == 0));
        assert!(engine.completions.is_empty());
    }

    #[test]
    fn try_accessors_and_arrival_instants() {
        let (_store, _cache, engine) = setup(QuantKind::F32, vec![8, 8], "instant", 0.0);
        let h = engine.request((1, 1), Priority::OnDemand);
        h.wait_full();
        let (w, at) = h.try_full().expect("full landed");
        assert!(!w.w1.is_empty());
        assert!(at.elapsed().as_secs() < 60);
        for t in 0..4 {
            assert!(h.try_tile(t).is_some(), "tile {t} landed");
        }
        // a fresh handle has nothing available
        let h2 = TransferHandle::new((9, 9), 4, 0, QuantKind::F32, 0);
        assert!(h2.try_full().is_none());
        assert!(h2.try_tile(0).is_none());
    }

    #[test]
    fn quiesce_blocks_until_drain_without_polling() {
        // slow link: quiesce must actually sleep through multiple transfers
        let (_store, cache, engine) = setup(QuantKind::Int4, vec![8, 8], "rtx4090", 1.0);
        for e in 0..3 {
            engine.request((0, e), Priority::OnDemand);
        }
        let t0 = Instant::now();
        engine.quiesce().unwrap();
        assert_eq!(engine.pending(), 0);
        assert!(t0.elapsed().as_secs_f64() > 0.0);
        for e in 0..3 {
            assert!(cache.contains((0, e)));
        }
    }

    #[test]
    fn board_is_bounded() {
        let board = CompletionBoard::new();
        for i in 0..(BOARD_CAP + 10) {
            board.push(CompletionEvent {
                id: (0, i),
                kind: CompletionKind::Full,
                lane: 0,
                tier: QuantKind::F32,
            });
        }
        assert_eq!(board.len(), BOARD_CAP);
        // oldest events were dropped
        assert_eq!(board.try_pop().unwrap().id, (0, 10));
    }

    #[test]
    fn wait_pop_times_out_empty() {
        let board = CompletionBoard::new();
        let t0 = Instant::now();
        assert!(board.wait_pop(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let (_store, _cache, engine) = setup(QuantKind::F32, vec![4, 4], "instant", 0.0);
        engine.request((0, 0), Priority::OnDemand).wait_full();
        drop(engine); // must join without hanging
    }

    // -- multi-lane -----------------------------------------------------------

    #[test]
    fn round_robin_cycles_lanes() {
        let (_store, _cache, engine) = setup_lanes(
            QuantKind::F32,
            vec![8, 8],
            "instant",
            0.0,
            LaneConfig::new(2, LanePolicy::RoundRobin),
        );
        assert_eq!(engine.n_lanes(), 2);
        let lanes: Vec<LaneId> = (0..4)
            .map(|e| engine.request((0, e), Priority::OnDemand).lane)
            .collect();
        assert_eq!(lanes, vec![0, 1, 0, 1]);
        engine.quiesce().unwrap();
        let snaps = engine.lane_snapshots();
        assert_eq!(snaps[0].transfers, 2);
        assert_eq!(snaps[1].transfers, 2);
        assert!(snaps.iter().all(|s| s.queued_bytes == 0 && s.queued_jobs == 0));
    }

    #[test]
    fn least_queued_bytes_prefers_idle_lane() {
        // Slow link: the first job keeps lane 0 loaded, so the second must
        // be assigned to the (empty) lane 1.
        let (_store, _cache, engine) = setup_lanes(
            QuantKind::Int4,
            vec![8, 8],
            "rtx4090",
            1.0,
            LaneConfig::new(2, LanePolicy::LeastQueuedBytes),
        );
        let a = engine.request((0, 0), Priority::OnDemand);
        let b = engine.request((0, 1), Priority::OnDemand);
        assert_eq!(a.lane, 0, "tie breaks toward the lowest lane");
        assert_eq!(b.lane, 1, "loaded lane 0 must be avoided");
        engine.quiesce().unwrap();
    }

    #[test]
    fn pinned_reserves_lane_zero_for_on_demand() {
        let (_store, _cache, engine) = setup_lanes(
            QuantKind::F32,
            vec![8, 8],
            "instant",
            0.0,
            LaneConfig::new(3, LanePolicy::Pinned),
        );
        let od = engine.request((0, 0), Priority::OnDemand);
        assert_eq!(od.lane, 0);
        for e in 1..6 {
            let h = engine.request((0, e), Priority::Prefetch);
            assert_ne!(h.lane, 0, "prefetch must never ride the reserved lane");
        }
        engine.quiesce().unwrap();
        let snaps = engine.lane_snapshots();
        assert_eq!(snaps[0].prefetch, 0, "reserved lane carried no prefetch");
        assert_eq!(snaps[0].on_demand, 1);
        assert_eq!(snaps[1].on_demand + snaps[2].on_demand, 0);
    }

    #[test]
    fn per_lane_wire_clocks_are_independent() {
        // Lane 1 runs at 0× wire time: a job there must finish while the
        // earlier job on slow lane 0 is still in flight.
        let (_store, _cache, engine) = setup_lanes(
            QuantKind::Int4,
            vec![8, 8],
            "rtx4090",
            1.0,
            LaneConfig::new(2, LanePolicy::RoundRobin).with_time_scales(vec![1.0, 0.0]),
        );
        let slow = engine.request((0, 0), Priority::OnDemand); // lane 0
        let fast = engine.request((0, 1), Priority::OnDemand); // lane 1
        assert_eq!((slow.lane, fast.lane), (0, 1));
        fast.wait_full();
        assert!(
            !slow.is_complete(),
            "fast lane must complete while the slow lane still transfers"
        );
        slow.wait_full();
        engine.quiesce().unwrap();
    }

    #[test]
    fn quiesce_reports_dead_lane_not_global_timeout() {
        // Lane 1 is slowed 10× then halted mid-transfer: with failover
        // disabled (legacy semantics) quiesce_for must fail fast with a
        // per-lane report instead of waiting out the backstop or hanging.
        let (_store, _cache, engine) = setup_lanes(
            QuantKind::Int4,
            vec![8, 8],
            "rtx4090",
            1.0,
            LaneConfig::new(2, LanePolicy::RoundRobin)
                .with_time_scales(vec![1.0, 10.0])
                .with_faults(FaultConfig::disabled()),
        );
        let a = engine.request((0, 0), Priority::OnDemand); // lane 0, drains
        let _b = engine.request((0, 1), Priority::OnDemand); // lane 1, doomed
        a.wait_full(); // lane 0 empty before the fault so only lane 1 is blamed
        while engine.lane_of((0, 0)).is_some() {
            std::thread::sleep(Duration::from_millis(1));
        }
        engine.halt_lane(1);
        let t0 = Instant::now();
        let err = engine
            .quiesce_for(Duration::from_secs(10))
            .expect_err("dead lane must surface");
        let msg = format!("{err:#}");
        assert!(msg.contains("lane 1"), "error must name the lane: {msg}");
        assert!(msg.contains("DEAD"), "error must flag the dead worker: {msg}");
        assert!(!msg.contains("lane 0"), "drained lane must not be blamed: {msg}");
        assert!(
            t0.elapsed() < Duration::from_secs(8),
            "dead lane must fail fast, not wait out the backstop"
        );
    }

    #[test]
    fn quiesce_backstop_reports_per_lane_pending() {
        // A lane that is alive but far too slow hits the backstop path and
        // still gets a per-lane report.
        let (_store, _cache, engine) = setup_lanes(
            QuantKind::Int4,
            vec![8, 8],
            "rtx4090",
            1.0,
            LaneConfig::new(2, LanePolicy::RoundRobin).with_time_scales(vec![0.0, 400.0]),
        );
        let _fast = engine.request((0, 0), Priority::OnDemand);
        let _slow = engine.request((0, 1), Priority::OnDemand);
        let err = engine
            .quiesce_for(Duration::from_millis(120))
            .expect_err("backstop must elapse");
        let msg = format!("{err:#}");
        assert!(msg.contains("backstop elapsed"), "{msg}");
        assert!(msg.contains("lane 1: 1 in-flight"), "{msg}");
        // full drain afterwards keeps the engine usable
        engine.quiesce_for(Duration::from_secs(30)).unwrap();
    }

    #[test]
    fn request_to_halted_lane_strands_instead_of_panicking() {
        // Pinned policy routes every on-demand job to lane 0; killing that
        // lane first means the send must fail. With failover disabled
        // (legacy semantics) the request must not panic — the job strands
        // in the in-flight registry and quiesce_for names the dead lane.
        let (_store, _cache, engine) = setup_lanes(
            QuantKind::F32,
            vec![8, 8],
            "instant",
            0.0,
            LaneConfig::new(2, LanePolicy::Pinned).with_faults(FaultConfig::disabled()),
        );
        engine.halt_lane(0);
        while engine.lanes[0]
            .worker
            .as_ref()
            .map(|w| !w.is_finished())
            .unwrap_or(false)
        {
            std::thread::sleep(Duration::from_millis(1));
        }
        let h = engine.request((0, 0), Priority::OnDemand);
        assert_eq!(h.lane, 0);
        assert!(!h.is_complete(), "stranded transfer can never complete");
        let err = engine
            .quiesce_for(Duration::from_millis(200))
            .expect_err("stranded job on a dead lane must be reported");
        let msg = format!("{err:#}");
        assert!(msg.contains("lane 0") && msg.contains("DEAD"), "{msg}");
    }

    #[test]
    fn lane_policy_names_roundtrip() {
        for name in LanePolicy::names() {
            let p = LanePolicy::from_name(name).expect("known name");
            assert_eq!(p.name(), *name);
        }
        assert!(LanePolicy::from_name("warp-drive").is_none());
    }

    // -- fault tolerance ------------------------------------------------------

    #[test]
    fn failover_reissues_dead_lane_jobs() {
        // Lane 1 runs 400× slower, takes a job, then dies: the fault pump
        // must re-home the job onto (instant) lane 0 and quiesce must
        // drain clean with the failover recorded.
        let (_store, cache, engine) = setup_lanes(
            QuantKind::Int4,
            vec![8, 8],
            "rtx4090",
            1.0,
            LaneConfig::new(2, LanePolicy::RoundRobin)
                .with_time_scales(vec![0.0, 400.0]),
        );
        let a = engine.request((0, 0), Priority::OnDemand); // lane 0
        let b = engine.request((0, 1), Priority::OnDemand); // lane 1
        assert_eq!((a.lane, b.lane), (0, 1));
        engine.halt_lane(1);
        let report = engine.quiesce().unwrap();
        assert_eq!(report.failovers, 1, "{report:?}");
        assert_eq!(report.dead_lanes, vec![1]);
        assert!(report.failed.is_empty(), "{report:?}");
        assert!(b.is_complete(), "failed-over transfer must complete");
        assert!(cache.contains((0, 1)), "failed-over job must land in cache");
        assert_eq!(engine.lane_health(1), LaneHealth::Dead);
        // gauges conserve across the lane→lane charge migration
        let snaps = engine.lane_snapshots();
        assert!(
            snaps.iter().all(|s| s.queued_bytes == 0 && s.queued_jobs == 0),
            "{snaps:?}"
        );
        assert_eq!(snaps[1].failovers, 1, "failover attributed to the dead lane");
        // fresh requests now avoid the dead lane entirely
        let c = engine.request((0, 2), Priority::OnDemand);
        assert_eq!(c.lane, 0);
        engine.quiesce().unwrap();
    }

    #[test]
    fn flaky_drops_are_retried_to_completion() {
        // Lane 0 drops every job it admits; the drop marks it Suspect, so
        // the retry re-homes onto lane 1 and the transfer still lands.
        let (_store, cache, engine) = setup_lanes(
            QuantKind::F32,
            vec![8, 8],
            "instant",
            0.0,
            LaneConfig::new(2, LanePolicy::RoundRobin),
        );
        engine.inject(&FaultAction::FlakyLane(0, 1));
        let h = engine.request((0, 0), Priority::OnDemand);
        assert_eq!(h.lane, 0);
        let report = engine.quiesce().unwrap();
        assert_eq!(report.retries, 1, "{report:?}");
        assert!(report.failed.is_empty(), "{report:?}");
        assert!(report.dead_lanes.is_empty());
        assert!(h.is_complete());
        assert!(cache.contains((0, 0)));
        assert_eq!(engine.lane_health(0), LaneHealth::Suspect);
        assert_eq!(engine.lane_health(1), LaneHealth::Healthy);
        // conservation: one request, one transfer, gauges drained
        assert_eq!(engine.stats.transfers.load(Ordering::Relaxed), 1);
        let snaps = engine.lane_snapshots();
        assert!(
            snaps.iter().all(|s| s.queued_bytes == 0 && s.queued_jobs == 0),
            "{snaps:?}"
        );
    }

    #[test]
    fn exhausted_retries_fail_the_handle() {
        // A single flaky lane (drops everything) with a zero-retry budget:
        // the transfer must fail terminally — not strand quiesce.
        let (_store, cache, engine) = setup_lanes(
            QuantKind::F32,
            vec![8, 8],
            "instant",
            0.0,
            LaneConfig::new(1, LanePolicy::RoundRobin)
                .with_faults(FaultConfig { max_retries: 0, ..FaultConfig::default() }),
        );
        engine.inject(&FaultAction::FlakyLane(0, 1));
        let h = engine.request((0, 0), Priority::OnDemand);
        let report = engine.quiesce().unwrap();
        assert!(h.is_failed(), "exhausted ladder must fail the handle");
        assert!(!h.is_complete());
        assert_eq!(report.failed, vec![(0, 0)]);
        assert_eq!(engine.stats.failed.load(Ordering::Relaxed), 1);
        assert!(!cache.contains((0, 0)));
        // the failed job released its gauge charge
        let snaps = engine.lane_snapshots();
        assert!(
            snaps.iter().all(|s| s.queued_bytes == 0 && s.queued_jobs == 0),
            "{snaps:?}"
        );
    }

    #[test]
    fn fault_plan_injection_applies_at_steps() {
        let (_store, _cache, engine) = setup_lanes(
            QuantKind::F32,
            vec![8, 8],
            "instant",
            0.0,
            LaneConfig::new(2, LanePolicy::RoundRobin),
        );
        let plan = FaultPlan::parse("1:slow:0:3;2:halt:1").unwrap();
        engine.apply_fault_plan(&plan, 0); // no events at step 0
        assert_eq!(engine.lane_health(0), LaneHealth::Healthy);
        assert_eq!(engine.lane_health(1), LaneHealth::Healthy);
        engine.apply_fault_plan(&plan, 1);
        let scale =
            f64::from_bits(engine.lanes[0].faults.scale_bits.load(Ordering::Relaxed));
        assert_eq!(scale, 3.0);
        assert_eq!(engine.lane_health(1), LaneHealth::Healthy);
        engine.apply_fault_plan(&plan, 2);
        assert_eq!(engine.lane_health(1), LaneHealth::Dead);
        // requests keep landing: assignment avoids the dead lane
        let h = engine.request((0, 0), Priority::OnDemand);
        assert_eq!(h.lane, 0);
        let report = engine.quiesce().unwrap();
        assert_eq!(report.dead_lanes, vec![1]);
    }

    // -- sharded device backends ----------------------------------------------

    use crate::memory::sharded_cache::Placement;

    fn setup_devices(
        kind: QuantKind,
        allocations: Vec<Vec<usize>>,
        placement: Placement,
        platform: &str,
        scale: f64,
        lanes: LaneConfig,
    ) -> (Arc<HostStore>, Arc<ShardedCache>, TransferEngine) {
        let cfg = test_config();
        let w = fake_weights(&cfg, 7);
        let store = Arc::new(HostStore::build(&cfg, &w, kind).unwrap());
        let cache = Arc::new(ShardedCache::new(allocations, placement));
        let engine = TransferEngine::with_devices(
            Arc::clone(&store),
            Arc::clone(&cache),
            Platform::preset(platform).unwrap(),
            4,
            scale,
            lanes,
        );
        (store, cache, engine)
    }

    #[test]
    fn device_affinity_partitions_lanes() {
        // 2 devices (layer-sliced over the 2-layer micro config), 4 lanes:
        // layer-0 transfers must ride lanes {0,2}, layer-1 lanes {1,3}.
        let (_store, cache, engine) = setup_devices(
            QuantKind::F32,
            vec![vec![8, 8]; 2],
            Placement::LayerSliced,
            "instant",
            0.0,
            LaneConfig::new(4, LanePolicy::RoundRobin),
        );
        assert_eq!(engine.n_devices(), 2);
        assert_eq!(engine.lanes_for_device(0), vec![0, 2]);
        assert_eq!(engine.lanes_for_device(1), vec![1, 3]);
        for e in 0..4 {
            let h0 = engine.request((0, e), Priority::OnDemand);
            assert_eq!(h0.lane % 2, 0, "layer 0 rode lane {}", h0.lane);
            let h1 = engine.request((1, e), Priority::OnDemand);
            assert_eq!(h1.lane % 2, 1, "layer 1 rode lane {}", h1.lane);
        }
        engine.quiesce().unwrap();
        // completed loads landed on the owning shard only
        for e in 0..4 {
            assert!(cache.shard(0).contains((0, e)));
            assert!(!cache.shard(1).contains((0, e)));
            assert!(cache.shard(1).contains((1, e)));
        }
        // device queued-bytes gauge drains to zero like the lane gauges
        let snaps = engine.device_snapshots();
        assert_eq!(snaps.len(), 2);
        assert!(snaps.iter().all(|s| s.queued_bytes == 0), "{snaps:?}");
        assert!(snaps.iter().all(|s| s.resident == 4), "{snaps:?}");
    }

    #[test]
    fn device_round_robin_cycles_within_each_group() {
        // Alternating cross-device traffic must cycle each device's own
        // group: a single global cursor would alias device 0 to lane 0
        // and device 1 to lane 3 forever, starving lanes 2 and 1.
        let (_store, _cache, engine) = setup_devices(
            QuantKind::F32,
            vec![vec![8, 8]; 2],
            Placement::LayerSliced,
            "instant",
            0.0,
            LaneConfig::new(4, LanePolicy::RoundRobin),
        );
        let mut lanes0 = Vec::new();
        let mut lanes1 = Vec::new();
        for e in 0..4 {
            lanes0.push(engine.request((0, e), Priority::OnDemand).lane);
            lanes1.push(engine.request((1, e), Priority::OnDemand).lane);
        }
        assert_eq!(lanes0, vec![0, 2, 0, 2], "device 0 cycles its own group");
        assert_eq!(lanes1, vec![1, 3, 1, 3], "device 1 cycles its own group");
        engine.quiesce().unwrap();
    }

    #[test]
    fn fewer_lanes_than_devices_share_a_fallback_lane() {
        // 3 devices over 2 lanes: device 2's lane group is empty, so its
        // transfers fall back to lane 2 % 2 = 0 instead of panicking.
        let (_store, cache, engine) = setup_devices(
            QuantKind::F32,
            vec![vec![8, 8]; 3],
            Placement::ExpertHash,
            "instant",
            0.0,
            LaneConfig::new(2, LanePolicy::RoundRobin),
        );
        assert_eq!(engine.lanes_for_device(2), vec![0]);
        for e in 0..8 {
            let id = (0usize, e);
            let dev = cache.device_of(id);
            let expect = engine.lanes_for_device(dev)[0];
            let h = engine.request(id, Priority::OnDemand);
            assert_eq!(h.lane, expect, "expert {id:?} of device {dev}");
        }
        engine.quiesce().unwrap();
    }

    #[test]
    fn pinned_policy_applies_within_device_group() {
        // 2 devices × 4 lanes under `pinned`: each device's group is
        // [d, d+2]; on-demand rides the group head, prefetch the rest.
        let (_store, _cache, engine) = setup_devices(
            QuantKind::F32,
            vec![vec![8, 8]; 2],
            Placement::LayerSliced,
            "instant",
            0.0,
            LaneConfig::new(4, LanePolicy::Pinned),
        );
        let od = engine.request((0, 0), Priority::OnDemand);
        assert_eq!(od.lane, 0, "device 0 on-demand rides its group head");
        let pf = engine.request((0, 1), Priority::Prefetch);
        assert_eq!(pf.lane, 2, "device 0 prefetch avoids the reserved lane");
        let od1 = engine.request((1, 0), Priority::OnDemand);
        assert_eq!(od1.lane, 1, "device 1 on-demand rides its group head");
        let pf1 = engine.request((1, 1), Priority::Prefetch);
        assert_eq!(pf1.lane, 3);
        engine.quiesce().unwrap();
    }

    #[test]
    fn single_device_set_matches_historical_assignment() {
        // with_lanes wraps a single shard: assignment must be the PR 3
        // logic (round-robin over all lanes, no affinity confinement).
        let (_store, _cache, engine) = setup_lanes(
            QuantKind::F32,
            vec![8, 8],
            "instant",
            0.0,
            LaneConfig::new(3, LanePolicy::RoundRobin),
        );
        assert_eq!(engine.n_devices(), 1);
        let lanes: Vec<LaneId> = (0..6)
            .map(|e| engine.request((0, e), Priority::OnDemand).lane)
            .collect();
        assert_eq!(lanes, vec![0, 1, 2, 0, 1, 2]);
        engine.quiesce().unwrap();
    }

    // -- tiered precision -----------------------------------------------------

    fn setup_tiered(
        kinds: &[QuantKind],
        precision: PrecisionPolicy,
        alloc: Vec<usize>,
        platform: &str,
        scale: f64,
    ) -> (Arc<TieredStore>, Arc<DeviceCache>, TransferEngine) {
        let cfg = test_config();
        let w = fake_weights(&cfg, 7);
        let tiers = Arc::new(TieredStore::build(&cfg, &w, kinds).unwrap());
        let cache = Arc::new(DeviceCache::new(alloc));
        let engine = TransferEngine::with_tiers(
            Arc::clone(&tiers),
            precision,
            Arc::new(ShardedCache::single(Arc::clone(&cache))),
            Platform::preset(platform).unwrap(),
            4,
            scale,
            LaneConfig::default(),
        );
        (tiers, cache, engine)
    }

    #[test]
    fn urgency_policy_routes_tiers_and_counts_bytes() {
        let (tiers, cache, engine) = setup_tiered(
            &[QuantKind::Int2, QuantKind::Int8],
            PrecisionPolicy::Urgency,
            vec![8, 8],
            "instant",
            0.0,
        );
        // on-demand rides the lowest tier, full-slack prefetch the highest
        let od = engine.request((0, 0), Priority::OnDemand);
        assert_eq!(od.kind, QuantKind::Int2);
        assert_eq!(od.bytes, tiers.expert_transfer_bytes((0, 0), QuantKind::Int2));
        let pf = engine.request((0, 1), Priority::Prefetch);
        assert_eq!(pf.kind, QuantKind::Int8);
        od.wait_full();
        pf.wait_full();
        engine.quiesce().unwrap();
        // resident meta records the source tier + wire bytes
        let m = cache.resident_meta((0, 0)).expect("on-demand landed in cache");
        assert_eq!(m.kind, QuantKind::Int2);
        assert_eq!(m.bytes, od.bytes);
        // per-tier counters attribute each transfer's bytes to its tier
        let snaps = engine.tier_snapshots();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[0].kind, QuantKind::Int2);
        assert_eq!(snaps[0].transfers, 1);
        assert_eq!(snaps[0].bytes, od.bytes as u64);
        assert_eq!(snaps[1].kind, QuantKind::Int8);
        assert_eq!(snaps[1].transfers, 1);
        assert_eq!(snaps[1].bytes, pf.bytes as u64);
        assert_eq!(
            engine.stats.bytes.load(Ordering::Relaxed),
            (od.bytes + pf.bytes) as u64,
            "tier bytes must sum to the aggregate gauge"
        );
        // slack scales the prefetch tier down toward the urgent encoding
        let low = engine.request_with_slack((1, 0), Priority::Prefetch, 0.0);
        assert_eq!(low.kind, QuantKind::Int2);
        engine.quiesce().unwrap();
    }

    // -- remote sourcing ------------------------------------------------------

    /// In-process stand-in for `crate::net::remote::RemoteFetcher`: serves
    /// clones from a local twin store, failing the first `fail_first`
    /// calls (a deterministic schedule regardless of lane interleaving).
    struct TwinFetcher {
        twin: Arc<crate::memory::host_store::HostStore>,
        calls: AtomicU64,
        fail_first: u64,
    }

    impl crate::memory::host_store::ExpertFetcher for TwinFetcher {
        fn fetch(
            &self,
            id: ExpertId,
        ) -> std::result::Result<crate::memory::host_store::QuantExpert, String> {
            let n = self.calls.fetch_add(1, Ordering::Relaxed) + 1;
            if n <= self.fail_first {
                return Err("injected fetch failure".into());
            }
            Ok(self.twin.get(id).clone())
        }
    }

    fn setup_remote(kind: QuantKind, fail_first: u64) -> (Arc<TieredStore>, TransferEngine) {
        let cfg = test_config();
        let w = fake_weights(&cfg, 7);
        let twin = Arc::new(HostStore::build(&cfg, &w, kind).unwrap());
        let sizes: Vec<usize> = (0..cfg.n_layers)
            .flat_map(|l| (0..cfg.n_experts).map(move |e| (l, e)))
            .map(|id| twin.expert_transfer_bytes(id))
            .collect();
        let fetcher = Arc::new(TwinFetcher {
            twin: Arc::clone(&twin),
            calls: AtomicU64::new(0),
            fail_first,
        });
        let remote = Arc::new(
            HostStore::remote(
                kind,
                cfg.n_layers,
                cfg.n_experts,
                cfg.expert_bytes_f32(),
                sizes,
                fetcher,
                Arc::new(crate::memory::host_store::FetchCounters::default()),
            )
            .unwrap(),
        );
        let tiers = Arc::new(TieredStore::single(remote));
        let cache = Arc::new(DeviceCache::new(vec![8, 8]));
        let engine = TransferEngine::with_tiers(
            Arc::clone(&tiers),
            PrecisionPolicy::Fixed,
            Arc::new(ShardedCache::single(cache)),
            Platform::preset("instant").unwrap(),
            4,
            0.0,
            LaneConfig::default(),
        );
        (tiers, engine)
    }

    #[test]
    fn remote_source_attribution_conserves_bytes() {
        let (tiers, engine) = setup_remote(QuantKind::Int4, 0);
        // first touch: every byte is remote-sourced
        let h1 = engine.request((0, 0), Priority::OnDemand);
        let h2 = engine.request((1, 2), Priority::OnDemand);
        h1.wait_full();
        h2.wait_full();
        engine.quiesce().unwrap();
        let s = engine.source_snapshot();
        assert_eq!(s.remote_bytes, (h1.bytes + h2.bytes) as u64);
        assert_eq!(s.local_bytes, 0);
        assert_eq!(s.remote_faults, 0);
        // re-transfer of a pinned expert is local-sourced
        let h3 = engine.request((0, 0), Priority::OnDemand);
        h3.wait_full();
        engine.quiesce().unwrap();
        let s = engine.source_snapshot();
        assert_eq!(s.local_bytes, h3.bytes as u64);
        assert_eq!(
            s.local_bytes + s.remote_bytes,
            engine.stats.bytes.load(Ordering::Relaxed),
            "source split must conserve the aggregate byte gauge"
        );
        // remote decode is bit-identical to the twin store's
        let direct = tiers.store(QuantKind::Int4).dequantize((0, 0));
        assert_eq!(h3.wait_full().w1.data, direct.w1.data);
    }

    #[test]
    fn failed_remote_fetch_feeds_fault_pump_and_retries() {
        // the first fetch fails; the fault pump must re-issue the dropped
        // admit (quiesce drives the pump) and the retry's fetch succeeds
        let (_tiers, engine) = setup_remote(QuantKind::Int4, 1);
        let handles: Vec<_> = (0..4)
            .map(|e| engine.request((0, e), Priority::OnDemand))
            .collect();
        let report = engine.quiesce().unwrap();
        for h in &handles {
            h.wait_full();
        }
        let s = engine.source_snapshot();
        assert_eq!(s.remote_faults, 1, "exactly one admit hit the failure");
        assert!(report.retries >= 1, "drop re-issued through the fault pump");
        assert_eq!(
            engine.stats.transfers.load(Ordering::Relaxed),
            4,
            "every expert still lands exactly once"
        );
        assert_eq!(
            s.local_bytes + s.remote_bytes,
            engine.stats.bytes.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn upgrade_replaces_resident_copy_at_higher_tier() {
        let (tiers, cache, engine) = setup_tiered(
            &[QuantKind::Int2, QuantKind::Int8],
            PrecisionPolicy::Urgency,
            vec![8, 8],
            "instant",
            0.0,
        );
        engine.request((0, 3), Priority::OnDemand).wait_full(); // int2 resident
        engine.quiesce().unwrap();
        assert_eq!(cache.resident_meta((0, 3)).unwrap().kind, QuantKind::Int2);
        let up = engine.request_at((0, 3), Priority::Upgrade, QuantKind::Int8);
        assert_eq!(up.kind, QuantKind::Int8);
        let full = up.wait_full();
        engine.quiesce().unwrap();
        // the resident entry now carries the int8 decode + its byte charge
        let m = cache.resident_meta((0, 3)).unwrap();
        assert_eq!(m.kind, QuantKind::Int8);
        assert_eq!(m.bytes, tiers.expert_transfer_bytes((0, 3), QuantKind::Int8));
        let direct = tiers.store(QuantKind::Int8).dequantize((0, 3));
        assert_eq!(full.w1.data, direct.w1.data);
        assert_eq!(engine.stats.upgrades.load(Ordering::Relaxed), 1);
        // a second upgrade to the same (or lower) tier is a no-op skip
        engine.request_at((0, 3), Priority::Upgrade, QuantKind::Int8).wait_full();
        engine.quiesce().unwrap();
        assert_eq!(engine.stats.upgrades.load(Ordering::Relaxed), 1);
        assert_eq!(engine.stats.skipped_cached.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn upgrade_landing_after_eviction_does_not_reinsert() {
        // Layer 0 holds a single expert. While an upgrade for (0,0) is on
        // the (slow) wire, another insert evicts it — the landed upgrade
        // must be dropped, not re-inserted over the live resident.
        let (tiers, cache, engine) = setup_tiered(
            &[QuantKind::Int2, QuantKind::Int8],
            PrecisionPolicy::Urgency,
            vec![1, 8],
            "rtx4090",
            1.0,
        );
        engine.request((0, 0), Priority::OnDemand).wait_full(); // int2 resident
        engine.quiesce().unwrap();
        let up = engine.request_at((0, 0), Priority::Upgrade, QuantKind::Int8);
        // evict the target while the upgrade transfers (~ms of wire time)
        cache.insert(
            (0, 1),
            Arc::new(tiers.store(QuantKind::Int2).dequantize((0, 1))),
        );
        assert!(!cache.contains((0, 0)), "capacity-1 layer evicted the target");
        up.wait_full();
        engine.quiesce().unwrap();
        assert!(
            !cache.contains((0, 0)),
            "landed upgrade must not evict the live resident to re-insert"
        );
        assert!(cache.contains((0, 1)));
        assert_eq!(engine.stats.upgrades.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn fixed_policy_single_tier_matches_historical_bytes() {
        // The single-tier tiered engine must charge exactly the wire
        // bytes the historical HostStore engine charges.
        let (_store, _cache, legacy) = setup(QuantKind::Int4, vec![8, 8], "instant", 0.0);
        let (_tiers, _tc, tiered) = setup_tiered(
            &[QuantKind::Int4],
            PrecisionPolicy::Fixed,
            vec![8, 8],
            "instant",
            0.0,
        );
        for e in 0..4 {
            legacy.request((0, e), Priority::OnDemand);
            tiered.request((0, e), Priority::OnDemand);
        }
        legacy.quiesce().unwrap();
        tiered.quiesce().unwrap();
        assert_eq!(
            legacy.stats.bytes.load(Ordering::Relaxed),
            tiered.stats.bytes.load(Ordering::Relaxed)
        );
        assert_eq!(tiered.tier_snapshots().len(), 1);
        assert_eq!(
            tiered.tier_snapshots()[0].bytes,
            tiered.stats.bytes.load(Ordering::Relaxed)
        );
    }
}
