//! Device-side ("GPU memory") expert cache with per-layer budgets + LRU.
//!
//! Capacity is counted in experts, matching the paper's formulation (total
//! cache size T split into per-layer sizes t_i). Within a layer, eviction is
//! LRU — the elimination policy every method in §6 uses. The per-layer
//! allocation vector is produced either uniformly (Mixtral-offloading
//! baseline) or by the DP planner ([`crate::coordinator::cache_plan`]).
//!
//! One `DeviceCache` models one device's memory pool. A multi-device
//! deployment shards experts across several of these behind
//! [`crate::memory::sharded_cache::ShardedCache`]; code that only needs
//! lookup/insert talks to either through the [`ExpertCache`] trait.
//!
//! Shared between the compute thread and the transfer engine's comm
//! threads; all state sits behind one mutex. LRU recency is tracked with a
//! lazy-deletion stamp queue, so `get`/`insert`/eviction are amortized
//! O(1) — a `Vec::remove(0)`-style scan would become a real cost once
//! shards multiply cache traffic.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::memory::host_store::ExpertF32;
use crate::memory::quant::QuantKind;
use crate::model::ExpertId;

/// Source-precision metadata of a resident expert: which tier's bytes it
/// was decoded from and how many wire bytes that encoding occupies. The
/// byte figure is what the layer's byte budget charges; the kind is what
/// degrade-vs-stall lookups and the upgrade path compare against
/// (docs/tiered-precision.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResidentMeta {
    pub kind: QuantKind,
    pub bytes: usize,
}

impl ResidentMeta {
    /// Metadata for an entry of unknown provenance (legacy `insert`):
    /// resident copies are dequantized f32, so the honest charge is the
    /// full f32 footprint at the top "tier".
    pub fn unknown(value: &ExpertF32) -> ResidentMeta {
        let n = value.w1.data.len() + value.w3.data.len() + value.w2.data.len();
        ResidentMeta { kind: QuantKind::F32, bytes: 4 * n }
    }
}

/// The lookup/insert surface shared by [`DeviceCache`] (one device) and
/// [`crate::memory::sharded_cache::ShardedCache`] (a placement-routed set
/// of devices). The scheduler, executor and prefetch planner talk to
/// `&dyn ExpertCache`, so a plan built for one device pool runs unchanged
/// against a sharded one.
pub trait ExpertCache: Send + Sync {
    /// Look up an expert; updates LRU recency and hit/miss counters.
    fn get(&self, id: ExpertId) -> Option<Arc<ExpertF32>>;
    /// Peek without touching recency or counters (prefetch planning).
    fn contains(&self, id: ExpertId) -> bool;
    /// Insert a ready expert, evicting the layer's LRU entry if at
    /// capacity. Returns the evicted id.
    fn insert(&self, id: ExpertId, value: Arc<ExpertF32>) -> Option<ExpertId>;
    /// Insert with source-tier metadata (byte-denominated accounting and
    /// degrade/upgrade decisions). Defaults to [`ExpertCache::insert`],
    /// dropping the metadata — single-precision caches need no more.
    fn insert_tiered(
        &self,
        id: ExpertId,
        value: Arc<ExpertF32>,
        meta: ResidentMeta,
    ) -> Option<ExpertId> {
        let _ = meta;
        self.insert(id, value)
    }
    /// Source-tier metadata of a resident expert. Peek: no recency,
    /// counter or placement effects. `None` when absent (or the cache
    /// does not track tiers).
    fn resident_meta(&self, id: ExpertId) -> Option<ResidentMeta> {
        let _ = id;
        None
    }
}

struct LayerState {
    capacity: usize,
    /// Lazy LRU queue: `(expert, stamp)` pushed on every touch. An entry
    /// is current iff its stamp equals `stamp[&expert]`; stale duplicates
    /// are skipped (and periodically compacted), which keeps every
    /// operation amortized O(1) instead of scanning a Vec.
    queue: VecDeque<(usize, u64)>,
    /// expert -> most recent touch stamp (resident experts only).
    stamp: HashMap<usize, u64>,
}

impl LayerState {
    fn new(capacity: usize) -> LayerState {
        LayerState { capacity, queue: VecDeque::new(), stamp: HashMap::new() }
    }

    fn len(&self) -> usize {
        self.stamp.len()
    }

    /// Mark `e` most-recently-used (inserting it if absent).
    fn touch(&mut self, e: usize, clock: &mut u64) {
        *clock += 1;
        self.stamp.insert(e, *clock);
        self.queue.push_back((e, *clock));
        // Bound stale entries so the queue stays O(resident).
        if self.queue.len() > 2 * self.stamp.len().max(4) {
            let stamp = &self.stamp;
            self.queue.retain(|&(e, s)| stamp.get(&e) == Some(&s));
        }
    }

    /// Pop the least-recently-used resident expert, if any.
    fn pop_lru(&mut self) -> Option<usize> {
        while let Some((e, s)) = self.queue.pop_front() {
            if self.stamp.get(&e) == Some(&s) {
                self.stamp.remove(&e);
                return Some(e);
            }
        }
        None
    }

    /// Resident experts in LRU→MRU order (debug/test surface, O(queue)).
    fn order(&self) -> Vec<usize> {
        self.queue
            .iter()
            .filter(|&&(e, s)| self.stamp.get(&e) == Some(&s))
            .map(|&(e, _)| e)
            .collect()
    }

    /// Drop one specific resident expert (weighted-eviction path). Its
    /// queue entries go stale and are skipped/compacted lazily.
    fn remove(&mut self, e: usize) {
        self.stamp.remove(&e);
    }
}

struct Inner {
    layers: Vec<LayerState>,
    entries: HashMap<ExpertId, Arc<ExpertF32>>,
    /// Source-tier metadata per resident entry (every entry has one;
    /// legacy inserts record [`ResidentMeta::unknown`]).
    meta: HashMap<ExpertId, ResidentMeta>,
    /// Resident wire bytes per layer (sum of the entries' meta bytes).
    layer_bytes: Vec<usize>,
    /// Optional per-layer byte ceilings on top of the expert-count
    /// capacities — the byte-denominated budget of the tiered store.
    byte_budget: Option<Vec<usize>>,
    /// Monotone recency clock shared by every layer's stamp queue.
    clock: u64,
    /// Per-layer sensitivity importance biasing victim selection
    /// (consumer 3, docs/sensitivity.md). `None` — the uniform-map
    /// default — keeps exact LRU.
    eviction_weights: Option<Vec<f64>>,
    hits: u64,
    misses: u64,
    evictions: u64,
    /// Evictions where the importance weighting picked a victim other
    /// than the LRU head (`SensitivitySnapshot.evictions`).
    bias_evictions: u64,
    /// Device id this pool models, for flight-recorder track attribution
    /// only (0 unless [`DeviceCache::set_obs_device`] was called).
    obs_device: usize,
}

impl Inner {
    /// Pick the next victim for `layer`. Without eviction weights (or
    /// with at most one resident) this is exact LRU — the historical,
    /// amortized-O(1) path. With a positive layer weight the highest
    /// resident tier's entries are penalized by `w * len` LRU ranks, so
    /// an important layer keeps its high-precision copies and sheds a
    /// (slightly more recent) low-tier copy instead; ties keep the older
    /// entry. A layer whose residents all share one tier degenerates to
    /// the LRU head either way.
    fn pick_victim(&mut self, layer: usize) -> Option<usize> {
        let w = self
            .eviction_weights
            .as_ref()
            .and_then(|ws| ws.get(layer))
            .copied()
            .unwrap_or(0.0);
        if w <= 0.0 || self.layers[layer].len() <= 1 {
            return self.layers[layer].pop_lru();
        }
        let order = self.layers[layer].order();
        let max_bits = order
            .iter()
            .filter_map(|&e| self.meta.get(&(layer, e)))
            .map(|m| m.kind.bits())
            .max()
            .unwrap_or(0);
        let n = order.len() as f64;
        let mut best: Option<(f64, usize)> = None;
        for (rank, &e) in order.iter().enumerate() {
            // entries without meta count as top-tier (protected)
            let bits = self
                .meta
                .get(&(layer, e))
                .map(|m| m.kind.bits())
                .unwrap_or(max_bits);
            let score =
                rank as f64 + if bits == max_bits { w * n } else { 0.0 };
            if best.map_or(true, |(bs, _)| score < bs) {
                best = Some((score, e));
            }
        }
        let (_, victim) = best?;
        if victim != order[0] {
            self.bias_evictions += 1;
        }
        self.layers[layer].remove(victim);
        Some(victim)
    }

    /// Evict `layer`'s next victim (LRU, importance-weighted when
    /// configured), maintaining entry/meta/byte state.
    fn evict_lru(&mut self, layer: usize) -> Option<usize> {
        let victim = self.pick_victim(layer)?;
        self.entries.remove(&(layer, victim));
        if let Some(m) = self.meta.remove(&(layer, victim)) {
            self.layer_bytes[layer] = self.layer_bytes[layer].saturating_sub(m.bytes);
        }
        self.evictions += 1;
        crate::obs::instant(
            crate::obs::Track::Device(self.obs_device),
            crate::obs::Name::CacheEvict,
            crate::obs::expert_corr((layer, victim)),
            0,
        );
        Some(victim)
    }

    /// Evict LRU entries until `layer` fits its byte ceiling. The last
    /// resident entry is never evicted on byte pressure alone: a single
    /// over-budget expert must stay servable.
    fn enforce_byte_budget(&mut self, layer: usize) -> Option<usize> {
        let budget = self.byte_budget.as_ref().map(|b| b[layer])?;
        let mut first = None;
        while self.layer_bytes[layer] > budget && self.layers[layer].len() > 1 {
            let v = self.evict_lru(layer)?;
            first.get_or_insert(v);
        }
        first
    }

    /// Refresh a resident entry in place: recency, value, and byte
    /// charge (the old meta's bytes are released, the new one's added),
    /// then re-enforce the layer's byte ceiling.
    fn replace_resident(&mut self, id: ExpertId, value: Arc<ExpertF32>, meta: ResidentMeta) {
        self.layers[id.0].touch(id.1, &mut self.clock);
        self.entries.insert(id, value);
        if let Some(old) = self.meta.insert(id, meta) {
            self.layer_bytes[id.0] = self.layer_bytes[id.0].saturating_sub(old.bytes);
        }
        self.layer_bytes[id.0] += meta.bytes;
        self.enforce_byte_budget(id.0);
    }
}

/// Thread-safe expert cache.
pub struct DeviceCache {
    inner: Mutex<Inner>,
}

impl DeviceCache {
    /// `allocation[i]` = experts of layer i that may be resident.
    pub fn new(allocation: Vec<usize>) -> DeviceCache {
        let n_layers = allocation.len();
        DeviceCache {
            inner: Mutex::new(Inner {
                layers: allocation.into_iter().map(LayerState::new).collect(),
                entries: HashMap::new(),
                meta: HashMap::new(),
                layer_bytes: vec![0; n_layers],
                byte_budget: None,
                clock: 0,
                eviction_weights: None,
                hits: 0,
                misses: 0,
                evictions: 0,
                bias_evictions: 0,
                obs_device: 0,
            }),
        }
    }

    /// Tag this pool with the device id it models so flight-recorder
    /// eviction events land on the right track (purely observational).
    pub fn set_obs_device(&self, device: usize) {
        self.inner.lock().unwrap().obs_device = device;
    }

    /// Uniform split of `total` experts across `layers` (baseline policy).
    /// When the per-layer clamp binds, the clamped remainder is
    /// redistributed to unsaturated layers (remainder to the earliest), so
    /// the invariant `sum == min(total, layers * max_per_layer)` holds —
    /// budget is never silently dropped.
    pub fn uniform_allocation(total: usize, layers: usize, max_per_layer: usize) -> Vec<usize> {
        let mut alloc = vec![0usize; layers];
        if layers == 0 || max_per_layer == 0 {
            return alloc;
        }
        let mut remaining = total.min(layers * max_per_layer);
        while remaining > 0 {
            let unsat: Vec<usize> =
                (0..layers).filter(|&i| alloc[i] < max_per_layer).collect();
            let base = remaining / unsat.len();
            let extra = remaining % unsat.len();
            let mut granted = 0;
            for (j, &i) in unsat.iter().enumerate() {
                let want = base + usize::from(j < extra);
                let take = want.min(max_per_layer - alloc[i]);
                alloc[i] += take;
                granted += take;
            }
            if granted == 0 {
                // unreachable (remaining is pre-clamped), kept as a guard
                break;
            }
            remaining -= granted;
        }
        alloc
    }

    pub fn allocation(&self) -> Vec<usize> {
        self.inner.lock().unwrap().layers.iter().map(|l| l.capacity).collect()
    }

    /// Replace the per-layer budgets (the DP planner path). Shrinking a
    /// layer evicts its LRU tail immediately.
    pub fn set_allocation(&self, allocation: &[usize]) {
        let mut g = self.inner.lock().unwrap();
        assert_eq!(allocation.len(), g.layers.len());
        for (i, &cap) in allocation.iter().enumerate() {
            g.layers[i].capacity = cap;
            while g.layers[i].len() > cap {
                if g.evict_lru(i).is_none() {
                    break;
                }
            }
        }
    }

    /// Set (or clear) the per-layer byte ceilings. Layers over their new
    /// ceiling evict LRU tails immediately — except the last resident
    /// entry, which stays servable even when it alone exceeds the budget.
    pub fn set_byte_budget(&self, budget: Option<Vec<usize>>) {
        let mut g = self.inner.lock().unwrap();
        if let Some(b) = &budget {
            assert_eq!(b.len(), g.layers.len());
        }
        g.byte_budget = budget;
        for i in 0..g.layers.len() {
            g.enforce_byte_budget(i);
        }
    }

    pub fn byte_budget(&self) -> Option<Vec<usize>> {
        self.inner.lock().unwrap().byte_budget.clone()
    }

    /// Install (or clear) per-layer sensitivity eviction weights
    /// (consumer 3, docs/sensitivity.md). `None` — the uniform-map
    /// default — keeps exact LRU victim selection, bit-for-bit.
    pub fn set_eviction_weights(&self, weights: Option<Vec<f64>>) {
        let mut g = self.inner.lock().unwrap();
        if let Some(w) = &weights {
            assert_eq!(w.len(), g.layers.len());
        }
        g.eviction_weights = weights;
    }

    /// Evictions where importance weighting overrode the LRU head.
    pub fn bias_evictions(&self) -> u64 {
        self.inner.lock().unwrap().bias_evictions
    }

    /// Resident wire bytes of one layer (sum of entry meta bytes).
    pub fn layer_resident_bytes(&self, layer: usize) -> usize {
        self.inner.lock().unwrap().layer_bytes[layer]
    }

    /// Resident wire bytes across every layer.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().layer_bytes.iter().sum()
    }

    /// Look up an expert; updates LRU recency and hit/miss counters.
    pub fn get(&self, id: ExpertId) -> Option<Arc<ExpertF32>> {
        let mut g = self.inner.lock().unwrap();
        let g = &mut *g;
        if let Some(v) = g.entries.get(&id).cloned() {
            g.layers[id.0].touch(id.1, &mut g.clock);
            g.hits += 1;
            Some(v)
        } else {
            g.misses += 1;
            None
        }
    }

    /// Peek without touching recency or counters (prefetch planning).
    pub fn contains(&self, id: ExpertId) -> bool {
        self.inner.lock().unwrap().entries.contains_key(&id)
    }

    /// Insert a ready expert, evicting the layer's LRU entry if at capacity.
    /// A zero-capacity layer ignores inserts. Returns the evicted id. The
    /// entry's tier metadata is recorded as [`ResidentMeta::unknown`]; the
    /// tiered transfer path uses [`DeviceCache::insert_tiered`] instead.
    pub fn insert(&self, id: ExpertId, value: Arc<ExpertF32>) -> Option<ExpertId> {
        let meta = ResidentMeta::unknown(&value);
        self.insert_tiered(id, value, meta)
    }

    /// [`DeviceCache::insert`] with explicit source-tier metadata. On a
    /// refresh (the id is already resident) the stored value *and* its
    /// metadata are replaced — an upgrade transfer landing a higher-tier
    /// copy re-charges the layer's byte gauge. Byte-ceiling pressure
    /// evicts additional LRU entries (never the entry just written).
    pub fn insert_tiered(
        &self,
        id: ExpertId,
        value: Arc<ExpertF32>,
        meta: ResidentMeta,
    ) -> Option<ExpertId> {
        let mut g = self.inner.lock().unwrap();
        let g = &mut *g;
        let cap = g.layers[id.0].capacity;
        if cap == 0 {
            return None;
        }
        if g.entries.contains_key(&id) {
            g.replace_resident(id, value, meta);
            return None;
        }
        let mut evicted = None;
        if g.layers[id.0].len() >= cap {
            if let Some(victim) = g.evict_lru(id.0) {
                evicted = Some((id.0, victim));
            }
        }
        g.layers[id.0].touch(id.1, &mut g.clock);
        g.entries.insert(id, value);
        g.meta.insert(id, meta);
        g.layer_bytes[id.0] += meta.bytes;
        if let Some(victim) = g.enforce_byte_budget(id.0) {
            evicted.get_or_insert((id.0, victim));
        }
        evicted
    }

    /// Peek a resident entry's source-tier metadata (no recency/counter
    /// effects).
    pub fn resident_meta(&self, id: ExpertId) -> Option<ResidentMeta> {
        self.inner.lock().unwrap().meta.get(&id).copied()
    }

    /// Atomically replace a *resident* entry's value + tier metadata (the
    /// upgrade-landing path). Returns false — dropping the value — when
    /// the id is not resident: an upgrade must only ever improve a copy
    /// the cache still holds; inserting fresh would evict a live LRU
    /// entry for data nothing asked for. The present-check and the
    /// replacement happen under one lock, so a concurrent eviction
    /// cannot slip between them.
    pub fn replace_if_resident(
        &self,
        id: ExpertId,
        value: Arc<ExpertF32>,
        meta: ResidentMeta,
    ) -> bool {
        let mut g = self.inner.lock().unwrap();
        if !g.entries.contains_key(&id) {
            return false;
        }
        g.replace_resident(id, value, meta);
        true
    }

    /// Resident experts of one layer, LRU first.
    pub fn resident(&self, layer: usize) -> Vec<usize> {
        self.inner.lock().unwrap().layers[layer].order()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses, evictions) counters.
    pub fn stats(&self) -> (u64, u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.hits, g.misses, g.evictions)
    }

    pub fn reset_stats(&self) {
        let mut g = self.inner.lock().unwrap();
        g.hits = 0;
        g.misses = 0;
        g.evictions = 0;
    }
}

impl ExpertCache for DeviceCache {
    fn get(&self, id: ExpertId) -> Option<Arc<ExpertF32>> {
        DeviceCache::get(self, id)
    }

    fn contains(&self, id: ExpertId) -> bool {
        DeviceCache::contains(self, id)
    }

    fn insert(&self, id: ExpertId, value: Arc<ExpertF32>) -> Option<ExpertId> {
        DeviceCache::insert(self, id, value)
    }

    fn insert_tiered(
        &self,
        id: ExpertId,
        value: Arc<ExpertF32>,
        meta: ResidentMeta,
    ) -> Option<ExpertId> {
        DeviceCache::insert_tiered(self, id, value, meta)
    }

    fn resident_meta(&self, id: ExpertId) -> Option<ResidentMeta> {
        DeviceCache::resident_meta(self, id)
    }
}

/// `&Arc<DeviceCache>` / `&Arc<ShardedCache>` coerce straight to
/// `&dyn ExpertCache` at call sites (a reference does not deref-then-
/// unsize on its own, so the shared-ownership wrapper implements the
/// trait by delegation).
impl<T: ExpertCache + ?Sized> ExpertCache for Arc<T> {
    fn get(&self, id: ExpertId) -> Option<Arc<ExpertF32>> {
        (**self).get(id)
    }

    fn contains(&self, id: ExpertId) -> bool {
        (**self).contains(id)
    }

    fn insert(&self, id: ExpertId, value: Arc<ExpertF32>) -> Option<ExpertId> {
        (**self).insert(id, value)
    }

    fn insert_tiered(
        &self,
        id: ExpertId,
        value: Arc<ExpertF32>,
        meta: ResidentMeta,
    ) -> Option<ExpertId> {
        (**self).insert_tiered(id, value, meta)
    }

    fn resident_meta(&self, id: ExpertId) -> Option<ResidentMeta> {
        (**self).resident_meta(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn dummy() -> Arc<ExpertF32> {
        Arc::new(ExpertF32 {
            w1: Tensor::zeros(vec![2, 2]),
            w3: Tensor::zeros(vec![2, 2]),
            w2: Tensor::zeros(vec![2, 2]),
        })
    }

    #[test]
    fn uniform_allocation_sums() {
        let a = DeviceCache::uniform_allocation(10, 4, 8);
        assert_eq!(a, vec![3, 3, 2, 2]);
        assert_eq!(a.iter().sum::<usize>(), 10);
        // clamped by per-layer max
        let b = DeviceCache::uniform_allocation(100, 2, 8);
        assert_eq!(b, vec![8, 8]);
    }

    #[test]
    fn uniform_allocation_redistributes_clamped_remainder() {
        // Clamp binds on the early layers: the remainder must flow to the
        // unsaturated ones instead of being dropped.
        let a = DeviceCache::uniform_allocation(10, 4, 3);
        assert_eq!(a, vec![3, 3, 2, 2]);
        assert_eq!(a.iter().sum::<usize>(), 10);
        let b = DeviceCache::uniform_allocation(7, 3, 3);
        assert_eq!(b, vec![3, 2, 2]);
        // invariant: sum == min(total, layers * max_per_layer)
        for (total, layers, max) in
            [(100usize, 2usize, 8usize), (0, 3, 4), (5, 5, 1), (17, 4, 6), (9, 1, 4)]
        {
            let v = DeviceCache::uniform_allocation(total, layers, max);
            assert_eq!(
                v.iter().sum::<usize>(),
                total.min(layers * max),
                "total={total} layers={layers} max={max} -> {v:?}"
            );
            assert!(v.iter().all(|&t| t <= max));
        }
        // degenerate shapes stay safe
        assert_eq!(DeviceCache::uniform_allocation(4, 0, 8), Vec::<usize>::new());
        assert_eq!(DeviceCache::uniform_allocation(4, 2, 0), vec![0, 0]);
    }

    #[test]
    fn lru_eviction_order() {
        let c = DeviceCache::new(vec![2]);
        c.insert((0, 1), dummy());
        c.insert((0, 2), dummy());
        assert!(c.get((0, 1)).is_some()); // 1 is now MRU
        let evicted = c.insert((0, 3), dummy());
        assert_eq!(evicted, Some((0, 2)));
        assert!(c.get((0, 2)).is_none());
        assert!(c.get((0, 1)).is_some());
    }

    #[test]
    fn capacity_respected_per_layer() {
        let c = DeviceCache::new(vec![1, 2]);
        c.insert((0, 0), dummy());
        c.insert((0, 1), dummy());
        c.insert((1, 0), dummy());
        c.insert((1, 1), dummy());
        assert_eq!(c.resident(0).len(), 1);
        assert_eq!(c.resident(1).len(), 2);
    }

    #[test]
    fn zero_capacity_layer_never_caches() {
        let c = DeviceCache::new(vec![0]);
        assert_eq!(c.insert((0, 0), dummy()), None);
        assert!(c.get((0, 0)).is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn reinsert_refreshes_not_duplicates() {
        let c = DeviceCache::new(vec![2]);
        c.insert((0, 0), dummy());
        c.insert((0, 1), dummy());
        c.insert((0, 0), dummy()); // refresh
        let evicted = c.insert((0, 2), dummy());
        assert_eq!(evicted, Some((0, 1))); // 1 was LRU after 0's refresh
        assert_eq!(c.resident(0).len(), 2);
    }

    #[test]
    fn shrink_allocation_evicts_lru_tail() {
        let c = DeviceCache::new(vec![3]);
        for e in 0..3 {
            c.insert((0, e), dummy());
        }
        c.set_allocation(&[1]);
        assert_eq!(c.resident(0), vec![2]); // only the MRU survives
        let (_, _, ev) = c.stats();
        assert_eq!(ev, 2);
    }

    #[test]
    fn stats_count() {
        let c = DeviceCache::new(vec![2]);
        c.insert((0, 0), dummy());
        c.get((0, 0));
        c.get((0, 5));
        let (h, m, _) = c.stats();
        assert_eq!((h, m), (1, 1));
        c.reset_stats();
        assert_eq!(c.stats(), (0, 0, 0));
    }

    #[test]
    fn tier_meta_tracked_and_bytes_accounted() {
        let c = DeviceCache::new(vec![4]);
        c.insert_tiered((0, 0), dummy(), ResidentMeta { kind: QuantKind::Int2, bytes: 100 });
        c.insert_tiered((0, 1), dummy(), ResidentMeta { kind: QuantKind::Int8, bytes: 400 });
        assert_eq!(
            c.resident_meta((0, 0)),
            Some(ResidentMeta { kind: QuantKind::Int2, bytes: 100 })
        );
        assert_eq!(c.layer_resident_bytes(0), 500);
        assert_eq!(c.resident_bytes(), 500);
        // refresh at a higher tier re-charges the gauge
        c.insert_tiered((0, 0), dummy(), ResidentMeta { kind: QuantKind::Int8, bytes: 400 });
        assert_eq!(c.layer_resident_bytes(0), 800);
        assert_eq!(c.resident_meta((0, 0)).unwrap().kind, QuantKind::Int8);
        // legacy insert records an unknown (f32-sized) meta
        c.insert((0, 2), dummy());
        let m = c.resident_meta((0, 2)).unwrap();
        assert_eq!(m.kind, QuantKind::F32);
        assert_eq!(m.bytes, 4 * 12); // three 2x2 dummy tensors
        // eviction releases the victim's bytes
        c.set_allocation(&[1]);
        assert_eq!(c.resident_bytes(), c.layer_resident_bytes(0));
        assert!(c.resident(0).len() == 1);
    }

    #[test]
    fn byte_budget_evicts_lru_but_keeps_last_entry() {
        let c = DeviceCache::new(vec![8]);
        for e in 0..3 {
            c.insert_tiered(
                (0, e),
                dummy(),
                ResidentMeta { kind: QuantKind::Int4, bytes: 200 },
            );
        }
        assert_eq!(c.layer_resident_bytes(0), 600);
        // ceiling of 450 bytes: evicting LRU (0,0) brings the layer to
        // 400 <= 450, so exactly one eviction
        c.set_byte_budget(Some(vec![450]));
        assert_eq!(c.resident(0), vec![1, 2]);
        assert_eq!(c.layer_resident_bytes(0), 400);
        // an insert that breaches the ceiling evicts the LRU tail
        let ev = c.insert_tiered(
            (0, 3),
            dummy(),
            ResidentMeta { kind: QuantKind::Int4, bytes: 200 },
        );
        assert_eq!(ev, Some((0, 1)));
        assert_eq!(c.resident(0), vec![2, 3]);
        // a single over-budget entry survives (must stay servable)
        c.insert_tiered((0, 9), dummy(), ResidentMeta { kind: QuantKind::F32, bytes: 9000 });
        assert!(c.contains((0, 9)));
        assert_eq!(c.resident(0), vec![9]);
        // clearing the budget stops byte-pressure evictions
        c.set_byte_budget(None);
        assert!(c.byte_budget().is_none());
        c.insert_tiered((0, 4), dummy(), ResidentMeta { kind: QuantKind::F32, bytes: 9000 });
        assert_eq!(c.resident(0).len(), 2);
    }

    #[test]
    fn byte_budget_allows_more_low_tier_entries_than_high() {
        // Same 800-byte ceiling: four int2 copies fit where only one
        // int8 copy does — the byte-denominated win of the tiered store.
        let c = DeviceCache::new(vec![8]);
        c.set_byte_budget(Some(vec![800]));
        for e in 0..4 {
            c.insert_tiered(
                (0, e),
                dummy(),
                ResidentMeta { kind: QuantKind::Int2, bytes: 200 },
            );
        }
        assert_eq!(c.resident(0).len(), 4);
        let c2 = DeviceCache::new(vec![8]);
        c2.set_byte_budget(Some(vec![800]));
        for e in 0..4 {
            c2.insert_tiered(
                (0, e),
                dummy(),
                ResidentMeta { kind: QuantKind::Int8, bytes: 800 },
            );
        }
        assert_eq!(c2.resident(0).len(), 1);
    }

    #[test]
    fn weighted_eviction_protects_high_tier_and_counts_bias() {
        let c = DeviceCache::new(vec![2]);
        c.set_eviction_weights(Some(vec![1.0]));
        // LRU is a high-tier copy, MRU a cheap int2 copy
        c.insert_tiered((0, 0), dummy(), ResidentMeta { kind: QuantKind::Int8, bytes: 400 });
        c.insert_tiered((0, 1), dummy(), ResidentMeta { kind: QuantKind::Int2, bytes: 100 });
        // plain LRU would shed (0,0); the importance weighting protects
        // the high-tier copy and sheds the more recent int2 one instead
        let ev =
            c.insert_tiered((0, 2), dummy(), ResidentMeta { kind: QuantKind::Int8, bytes: 400 });
        assert_eq!(ev, Some((0, 1)));
        assert!(c.contains((0, 0)));
        assert_eq!(c.bias_evictions(), 1);
        // an all-one-tier layer degenerates to exact LRU (no bias counted)
        let ev2 =
            c.insert_tiered((0, 3), dummy(), ResidentMeta { kind: QuantKind::Int8, bytes: 400 });
        assert_eq!(ev2, Some((0, 0)));
        assert_eq!(c.bias_evictions(), 1);
        // clearing the weights restores plain LRU outright
        c.set_eviction_weights(None);
        c.insert_tiered((0, 4), dummy(), ResidentMeta { kind: QuantKind::Int2, bytes: 100 });
        let ev3 =
            c.insert_tiered((0, 5), dummy(), ResidentMeta { kind: QuantKind::Int2, bytes: 100 });
        assert_eq!(ev3, Some((0, 3)));
        assert_eq!(c.bias_evictions(), 1);
    }

    #[test]
    fn lru_order_stable_under_many_touches() {
        // Hammer the recency path so the lazy stamp queue compacts several
        // times, then verify eviction still follows exact LRU order.
        let c = DeviceCache::new(vec![3]);
        for e in 0..3 {
            c.insert((0, e), dummy());
        }
        for _ in 0..1000 {
            c.get((0, 0));
            c.get((0, 2));
        }
        c.get((0, 1)); // order is now LRU->MRU: 0? no — 0,2 touched in loop, final: ...,0,2,1
        assert_eq!(c.resident(0), vec![0, 2, 1]);
        assert_eq!(c.insert((0, 3), dummy()), Some((0, 0)));
        assert_eq!(c.insert((0, 4), dummy()), Some((0, 2)));
        assert_eq!(c.insert((0, 5), dummy()), Some((0, 1)));
        assert_eq!(c.resident(0), vec![3, 4, 5]);
    }
}
