//! Device-side ("GPU memory") expert cache with per-layer budgets + LRU.
//!
//! Capacity is counted in experts, matching the paper's formulation (total
//! cache size T split into per-layer sizes t_i). Within a layer, eviction is
//! LRU — the elimination policy every method in §6 uses. The per-layer
//! allocation vector is produced either uniformly (Mixtral-offloading
//! baseline) or by the DP planner ([`crate::coordinator::cache_plan`]).
//!
//! Shared between the compute thread and the transfer engine's comm thread;
//! all state sits behind one mutex (operations are O(small) map/queue
//! updates, never compute).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::memory::host_store::ExpertF32;
use crate::model::ExpertId;

struct LayerState {
    capacity: usize,
    /// LRU order: front = least recently used.
    order: Vec<usize>,
}

struct Inner {
    layers: Vec<LayerState>,
    entries: HashMap<ExpertId, Arc<ExpertF32>>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Thread-safe expert cache.
pub struct DeviceCache {
    inner: Mutex<Inner>,
}

impl DeviceCache {
    /// `allocation[i]` = experts of layer i that may be resident.
    pub fn new(allocation: Vec<usize>) -> DeviceCache {
        DeviceCache {
            inner: Mutex::new(Inner {
                layers: allocation
                    .into_iter()
                    .map(|capacity| LayerState { capacity, order: Vec::new() })
                    .collect(),
                entries: HashMap::new(),
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
        }
    }

    /// Uniform split of `total` experts across `layers` (baseline policy);
    /// remainder goes to the earliest layers.
    pub fn uniform_allocation(total: usize, layers: usize, max_per_layer: usize) -> Vec<usize> {
        let base = total / layers;
        let extra = total % layers;
        (0..layers)
            .map(|i| (base + usize::from(i < extra)).min(max_per_layer))
            .collect()
    }

    pub fn allocation(&self) -> Vec<usize> {
        self.inner.lock().unwrap().layers.iter().map(|l| l.capacity).collect()
    }

    /// Replace the per-layer budgets (the DP planner path). Shrinking a
    /// layer evicts its LRU tail immediately.
    pub fn set_allocation(&self, allocation: &[usize]) {
        let mut g = self.inner.lock().unwrap();
        assert_eq!(allocation.len(), g.layers.len());
        for (i, &cap) in allocation.iter().enumerate() {
            g.layers[i].capacity = cap;
            while g.layers[i].order.len() > cap {
                let victim = g.layers[i].order.remove(0);
                g.entries.remove(&(i, victim));
                g.evictions += 1;
            }
        }
    }

    /// Look up an expert; updates LRU recency and hit/miss counters.
    pub fn get(&self, id: ExpertId) -> Option<Arc<ExpertF32>> {
        let mut g = self.inner.lock().unwrap();
        if let Some(v) = g.entries.get(&id).cloned() {
            let order = &mut g.layers[id.0].order;
            if let Some(pos) = order.iter().position(|&e| e == id.1) {
                let e = order.remove(pos);
                order.push(e);
            }
            g.hits += 1;
            Some(v)
        } else {
            g.misses += 1;
            None
        }
    }

    /// Peek without touching recency or counters (prefetch planning).
    pub fn contains(&self, id: ExpertId) -> bool {
        self.inner.lock().unwrap().entries.contains_key(&id)
    }

    /// Insert a ready expert, evicting the layer's LRU entry if at capacity.
    /// A zero-capacity layer ignores inserts. Returns the evicted id.
    pub fn insert(&self, id: ExpertId, value: Arc<ExpertF32>) -> Option<ExpertId> {
        let mut g = self.inner.lock().unwrap();
        let cap = g.layers[id.0].capacity;
        if cap == 0 {
            return None;
        }
        if g.entries.contains_key(&id) {
            // refresh recency only
            let order = &mut g.layers[id.0].order;
            if let Some(pos) = order.iter().position(|&e| e == id.1) {
                let e = order.remove(pos);
                order.push(e);
            }
            g.entries.insert(id, value);
            return None;
        }
        let mut evicted = None;
        if g.layers[id.0].order.len() >= cap {
            let victim = g.layers[id.0].order.remove(0);
            g.entries.remove(&(id.0, victim));
            g.evictions += 1;
            evicted = Some((id.0, victim));
        }
        g.layers[id.0].order.push(id.1);
        g.entries.insert(id, value);
        evicted
    }

    /// Resident experts of one layer.
    pub fn resident(&self, layer: usize) -> Vec<usize> {
        self.inner.lock().unwrap().layers[layer].order.clone()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses, evictions) counters.
    pub fn stats(&self) -> (u64, u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.hits, g.misses, g.evictions)
    }

    pub fn reset_stats(&self) {
        let mut g = self.inner.lock().unwrap();
        g.hits = 0;
        g.misses = 0;
        g.evictions = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn dummy() -> Arc<ExpertF32> {
        Arc::new(ExpertF32 {
            w1: Tensor::zeros(vec![2, 2]),
            w3: Tensor::zeros(vec![2, 2]),
            w2: Tensor::zeros(vec![2, 2]),
        })
    }

    #[test]
    fn uniform_allocation_sums() {
        let a = DeviceCache::uniform_allocation(10, 4, 8);
        assert_eq!(a, vec![3, 3, 2, 2]);
        assert_eq!(a.iter().sum::<usize>(), 10);
        // clamped by per-layer max
        let b = DeviceCache::uniform_allocation(100, 2, 8);
        assert_eq!(b, vec![8, 8]);
    }

    #[test]
    fn lru_eviction_order() {
        let c = DeviceCache::new(vec![2]);
        c.insert((0, 1), dummy());
        c.insert((0, 2), dummy());
        assert!(c.get((0, 1)).is_some()); // 1 is now MRU
        let evicted = c.insert((0, 3), dummy());
        assert_eq!(evicted, Some((0, 2)));
        assert!(c.get((0, 2)).is_none());
        assert!(c.get((0, 1)).is_some());
    }

    #[test]
    fn capacity_respected_per_layer() {
        let c = DeviceCache::new(vec![1, 2]);
        c.insert((0, 0), dummy());
        c.insert((0, 1), dummy());
        c.insert((1, 0), dummy());
        c.insert((1, 1), dummy());
        assert_eq!(c.resident(0).len(), 1);
        assert_eq!(c.resident(1).len(), 2);
    }

    #[test]
    fn zero_capacity_layer_never_caches() {
        let c = DeviceCache::new(vec![0]);
        assert_eq!(c.insert((0, 0), dummy()), None);
        assert!(c.get((0, 0)).is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn reinsert_refreshes_not_duplicates() {
        let c = DeviceCache::new(vec![2]);
        c.insert((0, 0), dummy());
        c.insert((0, 1), dummy());
        c.insert((0, 0), dummy()); // refresh
        let evicted = c.insert((0, 2), dummy());
        assert_eq!(evicted, Some((0, 1))); // 1 was LRU after 0's refresh
        assert_eq!(c.resident(0).len(), 2);
    }

    #[test]
    fn shrink_allocation_evicts_lru_tail() {
        let c = DeviceCache::new(vec![3]);
        for e in 0..3 {
            c.insert((0, e), dummy());
        }
        c.set_allocation(&[1]);
        assert_eq!(c.resident(0), vec![2]); // only the MRU survives
        let (_, _, ev) = c.stats();
        assert_eq!(ev, 2);
    }

    #[test]
    fn stats_count() {
        let c = DeviceCache::new(vec![2]);
        c.insert((0, 0), dummy());
        c.get((0, 0));
        c.get((0, 5));
        let (h, m, _) = c.stats();
        assert_eq!((h, m), (1, 1));
        c.reset_stats();
        assert_eq!(c.stats(), (0, 0, 0));
    }
}
