//! Block-wise affine quantization codec (hqq-style substitution).
//!
//! The paper quantizes Mixtral with hqq into 4-bit and "4+2"-bit (attention
//! 4-bit, MoE experts 2-bit). For serving, what quantization changes is the
//! *transferred byte volume* per expert and a small dequant cost at cache
//! fill; we implement a real codec (not a constant factor) so both effects
//! are exercised: experts are stored quantized in the host store and
//! dequantized to f32 when they cross the (simulated) PCIe link.

/// Quantization precision for stored expert weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantKind {
    F32,
    Int8,
    Int4,
    Int2,
}

impl QuantKind {
    pub fn bits(self) -> usize {
        match self {
            QuantKind::F32 => 32,
            QuantKind::Int8 => 8,
            QuantKind::Int4 => 4,
            QuantKind::Int2 => 2,
        }
    }

    /// Packed code bytes needed for `n` values in this precision —
    /// honest for every kind: `F32` stores 4 bytes *per value* (it is
    /// not "1 value per byte"), the integer kinds pack `8/bits` codes
    /// per byte with a ceil on the ragged tail.
    pub fn bytes_for(self, n: usize) -> usize {
        match self {
            QuantKind::F32 => 4 * n,
            k => n.div_ceil(8 / k.bits()),
        }
    }

    /// Dense index for per-tier counter arrays, ascending precision:
    /// int2 = 0, int4 = 1, int8 = 2, f32 = 3.
    pub fn tier_index(self) -> usize {
        match self {
            QuantKind::Int2 => 0,
            QuantKind::Int4 => 1,
            QuantKind::Int8 => 2,
            QuantKind::F32 => 3,
        }
    }

    /// Number of distinct kinds (the range of [`QuantKind::tier_index`]).
    pub const COUNT: usize = 4;

    pub fn from_name(s: &str) -> Option<QuantKind> {
        match s {
            "f32" | "fp32" => Some(QuantKind::F32),
            "int8" | "q8" | "8bit" => Some(QuantKind::Int8),
            "int4" | "q4" | "4bit" => Some(QuantKind::Int4),
            "int2" | "q2" | "2bit" | "4+2bit" => Some(QuantKind::Int2),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QuantKind::F32 => "f32",
            QuantKind::Int8 => "int8",
            QuantKind::Int4 => "int4",
            QuantKind::Int2 => "int2",
        }
    }
}

/// Number of f32 values per quantization block (per-block scale+min pair).
pub const BLOCK: usize = 64;

/// A quantized 1-D tensor (shape is tracked by the owner).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantTensor {
    pub kind: QuantKind,
    pub len: usize,
    /// Per-block affine params; empty for F32.
    pub scales: Vec<f32>,
    pub mins: Vec<f32>,
    /// Packed codes (or raw LE f32 bytes for F32).
    pub data: Vec<u8>,
}

impl QuantTensor {
    pub fn quantize(values: &[f32], kind: QuantKind) -> QuantTensor {
        match kind {
            QuantKind::F32 => QuantTensor {
                kind,
                len: values.len(),
                scales: Vec::new(),
                mins: Vec::new(),
                data: values.iter().flat_map(|v| v.to_le_bytes()).collect(),
            },
            _ => {
                let bits = kind.bits();
                let levels = (1usize << bits) - 1;
                let n_blocks = values.len().div_ceil(BLOCK);
                let mut scales = Vec::with_capacity(n_blocks);
                let mut mins = Vec::with_capacity(n_blocks);
                let vpb = 8 / bits;
                let mut data = vec![0u8; kind.bytes_for(values.len())];
                for b in 0..n_blocks {
                    let s = b * BLOCK;
                    let e = (s + BLOCK).min(values.len());
                    let blk = &values[s..e];
                    let mn = blk.iter().cloned().fold(f32::INFINITY, f32::min);
                    let mx = blk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let scale = if mx > mn { (mx - mn) / levels as f32 } else { 1.0 };
                    scales.push(scale);
                    mins.push(mn);
                    for (i, &v) in blk.iter().enumerate() {
                        let q = (((v - mn) / scale).round() as i64)
                            .clamp(0, levels as i64) as u8;
                        let idx = s + i;
                        let byte = idx / vpb;
                        let slot = idx % vpb;
                        data[byte] |= q << (slot * bits);
                    }
                }
                QuantTensor { kind, len: values.len(), scales, mins, data }
            }
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.len];
        self.dequantize_range(0, self.len, &mut out);
        out
    }

    /// Dequantize values [start, end) into `out[start..end]` — the tile-wise
    /// transfer path decodes only the tile that just "arrived".
    pub fn dequantize_range(&self, start: usize, end: usize, out: &mut [f32]) {
        assert!(end <= self.len && out.len() >= end);
        match self.kind {
            QuantKind::F32 => {
                for i in start..end {
                    let b = &self.data[i * 4..i * 4 + 4];
                    out[i] = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                }
            }
            kind => {
                let bits = kind.bits();
                let vpb = 8 / bits;
                let mask = ((1u16 << bits) - 1) as u8;
                for i in start..end {
                    let q = (self.data[i / vpb] >> ((i % vpb) * bits)) & mask;
                    let blk = i / BLOCK;
                    out[i] = self.mins[blk] + q as f32 * self.scales[blk];
                }
            }
        }
    }

    /// Bytes that cross the link for this tensor (codes + block params).
    pub fn size_bytes(&self) -> usize {
        self.data.len() + 4 * (self.scales.len() + self.mins.len())
    }

    /// Max absolute reconstruction error bound: half a quantization step.
    pub fn max_step(&self) -> f32 {
        self.scales.iter().cloned().fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.f32() - 0.5) * 2.0).collect()
    }

    #[test]
    fn f32_roundtrip_exact() {
        let v = rand_vec(300, 1);
        let q = QuantTensor::quantize(&v, QuantKind::F32);
        assert_eq!(q.dequantize(), v);
        assert_eq!(q.size_bytes(), 1200);
    }

    #[test]
    fn int8_error_within_half_step() {
        let v = rand_vec(1000, 2);
        let q = QuantTensor::quantize(&v, QuantKind::Int8);
        let d = q.dequantize();
        for (a, b) in v.iter().zip(&d) {
            assert!((a - b).abs() <= q.max_step() * 0.5 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn int4_int2_monotone_error() {
        let v = rand_vec(4096, 3);
        let err = |k| {
            let q = QuantTensor::quantize(&v, k);
            let d = q.dequantize();
            v.iter().zip(&d).map(|(a, b)| (a - b).abs() as f64).sum::<f64>() / v.len() as f64
        };
        let (e8, e4, e2) = (err(QuantKind::Int8), err(QuantKind::Int4), err(QuantKind::Int2));
        assert!(e8 < e4 && e4 < e2, "e8={e8} e4={e4} e2={e2}");
    }

    #[test]
    fn sizes_scale_with_bits() {
        let v = rand_vec(4096, 4);
        let s8 = QuantTensor::quantize(&v, QuantKind::Int8).size_bytes();
        let s4 = QuantTensor::quantize(&v, QuantKind::Int4).size_bytes();
        let s2 = QuantTensor::quantize(&v, QuantKind::Int2).size_bytes();
        assert!(s4 < s8 && s2 < s4);
        // codes dominate; ratios near 2x
        assert!((s8 as f64 / s4 as f64) > 1.7);
        assert!((s4 as f64 / s2 as f64) > 1.6);
    }

    #[test]
    fn constant_block_handled() {
        let v = vec![3.25f32; 128];
        for k in [QuantKind::Int8, QuantKind::Int4, QuantKind::Int2] {
            let q = QuantTensor::quantize(&v, k);
            let d = q.dequantize();
            for x in d {
                assert!((x - 3.25).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn ragged_tail_block() {
        let v = rand_vec(BLOCK + 17, 5);
        let q = QuantTensor::quantize(&v, QuantKind::Int4);
        let d = q.dequantize();
        assert_eq!(d.len(), v.len());
        for (a, b) in v.iter().zip(&d) {
            assert!((a - b).abs() <= q.max_step() * 0.5 + 1e-6);
        }
    }

    #[test]
    fn range_dequant_matches_full() {
        let v = rand_vec(1024, 6);
        let q = QuantTensor::quantize(&v, QuantKind::Int4);
        let full = q.dequantize();
        let mut partial = vec![0f32; v.len()];
        // decode in 4 tiles
        for t in 0..4 {
            q.dequantize_range(t * 256, (t + 1) * 256, &mut partial);
        }
        assert_eq!(full, partial);
    }

    #[test]
    fn from_name_parses() {
        assert_eq!(QuantKind::from_name("4bit"), Some(QuantKind::Int4));
        assert_eq!(QuantKind::from_name("4+2bit"), Some(QuantKind::Int2));
        assert_eq!(QuantKind::from_name("bogus"), None);
    }

    #[test]
    fn bytes_for_is_honest_for_every_kind() {
        // F32 is 4 bytes per value — not "1 value per byte".
        assert_eq!(QuantKind::F32.bytes_for(3), 12);
        assert_eq!(QuantKind::Int8.bytes_for(3), 3);
        assert_eq!(QuantKind::Int4.bytes_for(3), 2); // ceil(3/2)
        assert_eq!(QuantKind::Int2.bytes_for(3), 1); // ceil(3/4)
        assert_eq!(QuantKind::Int2.bytes_for(5), 2);
        for k in [QuantKind::F32, QuantKind::Int8, QuantKind::Int4, QuantKind::Int2] {
            assert_eq!(k.bytes_for(0), 0);
        }
    }

    #[test]
    fn packed_and_wire_sizes_match_bytes_for_all_kinds() {
        for &n in &[1usize, 63, 64, 65, 300, 1024] {
            let v = rand_vec(n, 7 + n as u64);
            for k in [QuantKind::F32, QuantKind::Int8, QuantKind::Int4, QuantKind::Int2] {
                let q = QuantTensor::quantize(&v, k);
                assert_eq!(q.data.len(), k.bytes_for(n), "codes: {k:?} n={n}");
                let n_blocks = if k == QuantKind::F32 { 0 } else { n.div_ceil(BLOCK) };
                assert_eq!(
                    q.size_bytes(),
                    k.bytes_for(n) + 8 * n_blocks,
                    "wire bytes: {k:?} n={n}"
                );
            }
        }
    }

    #[test]
    fn tier_index_is_dense_and_ascending() {
        let kinds = [QuantKind::Int2, QuantKind::Int4, QuantKind::Int8, QuantKind::F32];
        for (i, k) in kinds.iter().enumerate() {
            assert_eq!(k.tier_index(), i);
        }
        assert_eq!(QuantKind::COUNT, kinds.len());
        // ascending tier index means ascending bits
        for w in kinds.windows(2) {
            assert!(w[0].bits() < w[1].bits());
        }
    }

    #[test]
    fn prop_roundtrip_error_bounded_per_kind() {
        // Every kind reconstructs within half a quantization step (exact
        // for F32) on random tensors of random ragged lengths.
        crate::util::prop::check("quant-roundtrip-bounds", 24, |rng| {
            let n = 1 + rng.usize_below(700);
            let scale = 0.1 + rng.f32() * 4.0;
            let v: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 2.0 * scale).collect();
            for k in [QuantKind::F32, QuantKind::Int8, QuantKind::Int4, QuantKind::Int2] {
                let q = QuantTensor::quantize(&v, k);
                let d = q.dequantize();
                crate::prop_assert!(d.len() == v.len(), "{k:?}: length changed");
                let bound = if k == QuantKind::F32 { 0.0 } else { q.max_step() * 0.5 };
                for (i, (a, b)) in v.iter().zip(&d).enumerate() {
                    crate::prop_assert!(
                        (a - b).abs() <= bound + 1e-6,
                        "{k:?} n={n} i={i}: {a} vs {b} (bound {bound})"
                    );
                }
            }
            Ok(())
        });
    }
}
