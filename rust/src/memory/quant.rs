//! Block-wise affine quantization codec (hqq-style substitution).
//!
//! The paper quantizes Mixtral with hqq into 4-bit and "4+2"-bit (attention
//! 4-bit, MoE experts 2-bit). For serving, what quantization changes is the
//! *transferred byte volume* per expert and a small dequant cost at cache
//! fill; we implement a real codec (not a constant factor) so both effects
//! are exercised: experts are stored quantized in the host store and
//! dequantized to f32 when they cross the (simulated) PCIe link.

/// Quantization precision for stored expert weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantKind {
    F32,
    Int8,
    Int4,
    Int2,
}

impl QuantKind {
    pub fn bits(self) -> usize {
        match self {
            QuantKind::F32 => 32,
            QuantKind::Int8 => 8,
            QuantKind::Int4 => 4,
            QuantKind::Int2 => 2,
        }
    }

    pub fn values_per_byte(self) -> usize {
        8 / self.bits().min(8)
    }

    pub fn from_name(s: &str) -> Option<QuantKind> {
        match s {
            "f32" | "fp32" => Some(QuantKind::F32),
            "int8" | "q8" | "8bit" => Some(QuantKind::Int8),
            "int4" | "q4" | "4bit" => Some(QuantKind::Int4),
            "int2" | "q2" | "2bit" | "4+2bit" => Some(QuantKind::Int2),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QuantKind::F32 => "f32",
            QuantKind::Int8 => "int8",
            QuantKind::Int4 => "int4",
            QuantKind::Int2 => "int2",
        }
    }
}

/// Number of f32 values per quantization block (per-block scale+min pair).
pub const BLOCK: usize = 64;

/// A quantized 1-D tensor (shape is tracked by the owner).
#[derive(Clone, Debug)]
pub struct QuantTensor {
    pub kind: QuantKind,
    pub len: usize,
    /// Per-block affine params; empty for F32.
    pub scales: Vec<f32>,
    pub mins: Vec<f32>,
    /// Packed codes (or raw LE f32 bytes for F32).
    pub data: Vec<u8>,
}

impl QuantTensor {
    pub fn quantize(values: &[f32], kind: QuantKind) -> QuantTensor {
        match kind {
            QuantKind::F32 => QuantTensor {
                kind,
                len: values.len(),
                scales: Vec::new(),
                mins: Vec::new(),
                data: values.iter().flat_map(|v| v.to_le_bytes()).collect(),
            },
            _ => {
                let bits = kind.bits();
                let levels = (1usize << bits) - 1;
                let n_blocks = values.len().div_ceil(BLOCK);
                let mut scales = Vec::with_capacity(n_blocks);
                let mut mins = Vec::with_capacity(n_blocks);
                let vpb = kind.values_per_byte();
                let mut data = vec![0u8; values.len().div_ceil(vpb)];
                for b in 0..n_blocks {
                    let s = b * BLOCK;
                    let e = (s + BLOCK).min(values.len());
                    let blk = &values[s..e];
                    let mn = blk.iter().cloned().fold(f32::INFINITY, f32::min);
                    let mx = blk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let scale = if mx > mn { (mx - mn) / levels as f32 } else { 1.0 };
                    scales.push(scale);
                    mins.push(mn);
                    for (i, &v) in blk.iter().enumerate() {
                        let q = (((v - mn) / scale).round() as i64)
                            .clamp(0, levels as i64) as u8;
                        let idx = s + i;
                        let byte = idx / vpb;
                        let slot = idx % vpb;
                        data[byte] |= q << (slot * bits);
                    }
                }
                QuantTensor { kind, len: values.len(), scales, mins, data }
            }
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.len];
        self.dequantize_range(0, self.len, &mut out);
        out
    }

    /// Dequantize values [start, end) into `out[start..end]` — the tile-wise
    /// transfer path decodes only the tile that just "arrived".
    pub fn dequantize_range(&self, start: usize, end: usize, out: &mut [f32]) {
        assert!(end <= self.len && out.len() >= end);
        match self.kind {
            QuantKind::F32 => {
                for i in start..end {
                    let b = &self.data[i * 4..i * 4 + 4];
                    out[i] = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                }
            }
            kind => {
                let bits = kind.bits();
                let vpb = kind.values_per_byte();
                let mask = ((1u16 << bits) - 1) as u8;
                for i in start..end {
                    let q = (self.data[i / vpb] >> ((i % vpb) * bits)) & mask;
                    let blk = i / BLOCK;
                    out[i] = self.mins[blk] + q as f32 * self.scales[blk];
                }
            }
        }
    }

    /// Bytes that cross the link for this tensor (codes + block params).
    pub fn size_bytes(&self) -> usize {
        self.data.len() + 4 * (self.scales.len() + self.mins.len())
    }

    /// Max absolute reconstruction error bound: half a quantization step.
    pub fn max_step(&self) -> f32 {
        self.scales.iter().cloned().fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.f32() - 0.5) * 2.0).collect()
    }

    #[test]
    fn f32_roundtrip_exact() {
        let v = rand_vec(300, 1);
        let q = QuantTensor::quantize(&v, QuantKind::F32);
        assert_eq!(q.dequantize(), v);
        assert_eq!(q.size_bytes(), 1200);
    }

    #[test]
    fn int8_error_within_half_step() {
        let v = rand_vec(1000, 2);
        let q = QuantTensor::quantize(&v, QuantKind::Int8);
        let d = q.dequantize();
        for (a, b) in v.iter().zip(&d) {
            assert!((a - b).abs() <= q.max_step() * 0.5 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn int4_int2_monotone_error() {
        let v = rand_vec(4096, 3);
        let err = |k| {
            let q = QuantTensor::quantize(&v, k);
            let d = q.dequantize();
            v.iter().zip(&d).map(|(a, b)| (a - b).abs() as f64).sum::<f64>() / v.len() as f64
        };
        let (e8, e4, e2) = (err(QuantKind::Int8), err(QuantKind::Int4), err(QuantKind::Int2));
        assert!(e8 < e4 && e4 < e2, "e8={e8} e4={e4} e2={e2}");
    }

    #[test]
    fn sizes_scale_with_bits() {
        let v = rand_vec(4096, 4);
        let s8 = QuantTensor::quantize(&v, QuantKind::Int8).size_bytes();
        let s4 = QuantTensor::quantize(&v, QuantKind::Int4).size_bytes();
        let s2 = QuantTensor::quantize(&v, QuantKind::Int2).size_bytes();
        assert!(s4 < s8 && s2 < s4);
        // codes dominate; ratios near 2x
        assert!((s8 as f64 / s4 as f64) > 1.7);
        assert!((s4 as f64 / s2 as f64) > 1.6);
    }

    #[test]
    fn constant_block_handled() {
        let v = vec![3.25f32; 128];
        for k in [QuantKind::Int8, QuantKind::Int4, QuantKind::Int2] {
            let q = QuantTensor::quantize(&v, k);
            let d = q.dequantize();
            for x in d {
                assert!((x - 3.25).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn ragged_tail_block() {
        let v = rand_vec(BLOCK + 17, 5);
        let q = QuantTensor::quantize(&v, QuantKind::Int4);
        let d = q.dequantize();
        assert_eq!(d.len(), v.len());
        for (a, b) in v.iter().zip(&d) {
            assert!((a - b).abs() <= q.max_step() * 0.5 + 1e-6);
        }
    }

    #[test]
    fn range_dequant_matches_full() {
        let v = rand_vec(1024, 6);
        let q = QuantTensor::quantize(&v, QuantKind::Int4);
        let full = q.dequantize();
        let mut partial = vec![0f32; v.len()];
        // decode in 4 tiles
        for t in 0..4 {
            q.dequantize_range(t * 256, (t + 1) * 256, &mut partial);
        }
        assert_eq!(full, partial);
    }

    #[test]
    fn from_name_parses() {
        assert_eq!(QuantKind::from_name("4bit"), Some(QuantKind::Int4));
        assert_eq!(QuantKind::from_name("4+2bit"), Some(QuantKind::Int2));
        assert_eq!(QuantKind::from_name("bogus"), None);
    }
}
