//! The simulated device memory hierarchy (DESIGN.md 'Substitutions'):
//! [`host_store`] is "CPU memory" holding every expert quantized,
//! [`device_cache`] is one bounded "GPU memory" expert cache,
//! [`sharded_cache`] shards experts across several of those per-device
//! pools behind a placement policy (docs/sharded-backends.md), and
//! [`transfer`] is the PCIe link + comm stream**s** — N parallel lanes,
//! each paced by its own wire clock derived from a [`platform`] preset
//! calibrated so per-expert load times match the paper's testbeds (lane
//! semantics: docs/transfer-lanes.md). With more than one device, lanes
//! gain a device affinity: a transfer for device d rides a lane pinned
//! to d's lane group. [`tiered_store`] keeps every expert in several
//! precision variants and picks the bit width per transfer by urgency
//! (docs/tiered-precision.md), which makes the caches byte-denominated:
//! entries carry their source tier + wire bytes and layers can hold a
//! byte budget on top of the expert-count budget. [`faults`] scripts
//! lane/device fault injection against [`transfer`]'s health, retry and
//! failover machinery (docs/fault-tolerance.md).

pub mod device_cache;
pub mod faults;
pub mod host_store;
pub mod platform;
pub mod quant;
pub mod sharded_cache;
pub mod tiered_store;
pub mod transfer;
