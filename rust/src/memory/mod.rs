//! The simulated device memory hierarchy (DESIGN.md 'Substitutions'):
//! [`host_store`] is "CPU memory" holding every expert quantized,
//! [`device_cache`] is the bounded "GPU memory" expert cache, and
//! [`transfer`] is the PCIe link + comm stream**s** — N parallel lanes,
//! each paced by its own wire clock derived from a [`platform`] preset
//! calibrated so per-expert load times match the paper's testbeds (lane
//! semantics: docs/transfer-lanes.md).

pub mod device_cache;
pub mod host_store;
pub mod platform;
pub mod quant;
pub mod transfer;
