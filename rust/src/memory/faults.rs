//! Scripted fault injection for the transfer engine's chaos harness
//! (docs/fault-tolerance.md).
//!
//! A [`FaultPlan`] is a list of [`FaultEvent`]s keyed by decode step; the
//! engine applies every event whose step matches the current one via
//! [`crate::memory::transfer::TransferEngine::apply_fault_plan`]. Plans
//! are pure data — parse/format round-trips bit-for-bit, so a recorded
//! plan replays exactly (the chaos regression suite relies on this).
//!
//! Grammar (`--fault-plan`): `;`-separated events, each
//! `STEP:KIND[:ARG[:ARG]]`:
//!
//! | event              | meaning                                           |
//! |--------------------|---------------------------------------------------|
//! | `3:halt:1`         | halt lane 1 at decode step 3                      |
//! | `5:slow:0:4`       | lane 0 wire time ×4 from step 5 on                |
//! | `8:flaky:1:3`      | lane 1 drops every 3rd admitted job from step 8   |
//! | `2:delay:0:7`      | lane 0 adds 7 ms of wire time per tile from step 2|
//! | `10:blackout:0`    | halt every lane serving device 0 at step 10       |

use std::fmt;

use anyhow::{anyhow, bail, Result};

/// One injectable fault. Lane/device indices are validated against the
/// live engine at injection time, not at parse time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// Stop a lane's worker without draining its queue.
    HaltLane(usize),
    /// Multiply a lane's simulated wire time by the factor (1.0 = nominal).
    SlowLane(usize, f64),
    /// Make a lane drop every k-th job it admits (0 turns the fault off).
    FlakyLane(usize, u64),
    /// Add a fixed per-tile delay (milliseconds) to a lane's wire time.
    DelayLane(usize, u64),
    /// Halt every lane in a device's affinity group.
    Blackout(usize),
}

/// A [`FaultAction`] scheduled for one decode step.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub step: usize,
    pub action: FaultAction,
}

/// An ordered fault script, applied step by step during decode.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

fn parse_num<T: std::str::FromStr>(event: &str, field: &str) -> Result<T> {
    field
        .parse()
        .map_err(|_| anyhow!("fault event '{event}': bad number '{field}'"))
}

impl FaultPlan {
    /// Parse the CLI grammar above. Empty segments are skipped, so both
    /// `""` and trailing `;` are legal.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for part in s.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() < 3 {
                bail!("fault event '{part}': want STEP:KIND:ARG[:ARG]");
            }
            let step: usize = parse_num(part, fields[0])?;
            let arg3 = || -> Result<&str> {
                fields
                    .get(3)
                    .copied()
                    .ok_or_else(|| anyhow!("fault event '{part}': missing argument"))
            };
            let action = match fields[1] {
                "halt" => FaultAction::HaltLane(parse_num(part, fields[2])?),
                "slow" => {
                    FaultAction::SlowLane(parse_num(part, fields[2])?, parse_num(part, arg3()?)?)
                }
                "flaky" => {
                    FaultAction::FlakyLane(parse_num(part, fields[2])?, parse_num(part, arg3()?)?)
                }
                "delay" => {
                    FaultAction::DelayLane(parse_num(part, fields[2])?, parse_num(part, arg3()?)?)
                }
                "blackout" => FaultAction::Blackout(parse_num(part, fields[2])?),
                other => bail!(
                    "fault event '{part}': unknown kind '{other}' \
                     (want halt|slow|flaky|delay|blackout)"
                ),
            };
            events.push(FaultEvent { step, action });
        }
        Ok(FaultPlan { events })
    }

    /// Events scheduled for `step`, in script order.
    pub fn at(&self, step: usize) -> impl Iterator<Item = &FaultAction> {
        self.events
            .iter()
            .filter(move |e| e.step == step)
            .map(|e| &e.action)
    }

    /// Last step that carries an event (None for an empty plan).
    pub fn last_step(&self) -> Option<usize> {
        self.events.iter().map(|e| e.step).max()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

impl fmt::Display for FaultAction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultAction::HaltLane(l) => write!(f, "halt:{l}"),
            FaultAction::SlowLane(l, x) => write!(f, "slow:{l}:{x}"),
            FaultAction::FlakyLane(l, k) => write!(f, "flaky:{l}:{k}"),
            FaultAction::DelayLane(l, ms) => write!(f, "delay:{l}:{ms}"),
            FaultAction::Blackout(d) => write!(f, "blackout:{d}"),
        }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, ev) in self.events.iter().enumerate() {
            if i > 0 {
                f.write_str(";")?;
            }
            write!(f, "{}:{}", ev.step, ev.action)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_kinds_and_roundtrip() {
        let src = "3:halt:1;5:slow:0:4;8:flaky:1:3;2:delay:0:7;10:blackout:0";
        let plan = FaultPlan::parse(src).unwrap();
        assert_eq!(plan.len(), 5);
        assert_eq!(plan.events[0].step, 3);
        assert_eq!(plan.events[0].action, FaultAction::HaltLane(1));
        assert_eq!(plan.events[1].action, FaultAction::SlowLane(0, 4.0));
        assert_eq!(plan.events[2].action, FaultAction::FlakyLane(1, 3));
        assert_eq!(plan.events[3].action, FaultAction::DelayLane(0, 7));
        assert_eq!(plan.events[4].action, FaultAction::Blackout(0));
        assert_eq!(plan.last_step(), Some(10));
        // format → parse is bit-for-bit stable (chaos replay relies on it)
        let printed = plan.to_string();
        assert_eq!(printed, src);
        assert_eq!(FaultPlan::parse(&printed).unwrap(), plan);
    }

    #[test]
    fn at_filters_by_step_in_script_order() {
        let plan = FaultPlan::parse("1:halt:0;2:slow:1:9;1:flaky:0:2").unwrap();
        let at1: Vec<&FaultAction> = plan.at(1).collect();
        assert_eq!(at1, vec![&FaultAction::HaltLane(0), &FaultAction::FlakyLane(0, 2)]);
        assert_eq!(plan.at(0).count(), 0);
        assert_eq!(plan.at(2).count(), 1);
    }

    #[test]
    fn empty_and_trailing_separators_are_legal() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert_eq!(FaultPlan::parse("1:halt:0;").unwrap().len(), 1);
        assert_eq!(FaultPlan::parse(" 1:halt:0 ; 2:halt:1 ").unwrap().len(), 2);
    }

    #[test]
    fn bad_events_name_the_offender() {
        for bad in ["x:halt:0", "1:warp:0", "1:slow:0", "1:halt", "1:flaky:0:x"] {
            let err = FaultPlan::parse(bad).expect_err(bad);
            assert!(format!("{err}").contains("fault event"), "{bad}: {err}");
        }
    }
}
