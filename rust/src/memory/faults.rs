//! Scripted fault injection for the transfer engine's chaos harness
//! (docs/fault-tolerance.md).
//!
//! A [`FaultPlan`] is a list of [`FaultEvent`]s keyed by decode step; the
//! engine applies every event whose step matches the current one via
//! [`crate::memory::transfer::TransferEngine::apply_fault_plan`]. Plans
//! are pure data — parse/format round-trips bit-for-bit, so a recorded
//! plan replays exactly (the chaos regression suite relies on this).
//!
//! Grammar (`--fault-plan`): `;`-separated events, each
//! `STEP:KIND[:ARG[:ARG]]`:
//!
//! | event              | meaning                                           |
//! |--------------------|---------------------------------------------------|
//! | `3:halt:1`         | halt lane 1 at decode step 3                      |
//! | `5:slow:0:4`       | lane 0 wire time ×4 from step 5 on                |
//! | `8:flaky:1:3`      | lane 1 drops every 3rd admitted job from step 8   |
//! | `2:delay:0:7`      | lane 0 adds 7 ms of wire time per tile from step 2|
//! | `10:blackout:0`    | halt every lane serving device 0 at step 10       |

use std::fmt;

use anyhow::{anyhow, bail, Result};

/// One injectable fault. Lane/device indices can't be range-checked at
/// parse time (the engine shape isn't known yet) — callers that do know
/// it run [`FaultPlan::validate`] before arming the plan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// Stop a lane's worker without draining its queue.
    HaltLane(usize),
    /// Multiply a lane's simulated wire time by the factor (1.0 = nominal).
    SlowLane(usize, f64),
    /// Make a lane drop every k-th job it admits (0 turns the fault off).
    FlakyLane(usize, u64),
    /// Add a fixed per-tile delay (milliseconds) to a lane's wire time.
    DelayLane(usize, u64),
    /// Halt every lane in a device's affinity group.
    Blackout(usize),
}

impl FaultAction {
    /// Kind name as it appears in the grammar.
    pub fn kind(&self) -> &'static str {
        match self {
            FaultAction::HaltLane(_) => "halt",
            FaultAction::SlowLane(..) => "slow",
            FaultAction::FlakyLane(..) => "flaky",
            FaultAction::DelayLane(..) => "delay",
            FaultAction::Blackout(_) => "blackout",
        }
    }

    /// The lane (or device, for blackout) index the action targets.
    fn target(&self) -> usize {
        match *self {
            FaultAction::HaltLane(l)
            | FaultAction::SlowLane(l, _)
            | FaultAction::FlakyLane(l, _)
            | FaultAction::DelayLane(l, _)
            | FaultAction::Blackout(l) => l,
        }
    }
}

/// A [`FaultAction`] scheduled for one decode step.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub step: usize,
    pub action: FaultAction,
}

/// An ordered fault script, applied step by step during decode.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

fn parse_num<T: std::str::FromStr>(event: &str, field: &str) -> Result<T> {
    field
        .parse()
        .map_err(|_| anyhow!("fault event '{event}': bad number '{field}'"))
}

impl FaultPlan {
    /// Parse the CLI grammar above. Empty segments are skipped, so both
    /// `""` and trailing `;` are legal.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for part in s.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() < 3 {
                bail!("fault event '{part}': want STEP:KIND:ARG[:ARG]");
            }
            let step: usize = parse_num(part, fields[0])?;
            let arg3 = || -> Result<&str> {
                fields
                    .get(3)
                    .copied()
                    .ok_or_else(|| anyhow!("fault event '{part}': missing argument"))
            };
            let action = match fields[1] {
                "halt" => FaultAction::HaltLane(parse_num(part, fields[2])?),
                "slow" => {
                    FaultAction::SlowLane(parse_num(part, fields[2])?, parse_num(part, arg3()?)?)
                }
                "flaky" => {
                    FaultAction::FlakyLane(parse_num(part, fields[2])?, parse_num(part, arg3()?)?)
                }
                "delay" => {
                    FaultAction::DelayLane(parse_num(part, fields[2])?, parse_num(part, arg3()?)?)
                }
                "blackout" => FaultAction::Blackout(parse_num(part, fields[2])?),
                other => bail!(
                    "fault event '{part}': unknown kind '{other}' \
                     (want halt|slow|flaky|delay|blackout)"
                ),
            };
            // Two events with the same (step, kind, target) would race on
            // one knob in script order — almost certainly a typo'd plan.
            // Same step + target with *different* kinds stays legal.
            if events.iter().any(|e: &FaultEvent| {
                e.step == step
                    && e.action.kind() == action.kind()
                    && e.action.target() == action.target()
            }) {
                bail!(
                    "fault event '{part}': duplicate {} on target {} at step {step}",
                    action.kind(),
                    action.target()
                );
            }
            events.push(FaultEvent { step, action });
        }
        Ok(FaultPlan { events })
    }

    /// Range-check every event against the engine shape: lane faults must
    /// name a lane below `n_lanes`, blackouts a device below `n_devices`.
    /// Parse can't do this (the plan is parsed before the engine exists),
    /// so the CLI calls it once both counts are known.
    pub fn validate(&self, n_lanes: usize, n_devices: usize) -> Result<()> {
        for ev in &self.events {
            let t = ev.action.target();
            match ev.action {
                FaultAction::Blackout(_) => {
                    if t >= n_devices {
                        bail!(
                            "fault event '{}:{}': device {t} out of range \
                             (engine has {n_devices} devices)",
                            ev.step,
                            ev.action
                        );
                    }
                }
                _ => {
                    if t >= n_lanes {
                        bail!(
                            "fault event '{}:{}': lane {t} out of range \
                             (engine has {n_lanes} lanes)",
                            ev.step,
                            ev.action
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Events scheduled for `step`, in script order.
    pub fn at(&self, step: usize) -> impl Iterator<Item = &FaultAction> {
        self.events
            .iter()
            .filter(move |e| e.step == step)
            .map(|e| &e.action)
    }

    /// Last step that carries an event (None for an empty plan).
    pub fn last_step(&self) -> Option<usize> {
        self.events.iter().map(|e| e.step).max()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

impl fmt::Display for FaultAction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultAction::HaltLane(l) => write!(f, "halt:{l}"),
            FaultAction::SlowLane(l, x) => write!(f, "slow:{l}:{x}"),
            FaultAction::FlakyLane(l, k) => write!(f, "flaky:{l}:{k}"),
            FaultAction::DelayLane(l, ms) => write!(f, "delay:{l}:{ms}"),
            FaultAction::Blackout(d) => write!(f, "blackout:{d}"),
        }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, ev) in self.events.iter().enumerate() {
            if i > 0 {
                f.write_str(";")?;
            }
            write!(f, "{}:{}", ev.step, ev.action)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_kinds_and_roundtrip() {
        let src = "3:halt:1;5:slow:0:4;8:flaky:1:3;2:delay:0:7;10:blackout:0";
        let plan = FaultPlan::parse(src).unwrap();
        assert_eq!(plan.len(), 5);
        assert_eq!(plan.events[0].step, 3);
        assert_eq!(plan.events[0].action, FaultAction::HaltLane(1));
        assert_eq!(plan.events[1].action, FaultAction::SlowLane(0, 4.0));
        assert_eq!(plan.events[2].action, FaultAction::FlakyLane(1, 3));
        assert_eq!(plan.events[3].action, FaultAction::DelayLane(0, 7));
        assert_eq!(plan.events[4].action, FaultAction::Blackout(0));
        assert_eq!(plan.last_step(), Some(10));
        // format → parse is bit-for-bit stable (chaos replay relies on it)
        let printed = plan.to_string();
        assert_eq!(printed, src);
        assert_eq!(FaultPlan::parse(&printed).unwrap(), plan);
    }

    #[test]
    fn at_filters_by_step_in_script_order() {
        let plan = FaultPlan::parse("1:halt:0;2:slow:1:9;1:flaky:0:2").unwrap();
        let at1: Vec<&FaultAction> = plan.at(1).collect();
        assert_eq!(at1, vec![&FaultAction::HaltLane(0), &FaultAction::FlakyLane(0, 2)]);
        assert_eq!(plan.at(0).count(), 0);
        assert_eq!(plan.at(2).count(), 1);
    }

    #[test]
    fn empty_and_trailing_separators_are_legal() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert_eq!(FaultPlan::parse("1:halt:0;").unwrap().len(), 1);
        assert_eq!(FaultPlan::parse(" 1:halt:0 ; 2:halt:1 ").unwrap().len(), 2);
    }

    #[test]
    fn bad_events_name_the_offender() {
        for bad in [
            "x:halt:0",    // non-numeric step
            "1:warp:0",    // unknown kind
            "1:slow:0",    // missing factor
            "1:halt",      // too few fields
            "1:flaky:0:x", // non-numeric drop period
            "1:delay:0:1.5", // fractional milliseconds
            ":halt:0",     // empty step
            "1::0",        // empty kind
            "1:blackout",  // blackout without a device
        ] {
            let err = FaultPlan::parse(bad).expect_err(bad);
            assert!(format!("{err}").contains("fault event"), "{bad}: {err}");
        }
    }

    #[test]
    fn duplicate_step_kind_target_rejected() {
        // exact duplicate
        let err = FaultPlan::parse("3:halt:1;3:halt:1").unwrap_err();
        assert!(format!("{err}").contains("duplicate halt"), "{err}");
        // same (step, kind, target) with a different argument still collides
        let err = FaultPlan::parse("5:slow:0:4;5:slow:0:8").unwrap_err();
        assert!(format!("{err}").contains("duplicate slow"), "{err}");
        // different step, kind, or target are all fine
        assert_eq!(FaultPlan::parse("3:halt:1;4:halt:1").unwrap().len(), 2);
        assert_eq!(FaultPlan::parse("3:halt:1;3:flaky:1:2").unwrap().len(), 2);
        assert_eq!(FaultPlan::parse("3:halt:1;3:halt:0").unwrap().len(), 2);
        // a lane fault and a blackout of the same index never collide
        assert_eq!(FaultPlan::parse("3:halt:1;3:blackout:1").unwrap().len(), 2);
    }

    #[test]
    fn validate_range_checks_lanes_and_devices() {
        let plan = FaultPlan::parse("1:halt:0;2:slow:1:9;3:blackout:0").unwrap();
        assert!(plan.validate(2, 1).is_ok());
        // lane 1 needs at least 2 lanes
        let err = plan.validate(1, 1).unwrap_err();
        assert!(format!("{err}").contains("lane 1 out of range"), "{err}");
        // blackout device 0 needs at least 1 device
        let err = plan.validate(2, 0).unwrap_err();
        assert!(format!("{err}").contains("device 0 out of range"), "{err}");
        // an empty plan always validates
        assert!(FaultPlan::parse("").unwrap().validate(0, 0).is_ok());
    }
}
