//! Platform presets: the simulated CPU→GPU link.
//!
//! The paper's testbeds are RTX 4090 / A6000 boxes moving Mixtral-8x7b or
//! -8x22b experts over PCIe 4.0 x16. We run a tiny trained MoE, so using raw
//! PCIe bandwidth would make expert loads ~1000× cheaper *relative to
//! compute* than in the paper and invert the regime it studies. The
//! calibration (DESIGN.md 'Substitutions') scales the link bandwidth by the
//! model-size ratio, i.e. per-expert transfer *time* matches the paper's
//! testbed while byte volumes track our real (quantized) expert sizes:
//!
//!   eff_bw = pcie_bw × (our_f32_expert_bytes / mixtral_f32_expert_bytes)
//!
//! so who-wins / crossover behaviour vs cache size, quantization and
//! platform is preserved.

/// Mixtral-8x7b expert: 3 matrices of 4096×14336 f32.
pub const MIXTRAL_8X7B_EXPERT_BYTES_F32: f64 = 3.0 * 4096.0 * 14336.0 * 4.0;
/// Mixtral-8x22b expert: 3 matrices of 6144×16384 f32.
pub const MIXTRAL_8X22B_EXPERT_BYTES_F32: f64 = 3.0 * 6144.0 * 16384.0 * 4.0;

#[derive(Clone, Debug)]
pub struct Platform {
    pub name: String,
    /// Effective host→device bandwidth of the real testbed, bytes/s.
    pub pcie_bytes_per_sec: f64,
    /// Per-transfer setup latency, seconds (DMA setup + driver).
    pub latency_sec: f64,
    /// Paper-model expert size this platform is calibrated against.
    pub ref_expert_bytes_f32: f64,
}

impl Platform {
    /// Named presets. `rtx4090` / `a6000` follow the paper's §6.1 platforms;
    /// `a6000-22b` calibrates against Mixtral-8x22b experts (paper also runs
    /// 8x22b on A6000); `jetson` is an edge-class sanity point.
    pub fn preset(name: &str) -> Option<Platform> {
        let (bw_gbps, latency_us, ref_bytes) = match name {
            "rtx4090" => (21.0, 15.0, MIXTRAL_8X7B_EXPERT_BYTES_F32),
            "a6000" => (24.0, 15.0, MIXTRAL_8X7B_EXPERT_BYTES_F32),
            "a6000-22b" => (24.0, 15.0, MIXTRAL_8X22B_EXPERT_BYTES_F32),
            "jetson" => (8.0, 30.0, MIXTRAL_8X7B_EXPERT_BYTES_F32),
            // Instant link: logical correctness testing without timing noise.
            "instant" => {
                return Some(Platform {
                    name: "instant".into(),
                    pcie_bytes_per_sec: f64::INFINITY,
                    latency_sec: 0.0,
                    ref_expert_bytes_f32: MIXTRAL_8X7B_EXPERT_BYTES_F32,
                })
            }
            _ => return None,
        };
        Some(Platform {
            name: name.to_string(),
            pcie_bytes_per_sec: bw_gbps * 1e9,
            latency_sec: latency_us * 1e-6,
            ref_expert_bytes_f32: ref_bytes,
        })
    }

    pub fn names() -> &'static [&'static str] {
        &["rtx4090", "a6000", "a6000-22b", "jetson", "instant"]
    }

    /// Model-scaled effective bandwidth for a model whose f32 expert is
    /// `our_expert_bytes_f32` bytes. See module docs.
    pub fn effective_bandwidth(&self, our_expert_bytes_f32: usize) -> f64 {
        if self.pcie_bytes_per_sec.is_infinite() {
            return f64::INFINITY;
        }
        self.pcie_bytes_per_sec * (our_expert_bytes_f32 as f64 / self.ref_expert_bytes_f32)
    }

    /// Simulated wall-clock for moving `bytes` of a model with the given
    /// f32 expert size across this link.
    pub fn transfer_time(&self, bytes: usize, our_expert_bytes_f32: usize) -> f64 {
        let bw = self.effective_bandwidth(our_expert_bytes_f32);
        if bw.is_infinite() {
            return 0.0;
        }
        self.latency_sec + bytes as f64 / bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY_EXPERT: usize = 3 * 128 * 256 * 4; // tiny config f32 expert

    #[test]
    fn presets_exist() {
        for name in Platform::names() {
            assert!(Platform::preset(name).is_some(), "{name}");
        }
        assert!(Platform::preset("tpu-v9000").is_none());
    }

    #[test]
    fn per_expert_time_matches_paper_scale() {
        // Paper: 4-bit Mixtral-8x7b expert ≈ 88 MB over ~21 GB/s ≈ 4.2 ms.
        let p = Platform::preset("rtx4090").unwrap();
        let int4_bytes = TINY_EXPERT / 8 + TINY_EXPERT / 64 / 4 * 8; // codes + params
        let t = p.transfer_time(int4_bytes, TINY_EXPERT);
        assert!(t > 2e-3 && t < 8e-3, "expert load {t}s out of paper range");
    }

    #[test]
    fn quantization_cuts_transfer_time() {
        let p = Platform::preset("a6000").unwrap();
        let t_f32 = p.transfer_time(TINY_EXPERT, TINY_EXPERT);
        let t_int4 = p.transfer_time(TINY_EXPERT / 8, TINY_EXPERT);
        assert!(t_int4 < t_f32 / 4.0);
    }

    #[test]
    fn instant_is_free() {
        let p = Platform::preset("instant").unwrap();
        assert_eq!(p.transfer_time(1 << 30, TINY_EXPERT), 0.0);
    }

    #[test]
    fn faster_platform_faster_transfer() {
        let fast = Platform::preset("a6000").unwrap();
        let slow = Platform::preset("jetson").unwrap();
        assert!(
            fast.transfer_time(1 << 20, TINY_EXPERT) < slow.transfer_time(1 << 20, TINY_EXPERT)
        );
    }
}
