//! Sharded device backends: a set of per-device [`DeviceCache`] shards
//! behind a [`Placement`] policy (docs/sharded-backends.md).
//!
//! One [`DeviceCache`] models one device's memory pool. Production MoE
//! serving spreads experts over several devices — the edge-distributed
//! deployment OD-MoE argues for, and the placement problem "Towards MoE
//! Deployment" shows dominates serving cost — so the memory layer's
//! canonical object is a `ShardedCache`: each shard keeps its own
//! per-layer budgets, LRU state and hit/miss/eviction counters, and a
//! placement maps every [`ExpertId`] to the device that owns it —
//! `layer`/`hash` as pure functions of the id, `load` by memoized
//! first touch (stable within a run, traffic-order dependent across
//! runs). Routing (`get`/`contains`/`insert`) always lands on the
//! owning shard, so per-device counters sum to exactly what a single
//! global cache would have counted.
//!
//! A single-shard `ShardedCache` ([`ShardedCache::single`]) wraps an
//! existing `Arc<DeviceCache>` without copying, which keeps the
//! historical one-device engine bit-for-bit identical: placement is the
//! constant function 0 and every call forwards to the wrapped cache.
//!
//! The transfer engine gives its comm lanes a device affinity derived
//! from [`ShardedCache::device_of`] (see
//! [`crate::memory::transfer::TransferEngine`]), and the executor merges
//! arrivals across devices in canonical reduction order, so output bits
//! are independent of which device lands first.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::memory::device_cache::{DeviceCache, ExpertCache, ResidentMeta};
use crate::memory::host_store::ExpertF32;
use crate::model::ExpertId;

/// Index of a device backend (0-based).
pub type DeviceId = usize;

/// How experts map to devices (`--placement layer|hash|load`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Contiguous layer slices: device d owns layers
    /// `[d*L/D, (d+1)*L/D)`. Keeps a layer's experts co-located (one
    /// device hop per layer) — the pipeline-ish split.
    LayerSliced,
    /// Deterministic hash of (layer, expert) across devices: every layer
    /// spreads over all devices — the capacity-balancing split.
    ExpertHash,
    /// First-touch least-loaded: an expert is bound to the device with
    /// the fewest assigned experts when first seen, then memoized so the
    /// mapping stays stable for lookups and lane affinity.
    LoadAware,
}

impl Placement {
    /// Parse a CLI/config name.
    pub fn from_name(name: &str) -> Option<Placement> {
        match name {
            "layer" => Some(Placement::LayerSliced),
            "hash" => Some(Placement::ExpertHash),
            "load" => Some(Placement::LoadAware),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Placement::LayerSliced => "layer",
            Placement::ExpertHash => "hash",
            Placement::LoadAware => "load",
        }
    }

    pub fn names() -> &'static [&'static str] {
        &["layer", "hash", "load"]
    }

    /// Owning device of a whole layer under [`Placement::LayerSliced`]
    /// (also used by the budget partitioner to find a device's layers).
    pub fn owner_of_layer(layer: usize, n_layers: usize, n_devices: usize) -> DeviceId {
        if n_layers == 0 || n_devices == 0 {
            return 0;
        }
        (layer * n_devices / n_layers).min(n_devices - 1)
    }

    /// Stateless part of the mapping ([`Placement::LoadAware`] is resolved
    /// by [`ShardedCache::device_of`], which memoizes assignments).
    fn device_of(&self, id: ExpertId, n_layers: usize, n_devices: usize) -> DeviceId {
        match self {
            Placement::LayerSliced => Self::owner_of_layer(id.0, n_layers, n_devices),
            Placement::ExpertHash => {
                // Fibonacci-style mixing: deterministic, spreads both the
                // layer and expert indices.
                id.0.wrapping_mul(0x9E37_79B1)
                    .wrapping_add(id.1.wrapping_mul(0x85EB_CA77))
                    % n_devices
            }
            Placement::LoadAware => 0, // overridden by the memoized map
        }
    }
}

/// Point-in-time counters of one device shard, for `ServerStats` /
/// benches. `queued_bytes` is filled in by the transfer engine (bytes
/// assigned to this device's transfers and not yet landed).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DeviceSnapshot {
    pub device: DeviceId,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Experts currently resident on this device.
    pub resident: usize,
    /// Sum of the shard's per-layer budgets (in experts).
    pub capacity: usize,
    pub queued_bytes: u64,
    /// Resident wire bytes across the shard's layers (sum of each
    /// entry's source-tier byte charge).
    pub resident_bytes: u64,
    /// Sum of the shard's per-layer byte ceilings (0 = no byte budget).
    pub capacity_bytes: u64,
}

/// First-touch assignment state for [`Placement::LoadAware`].
struct LoadMap {
    assigned: HashMap<ExpertId, DeviceId>,
    /// Experts bound to each device so far.
    counts: Vec<usize>,
}

/// A set of per-device expert caches behind one placement policy.
pub struct ShardedCache {
    shards: Vec<Arc<DeviceCache>>,
    placement: Placement,
    n_layers: usize,
    load: Mutex<LoadMap>,
}

impl ShardedCache {
    /// Wrap one existing cache as a single-device set (placement is the
    /// constant 0; the wrapped `Arc` stays shared with the caller).
    pub fn single(cache: Arc<DeviceCache>) -> ShardedCache {
        let n_layers = cache.allocation().len();
        ShardedCache {
            shards: vec![cache],
            placement: Placement::LayerSliced,
            n_layers,
            load: Mutex::new(LoadMap { assigned: HashMap::new(), counts: vec![0] }),
        }
    }

    /// Build one shard per allocation vector. Every vector must cover the
    /// same layer count; `allocations[d][l]` is device d's budget for
    /// layer l (0 for layers the placement never routes to d).
    pub fn new(allocations: Vec<Vec<usize>>, placement: Placement) -> ShardedCache {
        assert!(!allocations.is_empty(), "need at least one device");
        let n_layers = allocations[0].len();
        assert!(
            allocations.iter().all(|a| a.len() == n_layers),
            "per-device allocations must cover the same layers"
        );
        let n = allocations.len();
        ShardedCache {
            shards: allocations
                .into_iter()
                .enumerate()
                .map(|(d, a)| {
                    let c = Arc::new(DeviceCache::new(a));
                    c.set_obs_device(d);
                    c
                })
                .collect(),
            placement,
            n_layers,
            load: Mutex::new(LoadMap { assigned: HashMap::new(), counts: vec![0; n] }),
        }
    }

    /// Split the global expert budget T across devices (remainder to the
    /// earliest devices) — the step before each device's per-layer split.
    pub fn partition_budget(total: usize, devices: usize) -> Vec<usize> {
        assert!(devices >= 1, "need at least one device");
        let base = total / devices;
        let extra = total % devices;
        (0..devices).map(|d| base + usize::from(d < extra)).collect()
    }

    pub fn n_devices(&self) -> usize {
        self.shards.len()
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// One device's cache.
    pub fn shard(&self, device: DeviceId) -> &Arc<DeviceCache> {
        &self.shards[device]
    }

    pub fn shards(&self) -> &[Arc<DeviceCache>] {
        &self.shards
    }

    /// The device owning `id`. Stable for the lifetime of the set:
    /// `layer`/`hash` are pure functions of the id (reproducible across
    /// runs); `LoadAware` binds an expert to the least-loaded device on
    /// first touch and memoizes the choice — the mapping never moves
    /// mid-run, but which device wins depends on traffic order, so it is
    /// not reproducible across runs.
    pub fn device_of(&self, id: ExpertId) -> DeviceId {
        let n = self.shards.len();
        if n == 1 {
            return 0;
        }
        match self.placement {
            Placement::LoadAware => {
                let mut g = self.load.lock().unwrap();
                if let Some(&d) = g.assigned.get(&id) {
                    return d;
                }
                let d = (0..n).min_by_key(|&i| (g.counts[i], i)).expect("shards non-empty");
                g.assigned.insert(id, d);
                g.counts[d] += 1;
                d
            }
            p => p.device_of(id, self.n_layers, n),
        }
    }

    /// Look up on the owning shard (its hit/miss counters move; real
    /// demand, so `LoadAware` may bind here).
    pub fn get(&self, id: ExpertId) -> Option<Arc<ExpertF32>> {
        self.shards[self.device_of(id)].get(id)
    }

    /// Peek on the owning shard — no counter/recency effects, and no
    /// placement effects either: a speculative probe (prefetch planning
    /// peeks at *predicted* experts) must not consume a `LoadAware`
    /// first-touch binding. An unbound expert is resident nowhere, so
    /// the answer is `false` without binding it.
    pub fn contains(&self, id: ExpertId) -> bool {
        match self.device_of_peek(id) {
            Some(d) => self.shards[d].contains(id),
            None => false,
        }
    }

    /// Insert into the owning shard (evicting that shard's LRU entry if
    /// its layer is at capacity).
    pub fn insert(&self, id: ExpertId, value: Arc<ExpertF32>) -> Option<ExpertId> {
        self.shards[self.device_of(id)].insert(id, value)
    }

    /// Insert with source-tier metadata on the owning shard.
    pub fn insert_tiered(
        &self,
        id: ExpertId,
        value: Arc<ExpertF32>,
        meta: ResidentMeta,
    ) -> Option<ExpertId> {
        self.shards[self.device_of(id)].insert_tiered(id, value, meta)
    }

    /// Peek a resident entry's tier metadata. Like
    /// [`ShardedCache::contains`], a speculative probe must not consume a
    /// `LoadAware` first-touch binding: an unbound expert is resident
    /// nowhere, so the answer is `None` without binding it.
    pub fn resident_meta(&self, id: ExpertId) -> Option<ResidentMeta> {
        match self.device_of_peek(id) {
            Some(d) => self.shards[d].resident_meta(id),
            None => None,
        }
    }

    /// Atomically replace a resident entry on its owning shard (the
    /// upgrade-landing path; see
    /// [`DeviceCache::replace_if_resident`]). An unbound `LoadAware`
    /// expert is resident nowhere, so the answer is `false` without
    /// binding it.
    pub fn replace_if_resident(
        &self,
        id: ExpertId,
        value: Arc<ExpertF32>,
        meta: ResidentMeta,
    ) -> bool {
        match self.device_of_peek(id) {
            Some(d) => self.shards[d].replace_if_resident(id, value, meta),
            None => false,
        }
    }

    /// The owning device, if determinable without creating a `LoadAware`
    /// first-touch binding. Pure placements (`layer`/`hash`, or a single
    /// shard) always resolve; an unbound `LoadAware` expert returns
    /// `None`.
    pub fn device_of_peek(&self, id: ExpertId) -> Option<DeviceId> {
        let n = self.shards.len();
        if n > 1 && self.placement == Placement::LoadAware {
            return self.load.lock().unwrap().assigned.get(&id).copied();
        }
        Some(self.device_of(id))
    }

    /// Degraded-serving fallback (docs/fault-tolerance.md): a copy of
    /// `id` resident on a *non-owning* shard — e.g. left behind by an
    /// earlier placement epoch or a replicated hot expert. Scans shards
    /// in device order and returns the first copy with its source-tier
    /// meta; `None` when no replica exists (single-shard sets always
    /// answer `None` — the owning copy is not a replica).
    pub fn find_replica(&self, id: ExpertId) -> Option<(Arc<ExpertF32>, ResidentMeta)> {
        let owner = self.device_of_peek(id);
        for (d, shard) in self.shards.iter().enumerate() {
            if Some(d) == owner {
                continue;
            }
            if let (Some(w), Some(meta)) = (shard.get(id), shard.resident_meta(id)) {
                return Some((w, meta));
            }
        }
        None
    }

    /// Resident experts of one layer, merged across shards in device
    /// order (each shard's slice is LRU→MRU).
    pub fn resident(&self, layer: usize) -> Vec<usize> {
        self.shards.iter().flat_map(|s| s.resident(layer)).collect()
    }

    /// Total resident experts across shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Aggregate (hits, misses, evictions) — per-device counters sum to
    /// exactly the single-cache figures.
    pub fn stats(&self) -> (u64, u64, u64) {
        self.shards.iter().fold((0, 0, 0), |(h, m, e), s| {
            let (sh, sm, se) = s.stats();
            (h + sh, m + sm, e + se)
        })
    }

    pub fn reset_stats(&self) {
        for s in &self.shards {
            s.reset_stats();
        }
    }

    /// Element-wise sum of the shards' per-layer budgets (the global
    /// allocation a single cache would hold).
    pub fn allocation(&self) -> Vec<usize> {
        let mut total = vec![0usize; self.n_layers];
        for s in &self.shards {
            for (t, a) in total.iter_mut().zip(s.allocation()) {
                *t += a;
            }
        }
        total
    }

    /// Apply a *global* per-layer allocation, splitting each layer's
    /// budget across the shards that can own its experts: the whole
    /// budget to the layer's owner under `layer` placement, an even split
    /// (remainder to the earliest devices) under `hash`/`load`. Shrinking
    /// evicts shard-local LRU tails immediately.
    pub fn set_allocation(&self, allocation: &[usize]) {
        assert_eq!(allocation.len(), self.n_layers);
        let n = self.shards.len();
        if n == 1 {
            self.shards[0].set_allocation(allocation);
            return;
        }
        for (d, shard) in self.shards.iter().enumerate() {
            let local: Vec<usize> = allocation
                .iter()
                .enumerate()
                .map(|(l, &cap)| match self.placement {
                    Placement::LayerSliced => {
                        if Placement::owner_of_layer(l, self.n_layers, n) == d {
                            cap
                        } else {
                            0
                        }
                    }
                    _ => cap / n + usize::from(d < cap % n),
                })
                .collect();
            shard.set_allocation(&local);
        }
    }

    /// Resident wire bytes across every shard.
    pub fn resident_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.resident_bytes()).sum()
    }

    /// Install (or clear) per-layer eviction weights on every shard —
    /// the [`crate::coordinator::sensitivity::SensitivityMap`] eviction
    /// consumer. Weights are global per layer, so each shard gets the
    /// same copy; shards with no residents in a layer simply never
    /// consult it.
    pub fn set_eviction_weights(&self, weights: Option<Vec<f64>>) {
        for s in &self.shards {
            s.set_eviction_weights(weights.clone());
        }
    }

    /// Total evictions where the sensitivity bias overrode the plain
    /// LRU choice, summed across shards.
    pub fn bias_evictions(&self) -> u64 {
        self.shards.iter().map(|s| s.bias_evictions()).sum()
    }

    /// Per-device counter snapshots (`queued_bytes` left at 0 — the
    /// transfer engine overlays it, see
    /// [`crate::memory::transfer::TransferEngine::device_snapshots`]).
    pub fn device_snapshots(&self) -> Vec<DeviceSnapshot> {
        self.shards
            .iter()
            .enumerate()
            .map(|(d, s)| {
                let (hits, misses, evictions) = s.stats();
                DeviceSnapshot {
                    device: d,
                    hits,
                    misses,
                    evictions,
                    resident: s.len(),
                    capacity: s.allocation().iter().sum(),
                    queued_bytes: 0,
                    resident_bytes: s.resident_bytes() as u64,
                    capacity_bytes: s
                        .byte_budget()
                        .map(|b| b.iter().sum::<usize>() as u64)
                        .unwrap_or(0),
                }
            })
            .collect()
    }
}

impl ExpertCache for ShardedCache {
    fn get(&self, id: ExpertId) -> Option<Arc<ExpertF32>> {
        ShardedCache::get(self, id)
    }

    fn contains(&self, id: ExpertId) -> bool {
        ShardedCache::contains(self, id)
    }

    fn insert(&self, id: ExpertId, value: Arc<ExpertF32>) -> Option<ExpertId> {
        ShardedCache::insert(self, id, value)
    }

    fn insert_tiered(
        &self,
        id: ExpertId,
        value: Arc<ExpertF32>,
        meta: ResidentMeta,
    ) -> Option<ExpertId> {
        ShardedCache::insert_tiered(self, id, value, meta)
    }

    fn resident_meta(&self, id: ExpertId) -> Option<ResidentMeta> {
        ShardedCache::resident_meta(self, id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn dummy() -> Arc<ExpertF32> {
        Arc::new(ExpertF32 {
            w1: Tensor::zeros(vec![2, 2]),
            w3: Tensor::zeros(vec![2, 2]),
            w2: Tensor::zeros(vec![2, 2]),
        })
    }

    #[test]
    fn placement_names_roundtrip() {
        for name in Placement::names() {
            let p = Placement::from_name(name).expect("known name");
            assert_eq!(p.name(), *name);
        }
        assert!(Placement::from_name("tarot").is_none());
    }

    #[test]
    fn layer_sliced_owns_contiguous_blocks() {
        // 4 layers over 2 devices: layers 0-1 on device 0, 2-3 on device 1.
        let c = ShardedCache::new(vec![vec![2; 4], vec![2; 4]], Placement::LayerSliced);
        assert_eq!(c.device_of((0, 5)), 0);
        assert_eq!(c.device_of((1, 0)), 0);
        assert_eq!(c.device_of((2, 7)), 1);
        assert_eq!(c.device_of((3, 1)), 1);
        // expert index never matters under layer placement
        for e in 0..8 {
            assert_eq!(c.device_of((2, e)), 1);
        }
    }

    #[test]
    fn expert_hash_spreads_and_is_stable() {
        let c = ShardedCache::new(vec![vec![8, 8]; 4], Placement::ExpertHash);
        let mut seen = vec![0usize; 4];
        for l in 0..2 {
            for e in 0..8 {
                let d = c.device_of((l, e));
                assert_eq!(d, c.device_of((l, e)), "mapping must be stable");
                seen[d] += 1;
            }
        }
        assert!(
            seen.iter().filter(|&&n| n > 0).count() >= 2,
            "hash placement must use more than one device: {seen:?}"
        );
    }

    #[test]
    fn load_aware_balances_first_touch_and_memoizes() {
        let c = ShardedCache::new(vec![vec![8, 8]; 3], Placement::LoadAware);
        let ids: Vec<ExpertId> = (0..6).map(|e| (0, e)).collect();
        let devs: Vec<DeviceId> = ids.iter().map(|&id| c.device_of(id)).collect();
        // 6 experts over 3 devices: exactly 2 each, round-robin by load
        let mut per = vec![0usize; 3];
        for &d in &devs {
            per[d] += 1;
        }
        assert_eq!(per, vec![2, 2, 2], "{devs:?}");
        // memoized: re-query returns the same device
        for (id, d) in ids.iter().zip(&devs) {
            assert_eq!(c.device_of(*id), *d);
        }
    }

    #[test]
    fn load_aware_contains_does_not_bind() {
        let c = ShardedCache::new(vec![vec![8, 8]; 2], Placement::LoadAware);
        // speculative peeks at predicted experts (the prefetch-planning
        // shape) must not consume first-touch bindings
        for e in 0..3 {
            assert!(!c.contains((0, e)), "unbound expert is resident nowhere");
        }
        // first *real* touches still see untouched load counts: the tie
        // binds to device 0 then 1 (phantom peek bindings — 0,1,0 for the
        // three peeks above — would have skewed this to 1 first)
        assert_eq!(c.device_of((1, 5)), 0);
        assert_eq!(c.device_of((1, 6)), 1);
    }

    #[test]
    fn routing_hits_owning_shard_and_counters_sum() {
        let c = ShardedCache::new(vec![vec![4, 4], vec![4, 4]], Placement::ExpertHash);
        for e in 0..8 {
            c.insert((0, e), dummy());
        }
        for e in 0..8 {
            assert!(c.get((0, e)).is_some());
            let d = c.device_of((0, e));
            assert!(c.shard(d).contains((0, e)), "expert must live on its owner");
            assert!(
                !c.shard(1 - d).contains((0, e)),
                "expert must not leak to the other shard"
            );
        }
        c.get((1, 0)); // miss
        let (h, m, e) = c.stats();
        assert_eq!((h, m), (8, 1));
        let snaps = c.device_snapshots();
        assert_eq!(snaps.iter().map(|s| s.hits).sum::<u64>(), h);
        assert_eq!(snaps.iter().map(|s| s.misses).sum::<u64>(), m);
        assert_eq!(snaps.iter().map(|s| s.evictions).sum::<u64>(), e);
        assert_eq!(snaps.iter().map(|s| s.resident).sum::<usize>(), c.len());
    }

    #[test]
    fn single_wraps_shared_arc() {
        let inner = Arc::new(DeviceCache::new(vec![2, 2]));
        let c = ShardedCache::single(Arc::clone(&inner));
        assert_eq!(c.n_devices(), 1);
        c.insert((0, 1), dummy());
        // the caller's Arc sees the same data — no copy was made
        assert!(inner.contains((0, 1)));
        assert_eq!(c.device_of((1, 7)), 0);
        assert_eq!(c.stats(), inner.stats());
    }

    #[test]
    fn partition_budget_sums_with_remainder_first() {
        assert_eq!(ShardedCache::partition_budget(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(ShardedCache::partition_budget(8, 2), vec![4, 4]);
        assert_eq!(ShardedCache::partition_budget(0, 3), vec![0, 0, 0]);
        assert_eq!(ShardedCache::partition_budget(2, 5), vec![1, 1, 0, 0, 0]);
    }

    #[test]
    fn global_allocation_sums_and_set_allocation_routes() {
        let c = ShardedCache::new(vec![vec![2, 2], vec![2, 2]], Placement::ExpertHash);
        assert_eq!(c.allocation(), vec![4, 4]);
        c.set_allocation(&[3, 1]);
        assert_eq!(c.allocation(), vec![3, 1]);
        // hash/load split: even with remainder to the earliest device
        assert_eq!(c.shard(0).allocation(), vec![2, 1]);
        assert_eq!(c.shard(1).allocation(), vec![1, 0]);

        let lc = ShardedCache::new(vec![vec![2, 2], vec![2, 2]], Placement::LayerSliced);
        lc.set_allocation(&[3, 1]);
        // layer placement: the owning device takes the whole layer budget
        assert_eq!(lc.shard(0).allocation(), vec![3, 0]);
        assert_eq!(lc.shard(1).allocation(), vec![0, 1]);
        assert_eq!(lc.allocation(), vec![3, 1]);
    }

    #[test]
    fn tier_meta_routes_to_owning_shard_without_binding() {
        use crate::memory::quant::QuantKind;
        let c = ShardedCache::new(vec![vec![4, 4]; 2], Placement::ExpertHash);
        let meta = ResidentMeta { kind: QuantKind::Int2, bytes: 64 };
        c.insert_tiered((0, 3), dummy(), meta);
        assert_eq!(c.resident_meta((0, 3)), Some(meta));
        let d = c.device_of((0, 3));
        assert_eq!(c.shard(d).resident_meta((0, 3)), Some(meta));
        assert_eq!(c.shard(1 - d).resident_meta((0, 3)), None);
        // LoadAware: peeking meta of an unbound expert must not bind it
        let la = ShardedCache::new(vec![vec![4, 4]; 2], Placement::LoadAware);
        assert_eq!(la.resident_meta((0, 0)), None);
        assert_eq!(la.device_of_peek((0, 0)), None);
        assert_eq!(la.device_of((1, 5)), 0, "first real touch still sees clean counts");
    }

    #[test]
    fn snapshots_surface_resident_and_capacity_bytes() {
        use crate::memory::quant::QuantKind;
        let c = ShardedCache::new(vec![vec![4, 4]; 2], Placement::ExpertHash);
        // per-shard byte ceilings (the engine installs these per shard —
        // see coordinator::engine::apply_tiered_counts)
        c.shard(0).set_byte_budget(Some(vec![500, 251]));
        c.shard(1).set_byte_budget(Some(vec![500, 250]));
        let m = ResidentMeta { kind: QuantKind::Int4, bytes: 128 };
        c.insert_tiered((0, 0), dummy(), m);
        let d = c.device_of((0, 0));
        let snaps = c.device_snapshots();
        assert_eq!(snaps[d].resident_bytes, 128);
        assert_eq!(snaps[d].capacity_bytes, if d == 0 { 751 } else { 750 });
        assert_eq!(snaps[1 - d].resident_bytes, 0);
        assert_eq!(c.resident_bytes(), 128);
    }

    #[test]
    fn shrinking_evicts_only_on_the_owning_shard() {
        let c = ShardedCache::new(vec![vec![4], vec![4]], Placement::ExpertHash);
        for e in 0..8 {
            c.insert((0, e), dummy());
        }
        let before = c.len();
        c.set_allocation(&[2]);
        assert!(c.len() <= 2);
        assert!(before > c.len());
        let (_, _, ev) = c.stats();
        assert_eq!(ev as usize, before - c.len());
    }
}
