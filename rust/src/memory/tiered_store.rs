//! Tiered mixed-precision expert store + urgency-driven bitwidth policy.
//!
//! AdapMoE quantizes experts once, globally; but the loading bottleneck it
//! attacks is *bytes over the link*. Following HOBBIT's mixed-precision
//! expert management (PAPERS.md) and EdgeMoE's per-expert bitwidths, the
//! [`TieredStore`] keeps every expert in **several** precision variants —
//! one [`HostStore`] per [`QuantKind`] tier, all built from the same f32
//! weights — and a [`PrecisionPolicy`] picks which tier's bytes a given
//! transfer moves:
//!
//! * **on-demand** (compute-stalling) loads ride the *lowest* tier — the
//!   fewest bytes on the critical path;
//! * **prefetches** ride a tier scaled by the caller's slack signal
//!   (prefetch probability mass / gating score margin): speculative,
//!   low-probability loads get the high-precision copy, near-certain ones
//!   drop toward the urgent tier so they still land in time;
//! * a background **upgrade** path re-transfers resident low-bit experts
//!   at a higher tier when the lanes are idle
//!   ([`crate::memory::transfer::Priority::Upgrade`]).
//!
//! A single-tier store ([`TieredStore::single`]) wraps an existing
//! `Arc<HostStore>` without copying, which keeps the historical one-kind
//! engine bit-for-bit identical: the policy degenerates to the constant
//! function and every transfer charges exactly the same wire bytes as
//! before (rust/tests/tiers.rs locks this down). Degrade-vs-stall lookup
//! semantics live in [`crate::coordinator::scheduler::build_plan_tiered`];
//! the full subsystem is documented in docs/tiered-precision.md.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::memory::host_store::HostStore;
use crate::memory::quant::QuantKind;
use crate::memory::transfer::Priority;
use crate::model::config::ModelConfig;
use crate::model::weights::Weights;
use crate::model::ExpertId;

/// Every expert in several precision variants (ascending bit width).
pub struct TieredStore {
    /// Tier list, strictly ascending in bits (e.g. `[Int2, Int4, Int8]`).
    tiers: Vec<QuantKind>,
    /// One full host store per tier, index-aligned with `tiers`.
    stores: Vec<Arc<HostStore>>,
}

impl TieredStore {
    /// Quantize every expert at every requested tier. Duplicates are
    /// rejected; the list is sorted ascending by bits so tier 0 is always
    /// the cheapest wire encoding.
    pub fn build(cfg: &ModelConfig, weights: &Weights, tiers: &[QuantKind]) -> Result<TieredStore> {
        if tiers.is_empty() {
            bail!("tiered store needs at least one precision tier");
        }
        let mut kinds = tiers.to_vec();
        kinds.sort_by_key(|k| k.bits());
        for w in kinds.windows(2) {
            if w[0] == w[1] {
                bail!("duplicate precision tier {}", w[0].name());
            }
        }
        let stores = kinds
            .iter()
            .map(|&k| Ok(Arc::new(HostStore::build(cfg, weights, k)?)))
            .collect::<Result<Vec<_>>>()?;
        Ok(TieredStore { tiers: kinds, stores })
    }

    /// Wrap one existing store as a single-tier set (no copy; the Arc
    /// stays shared with the caller) — the historical engine shape.
    pub fn single(store: Arc<HostStore>) -> TieredStore {
        TieredStore { tiers: vec![store.kind], stores: vec![store] }
    }

    /// Assemble a tier set from pre-built stores (the remote path: one
    /// lazily-fetched [`HostStore::remote`] per tier, all sharing a
    /// transport). Same invariants as [`TieredStore::build`] — kinds are
    /// sorted ascending by bits, duplicates and empty sets rejected — plus
    /// every store must describe the same expert grid.
    pub fn from_parts(stores: Vec<Arc<HostStore>>) -> Result<TieredStore> {
        if stores.is_empty() {
            bail!("tiered store needs at least one precision tier");
        }
        let mut stores = stores;
        stores.sort_by_key(|s| s.kind.bits());
        for w in stores.windows(2) {
            if w[0].kind == w[1].kind {
                bail!("duplicate precision tier {}", w[0].kind.name());
            }
            if w[0].n_layers != w[1].n_layers || w[0].n_experts != w[1].n_experts {
                bail!(
                    "tier {} is {}x{} experts but tier {} is {}x{}",
                    w[0].kind.name(),
                    w[0].n_layers,
                    w[0].n_experts,
                    w[1].kind.name(),
                    w[1].n_layers,
                    w[1].n_experts
                );
            }
        }
        let tiers = stores.iter().map(|s| s.kind).collect();
        Ok(TieredStore { tiers, stores })
    }

    /// True when any tier is remote-backed (experts arrive over the wire
    /// on first touch instead of living in host memory up front).
    pub fn is_remote(&self) -> bool {
        self.stores.iter().any(|s| s.is_remote())
    }

    /// The shared remote-fetch counters, when any tier is remote-backed.
    /// All remote tiers share one transport, so the first hit is the set.
    pub fn remote_counters(
        &self,
    ) -> Option<Arc<crate::memory::host_store::FetchCounters>> {
        self.stores.iter().find_map(|s| s.fetch_counters().cloned())
    }

    /// Parse a comma-separated tier list (`"int2,int4"`); names as in
    /// [`QuantKind::from_name`]. Returns `None` on any unknown name.
    pub fn parse_tiers(s: &str) -> Option<Vec<QuantKind>> {
        s.split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(QuantKind::from_name)
            .collect()
    }

    pub fn tiers(&self) -> &[QuantKind] {
        &self.tiers
    }

    pub fn n_tiers(&self) -> usize {
        self.tiers.len()
    }

    /// Cheapest tier (fewest wire bytes) — the urgent-load encoding.
    pub fn lowest(&self) -> QuantKind {
        self.tiers[0]
    }

    /// Highest-precision tier — the prefetch/upgrade target and the
    /// "preferred" resident encoding.
    pub fn highest(&self) -> QuantKind {
        *self.tiers.last().expect("non-empty tier list")
    }

    pub fn has(&self, kind: QuantKind) -> bool {
        self.tiers.contains(&kind)
    }

    /// The next tier above `kind`, if any (the upgrade path's target
    /// chain). `None` when `kind` is already the top tier — or is not a
    /// tier at all (e.g. a legacy f32 resident in an int-only store).
    pub fn above(&self, kind: QuantKind) -> Option<QuantKind> {
        self.tiers
            .iter()
            .copied()
            .find(|t| t.bits() > kind.bits())
    }

    /// The host store holding `kind`'s encodings. Panics if `kind` is not
    /// one of the configured tiers — transfer jobs carry a tier chosen by
    /// the policy, so an unknown kind is a logic error, not bad input.
    pub fn store(&self, kind: QuantKind) -> &Arc<HostStore> {
        let i = self
            .tiers
            .iter()
            .position(|&t| t == kind)
            .unwrap_or_else(|| panic!("{} is not a configured tier", kind.name()));
        &self.stores[i]
    }

    /// The highest tier's store — what the cache planner and resident
    /// byte budgets are denominated against.
    pub fn base(&self) -> &Arc<HostStore> {
        self.stores.last().expect("non-empty tier list")
    }

    /// Wire bytes of one expert at one tier.
    pub fn expert_transfer_bytes(&self, id: ExpertId, kind: QuantKind) -> usize {
        self.store(kind).expert_transfer_bytes(id)
    }

    pub fn n_experts(&self) -> usize {
        self.stores[0].n_experts
    }

    pub fn n_layers(&self) -> usize {
        self.stores[0].n_layers
    }

    pub fn expert_bytes_f32(&self) -> usize {
        self.stores[0].expert_bytes_f32
    }
}

/// How [`crate::memory::transfer::TransferEngine::request`] picks the
/// bit-width tier a fresh transfer rides (`--precision-policy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrecisionPolicy {
    /// Every transfer rides the highest (sole) configured tier — the
    /// historical single-precision behaviour.
    Fixed,
    /// On-demand loads ride the lowest tier (fewest bytes while compute
    /// stalls); prefetches/upgrades ride a tier scaled by the caller's
    /// slack signal — slack 1.0 (pure speculation) picks the top tier,
    /// slack 0.0 (about to be needed) drops to the urgent tier.
    Urgency,
}

impl PrecisionPolicy {
    pub fn from_name(name: &str) -> Option<PrecisionPolicy> {
        match name {
            "fixed" => Some(PrecisionPolicy::Fixed),
            "urgency" => Some(PrecisionPolicy::Urgency),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PrecisionPolicy::Fixed => "fixed",
            PrecisionPolicy::Urgency => "urgency",
        }
    }

    pub fn names() -> &'static [&'static str] {
        &["fixed", "urgency"]
    }

    /// Pick the tier for a fresh transfer. `slack` ∈ [0, 1] is the
    /// caller's schedule-slack estimate (ignored by `Fixed` and by
    /// on-demand loads, which always take the cheapest encoding under
    /// `Urgency`).
    pub fn select(&self, tiers: &[QuantKind], priority: Priority, slack: f64) -> QuantKind {
        let hi = tiers.len() - 1;
        match (self, priority) {
            (PrecisionPolicy::Fixed, _) => tiers[hi],
            (PrecisionPolicy::Urgency, Priority::OnDemand) => tiers[0],
            (PrecisionPolicy::Urgency, _) => {
                let s = slack.clamp(0.0, 1.0);
                tiers[((s * hi as f64).round() as usize).min(hi)]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{micro_config, synthetic_weights};

    fn store3() -> TieredStore {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 5);
        TieredStore::build(&cfg, &w, &[QuantKind::Int8, QuantKind::Int2, QuantKind::Int4])
            .unwrap()
    }

    #[test]
    fn build_sorts_tiers_ascending_and_sizes_scale() {
        let ts = store3();
        assert_eq!(ts.tiers(), &[QuantKind::Int2, QuantKind::Int4, QuantKind::Int8]);
        assert_eq!(ts.lowest(), QuantKind::Int2);
        assert_eq!(ts.highest(), QuantKind::Int8);
        let b2 = ts.expert_transfer_bytes((0, 0), QuantKind::Int2);
        let b4 = ts.expert_transfer_bytes((0, 0), QuantKind::Int4);
        let b8 = ts.expert_transfer_bytes((0, 0), QuantKind::Int8);
        assert!(b2 < b4 && b4 < b8, "{b2} {b4} {b8}");
        assert_eq!(ts.base().kind, QuantKind::Int8);
    }

    #[test]
    fn duplicate_or_empty_tiers_rejected() {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 6);
        assert!(TieredStore::build(&cfg, &w, &[]).is_err());
        assert!(
            TieredStore::build(&cfg, &w, &[QuantKind::Int4, QuantKind::Int4]).is_err()
        );
    }

    #[test]
    fn single_wraps_shared_store() {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 7);
        let hs = Arc::new(HostStore::build(&cfg, &w, QuantKind::Int4).unwrap());
        let ts = TieredStore::single(Arc::clone(&hs));
        assert_eq!(ts.n_tiers(), 1);
        assert_eq!(ts.lowest(), QuantKind::Int4);
        assert_eq!(ts.highest(), QuantKind::Int4);
        assert!(Arc::ptr_eq(ts.store(QuantKind::Int4), &hs));
        assert_eq!(
            ts.expert_transfer_bytes((1, 2), QuantKind::Int4),
            hs.expert_transfer_bytes((1, 2))
        );
    }

    #[test]
    fn from_parts_sorts_validates_and_matches_build() {
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 9);
        let i8s = Arc::new(HostStore::build(&cfg, &w, QuantKind::Int8).unwrap());
        let i2s = Arc::new(HostStore::build(&cfg, &w, QuantKind::Int2).unwrap());
        let ts = TieredStore::from_parts(vec![Arc::clone(&i8s), Arc::clone(&i2s)]).unwrap();
        assert_eq!(ts.tiers(), &[QuantKind::Int2, QuantKind::Int8]);
        assert!(Arc::ptr_eq(ts.store(QuantKind::Int8), &i8s));
        assert!(!ts.is_remote());
        assert!(ts.remote_counters().is_none());
        assert!(TieredStore::from_parts(vec![]).is_err());
        assert!(
            TieredStore::from_parts(vec![Arc::clone(&i2s), Arc::clone(&i2s)]).is_err()
        );
    }

    #[test]
    fn above_walks_the_upgrade_chain() {
        let ts = store3();
        assert_eq!(ts.above(QuantKind::Int2), Some(QuantKind::Int4));
        assert_eq!(ts.above(QuantKind::Int4), Some(QuantKind::Int8));
        assert_eq!(ts.above(QuantKind::Int8), None);
        // a non-tier kind below the top still finds the next tier up
        let cfg = micro_config();
        let w = synthetic_weights(&cfg, 8);
        let ts2 = TieredStore::build(&cfg, &w, &[QuantKind::Int2, QuantKind::Int8]).unwrap();
        assert_eq!(ts2.above(QuantKind::Int4), Some(QuantKind::Int8));
        // legacy f32 residents are never "upgradable"
        assert_eq!(ts.above(QuantKind::F32), None);
    }

    #[test]
    fn parse_tiers_roundtrips() {
        assert_eq!(
            TieredStore::parse_tiers("int2,int4"),
            Some(vec![QuantKind::Int2, QuantKind::Int4])
        );
        assert_eq!(
            TieredStore::parse_tiers(" int8 , f32 "),
            Some(vec![QuantKind::Int8, QuantKind::F32])
        );
        assert_eq!(TieredStore::parse_tiers("int4,warp"), None);
        assert_eq!(TieredStore::parse_tiers(""), Some(Vec::new()));
    }

    #[test]
    fn policy_selects_by_urgency_and_slack() {
        let tiers = [QuantKind::Int2, QuantKind::Int4, QuantKind::Int8];
        let fixed = PrecisionPolicy::Fixed;
        let urg = PrecisionPolicy::Urgency;
        // fixed always rides the top (sole) tier
        assert_eq!(fixed.select(&tiers, Priority::OnDemand, 0.0), QuantKind::Int8);
        assert_eq!(fixed.select(&tiers, Priority::Prefetch, 1.0), QuantKind::Int8);
        // urgency: on-demand pins the cheapest encoding
        assert_eq!(urg.select(&tiers, Priority::OnDemand, 1.0), QuantKind::Int2);
        // prefetch scales with slack
        assert_eq!(urg.select(&tiers, Priority::Prefetch, 1.0), QuantKind::Int8);
        assert_eq!(urg.select(&tiers, Priority::Prefetch, 0.5), QuantKind::Int4);
        assert_eq!(urg.select(&tiers, Priority::Prefetch, 0.0), QuantKind::Int2);
        assert_eq!(urg.select(&tiers, Priority::Upgrade, 1.0), QuantKind::Int8);
        // out-of-range slack clamps
        assert_eq!(urg.select(&tiers, Priority::Prefetch, 9.0), QuantKind::Int8);
        // single tier degenerates to the constant function
        let one = [QuantKind::Int4];
        assert_eq!(urg.select(&one, Priority::OnDemand, 0.3), QuantKind::Int4);
        assert_eq!(fixed.select(&one, Priority::Prefetch, 0.3), QuantKind::Int4);
    }

    #[test]
    fn policy_names_roundtrip() {
        for name in PrecisionPolicy::names() {
            assert_eq!(PrecisionPolicy::from_name(name).unwrap().name(), *name);
        }
        assert!(PrecisionPolicy::from_name("psychic").is_none());
    }
}
