//! Host-side ("CPU memory") store of all expert weights, quantized.
//!
//! The offloading premise of the paper: every expert lives here; only a
//! bounded set is resident in [`super::device_cache::DeviceCache`] at a
//! time. The store is immutable after construction and shared by reference
//! with the transfer engine's comm thread.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::memory::quant::{QuantKind, QuantTensor};
use crate::model::config::ModelConfig;
use crate::model::weights::Weights;
use crate::model::ExpertId;
use crate::tensor::Tensor;

/// One expert's three matrices, quantized for storage/transfer.
#[derive(Clone, Debug)]
pub struct QuantExpert {
    pub w1: QuantTensor, // [d, f] flattened
    pub w3: QuantTensor, // [d, f]
    pub w2: QuantTensor, // [f, d]
    pub d: usize,
    pub f: usize,
}

impl QuantExpert {
    pub fn size_bytes(&self) -> usize {
        self.w1.size_bytes() + self.w3.size_bytes() + self.w2.size_bytes()
    }
}

/// One expert's dequantized, compute-ready f32 weights.
#[derive(Clone, Debug)]
pub struct ExpertF32 {
    pub w1: Tensor, // [d, f]
    pub w3: Tensor, // [d, f]
    pub w2: Tensor, // [f, d]
}

pub struct HostStore {
    experts: HashMap<ExpertId, QuantExpert>,
    pub kind: QuantKind,
    pub n_layers: usize,
    pub n_experts: usize,
    /// f32 expert size of this model — the platform calibration input.
    pub expert_bytes_f32: usize,
}

impl HostStore {
    /// Quantize every expert in `weights` into the store.
    pub fn build(cfg: &ModelConfig, weights: &Weights, kind: QuantKind) -> Result<HostStore> {
        let mut experts = HashMap::new();
        for l in 0..cfg.n_layers {
            for e in 0..cfg.n_experts {
                let (w1, w3, w2) = weights.expert(l, e)?;
                if w1.dims != vec![cfg.d_model, cfg.d_ff] || w2.dims != vec![cfg.d_ff, cfg.d_model]
                {
                    bail!("expert ({l},{e}) has unexpected dims {:?}/{:?}", w1.dims, w2.dims);
                }
                experts.insert(
                    (l, e),
                    QuantExpert {
                        w1: QuantTensor::quantize(&w1.data, kind),
                        w3: QuantTensor::quantize(&w3.data, kind),
                        w2: QuantTensor::quantize(&w2.data, kind),
                        d: cfg.d_model,
                        f: cfg.d_ff,
                    },
                );
            }
        }
        Ok(HostStore {
            experts,
            kind,
            n_layers: cfg.n_layers,
            n_experts: cfg.n_experts,
            expert_bytes_f32: cfg.expert_bytes_f32(),
        })
    }

    pub fn get(&self, id: ExpertId) -> &QuantExpert {
        &self.experts[&id]
    }

    /// Bytes that cross the simulated link when loading this expert.
    pub fn expert_transfer_bytes(&self, id: ExpertId) -> usize {
        self.get(id).size_bytes()
    }

    /// Full dequantization of one expert (the non-tiled transfer path).
    pub fn dequantize(&self, id: ExpertId) -> ExpertF32 {
        let q = self.get(id);
        ExpertF32 {
            w1: Tensor { dims: vec![q.d, q.f], data: q.w1.dequantize() },
            w3: Tensor { dims: vec![q.d, q.f], data: q.w3.dequantize() },
            w2: Tensor { dims: vec![q.f, q.d], data: q.w2.dequantize() },
        }
    }

    /// Dequantize the f-tile [f_start, f_end) of one expert — the tile-wise
    /// transfer unit of §5/Fig. 6. Row-major layouts make w1/w3 tiles
    /// column slices and the w2 tile a row slice.
    pub fn dequantize_tile(&self, id: ExpertId, f_start: usize, f_end: usize) -> ExpertF32 {
        let q = self.get(id);
        let (d, f) = (q.d, q.f);
        assert!(f_end <= f && f_start < f_end);
        let w = f_end - f_start;
        // w1/w3 are [d, f]: tile is strided. Decode the covering range once,
        // then gather the columns.
        let mut full1 = vec![0f32; d * f];
        let mut full3 = vec![0f32; d * f];
        q.w1.dequantize_range(0, d * f, &mut full1);
        q.w3.dequantize_range(0, d * f, &mut full3);
        let mut t1 = Vec::with_capacity(d * w);
        let mut t3 = Vec::with_capacity(d * w);
        for r in 0..d {
            t1.extend_from_slice(&full1[r * f + f_start..r * f + f_end]);
            t3.extend_from_slice(&full3[r * f + f_start..r * f + f_end]);
        }
        // w2 is [f, d]: tile rows are contiguous.
        let mut full2 = vec![0f32; f * d];
        q.w2.dequantize_range(f_start * d, f_end * d, &mut full2);
        let t2 = full2[f_start * d..f_end * d].to_vec();
        ExpertF32 {
            w1: Tensor { dims: vec![d, w], data: t1 },
            w3: Tensor { dims: vec![d, w], data: t3 },
            w2: Tensor { dims: vec![w, d], data: t2 },
        }
    }

    pub fn total_experts(&self) -> usize {
        self.n_layers * self.n_experts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{micro_config as test_config, synthetic_weights as fake_weights};

    #[test]
    fn build_and_sizes() {
        let cfg = test_config();
        let w = fake_weights(&cfg, 1);
        let hs = HostStore::build(&cfg, &w, QuantKind::Int4).unwrap();
        assert_eq!(hs.total_experts(), cfg.total_experts());
        let b = hs.expert_transfer_bytes((0, 0));
        // int4 ≈ f32/8 plus block params
        assert!(b < cfg.expert_bytes_f32() / 6, "b={b}");
    }

    #[test]
    fn f32_roundtrip_exact() {
        let cfg = test_config();
        let w = fake_weights(&cfg, 2);
        let hs = HostStore::build(&cfg, &w, QuantKind::F32).unwrap();
        let d = hs.dequantize((1, 3));
        assert_eq!(&d.w1.data, &w.get("l1.e3.w1").unwrap().data);
        assert_eq!(&d.w2.data, &w.get("l1.e3.w2").unwrap().data);
    }

    #[test]
    fn tiles_reassemble_to_full() {
        let cfg = test_config();
        let w = fake_weights(&cfg, 3);
        let hs = HostStore::build(&cfg, &w, QuantKind::Int8).unwrap();
        let full = hs.dequantize((0, 1));
        let n_tiles = 4;
        let step = cfg.d_ff / n_tiles;
        let mut w1 = vec![0f32; cfg.d_model * cfg.d_ff];
        let mut w2 = vec![0f32; cfg.d_ff * cfg.d_model];
        for t in 0..n_tiles {
            let tile = hs.dequantize_tile((0, 1), t * step, (t + 1) * step);
            for r in 0..cfg.d_model {
                w1[r * cfg.d_ff + t * step..r * cfg.d_ff + (t + 1) * step]
                    .copy_from_slice(&tile.w1.data[r * step..(r + 1) * step]);
            }
            w2[t * step * cfg.d_model..(t + 1) * step * cfg.d_model]
                .copy_from_slice(&tile.w2.data);
        }
        assert_eq!(w1, full.w1.data);
        assert_eq!(w2, full.w2.data);
    }

    #[test]
    fn quant_error_bounded() {
        let cfg = test_config();
        let w = fake_weights(&cfg, 4);
        let hs = HostStore::build(&cfg, &w, QuantKind::Int8).unwrap();
        let deq = hs.dequantize((0, 0));
        let orig = w.get("l0.e0.w1").unwrap();
        let max_err = deq
            .w1
            .data
            .iter()
            .zip(&orig.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 0.002, "max_err={max_err}");
    }

    #[test]
    fn missing_expert_fails_build() {
        let cfg = test_config();
        let mut w = fake_weights(&cfg, 5);
        w.tensors.remove("l0.e0.w1");
        assert!(HostStore::build(&cfg, &w, QuantKind::Int4).is_err());
    }
}
